"""Kernel-vs-oracle correctness: the CORE numeric signal for Layer 1.

Every Pallas kernel must match its pure-jnp reference to f32 tolerance,
including under hypothesis-driven shape/value sweeps and at padding
boundaries (mask rows must contribute exactly 0).
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - environment-dependent
    HAVE_HYPOTHESIS = False

from compile.kernels import (
    gauss_ar1_ratio_pallas,
    logistic_loglik_pallas,
    logistic_predict_pallas,
    logistic_ratio_pallas,
)
from compile.kernels import ref

RTOL = 1e-5
ATOL = 1e-5


def _rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


def _logistic_inputs(seed, m, d, n_pad=0):
    k = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(k[0], m, d)
    t = jnp.sign(_rand(k[1], m)).astype(jnp.float32)
    t = jnp.where(t == 0, 1.0, t)
    mask = jnp.ones((m,), jnp.float32)
    if n_pad:
        mask = mask.at[m - n_pad :].set(0.0)
    w_old = _rand(k[2], d)
    w_new = _rand(k[3], d)
    return x, t, mask, w_old, w_new


@pytest.mark.parametrize("m,d", [(16, 3), (64, 50), (128, 50), (256, 2), (100, 7), (1024, 50)])
def test_logistic_ratio_matches_ref(m, d):
    x, t, mask, w_old, w_new = _logistic_inputs(0, m, d)
    got = logistic_ratio_pallas(x, t, mask, w_old, w_new)
    want = ref.logistic_ratio_ref(x, t, mask, w_old, w_new)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("m,d,n_pad", [(128, 50, 28), (16, 3, 15), (64, 2, 1)])
def test_logistic_ratio_padding_rows_are_zero(m, d, n_pad):
    x, t, mask, w_old, w_new = _logistic_inputs(1, m, d, n_pad)
    got = np.asarray(logistic_ratio_pallas(x, t, mask, w_old, w_new))
    assert np.all(got[m - n_pad :] == 0.0)
    want = ref.logistic_ratio_ref(x, t, mask, w_old, w_new)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_logistic_ratio_identity_weights_is_zero():
    x, t, mask, w, _ = _logistic_inputs(2, 64, 5)
    got = np.asarray(logistic_ratio_pallas(x, t, mask, w, w))
    np.testing.assert_allclose(got, np.zeros(64), atol=1e-6)


def test_logistic_ratio_extreme_logits_stable():
    # Saturated logits must not produce inf/nan (log-sigmoid stability).
    m, d = 16, 4
    x = jnp.full((m, d), 100.0, jnp.float32)
    t = jnp.ones((m,), jnp.float32)
    mask = jnp.ones((m,), jnp.float32)
    w_old = jnp.full((d,), -10.0, jnp.float32)
    w_new = jnp.full((d,), 10.0, jnp.float32)
    got = np.asarray(logistic_ratio_pallas(x, t, mask, w_old, w_new))
    assert np.all(np.isfinite(got))
    want = np.asarray(ref.logistic_ratio_ref(x, t, mask, w_old, w_new))
    np.testing.assert_allclose(got, want, rtol=1e-4)


@pytest.mark.parametrize("m,d", [(16, 3), (128, 50), (100, 2)])
def test_logistic_loglik_matches_ref(m, d):
    x, t, mask, w, _ = _logistic_inputs(3, m, d)
    got = logistic_loglik_pallas(x, t, mask, w)
    want = ref.logistic_loglik_ref(x, t, mask, w)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_loglik_consistent_with_ratio():
    # ratio == loglik(new) - loglik(old), elementwise.
    x, t, mask, w_old, w_new = _logistic_inputs(4, 128, 10)
    r = logistic_ratio_pallas(x, t, mask, w_old, w_new)
    l_new = logistic_loglik_pallas(x, t, mask, w_new)
    l_old = logistic_loglik_pallas(x, t, mask, w_old)
    np.testing.assert_allclose(r, l_new - l_old, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("m,d", [(256, 50), (1024, 3), (256, 2)])
def test_logistic_predict_matches_ref(m, d):
    x, _, _, w, _ = _logistic_inputs(5, m, d)
    got = logistic_predict_pallas(x, w)
    want = ref.logistic_predict_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)
    g = np.asarray(got)
    assert np.all((g >= 0.0) & (g <= 1.0))


@pytest.mark.parametrize("m", [16, 64, 128, 100, 256])
def test_ar1_ratio_matches_ref(m):
    k = jax.random.split(jax.random.PRNGKey(6), 3)
    h_prev = _rand(k[0], m)
    h = _rand(k[1], m)
    mask = jnp.ones((m,), jnp.float32)
    params = jnp.array([0.95, 0.1, 0.90, 0.15], jnp.float32)
    got = gauss_ar1_ratio_pallas(h_prev, h, mask, params)
    want = ref.gauss_ar1_ratio_ref(h_prev, h, mask, params)
    np.testing.assert_allclose(got, want, rtol=RTOL, atol=ATOL)


def test_ar1_ratio_same_params_zero():
    m = 64
    k = jax.random.split(jax.random.PRNGKey(7), 2)
    h_prev, h = _rand(k[0], m), _rand(k[1], m)
    mask = jnp.ones((m,), jnp.float32)
    params = jnp.array([0.9, 0.2, 0.9, 0.2], jnp.float32)
    got = np.asarray(gauss_ar1_ratio_pallas(h_prev, h, mask, params))
    np.testing.assert_allclose(got, np.zeros(m), atol=1e-6)


def test_ar1_ratio_known_value():
    # Hand-computed single element.
    h_prev = jnp.array([1.0], jnp.float32)
    h = jnp.array([0.5], jnp.float32)
    mask = jnp.ones((1,), jnp.float32)
    phi0, s0, phi1, s1 = 0.95, 0.1, 0.5, 0.2

    def lp(x, mean, sig):
        return -0.5 * ((x - mean) / sig) ** 2 - math.log(sig) - 0.5 * math.log(2 * math.pi)

    want = lp(0.5, phi1 * 1.0, s1) - lp(0.5, phi0 * 1.0, s0)
    params = jnp.array([phi0, s0, phi1, s1], jnp.float32)
    got = float(gauss_ar1_ratio_pallas(h_prev, h, mask, params)[0])
    assert abs(got - want) < 1e-4


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 32, 64, 100, 128]),
        d=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**16),
        scale=st.floats(min_value=0.01, max_value=10.0),
    )
    def test_hypothesis_logistic_ratio(m, d, seed, scale):
        x, t, mask, w_old, w_new = _logistic_inputs(seed, m, d)
        x = x * scale
        got = logistic_ratio_pallas(x, t, mask, w_old, w_new)
        want = ref.logistic_ratio_ref(x, t, mask, w_old, w_new)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=st.sampled_from([8, 16, 64, 100, 128]),
        seed=st.integers(min_value=0, max_value=2**16),
        phi=st.floats(min_value=-0.999, max_value=0.999),
        sig=st.floats(min_value=0.01, max_value=5.0),
    )
    def test_hypothesis_ar1_ratio(m, seed, phi, sig):
        k = jax.random.split(jax.random.PRNGKey(seed), 2)
        h_prev, h = _rand(k[0], m), _rand(k[1], m)
        mask = jnp.ones((m,), jnp.float32)
        params = jnp.array([phi, sig, -phi, sig * 2.0], jnp.float32)
        got = gauss_ar1_ratio_pallas(h_prev, h, mask, params)
        want = ref.gauss_ar1_ratio_ref(h_prev, h, mask, params)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
