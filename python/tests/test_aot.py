"""AOT pipeline tests: lowering produces loadable HLO text with the right
entry signature, and the catalog covers every kernel kind the Rust
runtime expects."""

import json
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model

EXPECTED_KINDS = {
    "logistic_ratio",
    "logistic_loglik",
    "logistic_predict",
    "gauss_ar1_ratio",
}


def test_catalog_covers_all_kinds():
    kinds = {kind for _, kind, _, _, _ in aot.build_catalog()}
    assert kinds == EXPECTED_KINDS


def test_catalog_names_unique():
    names = [name for name, *_ in aot.build_catalog()]
    assert len(names) == len(set(names))


def test_catalog_includes_paper_minibatch_cover():
    # Paper uses m=100 minibatches on D=50 MNIST features: the ladder must
    # contain a variant with m >= 100 at d=50.
    ms = [
        meta["m"]
        for _, kind, _, _, meta in aot.build_catalog()
        if kind == "logistic_ratio" and meta["d"] == 50
    ]
    assert any(m >= 100 for m in ms)
    assert min(ms) <= 16  # small tail batches don't pay for a 1024 pad


def test_hlo_text_entry_signature():
    spec = jax.ShapeDtypeStruct((16, 3), jnp.float32)
    vec = jax.ShapeDtypeStruct((16,), jnp.float32)
    w = jax.ShapeDtypeStruct((3,), jnp.float32)
    text = aot.to_hlo_text(model.logistic_ratio, (spec, vec, vec, w, w))
    assert text.startswith("HloModule")
    assert "f32[16,3]" in text
    # return_tuple=True => entry computation returns a 1-tuple
    assert "->(f32[16]" in text.replace(" ", "")


def test_hlo_text_is_deterministic():
    spec = jax.ShapeDtypeStruct((16,), jnp.float32)
    p = jax.ShapeDtypeStruct((4,), jnp.float32)
    a = aot.to_hlo_text(model.gauss_ar1_ratio, (spec, spec, spec, p))
    b = aot.to_hlo_text(model.gauss_ar1_ratio, (spec, spec, spec, p))
    assert a == b


@pytest.mark.slow
def test_aot_main_writes_manifest(tmp_path):
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(out),
            "--only",
            "logistic_ratio_m16_d3,gauss_ar1_ratio_m16",
        ],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        check=True,
        env=env,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    names = {a["name"] for a in manifest["artifacts"]}
    assert "logistic_ratio_m16_d3" in names
    assert "gauss_ar1_ratio_m16" in names
    for a in manifest["artifacts"]:
        assert (out / a["path"]).exists()
        assert (out / a["path"]).read_text().startswith("HloModule")
