"""Pallas kernel for stochastic-volatility local sections.

A local section of the SV scaffold when sampling phi (or sigma) is
``{(* phi h_{t-1}) (deterministic), h_t (absorbing Gaussian)}`` — the
AR(1) transition density (paper §4.3, Fig. 9a).  Its contribution to the
log-acceptance ratio is

    l_t = log N(h_t | phi' h_{t-1}, sig'^2) - log N(h_t | phi h_{t-1}, sig^2)

Unlike the austerity setting, these "data items" are *latent* states with
chain dependencies; subsampling them is only valid at the scaffold level
(paper §3.2 Remark), which is exactly what the Rust coordinator does —
the kernel just scores whatever mini-batch of (h_{t-1}, h_t) pairs it is
handed.

Params are packed as (4,) = [phi_old, sig_old, phi_new, sig_new] so the
artifact has a single scalar-parameter input.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_HALF_LOG_2PI = 0.5 * math.log(2.0 * math.pi)


def _gauss_logpdf(x, mean, sig):
    z = (x - mean) / sig
    return -0.5 * z * z - jnp.log(sig) - _HALF_LOG_2PI


def _ar1_ratio_kernel(hprev_ref, h_ref, mask_ref, params_ref, out_ref):
    hprev = hprev_ref[...]    # (bm,)
    h = h_ref[...]            # (bm,)
    mask = mask_ref[...]      # (bm,)
    p = params_ref[...]       # (4,) [phi_old, sig_old, phi_new, sig_new]
    lp_old = _gauss_logpdf(h, p[0] * hprev, p[1])
    lp_new = _gauss_logpdf(h, p[2] * hprev, p[3])
    out_ref[...] = mask * (lp_new - lp_old)


def _block_m(m):
    if m % 128 == 0:
        return 128
    if m % 64 == 0:
        return 64
    return m


@functools.partial(jax.jit, static_argnames=())
def gauss_ar1_ratio_pallas(h_prev, h, mask, params):
    """Masked AR(1) transition log-density ratios.

    Args:
      h_prev: (m,) f32 parent states h_{t-1}.
      h:      (m,) f32 child states h_t.
      mask:   (m,) f32 1.0 live / 0.0 padding.
      params: (4,) f32 [phi_old, sig_old, phi_new, sig_new].
    Returns:
      (m,) f32 masked ratios l_t.
    """
    (m,) = h.shape
    bm = _block_m(m)
    vec = pl.BlockSpec((bm,), lambda i: (i,))
    return pl.pallas_call(
        _ar1_ratio_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=(m // bm,),
        in_specs=[vec, vec, vec, pl.BlockSpec((4,), lambda i: (0,))],
        out_specs=vec,
        interpret=True,
    )(h_prev, h, mask, params)
