"""Pallas kernels for Bayesian logistic regression local sections.

A *local section* of the BayesLR scaffold (paper Fig. 2) is
``{linear_logistic_i (deterministic), y_i (absorbing Bernoulli)}``; its
contribution to the MH log-acceptance ratio is

    l_i = log sigma(t_i * x_i . w_new) - log sigma(t_i * x_i . w_old)

with t_i = 2*y_i - 1 in {-1, +1}.  The subsampled-MH hot loop needs this
for a mini-batch of m sampled sections at a time, so the kernel is a
fused  (m,D)x(D) -> (m)  contraction + log-sigmoid epilogue.

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch dimension is the
grid; each grid step stages one (bm, D) tile of X plus the two weight
vectors into VMEM, performs the contraction in f32 (MXU-eligible layout:
contraction dim is the minor axis of X), and writes only the bm-vector of
ratios back to HBM.  VMEM footprint per step ~= 4*(bm*D + 2D + 3*bm) bytes
(~28 KiB at bm=128, D=50), far under the ~16 MiB VMEM budget, so a single
pass over HBM is the schedule.  ``interpret=True`` is mandatory for the
CPU PJRT client (real TPU lowering emits a Mosaic custom-call).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _log_sigmoid(z):
    """Numerically stable log(sigmoid(z)) = -softplus(-z)."""
    return jnp.where(z >= 0.0, -jnp.log1p(jnp.exp(-z)), z - jnp.log1p(jnp.exp(z)))


def _ratio_kernel(x_ref, t_ref, mask_ref, w_old_ref, w_new_ref, out_ref):
    """One grid step: ratios for a (bm, D) tile of the mini-batch."""
    x = x_ref[...]            # (bm, D) f32, staged in VMEM
    t = t_ref[...]            # (bm,)   f32 in {-1, +1}
    mask = mask_ref[...]      # (bm,)   f32 in {0, 1} (padding mask)
    w_old = w_old_ref[...]    # (D,)
    w_new = w_new_ref[...]    # (D,)
    # Contractions share the staged x tile; f32 accumulate.
    z_old = t * jnp.dot(x, w_old, preferred_element_type=jnp.float32)
    z_new = t * jnp.dot(x, w_new, preferred_element_type=jnp.float32)
    out_ref[...] = mask * (_log_sigmoid(z_new) - _log_sigmoid(z_old))


def _loglik_kernel(x_ref, t_ref, mask_ref, w_ref, out_ref):
    x = x_ref[...]
    t = t_ref[...]
    mask = mask_ref[...]
    w = w_ref[...]
    z = t * jnp.dot(x, w, preferred_element_type=jnp.float32)
    out_ref[...] = mask * _log_sigmoid(z)


def _predict_kernel(x_ref, w_ref, out_ref):
    x = x_ref[...]
    w = w_ref[...]
    z = jnp.dot(x, w, preferred_element_type=jnp.float32)
    out_ref[...] = jax.nn.sigmoid(z)


def _block_m(m):
    """Batch tile size: whole mini-batch if small, else 128-row tiles."""
    if m % 128 == 0:
        return 128
    if m % 64 == 0:
        return 64
    return m  # small/odd batches: single tile


def _vec_spec(bm):
    return pl.BlockSpec((bm,), lambda i: (i,))


def _full_vec_spec(d):
    return pl.BlockSpec((d,), lambda i: (0,))


@functools.partial(jax.jit, static_argnames=())
def logistic_ratio_pallas(x, t, mask, w_old, w_new):
    """Mini-batch log-likelihood ratios l_i (masked).

    Args:
      x:     (m, D) f32 feature rows of the sampled local sections.
      t:     (m,)   f32 labels in {-1, +1}.
      mask:  (m,)   f32 1.0 for live rows, 0.0 for padding.
      w_old: (D,)   f32 current weights.
      w_new: (D,)   f32 proposed weights.
    Returns:
      (m,) f32 with l_i = mask_i * (log sig(t_i x_i.w_new) - log sig(t_i x_i.w_old)).
    """
    m, d = x.shape
    bm = _block_m(m)
    return pl.pallas_call(
        _ratio_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            _vec_spec(bm),
            _vec_spec(bm),
            _full_vec_spec(d),
            _full_vec_spec(d),
        ],
        out_specs=_vec_spec(bm),
        interpret=True,
    )(x, t, mask, w_old, w_new)


@functools.partial(jax.jit, static_argnames=())
def logistic_loglik_pallas(x, t, mask, w):
    """Masked per-row log-likelihoods log sigma(t_i x_i.w)."""
    m, d = x.shape
    bm = _block_m(m)
    return pl.pallas_call(
        _loglik_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            _vec_spec(bm),
            _vec_spec(bm),
            _full_vec_spec(d),
        ],
        out_specs=_vec_spec(bm),
        interpret=True,
    )(x, t, mask, w)


@functools.partial(jax.jit, static_argnames=())
def logistic_predict_pallas(x, w):
    """Predictive probabilities sigma(x_i.w) for a test block."""
    m, d = x.shape
    bm = _block_m(m)
    return pl.pallas_call(
        _predict_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.float32),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            _full_vec_spec(d),
        ],
        out_specs=_vec_spec(bm),
        interpret=True,
    )(x, w)
