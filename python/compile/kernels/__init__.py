"""Layer-1 Pallas kernels for subppl.

Each kernel is the per-element hot spot of one mini-batch likelihood
(-ratio) evaluation that the Rust coordinator dispatches during a
subsampled-MH transition (Alg. 3 of the paper).  All kernels are lowered
with ``interpret=True`` so the emitted HLO runs on the CPU PJRT client;
the BlockSpec structure is written for TPU VMEM tiling regardless (see
DESIGN.md §Hardware-Adaptation).
"""

from .logistic import (
    logistic_ratio_pallas,
    logistic_loglik_pallas,
    logistic_predict_pallas,
)
from .gauss_ar1 import gauss_ar1_ratio_pallas

__all__ = [
    "logistic_ratio_pallas",
    "logistic_loglik_pallas",
    "logistic_predict_pallas",
    "gauss_ar1_ratio_pallas",
]
