"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package must match its oracle to float32 tolerance
across the pytest/hypothesis shape sweep in python/tests/.
"""

import math

import jax
import jax.numpy as jnp

_HALF_LOG_2PI = 0.5 * math.log(2.0 * math.pi)


def log_sigmoid_ref(z):
    return -jnp.logaddexp(0.0, -z)


def logistic_ratio_ref(x, t, mask, w_old, w_new):
    z_old = t * (x @ w_old)
    z_new = t * (x @ w_new)
    return mask * (log_sigmoid_ref(z_new) - log_sigmoid_ref(z_old))


def logistic_loglik_ref(x, t, mask, w):
    return mask * log_sigmoid_ref(t * (x @ w))


def logistic_predict_ref(x, w):
    return jax.nn.sigmoid(x @ w)


def gauss_logpdf_ref(x, mean, sig):
    z = (x - mean) / sig
    return -0.5 * z * z - jnp.log(sig) - _HALF_LOG_2PI


def gauss_ar1_ratio_ref(h_prev, h, mask, params):
    lp_old = gauss_logpdf_ref(h, params[0] * h_prev, params[1])
    lp_new = gauss_logpdf_ref(h, params[2] * h_prev, params[3])
    return mask * (lp_new - lp_old)
