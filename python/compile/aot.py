"""AOT pipeline: lower every Layer-2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts are shape-monomorphic, so we emit a small ladder of mini-batch
sizes per kernel; the Rust runtime picks the smallest fitting variant and
masks the padding rows.  A ``manifest.json`` indexes every artifact with
its kind, shapes and input signature so the Rust side never hard-codes
paths.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Mini-batch ladders.  m=100 is the paper's mini-batch; the runtime pads
# 100 -> 128.  Large variants serve the exact-MH full-scoring path and the
# test-set predictive sweep.
RATIO_MS = [16, 64, 128, 256, 1024]
PREDICT_MS = [256, 1024, 4096]
AR1_MS = [16, 64, 128, 256, 1024]
# Feature dims: 3 = synthetic 2-feature + bias (Fig. 5); 50 = MNIST-like
# PCA surrogate (Fig. 4); 2 = JointDPM synthetic 2-d experts (Fig. 6).
DS = [2, 3, 50]

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def to_hlo_text(fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_catalog():
    """(name, kind, fn, example_args, meta) for every artifact."""
    catalog = []
    for d in DS:
        for m in RATIO_MS:
            catalog.append(
                (
                    f"logistic_ratio_m{m}_d{d}",
                    "logistic_ratio",
                    model.logistic_ratio,
                    (_spec(m, d), _spec(m), _spec(m), _spec(d), _spec(d)),
                    {"m": m, "d": d},
                )
            )
            catalog.append(
                (
                    f"logistic_loglik_m{m}_d{d}",
                    "logistic_loglik",
                    model.logistic_loglik,
                    (_spec(m, d), _spec(m), _spec(m), _spec(d)),
                    {"m": m, "d": d},
                )
            )
        for m in PREDICT_MS:
            catalog.append(
                (
                    f"logistic_predict_m{m}_d{d}",
                    "logistic_predict",
                    model.logistic_predict,
                    (_spec(m, d), _spec(d)),
                    {"m": m, "d": d},
                )
            )
    for m in AR1_MS:
        catalog.append(
            (
                f"gauss_ar1_ratio_m{m}",
                "gauss_ar1_ratio",
                model.gauss_ar1_ratio,
                (_spec(m), _spec(m), _spec(m), _spec(4)),
                {"m": m, "d": 0},
            )
        )
    return catalog


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", default=None, help="comma-separated artifact-name filter (substring match)"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    filters = args.only.split(",") if args.only else None
    manifest = {"format": 1, "artifacts": []}
    for name, kind, fn, example_args, meta in build_catalog():
        if filters and not any(f in name for f in filters):
            continue
        text = to_hlo_text(fn, example_args)
        rel = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, rel), "w") as f:
            f.write(text)
        manifest["artifacts"].append(
            {
                "name": name,
                "kind": kind,
                "path": rel,
                "m": meta["m"],
                "d": meta["d"],
                "inputs": [list(a.shape) for a in example_args],
                "dtype": "f32",
            }
        )
        print(f"  wrote {rel} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # TSV twin for the dependency-free Rust loader
    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tkind\tpath\tm\td\n")
        for a in manifest["artifacts"]:
            f.write(f"{a['name']}\t{a['kind']}\t{a['path']}\t{a['m']}\t{a['d']}\n")
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
