"""Layer-2 JAX entry points that the AOT pipeline lowers to HLO text.

Each function here is a *batched likelihood(-ratio) graph* — the piece of
the paper's models (Table 1) that a mini-batch of scaffold local sections
reduces to.  They call the Layer-1 Pallas kernels so both layers lower
into the same HLO module; the Rust coordinator (Layer 3) loads the
resulting artifacts and feeds them mini-batches on the transition hot
path.  Python never runs at inference time.

All entry points return 1-tuples: the AOT recipe lowers with
``return_tuple=True`` and the Rust side unwraps with ``to_tuple1()``.
"""

from .kernels import (
    gauss_ar1_ratio_pallas,
    logistic_loglik_pallas,
    logistic_predict_pallas,
    logistic_ratio_pallas,
)


def logistic_ratio(x, t, mask, w_old, w_new):
    """Per-section log-likelihood ratios for BayesLR / JointDPM weights.

    This is the l_i of Eq. 6 for the logistic local-section family; the
    sequential test (Alg. 2) consumes the individual entries, so the
    vector is returned unreduced.
    """
    return (logistic_ratio_pallas(x, t, mask, w_old, w_new),)


def logistic_loglik(x, t, mask, w):
    """Per-section log-likelihoods (exact-MH full scoring path)."""
    return (logistic_loglik_pallas(x, t, mask, w),)


def logistic_predict(x, w):
    """Predictive probabilities for the risk metric (Fig. 4)."""
    return (logistic_predict_pallas(x, w),)


def gauss_ar1_ratio(h_prev, h, mask, params):
    """Per-section AR(1) transition ratios for the SV model (Fig. 9)."""
    return (gauss_ar1_ratio_pallas(h_prev, h, mask, params),)
