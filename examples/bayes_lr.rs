//! End-to-end driver (§4.1): Bayesian logistic regression on the
//! MNIST-7-vs-9 surrogate (12214 x 50 by default), comparing standard MH
//! against sublinear subsampled MH through the *full stack* — the Rust
//! PPL engine dispatching mini-batch likelihood ratios to the
//! AOT-compiled JAX/Pallas kernel via XLA/PJRT when `--fused` is given.
//!
//! Reports risk-of-predictive-mean vs wall clock (Fig. 4) and the §3.3
//! normality safeguard, and writes results/fig4_risk.csv.
//!
//! Run: `cargo run --release --example bayes_lr -- [--fast] [--fused] [--safeguard]`

use subppl::coordinator::experiments::{fig4_csv, fig4_risk, Fig4Config};
use subppl::coordinator::report::{results_dir, Table};
use subppl::coordinator::FusedEval;
use subppl::infer::{InterpreterEval, LocalEvaluator};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let fused = args.iter().any(|a| a == "--fused");
    let cfg = if fast {
        Fig4Config {
            n_train: 2000,
            n_test: 500,
            steps: 120,
            record_every: 10,
            ..Default::default()
        }
    } else {
        Fig4Config::default()
    };
    println!(
        "BayesLR end-to-end: N={} D={} steps={} m={} (evaluator: {})",
        cfg.n_train,
        cfg.d,
        cfg.steps,
        cfg.m,
        if fused { "xla-fused" } else { "interpreter" }
    );
    let mut evaluator: Box<dyn LocalEvaluator> = if fused {
        match FusedEval::open_default() {
            Ok(f) => Box::new(f),
            Err(e) => {
                eprintln!("fused unavailable ({e}); using interpreter");
                Box::new(InterpreterEval)
            }
        }
    } else {
        Box::new(InterpreterEval)
    };

    let curves = fig4_risk(&cfg, evaluator.as_mut());

    let mut t = Table::new(&[
        "method",
        "transitions",
        "accept%",
        "seconds",
        "final risk",
        "final 0-1 err",
        "JB p (safeguard)",
    ]);
    for c in &curves {
        let last = c.points.last().copied().unwrap_or((0.0, f64::NAN, f64::NAN));
        t.row(&[
            c.label.clone(),
            c.transitions.to_string(),
            format!("{:.1}", 100.0 * c.accepted as f64 / c.transitions as f64),
            format!("{:.2}", last.0),
            format!("{:.6}", last.1),
            format!("{:.4}", last.2),
            format!("{:.3}", c.normality_p),
        ]);
    }
    t.print();

    // loss-curve shape check (the paper's headline): subsampled reaches
    // low risk in less wall-clock than exact
    let exact = &curves[0];
    let sub = curves.iter().find(|c| c.label.contains("0.01")).unwrap();
    let exact_final_risk = exact.points.last().unwrap().1;
    let t_exact = exact.points.last().unwrap().0;
    let t_sub_reaching = sub
        .points
        .iter()
        .find(|(_, r, _)| *r <= exact_final_risk)
        .map(|(s, _, _)| *s);
    match t_sub_reaching {
        Some(ts) => println!(
            "\nsubsampled (eps=0.01) reached exact-MH's final risk in {ts:.2}s vs {t_exact:.2}s ({:.1}x speedup)",
            t_exact / ts
        ),
        None => println!(
            "\nsubsampled did not reach exact-MH's final risk within the budget (risks: {} vs {exact_final_risk})",
            sub.points.last().unwrap().1
        ),
    }

    let out = results_dir().join("fig4_risk.csv");
    fig4_csv(&curves).write_to(&out).expect("write csv");
    println!("wrote {}", out.display());

    if args.iter().any(|a| a == "--safeguard") {
        println!("\n§3.3 safeguard: Jarque-Bera p-values above (p > 0.01 means the CLT assumption of the sequential test is plausible on this model).");
    }
}
