//! Stochastic volatility (§4.3, Fig. 9): joint state + parameter
//! estimation with particle Gibbs over the latent log-volatility chains
//! and (subsampled) MH over (phi, sigma).  The local sections here are
//! latent AR(1) transitions with chain dependence — exactly the case
//! where edge subsampling goes beyond iid-data austerity (paper §3.2
//! Remark).
//!
//! Run: `cargo run --release --example stochastic_volatility -- [--fast]`

use subppl::coordinator::experiments::{fig9_csv, fig9_sv, Fig9Config};
use subppl::coordinator::report::{results_dir, Table};
use subppl::stats::RunningMoments;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let cfg = if fast {
        Fig9Config {
            series: 30,
            sweeps: 80,
            ..Default::default()
        }
    } else {
        Fig9Config::default()
    };
    println!(
        "SV: {} series of length {} (truth: phi=0.95 sigma=0.1), {} sweeps, eps={}",
        cfg.series, cfg.len, cfg.sweeps, cfg.eps
    );

    let exact = fig9_sv(&cfg, false);
    let sub = fig9_sv(&cfg, true);

    let mut t = Table::new(&[
        "method",
        "seconds",
        "phi mean±std",
        "sigma mean±std",
        "phi ESS/s",
        "sigma ESS/s",
    ]);
    for r in [&exact, &sub] {
        let mut pm = RunningMoments::new();
        let mut sm = RunningMoments::new();
        let burn = r.phi_samples.len() / 5;
        for &v in &r.phi_samples[burn..] {
            pm.push(v);
        }
        for &v in &r.sig_samples[burn..] {
            sm.push(v);
        }
        t.row(&[
            r.label.clone(),
            format!("{:.2}", r.seconds),
            format!("{:.3}±{:.3}", pm.mean(), pm.std()),
            format!("{:.3}±{:.3}", sm.mean(), sm.std()),
            format!("{:.3}", r.phi_ess_per_sec),
            format!("{:.3}", r.sig_ess_per_sec),
        ]);
    }
    t.print();
    println!(
        "\nESS/s gain of subsampled over exact: phi {:.2}x, sigma {:.2}x",
        sub.phi_ess_per_sec / exact.phi_ess_per_sec,
        sub.sig_ess_per_sec / exact.sig_ess_per_sec
    );

    let (hist, acf) = fig9_csv(&[exact, sub], 30);
    let dir = results_dir();
    hist.write_to(&dir.join("fig9_hist.csv")).expect("write");
    acf.write_to(&dir.join("fig9_acf.csv")).expect("write");
    println!("wrote {} and fig9_acf.csv", dir.join("fig9_hist.csv").display());
}
