//! Experiment harness: regenerate any (or all) of the paper's tables and
//! figures from one binary.
//!
//! Run: `cargo run --release --example harness -- [table1|fig4|fig5|fig6|fig9|all] [--fast] [--fused]`

use subppl::coordinator::experiments as exp;
use subppl::coordinator::report::{results_dir, Table};
use subppl::coordinator::FusedEval;
use subppl::infer::{InterpreterEval, LocalEvaluator};

fn evaluator(fused: bool) -> Box<dyn LocalEvaluator> {
    if fused {
        if let Ok(f) = FusedEval::open_default() {
            return Box::new(f);
        }
        eprintln!("fused evaluator unavailable; using interpreter");
    }
    Box::new(InterpreterEval)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let fused = args.iter().any(|a| a == "--fused");
    let which = args
        .iter()
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_else(|| "all".to_string());
    let all = which == "all";
    let dir = results_dir();

    if all || which == "table1" {
        println!("\n================ Table 1: exact-MH scaling ================");
        let rows = exp::table1_scaling(3);
        let mut t = Table::new(&["model", "N_small", "N_large", "t_small", "t_large", "exponent"]);
        for r in &rows {
            t.row(&[
                r.model.clone(),
                r.n_small.to_string(),
                r.n_large.to_string(),
                format!("{:.5}s", r.t_small),
                format!("{:.5}s", r.t_large),
                format!("{:.2}", r.exponent),
            ]);
        }
        t.print();
        println!("(paper: all three scale linearly; exponent ~1.0)");
    }

    if all || which == "fig5" {
        println!("\n================ Fig. 5: sublinearity ================");
        let cfg = if fast {
            exp::Fig5Config {
                ns: vec![1_000, 3_000, 10_000, 30_000],
                iters: 30,
                ..Default::default()
            }
        } else {
            exp::Fig5Config::default()
        };
        let mut ev = evaluator(fused);
        let rows = exp::fig5_sublinear(&cfg, ev.as_mut());
        let mut t = Table::new(&["N", "sections/iter", "E[sections]", "t_sub", "t_exact", "speedup"]);
        for r in &rows {
            t.row(&[
                r.n.to_string(),
                format!("{:.1}", r.avg_sections),
                format!("{:.1}", r.expected_sections),
                format!("{:.5}s", r.time_sub),
                format!("{:.5}s", r.time_exact),
                format!("{:.1}x", r.time_exact / r.time_sub),
            ]);
        }
        t.print();
        // fit the scaling exponent of sections vs N in log-log
        if rows.len() >= 2 {
            let (a, b) = (rows.first().unwrap(), rows.last().unwrap());
            let expo = (b.avg_sections / a.avg_sections).ln() / (b.n as f64 / a.n as f64).ln();
            println!("sections-vs-N exponent: {expo:.2} (1.0 = linear; paper: sublinear, near-flat)");
        }
        exp::fig5_csv(&rows)
            .write_to(&dir.join("fig5_sublinear.csv"))
            .unwrap();
    }

    if all || which == "fig4" {
        println!("\n================ Fig. 4: BayesLR risk vs time ================");
        let cfg = if fast {
            exp::Fig4Config {
                n_train: 2000,
                n_test: 500,
                steps: 120,
                record_every: 10,
                ..Default::default()
            }
        } else {
            exp::Fig4Config::default()
        };
        let mut ev = evaluator(fused);
        let curves = exp::fig4_risk(&cfg, ev.as_mut());
        let mut t = Table::new(&["method", "seconds", "final risk", "final 0-1", "JB p"]);
        for c in &curves {
            let last = c.points.last().copied().unwrap_or((0.0, f64::NAN, f64::NAN));
            t.row(&[
                c.label.clone(),
                format!("{:.2}", last.0),
                format!("{:.6}", last.1),
                format!("{:.4}", last.2),
                format!("{:.3}", c.normality_p),
            ]);
        }
        t.print();
        exp::fig4_csv(&curves).write_to(&dir.join("fig4_risk.csv")).unwrap();
    }

    if all || which == "fig6" {
        println!("\n================ Fig. 6: JointDPM accuracy vs time ================");
        let cfg = if fast {
            exp::Fig6Config {
                n_train: 300,
                n_test: 150,
                sweeps: 10,
                step_z: 30,
                ..Default::default()
            }
        } else {
            exp::Fig6Config::default()
        };
        let mut t = Table::new(&["method", "final seconds", "final accuracy", "clusters"]);
        for (label, sub) in [("exact-mh", false), ("subsampled-eps0.3", true)] {
            let pts = exp::fig6_dpm(&cfg, sub);
            let last = pts.last().unwrap();
            t.row(&[
                label.to_string(),
                format!("{:.2}", last.seconds),
                format!("{:.4}", last.accuracy),
                last.clusters.to_string(),
            ]);
        }
        t.print();
    }

    if all || which == "fig9" {
        println!("\n================ Fig. 9: stochastic volatility ================");
        let cfg = if fast {
            exp::Fig9Config {
                series: 30,
                sweeps: 60,
                ..Default::default()
            }
        } else {
            exp::Fig9Config::default()
        };
        let exact = exp::fig9_sv(&cfg, false);
        let sub = exp::fig9_sv(&cfg, true);
        let mut t = Table::new(&["method", "seconds", "phi ESS/s", "sig ESS/s"]);
        for r in [&exact, &sub] {
            t.row(&[
                r.label.clone(),
                format!("{:.2}", r.seconds),
                format!("{:.3}", r.phi_ess_per_sec),
                format!("{:.3}", r.sig_ess_per_sec),
            ]);
        }
        t.print();
        let (hist, acf) = exp::fig9_csv(&[exact, sub], 30);
        hist.write_to(&dir.join("fig9_hist.csv")).unwrap();
        acf.write_to(&dir.join("fig9_acf.csv")).unwrap();
    }

    println!("\nCSV series written under {}", dir.display());
}
