//! Quickstart: the paper's Fig. 1 program.
//!
//! Builds the PET, prints it (nodes, kinds, edges), shows the scaffold
//! of `b` (Fig. 1's colored nodes), then runs MH and reports the
//! posterior over the branch variable.
//!
//! Run with: `cargo run --release --example quickstart`

use subppl::coordinator::experiments::describe_pet;
use subppl::infer::{mh_transition, Proposal};
use subppl::math::Pcg64;
use subppl::trace::scaffold::build_scaffold;
use subppl::trace::{NodeKind, Trace};

fn main() {
    let src = r#"
        [assume b (bernoulli 0.5)]
        [assume mu (if b 1 (gamma 1 1))]
        [assume y (normal mu 0.1)]
        [observe y 10.0]
    "#;
    let mut trace = Trace::new();
    let mut rng = Pcg64::seeded(42);
    trace.run_program(src, &mut rng).expect("program runs");

    println!("=== probabilistic execution trace (Fig. 1) ===");
    print!("{}", describe_pet(&trace));

    let b = trace.lookup_node("b").unwrap();
    let scaffold = build_scaffold(&trace, b);
    println!("\n=== scaffold of b (colored nodes in Fig. 1) ===");
    println!("D (target set):    {:?}", scaffold.drg);
    println!("A (absorbing set): {:?}", scaffold.absorbing);
    println!("(T is discovered during regen: flipping b swaps the if-branch)");

    println!("\n=== inference: 10000 MH transitions on b and mu ===");
    let mut b_true = 0usize;
    let total = 10_000;
    for _ in 0..total {
        mh_transition(&mut trace, &mut rng, b, &Proposal::PriorResim).unwrap();
        // also move the gamma inside the branch when it exists
        let mu = trace.lookup_node("mu").unwrap();
        if let NodeKind::If { branch, .. } = &trace.node(mu).kind {
            if let Some(g) = branch.node() {
                mh_transition(&mut trace, &mut rng, g, &Proposal::Drift(0.5)).unwrap();
            }
        }
        if trace.value(b).as_bool().unwrap() {
            b_true += 1;
        }
    }
    println!(
        "posterior P(b = true | y = 10) ~= {:.4}   (y=10 is 90 sigma from mu=1, so ~0)",
        b_true as f64 / total as f64
    );
    println!(
        "final state: b={}, mu={:.3}, log joint={:.3}",
        trace.lookup_value("b").unwrap(),
        trace.lookup_value("mu").unwrap().as_f64().unwrap(),
        trace.log_joint()
    );
}
