//! JointDPM (§4.2, Fig. 6): nonlinear classification with a Dirichlet
//! process mixture of logistic experts — CRP + collapsed NIW feature
//! models + per-cluster weights, inferred with the paper's program:
//!
//! ```text
//! (cycle ((mh alpha all 1)
//!         (gibbs z one step_z)
//!         (subsampled_mh w one Nbatch eps drift sigma 1)) T)
//! ```
//!
//! Run: `cargo run --release --example joint_dpm -- [--fast] [--exact]`

use subppl::coordinator::experiments::{fig6_dpm, Fig6Config};
use subppl::coordinator::report::{results_dir, Csv, Table};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let fast = args.iter().any(|a| a == "--fast");
    let cfg = if fast {
        Fig6Config {
            n_train: 300,
            n_test: 150,
            sweeps: 12,
            step_z: 40,
            ..Default::default()
        }
    } else {
        Fig6Config::default()
    };
    println!(
        "JointDPM: N={} (test {}), {} sweeps, step_z={}, eps={}",
        cfg.n_train, cfg.n_test, cfg.sweeps, cfg.step_z, cfg.eps
    );

    let mut csv = Csv::new(&["method", "sweep", "seconds", "accuracy", "clusters"]);
    let mut table = Table::new(&["method", "final seconds", "final accuracy", "clusters"]);
    let methods: Vec<(&str, bool)> = if args.iter().any(|a| a == "--exact") {
        vec![("exact-mh", false)]
    } else {
        vec![("exact-mh", false), ("subsampled", true)]
    };
    for (label, sub) in methods {
        let pts = fig6_dpm(&cfg, sub);
        for (i, p) in pts.iter().enumerate() {
            csv.row(&[
                label.to_string(),
                i.to_string(),
                format!("{:.3}", p.seconds),
                format!("{:.4}", p.accuracy),
                p.clusters.to_string(),
            ]);
        }
        let last = pts.last().unwrap();
        table.row(&[
            label.to_string(),
            format!("{:.2}", last.seconds),
            format!("{:.4}", last.accuracy),
            last.clusters.to_string(),
        ]);
        println!(
            "{label}: accuracy trajectory {:?}",
            pts.iter()
                .map(|p| (p.accuracy * 100.0).round() / 100.0)
                .collect::<Vec<_>>()
        );
    }
    table.print();
    let out = results_dir().join("fig6_dpm.csv");
    csv.write_to(&out).expect("write csv");
    println!("wrote {}", out.display());
}
