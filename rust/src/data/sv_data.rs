//! Stochastic-volatility synthetic series (§4.3): x_t = exp(h_t/2) eps_t,
//! h_t ~ N(phi h_{t-1}, sigma^2), h_0 = 0.  The paper uses 200 series of
//! length 5 with phi = 0.95, sigma = 0.1.

use crate::math::Pcg64;

#[derive(Clone, Debug)]
pub struct SvSeries {
    pub x: Vec<f64>,
    /// Ground-truth latent states (for diagnostics only).
    pub h: Vec<f64>,
}

#[derive(Clone, Debug)]
pub struct SvConfig {
    pub phi: f64,
    pub sigma: f64,
    pub series: usize,
    pub len: usize,
}

impl Default for SvConfig {
    fn default() -> Self {
        SvConfig {
            phi: 0.95,
            sigma: 0.1,
            series: 200,
            len: 5,
        }
    }
}

/// Generate the dataset: `series` independent chains of length `len`.
pub fn generate(cfg: &SvConfig, seed: u64) -> Vec<SvSeries> {
    let mut rng = Pcg64::new(seed, 401);
    (0..cfg.series)
        .map(|_| {
            let mut h_prev = 0.0;
            let mut h = Vec::with_capacity(cfg.len);
            let mut x = Vec::with_capacity(cfg.len);
            for _ in 0..cfg.len {
                let ht = cfg.phi * h_prev + cfg.sigma * rng.normal();
                x.push((ht / 2.0).exp() * rng.normal());
                h.push(ht);
                h_prev = ht;
            }
            SvSeries { x, h }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_sizes() {
        let data = generate(&SvConfig::default(), 0);
        assert_eq!(data.len(), 200);
        assert!(data.iter().all(|s| s.x.len() == 5 && s.h.len() == 5));
    }

    #[test]
    fn latent_states_follow_ar1() {
        let cfg = SvConfig {
            series: 1,
            len: 50_000,
            ..SvConfig::default()
        };
        let data = generate(&cfg, 1);
        let h = &data[0].h;
        // lag-1 autocorrelation of h should be ~phi
        let n = h.len();
        let mean = h.iter().sum::<f64>() / n as f64;
        let c0: f64 = h.iter().map(|v| (v - mean).powi(2)).sum();
        let c1: f64 = (0..n - 1).map(|i| (h[i] - mean) * (h[i + 1] - mean)).sum();
        let rho = c1 / c0;
        assert!((rho - 0.95).abs() < 0.02, "rho={rho}");
    }

    #[test]
    fn deterministic() {
        let a = generate(&SvConfig::default(), 5);
        let b = generate(&SvConfig::default(), 5);
        assert_eq!(a[0].x, b[0].x);
    }
}
