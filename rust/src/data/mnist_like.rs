//! MNIST-7-vs-9 surrogate (§4.1 substitution, DESIGN.md).
//!
//! The paper trains on 12214 images of '7'/'9' reduced to 50 PCA
//! components.  We reproduce the *statistical shape* of that problem —
//! two anisotropic 50-D class clouds whose leading components carry most
//! of the class signal and whose overlap yields a few-percent Bayes
//! error — with a deterministic generator.  The experiment (risk of the
//! predictive mean vs compute) depends on N, D and the likelihood
//! geometry, all of which are preserved.

use crate::data::Dataset;
use crate::math::Pcg64;

pub const TRAIN_N: usize = 12214;
pub const TEST_N: usize = 2037;
pub const DIM: usize = 50;

/// PCA-like spectrum: variance of component k decays as 1/(k+1), mimicking
/// the long-tailed spectrum of image PCA.
fn component_scale(k: usize) -> f64 {
    (2.0 / (k as f64 + 1.0)).sqrt()
}

/// Class-mean separation concentrated in the leading components.
fn class_mean(k: usize, label: bool) -> f64 {
    let sign = if label { 1.0 } else { -1.0 };
    // strong signal in first ~8 components, fading after
    sign * 1.2 / (1.0 + k as f64 / 4.0)
}

fn gen(n: usize, d: usize, seed: u64, stream: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, stream);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let label = i % 2 == 0; // balanced like 7s vs 9s (roughly)
        let row: Vec<f64> = (0..d)
            .map(|k| class_mean(k, label) + component_scale(k) * rng.normal())
            .collect();
        x.push(row);
        y.push(label);
    }
    Dataset { x, y }
}

/// The training split (N = 12214, D = 50 by default).
pub fn train(seed: u64) -> Dataset {
    gen(TRAIN_N, DIM, seed, 201)
}

/// The test split (N = 2037).
pub fn test(seed: u64) -> Dataset {
    gen(TEST_N, DIM, seed, 202)
}

/// Arbitrary-size variant for scaling studies.
pub fn sized(n: usize, d: usize, seed: u64) -> Dataset {
    gen(n, d, seed, 203)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_split_sizes() {
        let tr = train(0);
        let te = test(0);
        assert_eq!(tr.n(), 12214);
        assert_eq!(te.n(), 2037);
        assert_eq!(tr.d(), 50);
    }

    #[test]
    fn problem_is_learnable_but_not_trivial() {
        let tr = sized(4000, 50, 1);
        // linear classifier along the mean-difference direction
        let correct = tr
            .x
            .iter()
            .zip(&tr.y)
            .filter(|(x, &y)| {
                let score: f64 = (0..50).map(|k| x[k] * class_mean(k, true)).sum();
                (score > 0.0) == y
            })
            .count();
        let acc = correct as f64 / 4000.0;
        assert!(acc > 0.93, "too hard: {acc}");
        assert!(acc < 0.9999, "too easy: {acc}");
    }

    #[test]
    fn spectrum_decays() {
        let tr = sized(5000, 50, 2);
        let var = |k: usize| {
            let m: f64 = tr.x.iter().map(|r| r[k]).sum::<f64>() / tr.n() as f64;
            tr.x.iter().map(|r| (r[k] - m).powi(2)).sum::<f64>() / tr.n() as f64
        };
        assert!(var(0) > 3.0 * var(20));
    }
}
