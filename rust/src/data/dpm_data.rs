//! Fig. 6b synthetic mixture-of-experts data for the JointDPM
//! experiment: K Gaussian clusters in 2-D, each with its own linear
//! decision boundary for the binary label.

use crate::data::Dataset;
use crate::math::Pcg64;

/// Cluster definition: feature Gaussian + logistic expert weights.
#[derive(Clone, Debug)]
pub struct Cluster {
    pub mean: [f64; 2],
    pub std: f64,
    /// logits = w . [x0, x1] + b
    pub w: [f64; 2],
    pub b: f64,
}

/// The ground-truth generative configuration (6 clusters, as found by
/// the paper's run in Fig. 6c).
pub fn default_clusters() -> Vec<Cluster> {
    vec![
        Cluster { mean: [-3.0, 2.5], std: 0.7, w: [2.5, 0.0], b: 0.0 },
        Cluster { mean: [0.0, 3.0], std: 0.6, w: [0.0, 3.0], b: -9.0 },
        Cluster { mean: [3.0, 2.5], std: 0.7, w: [-2.0, 2.0], b: 1.0 },
        Cluster { mean: [-2.5, -2.5], std: 0.8, w: [0.0, -2.5], b: -6.0 },
        Cluster { mean: [0.5, -3.0], std: 0.6, w: [3.0, 1.0], b: 1.0 },
        Cluster { mean: [3.0, -2.0], std: 0.7, w: [1.5, -1.5], b: -7.0 },
    ]
}

/// Sample n points from the mixture of experts.
pub fn generate(n: usize, seed: u64) -> (Dataset, Vec<usize>) {
    let clusters = default_clusters();
    let mut rng = Pcg64::new(seed, 301);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    let mut z = Vec::with_capacity(n);
    for _ in 0..n {
        let k = rng.below(clusters.len());
        let c = &clusters[k];
        let p = [
            c.mean[0] + c.std * rng.normal(),
            c.mean[1] + c.std * rng.normal(),
        ];
        let logit = c.w[0] * p[0] + c.w[1] * p[1] + c.b;
        let prob = 1.0 / (1.0 + (-logit).exp());
        x.push(vec![p[0], p[1]]);
        y.push(rng.bernoulli(prob));
        z.push(k);
    }
    (Dataset { x, y }, z)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generates_requested_size() {
        let (d, z) = generate(500, 0);
        assert_eq!(d.n(), 500);
        assert_eq!(d.d(), 2);
        assert_eq!(z.len(), 500);
    }

    #[test]
    fn all_clusters_used() {
        let (_, z) = generate(2000, 1);
        let mut seen = [false; 6];
        for &k in &z {
            seen[k] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn per_cluster_experts_beat_global_chance() {
        // within each cluster the expert boundary must be informative
        let (d, z) = generate(6000, 2);
        let clusters = default_clusters();
        for (k, c) in clusters.iter().enumerate() {
            let pts: Vec<usize> = (0..d.n()).filter(|&i| z[i] == k).collect();
            let correct = pts
                .iter()
                .filter(|&&i| {
                    let logit = c.w[0] * d.x[i][0] + c.w[1] * d.x[i][1] + c.b;
                    (logit > 0.0) == d.y[i]
                })
                .count();
            let acc = correct as f64 / pts.len() as f64;
            assert!(acc > 0.7, "cluster {k} expert acc {acc}");
        }
    }
}
