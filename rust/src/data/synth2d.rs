//! Fig. 5a synthetic logistic-regression data: two 2-D Gaussian blobs
//! with a bias column appended (d = 3), deterministic given a seed, used
//! for the sublinearity experiment where N is swept over decades.

use crate::data::Dataset;
use crate::math::Pcg64;

/// Generate `n` points: class 0 ~ N([-1,-1], 0.5 I), class 1 ~
/// N([+1,+1], 0.5 I), balanced, with a constant 1.0 bias feature.
pub fn generate(n: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 101);
    let mut x = Vec::with_capacity(n);
    let mut y = Vec::with_capacity(n);
    let s = 0.5f64.sqrt();
    for i in 0..n {
        let label = i % 2 == 0;
        let c = if label { 1.0 } else { -1.0 };
        x.push(vec![
            c + s * rng.normal(),
            c + s * rng.normal(),
            1.0, // bias
        ]);
        y.push(label);
    }
    Dataset { x, y }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_balance() {
        let d = generate(1000, 7);
        assert_eq!(d.n(), 1000);
        assert_eq!(d.d(), 3);
        assert!((d.positive_rate() - 0.5).abs() < 0.01);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate(100, 1);
        let b = generate(100, 1);
        assert_eq!(a.x, b.x);
        let c = generate(100, 2);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_are_separable_by_true_boundary() {
        // w = [1, 1, 0] should classify most points correctly
        let d = generate(2000, 3);
        let correct = d
            .x
            .iter()
            .zip(&d.y)
            .filter(|(x, &y)| (x[0] + x[1] > 0.0) == y)
            .count();
        assert!(correct as f64 / 2000.0 > 0.9);
    }
}
