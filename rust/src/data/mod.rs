//! Synthetic data generators for the paper's experiments.
//!
//! The MNIST 7-vs-9 PCA features used in §4.1 are not available in this
//! environment; `mnist_like` generates a surrogate with matched size,
//! dimensionality and class overlap (see DESIGN.md §Substitutions).

pub mod dpm_data;
pub mod mnist_like;
pub mod sv_data;
pub mod synth2d;

/// A binary-classification dataset with dense feature rows.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Row-major features, shape (n, d).
    pub x: Vec<Vec<f64>>,
    /// Labels.
    pub y: Vec<bool>,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.len()
    }

    pub fn d(&self) -> usize {
        self.x.first().map_or(0, |r| r.len())
    }

    /// Fraction of positive labels.
    pub fn positive_rate(&self) -> f64 {
        self.y.iter().filter(|&&b| b).count() as f64 / self.y.len().max(1) as f64
    }
}
