//! # subppl — sublinear-time approximate MCMC transitions for probabilistic programs
//!
//! A from-scratch Rust reproduction of Chen, Mansinghka & Ghahramani
//! (2014): a Venture-style probabilistic programming engine whose
//! Metropolis–Hastings transitions for globally-coupled latent variables
//! run in time *sublinear* in the number of dependent observations, by
//! subsampling *local sections* of the transition's scaffold on the
//! probabilistic execution trace (PET) and deciding accept/reject with a
//! sequential Student-t test.
//!
//! Architecture (see DESIGN.md):
//! * **L3 (this crate)** — language, PET, scaffolds, inference kernels,
//!   experiment coordination. Owns the transition hot path.
//! * **L2/L1 (python/, build-time only)** — JAX + Pallas mini-batch
//!   likelihood kernels, AOT-lowered to HLO text in `artifacts/`.
//! * **runtime/** — loads the artifacts through XLA/PJRT (`xla` crate)
//!   and serves batched log-likelihood-ratio evaluations to the
//!   subsampled-MH hot loop.

pub mod coordinator;
pub mod data;
pub mod dist;
pub mod infer;
pub mod math;
pub mod ppl;
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod trace;

pub use ppl::parser::{parse_program, parse_value};
pub use ppl::value::Value;
pub use trace::Trace;
