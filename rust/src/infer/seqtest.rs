//! Sequential test for the MH decision (paper Alg. 2).
//!
//! Given mu_0 and a stream of subsampled l_i's (drawn without
//! replacement), incrementally test H1: mu > mu_0 vs H2: mu < mu_0 with
//! a Student-t test whose standard error carries the finite-population
//! correction sqrt(1 - (n-1)/(N-1)).  Stops when the p-value falls below
//! epsilon, or when the whole population has been consumed (then the
//! comparison is exact).

use crate::math::special::student_t_sf;
use crate::stats::RunningMoments;

/// Outcome of feeding one mini-batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TestState {
    /// Draw another mini-batch.
    NeedMore,
    /// Confident (or exhausted): accept H1 (mu > mu_0) => accept move.
    Decided(bool),
}

/// Incremental state of one sequential test.
#[derive(Clone, Debug)]
pub struct SequentialTest {
    mu0: f64,
    n_total: usize,
    eps: f64,
    moments: RunningMoments,
}

impl SequentialTest {
    pub fn new(mu0: f64, n_total: usize, eps: f64) -> Self {
        assert!(n_total > 0);
        assert!(eps > 0.0 && eps < 1.0);
        SequentialTest {
            mu0,
            n_total,
            eps,
            moments: RunningMoments::new(),
        }
    }

    /// Number of l_i consumed so far.
    pub fn n(&self) -> usize {
        self.moments.n()
    }

    pub fn mean(&self) -> f64 {
        self.moments.mean()
    }

    /// The threshold the stream is being tested against.
    pub fn mu0(&self) -> f64 {
        self.mu0
    }

    /// Sample standard deviation of the l_i consumed so far.
    pub fn std(&self) -> f64 {
        self.moments.std()
    }

    /// Risk actually incurred by the decision at the current state: the
    /// p-value of the t-test (probability that the sign of
    /// `mean - mu0` is wrong).  Zero once the population is exhausted
    /// (the comparison is exact) or when every l_i seen was identical.
    pub fn realized_risk(&self) -> f64 {
        let n = self.moments.n();
        if n >= self.n_total || n < 2 {
            return 0.0;
        }
        let s_l = self.moments.std();
        if s_l == 0.0 {
            return 0.0;
        }
        let fpc = (1.0 - (n as f64 - 1.0) / (self.n_total as f64 - 1.0)).max(0.0);
        let s = s_l / (n as f64).sqrt() * fpc.sqrt();
        let t = (self.moments.mean() - self.mu0).abs() / s;
        student_t_sf(t, (n - 1) as f64)
    }

    /// Feed one mini-batch of l_i values; returns the updated state.
    pub fn update(&mut self, batch: &[f64]) -> TestState {
        for &l in batch {
            self.moments.push(l);
        }
        let n = self.moments.n();
        assert!(n <= self.n_total, "consumed more than the population");
        let mu_hat = self.moments.mean();
        if n == self.n_total {
            // whole population seen: mu is exact
            return TestState::Decided(mu_hat > self.mu0);
        }
        let s_l = self.moments.std();
        if s_l == 0.0 {
            // all values equal so far: no basis for a t-test; keep
            // drawing (guards the all-equal early-iteration false stop)
            return TestState::NeedMore;
        }
        // finite population correction (sampling w/o replacement)
        let fpc = (1.0 - (n as f64 - 1.0) / (self.n_total as f64 - 1.0)).max(0.0);
        let s = s_l / (n as f64).sqrt() * fpc.sqrt();
        let t = (mu_hat - self.mu0).abs() / s;
        let p = student_t_sf(t, (n - 1) as f64);
        if p < self.eps {
            TestState::Decided(mu_hat > self.mu0)
        } else {
            TestState::NeedMore
        }
    }
}

/// Run the full sequential test over a population with a supplied
/// without-replacement sampler; returns (accept, n_consumed).
/// `draw` must return the l value of the idx'th distinct element.
pub fn run_sequential_test(
    mu0: f64,
    n_total: usize,
    batch: usize,
    eps: f64,
    mut next_index: impl FnMut() -> usize,
    mut draw: impl FnMut(usize) -> f64,
) -> (bool, usize) {
    let mut test = SequentialTest::new(mu0, n_total, eps);
    let mut buf = Vec::with_capacity(batch);
    loop {
        buf.clear();
        let take = batch.min(n_total - test.n());
        for _ in 0..take {
            buf.push(draw(next_index()));
        }
        match test.update(&buf) {
            TestState::NeedMore => continue,
            TestState::Decided(acc) => return (acc, test.n()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Pcg64;

    fn population(n: usize, mean: f64, std: f64, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seeded(seed);
        (0..n).map(|_| mean + std * rng.normal()).collect()
    }

    fn run_on(pop: &[f64], mu0: f64, m: usize, eps: f64, seed: u64) -> (bool, usize) {
        let mut rng = Pcg64::seeded(seed);
        let order = rng.sample_without_replacement(pop.len(), pop.len());
        let mut it = order.into_iter();
        run_sequential_test(
            mu0,
            pop.len(),
            m,
            eps,
            move || it.next().unwrap(),
            |i| pop[i],
        )
    }

    #[test]
    fn clear_accept_uses_few_samples() {
        let pop = population(100_000, 1.0, 0.5, 1);
        let (acc, n) = run_on(&pop, 0.0, 100, 0.01, 2);
        assert!(acc);
        assert!(n <= 300, "consumed {n} of 100k for an easy decision");
    }

    #[test]
    fn clear_reject_uses_few_samples() {
        let pop = population(100_000, -1.0, 0.5, 3);
        let (acc, n) = run_on(&pop, 0.0, 100, 0.01, 4);
        assert!(!acc);
        assert!(n <= 300);
    }

    #[test]
    fn borderline_consumes_more() {
        // mean barely above mu0 relative to noise: needs more data
        let pop = population(50_000, 0.004, 1.0, 5);
        let (_, n_hard) = run_on(&pop, 0.0, 100, 0.01, 6);
        let easy = population(50_000, 1.0, 1.0, 7);
        let (_, n_easy) = run_on(&easy, 0.0, 100, 0.01, 8);
        assert!(n_hard > 4 * n_easy, "hard {n_hard} vs easy {n_easy}");
    }

    #[test]
    fn exhaustion_gives_exact_decision() {
        // tiny population, huge variance: test can't conclude early, and
        // the final decision must equal the exact comparison
        let pop = vec![10.0, -9.0, 8.5, -8.0, 0.6];
        let mu = pop.iter().sum::<f64>() / 5.0;
        for seed in 0..20 {
            let (acc, n) = run_on(&pop, 0.0, 2, 0.0001, seed);
            assert_eq!(acc, mu > 0.0);
            assert_eq!(n, 5);
        }
    }

    #[test]
    fn all_equal_values_never_false_stop() {
        // s_l = 0 branch: must keep drawing to exhaustion
        let pop = vec![0.5; 64];
        let (acc, n) = run_on(&pop, 0.3, 8, 0.01, 9);
        assert!(acc);
        assert_eq!(n, 64, "should have consumed everything");
    }

    #[test]
    fn decision_error_rate_shrinks_with_eps() {
        // population mean slightly above mu0; count wrong decisions
        let pop = population(20_000, 0.05, 1.0, 10);
        let mu = pop.iter().sum::<f64>() / pop.len() as f64;
        let truth = mu > 0.0;
        let mut wrong_loose = 0;
        let mut wrong_tight = 0;
        for seed in 0..60 {
            let (a, _) = run_on(&pop, 0.0, 100, 0.2, 100 + seed);
            if a != truth {
                wrong_loose += 1;
            }
            let (a, _) = run_on(&pop, 0.0, 100, 0.001, 100 + seed);
            if a != truth {
                wrong_tight += 1;
            }
        }
        assert!(
            wrong_tight <= wrong_loose,
            "tight eps must not err more: {wrong_tight} vs {wrong_loose}"
        );
    }

    #[test]
    fn infinite_mu0_short_circuits_sensibly() {
        // mu0 = +inf => H2 (reject) regardless; the caller short-circuits
        // but the test itself must also survive it
        let pop = population(1000, 0.0, 1.0, 11);
        let (acc, _) = run_on(&pop, f64::INFINITY, 100, 0.01, 12);
        assert!(!acc);
    }
}
