//! Particle Gibbs (conditional SMC) over a chain of latent states.
//!
//! `(pgibbs h (ordered_range a b) P 1)` in the paper's SV program: the
//! states `h_a..h_b` (addressed by scope blocks) are re-sampled jointly
//! with a conditional particle filter that keeps the current trajectory
//! as the reference particle.  Proposals are the states' own transition
//! priors (read generically off the trace via override evaluation);
//! weights are the observation likelihoods hanging off each state, plus
//! the boundary transition into the first state *after* the block.

use crate::infer::mh::TransitionStats;
use crate::math::Pcg64;
use crate::ppl::value::Value;
use crate::trace::node::{NodeId, NodeKind};
use crate::trace::partition::OverrideCtx;
use crate::trace::pet::Trace;
use std::collections::HashSet;

/// Per-step structure of the chain discovered from the trace.
#[derive(Debug)]
struct Step {
    /// The latent state node h_t.
    state: NodeId,
    /// The previous state node (None at the left boundary / h_0 static).
    prev: Option<NodeId>,
    /// Observed stochastic nodes depending on h_t (not through h_{t+1}).
    obs: Vec<NodeId>,
}

/// Discover the chain steps for the given scope blocks (must each hold
/// exactly one principal state node).
fn discover_chain(trace: &Trace, scope: &str, blocks: &[Value]) -> Result<Vec<Step>, String> {
    let sc = trace
        .scope(scope)
        .ok_or_else(|| format!("pgibbs: unknown scope {scope}"))?;
    let states: Vec<NodeId> = blocks
        .iter()
        .map(|b| {
            let ns = sc.block_nodes(b);
            match ns {
                [n] => Ok(*n),
                [] => Err(format!("pgibbs: empty block {b}")),
                _ => Err(format!("pgibbs: block {b} has {} nodes", ns.len())),
            }
        })
        .collect::<Result<_, _>>()?;
    let state_set: HashSet<NodeId> = trace.scope_nodes(scope).into_iter().collect();
    let mut steps = Vec::with_capacity(states.len());
    for (i, &h) in states.iter().enumerate() {
        // previous state: a scope member among h's ancestors through dets
        let mut prev = None;
        let mut stack: Vec<NodeId> = trace.node(h).dyn_parents();
        while let Some(p) = stack.pop() {
            if state_set.contains(&p) {
                prev = Some(p);
                break;
            }
            if trace.node(p).is_deterministic() {
                stack.extend(trace.node(p).dyn_parents());
            }
        }
        if i > 0 && prev != Some(states[i - 1]) {
            return Err("pgibbs: blocks are not a contiguous chain".into());
        }
        // observations: stochastic descendants through dets, excluding
        // other chain states
        let mut obs = Vec::new();
        let mut stack = vec![h];
        let mut seen = HashSet::new();
        while let Some(n) = stack.pop() {
            for &c in &trace.node(n).children {
                if !seen.insert(c) {
                    continue;
                }
                if state_set.contains(&c) {
                    continue; // the next chain state: boundary handling
                }
                if trace.node(c).is_stochastic() {
                    if trace.node(c).observed {
                        obs.push(c);
                    }
                } else {
                    stack.push(c);
                }
            }
        }
        steps.push(Step {
            state: h,
            prev,
            obs,
        });
    }
    Ok(steps)
}

/// The chain state *after* the last block, if any (its transition density
/// conditions the final weights).
fn next_state_after(trace: &Trace, scope: &str, last: NodeId) -> Option<NodeId> {
    let state_set: HashSet<NodeId> = trace.scope_nodes(scope).into_iter().collect();
    let mut stack = vec![last];
    let mut seen = HashSet::new();
    while let Some(n) = stack.pop() {
        for &c in &trace.node(n).children {
            if !seen.insert(c) {
                continue;
            }
            if state_set.contains(&c) {
                return Some(c);
            }
            if trace.node(c).is_deterministic() {
                stack.push(c);
            }
        }
    }
    None
}

/// Sample a state's transition prior with its previous state pinned.
fn sample_transition(
    trace: &Trace,
    state: NodeId,
    prev: Option<(NodeId, f64)>,
    rng: &mut Pcg64,
) -> Result<f64, String> {
    let mut ctx = OverrideCtx::new(trace);
    if let Some((p, val)) = prev {
        ctx.pin(p, Value::Real(val));
    }
    let node = trace.node(state);
    let args: Vec<Value> = node.args.iter().map(|a| ctx.arg_candidate(a)).collect();
    match &node.kind {
        NodeKind::StochFam(f) => f
            .sample(rng, &args)?
            .as_f64()
            .ok_or_else(|| "pgibbs: state must be real".into()),
        k => Err(format!("pgibbs: state node must be a family SP, got {k:?}")),
    }
}

/// log p(node's committed value | pins).
fn logpdf_with_pins(trace: &Trace, node: NodeId, pins: &[(NodeId, f64)]) -> f64 {
    let mut ctx = OverrideCtx::new(trace);
    for &(n, v) in pins {
        ctx.pin(n, Value::Real(v));
    }
    ctx.logpdf_candidate(node)
}

/// One conditional-SMC sweep over `blocks` of scope `scope`.
pub fn pgibbs_transition(
    trace: &mut Trace,
    rng: &mut Pcg64,
    scope: &str,
    blocks: &[Value],
    particles: usize,
) -> Result<TransitionStats, String> {
    assert!(particles >= 2, "pgibbs needs >= 2 particles");
    let steps = discover_chain(trace, scope, blocks)?;
    if steps.is_empty() {
        return Ok(TransitionStats::default());
    }
    // freshen everything we read
    let ids: Vec<NodeId> = steps.iter().map(|s| s.state).collect();
    for &h in &ids {
        trace.fresh_value(h);
        for p in trace.node(h).dyn_parents() {
            trace.fresh_value(p);
        }
        let kids = trace.node(h).children.clone();
        for k in kids {
            trace.fresh_value(k);
        }
    }
    let boundary = next_state_after(trace, scope, *ids.last().unwrap());
    let reference: Vec<f64> = ids
        .iter()
        .map(|&h| trace.node(h).value.as_f64().expect("state must be real"))
        .collect();

    let l = steps.len();
    let p = particles;
    let mut x = vec![vec![0.0f64; p]; l];
    let mut logw = vec![vec![0.0f64; p]; l];
    let mut anc = vec![vec![0usize; p]; l];

    for t in 0..l {
        let step = &steps[t];
        for i in 0..p {
            if i == 0 {
                // reference particle follows the current trajectory
                x[t][0] = reference[t];
                anc[t][0] = 0;
            } else {
                let a = if t == 0 {
                    i // no resampling at t=0 (ancestors are themselves)
                } else {
                    rng.categorical_log(&logw[t - 1])
                };
                anc[t][i] = a;
                let prev_val = if t == 0 {
                    None
                } else {
                    step.prev.map(|pn| (pn, x[t - 1][a]))
                };
                x[t][i] = sample_transition(trace, step.state, prev_val, rng)?;
            }
            // observation weight
            let mut w = 0.0;
            for &o in &step.obs {
                w += logpdf_with_pins(trace, o, &[(step.state, x[t][i])]);
            }
            // boundary weight on the last step
            if t == l - 1 {
                if let Some(b) = boundary {
                    w += logpdf_with_pins(trace, b, &[(step.state, x[t][i])]);
                }
            }
            logw[t][i] = w;
        }
    }

    // select a trajectory and trace back ancestors
    let mut idx = rng.categorical_log(&logw[l - 1]);
    let mut traj = vec![0.0f64; l];
    for t in (0..l).rev() {
        traj[t] = x[t][idx];
        idx = anc[t][idx];
    }
    // commit: write states, eagerly recompute their det children
    for (t, &h) in ids.iter().enumerate() {
        trace.set_value(h, Value::Real(traj[t]));
        trace.propagate_det(h);
    }
    Ok(TransitionStats {
        accepted: true,
        scaffold_size: l * p,
        sections_evaluated: l,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::RunningMoments;

    fn sv_src(xs: &[f64], phi: f64, sig: f64) -> String {
        let mut src = format!(
            "[assume phi {phi}]\n[assume sig {sig}]\n\
             [assume h (mem (lambda (t) (scope_include 'h t \
              (if (<= t 0) 0.0 (normal (* phi (h (- t 1))) sig)))))]\n\
             [assume x (lambda (t) (normal 0 (exp (/ (h t) 2))))]\n"
        );
        for (i, v) in xs.iter().enumerate() {
            src.push_str(&format!("[observe (x {}) {v}]\n", i + 1));
        }
        src
    }

    fn setup(src: &str, seed: u64) -> (Trace, Pcg64) {
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(src, &mut rng).unwrap();
        (t, rng)
    }

    #[test]
    fn chain_discovery() {
        let (t, _) = setup(&sv_src(&[0.1, -0.2, 0.3], 0.9, 0.2), 1);
        let blocks: Vec<Value> = (1..=3).map(Value::Int).collect();
        let steps = discover_chain(&t, "h", &blocks).unwrap();
        assert_eq!(steps.len(), 3);
        assert!(steps[0].prev.is_none()); // h_0 is static 0.0
        assert_eq!(steps[1].prev, Some(steps[0].state));
        assert_eq!(steps[2].prev, Some(steps[1].state));
        for s in &steps {
            assert_eq!(s.obs.len(), 1);
            assert!(t.node(s.obs[0]).observed);
        }
        assert_eq!(next_state_after(&t, "h", steps[2].state), None);
        assert_eq!(
            next_state_after(&t, "h", steps[0].state),
            Some(steps[1].state)
        );
    }

    #[test]
    fn pgibbs_moves_states_and_keeps_consistency() {
        let (mut t, mut rng) = setup(&sv_src(&[0.5, -0.4, 0.8, 0.1], 0.9, 0.3), 2);
        let blocks: Vec<Value> = (1..=4).map(Value::Int).collect();
        let before = t.log_joint();
        assert!(before.is_finite());
        let mut moved = false;
        let h1 = t.scope("h").unwrap().block_nodes(&Value::Int(1))[0];
        let v0 = t.value(h1).as_f64().unwrap();
        for _ in 0..50 {
            pgibbs_transition(&mut t, &mut rng, "h", &blocks, 10).unwrap();
            if (t.value(h1).as_f64().unwrap() - v0).abs() > 1e-12 {
                moved = true;
            }
            assert!(t.log_joint().is_finite());
        }
        assert!(moved, "pgibbs never moved the states");
    }

    /// Posterior check on a 1-state chain where the exact posterior is
    /// available: h1 ~ N(0, sig^2); x1 | h1 ~ N(0, exp(h1/2)^2).
    /// Compare pgibbs samples against a long exact-MH run.
    #[test]
    fn single_state_posterior_matches_mh() {
        let src = sv_src(&[1.4], 0.9, 0.8);
        let (mut t, mut rng) = setup(&src, 3);
        let blocks = vec![Value::Int(1)];
        let h1 = t.scope("h").unwrap().block_nodes(&Value::Int(1))[0];
        let mut pg = RunningMoments::new();
        for i in 0..30_000 {
            pgibbs_transition(&mut t, &mut rng, "h", &blocks, 24).unwrap();
            if i > 1000 {
                pg.push(t.value(h1).as_f64().unwrap());
            }
        }
        // exact-MH reference on a fresh trace
        let (mut t2, mut rng2) = setup(&src, 4);
        let h1b = t2.scope("h").unwrap().block_nodes(&Value::Int(1))[0];
        let mut mh = RunningMoments::new();
        for i in 0..60_000 {
            crate::infer::mh::mh_transition(
                &mut t2,
                &mut rng2,
                h1b,
                &crate::infer::mh::Proposal::Drift(0.6),
            )
            .unwrap();
            if i > 2000 {
                mh.push(t2.value(h1b).as_f64().unwrap());
            }
        }
        assert!(
            (pg.mean() - mh.mean()).abs() < 0.08,
            "pgibbs {} vs mh {}",
            pg.mean(),
            mh.mean()
        );
        assert!(
            (pg.std() - mh.std()).abs() < 0.1,
            "pgibbs std {} vs mh std {}",
            pg.std(),
            mh.std()
        );
    }
}
