//! The inference-program interpreter.
//!
//! Inference is programmable (paper §1, Fig. 3/7): programs like
//!
//! ```text
//! (cycle ((mh alpha all 1)
//!         (gibbs z one 10)
//!         (subsampled_mh w one 100 0.01 drift 0.1 1)
//!         (pgibbs h (ordered_range 1 5) 16 1)) 100)
//! ```
//!
//! address transitions to scope/block-tagged variables.  Commands can be
//! built programmatically or parsed from the surface syntax.

use crate::infer::gibbs::gibbs_transition;
use crate::infer::mh::{mh_transition, Proposal, TransitionStats};
use crate::infer::pgibbs::pgibbs_transition;
use crate::infer::planned::PlannedEval;
use crate::infer::subsampled_mh::{subsampled_mh_transition, LocalEvaluator, SubsampledConfig};
use crate::math::Pcg64;
use crate::ppl::ast::Expr;
use crate::ppl::value::Value;
use crate::trace::node::NodeId;
use crate::trace::pet::Trace;
use std::rc::Rc;

/// Which blocks of a scope a command targets.
#[derive(Clone, Debug)]
pub enum BlockSel {
    /// One uniformly random non-empty block per step.
    One,
    /// Every block, in registration order.
    All,
    /// A specific block key.
    Block(Value),
}

/// One inference command.
#[derive(Clone, Debug)]
pub enum InfCmd {
    Mh {
        scope: String,
        block: BlockSel,
        steps: usize,
        proposal: Proposal,
    },
    Gibbs {
        scope: String,
        block: BlockSel,
        steps: usize,
    },
    SubsampledMh {
        scope: String,
        block: BlockSel,
        cfg: SubsampledConfig,
        steps: usize,
    },
    PGibbs {
        scope: String,
        from: i64,
        to: i64,
        particles: usize,
        steps: usize,
    },
    Cycle {
        cmds: Vec<InfCmd>,
        reps: usize,
    },
}

impl InfCmd {
    /// Turn on risk-adaptive mini-batch control for every
    /// `subsampled_mh` command in this program (the CLI's
    /// `--target-risk` applies one bound program-wide; commands other
    /// than `subsampled_mh` are unaffected).
    pub fn set_target_risk(&mut self, target: f64) {
        match self {
            InfCmd::SubsampledMh { cfg, .. } => cfg.target_risk = Some(target),
            InfCmd::Cycle { cmds, .. } => {
                for c in cmds {
                    c.set_target_risk(target);
                }
            }
            _ => {}
        }
    }

    /// Set the shard-watchdog deadline for every `subsampled_mh`
    /// command in this program (the CLI's `--shard-timeout-ms` / a
    /// serve session's per-session value; `0` = process default).
    pub fn set_shard_timeout_ms(&mut self, ms: u64) {
        match self {
            InfCmd::SubsampledMh { cfg, .. } => cfg.shard_timeout_ms = ms,
            InfCmd::Cycle { cmds, .. } => {
                for c in cmds {
                    c.set_shard_timeout_ms(ms);
                }
            }
            _ => {}
        }
    }

    /// Set the column-store row self-check mode for every
    /// `subsampled_mh` command in this program (the CLI's
    /// `--store-verify` / a serve session's per-session value; unset
    /// commands fall back to `SUBPPL_STORE_VERIFY`).
    pub fn set_store_verify(&mut self, v: crate::trace::colstore::VerifyMode) {
        match self {
            InfCmd::SubsampledMh { cfg, .. } => cfg.store_verify = Some(v),
            InfCmd::Cycle { cmds, .. } => {
                for c in cmds {
                    c.set_store_verify(v);
                }
            }
            _ => {}
        }
    }
}

/// Aggregate statistics of an inference run.
#[derive(Clone, Copy, Debug, Default)]
pub struct InferStats {
    pub transitions: usize,
    pub accepted: usize,
    pub sections_evaluated: usize,
}

impl InferStats {
    fn absorb(&mut self, t: &TransitionStats) {
        self.transitions += 1;
        if t.accepted {
            self.accepted += 1;
        }
        self.sections_evaluated += t.sections_evaluated;
    }

    pub fn acceptance_rate(&self) -> f64 {
        self.accepted as f64 / self.transitions.max(1) as f64
    }
}

/// Resolve a block selector to target principal nodes.
fn targets(trace: &Trace, scope: &str, sel: &BlockSel, rng: &mut Pcg64) -> Vec<NodeId> {
    let sc = match trace.scope(scope) {
        Some(s) => s,
        None => return vec![],
    };
    match sel {
        BlockSel::One => {
            let live = sc.live_blocks();
            if live.is_empty() {
                return vec![];
            }
            let b = live[rng.below(live.len())].clone();
            sc.block_nodes(&b).to_vec()
        }
        BlockSel::All => sc
            .blocks
            .iter()
            .flat_map(|(_, ns)| ns.iter().copied())
            .collect(),
        BlockSel::Block(b) => sc.block_nodes(b).to_vec(),
    }
}

/// Execute one inference command against a trace.
pub fn run_command(
    trace: &mut Trace,
    rng: &mut Pcg64,
    cmd: &InfCmd,
    evaluator: &mut dyn LocalEvaluator,
) -> Result<InferStats, String> {
    let mut stats = InferStats::default();
    match cmd {
        InfCmd::Mh {
            scope,
            block,
            steps,
            proposal,
        } => {
            for _ in 0..*steps {
                for v in targets(trace, scope, block, rng) {
                    stats.absorb(&mh_transition(trace, rng, v, proposal)?);
                }
            }
        }
        InfCmd::Gibbs { scope, block, steps } => {
            for _ in 0..*steps {
                for v in targets(trace, scope, block, rng) {
                    stats.absorb(&gibbs_transition(trace, rng, v)?);
                }
            }
        }
        InfCmd::SubsampledMh {
            scope,
            block,
            cfg,
            steps,
        } => {
            for _ in 0..*steps {
                for v in targets(trace, scope, block, rng) {
                    stats.absorb(&subsampled_mh_transition(trace, rng, v, cfg, evaluator)?);
                }
            }
        }
        InfCmd::PGibbs {
            scope,
            from,
            to,
            particles,
            steps,
        } => {
            let blocks: Vec<Value> = (*from..=*to).map(Value::Int).collect();
            for _ in 0..*steps {
                stats.absorb(&pgibbs_transition(trace, rng, scope, &blocks, *particles)?);
            }
        }
        InfCmd::Cycle { cmds, reps } => {
            for _ in 0..*reps {
                for c in cmds {
                    let s = run_command(trace, rng, c, evaluator)?;
                    stats.transitions += s.transitions;
                    stats.accepted += s.accepted;
                    stats.sections_evaluated += s.sections_evaluated;
                }
            }
        }
    }
    Ok(stats)
}

/// Convenience: run with the default (planned, arena-backed) evaluator
/// in auto-parallel mode — large batch replays shard across the shared
/// worker pool (`SUBPPL_THREADS` / available parallelism; bitwise
/// identical to the sequential evaluator, so results don't depend on
/// the machine).
pub fn infer(trace: &mut Trace, rng: &mut Pcg64, cmd: &InfCmd) -> Result<InferStats, String> {
    run_command(trace, rng, cmd, &mut PlannedEval::auto())
}

// ---------------------------------------------------------------------
// surface-syntax parsing
// ---------------------------------------------------------------------

/// Parse an inference program expression, e.g.
/// `(cycle ((mh w one 1 drift 0.1) (gibbs z one 5)) 100)`.
pub fn parse_infer(src: &str) -> Result<InfCmd, String> {
    let expr = crate::ppl::parser::parse_expr(src)?;
    convert(&expr)
}

fn sym_of(e: &Rc<Expr>) -> Result<String, String> {
    match &**e {
        Expr::Sym(s) => Ok(s.to_string()),
        Expr::Const(Value::Sym(s)) => Ok(s.to_string()),
        other => Err(format!("expected symbol, got {other:?}")),
    }
}

fn num_of(e: &Rc<Expr>) -> Result<f64, String> {
    match &**e {
        Expr::Const(v) => v.as_f64().ok_or_else(|| format!("expected number, got {v}")),
        other => Err(format!("expected number, got {other:?}")),
    }
}

fn usize_of(e: &Rc<Expr>) -> Result<usize, String> {
    Ok(num_of(e)? as usize)
}

fn block_of(e: &Rc<Expr>) -> Result<BlockSel, String> {
    match &**e {
        Expr::Sym(s) if &**s == "one" => Ok(BlockSel::One),
        Expr::Sym(s) if &**s == "all" => Ok(BlockSel::All),
        Expr::Const(v) => Ok(BlockSel::Block(v.clone())),
        other => Err(format!("expected block selector, got {other:?}")),
    }
}

/// Parse optional trailing `drift <sigma>` + `<steps>`.
fn proposal_and_steps(rest: &[Rc<Expr>]) -> Result<(Proposal, usize), String> {
    match rest {
        [steps] => Ok((Proposal::PriorResim, usize_of(steps)?)),
        [kind, sigma, steps] if sym_of(kind).as_deref() == Ok("drift") => {
            Ok((Proposal::Drift(num_of(sigma)?), usize_of(steps)?))
        }
        _ => Err(format!("bad proposal/steps tail: {rest:?}")),
    }
}

fn convert(expr: &Rc<Expr>) -> Result<InfCmd, String> {
    let parts = match &**expr {
        Expr::App(parts) => parts,
        other => return Err(format!("expected (command ...), got {other:?}")),
    };
    let head = sym_of(&parts[0])?;
    let arg = |i: usize| -> Result<&Rc<Expr>, String> {
        parts
            .get(i)
            .ok_or_else(|| format!("({head} ...): missing argument {i}"))
    };
    match head.as_str() {
        "mh" => {
            let scope = sym_of(arg(1)?)?;
            let block = block_of(arg(2)?)?;
            if parts.len() < 4 {
                return Err("(mh ...): missing steps".into());
            }
            let (proposal, steps) = proposal_and_steps(&parts[3..])?;
            Ok(InfCmd::Mh {
                scope,
                block,
                steps,
                proposal,
            })
        }
        "gibbs" => Ok(InfCmd::Gibbs {
            scope: sym_of(arg(1)?)?,
            block: block_of(arg(2)?)?,
            steps: usize_of(arg(3)?)?,
        }),
        "subsampled_mh" => {
            let scope = sym_of(arg(1)?)?;
            let block = block_of(arg(2)?)?;
            let m = usize_of(arg(3)?)?;
            let eps = num_of(arg(4)?)?;
            if parts.len() < 6 {
                return Err("(subsampled_mh ...): missing steps".into());
            }
            let (proposal, steps) = proposal_and_steps(&parts[5..])?;
            Ok(InfCmd::SubsampledMh {
                scope,
                block,
                cfg: SubsampledConfig {
                    m,
                    eps,
                    proposal,
                    exact: false,
                    threads: 0,
                    target_risk: None,
                    shard_timeout_ms: 0,
                    store_verify: None,
                },
                steps,
            })
        }
        "pgibbs" => {
            // (pgibbs h (ordered_range a b) P steps)
            let scope = sym_of(arg(1)?)?;
            let (from, to) = match &**arg(2)? {
                Expr::App(range) if sym_of(&range[0]).as_deref() == Ok("ordered_range") => {
                    (num_of(&range[1])? as i64, num_of(&range[2])? as i64)
                }
                other => return Err(format!("expected (ordered_range a b), got {other:?}")),
            };
            Ok(InfCmd::PGibbs {
                scope,
                from,
                to,
                particles: usize_of(arg(3)?)?,
                steps: usize_of(arg(4)?)?,
            })
        }
        "cycle" => {
            let cmds = match &**arg(1)? {
                Expr::App(inner) => inner.iter().map(convert).collect::<Result<Vec<_>, _>>()?,
                other => return Err(format!("expected (cmds...), got {other:?}")),
            };
            Ok(InfCmd::Cycle {
                cmds,
                reps: usize_of(arg(2)?)?,
            })
        }
        other => Err(format!("unknown inference command: {other}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_nested_cycle() {
        let cmd = parse_infer(
            "(cycle ((mh alpha all 1) (gibbs z one 10) \
             (subsampled_mh w one 100 0.01 drift 0.1 1) \
             (pgibbs h (ordered_range 1 5) 16 1)) 25)",
        )
        .unwrap();
        match cmd {
            InfCmd::Cycle { cmds, reps } => {
                assert_eq!(reps, 25);
                assert_eq!(cmds.len(), 4);
                assert!(matches!(&cmds[0], InfCmd::Mh { scope, .. } if scope == "alpha"));
                assert!(matches!(&cmds[1], InfCmd::Gibbs { .. }));
                match &cmds[2] {
                    InfCmd::SubsampledMh { cfg, .. } => {
                        assert_eq!(cfg.m, 100);
                        assert!((cfg.eps - 0.01).abs() < 1e-12);
                        assert!(matches!(cfg.proposal, Proposal::Drift(s) if (s - 0.1).abs() < 1e-12));
                    }
                    c => panic!("{c:?}"),
                }
                assert!(
                    matches!(&cmds[3], InfCmd::PGibbs { from: 1, to: 5, particles: 16, .. })
                );
            }
            c => panic!("{c:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_infer("(frobnicate x)").is_err());
        assert!(parse_infer("(mh)").is_err());
        assert!(parse_infer("(pgibbs h (range 1 5) 16 1)").is_err());
    }

    #[test]
    fn end_to_end_program_runs() {
        let model = r#"
            [assume mu (scope_include 'mu 0 (normal 0 1))]
            [observe (normal mu 0.5) 1.2]
            [observe (normal mu 0.5) 0.8]
        "#;
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(1);
        t.run_program(model, &mut rng).unwrap();
        let cmd = parse_infer("(cycle ((mh mu one drift 0.5 1)) 2000)").unwrap();
        let stats = infer(&mut t, &mut rng, &cmd).unwrap();
        assert_eq!(stats.transitions, 2000);
        assert!(stats.acceptance_rate() > 0.1);
        // posterior mean of mu: prior N(0,1), 2 obs at 1.0 avg with var .25
        // => posterior mean = (2/0.25 * 1.0)/(1 + 2/0.25) = 8/9
        let mut m = crate::stats::RunningMoments::new();
        for _ in 0..4000 {
            infer(&mut t, &mut rng, &parse_infer("(mh mu one drift 0.5 1)").unwrap()).unwrap();
            m.push(t.fresh_value(t.lookup_node("mu").unwrap()).as_f64().unwrap());
        }
        assert!((m.mean() - 8.0 / 9.0).abs() < 0.07, "mean {}", m.mean());
    }
}
