//! Sublinear-time approximate MH (paper Alg. 3).
//!
//! The transition never constructs the full scaffold: it builds the
//! global section (v -> border), computes mu_0 from `log u` and the
//! global weight, then draws mini-batches of *local sections* without
//! replacement, scoring each non-destructively (override evaluation)
//! until the sequential test (Alg. 2) decides.  Acceptance commits only
//! the global section and bumps the staleness epoch; unvisited sections
//! update lazily (§3.5).
//!
//! Section scoring is pluggable: the interpreter walk below is the
//! general path; `coordinator::fused` supplies the XLA-batched path that
//! dispatches mini-batches to the AOT Pallas kernels.

use crate::infer::mh::{mh_transition, Proposal, TransitionStats};
use crate::infer::planned::EvalStats;
use crate::infer::seqtest::{SequentialTest, TestState};
use crate::math::{inv_normal_cdf, Pcg64};
use crate::ppl::value::Value;
use crate::trace::node::{NodeId, NodeKind};
use crate::trace::partition::{
    commit_global, discover_section, freshen_partition, OverrideCtx, Partition,
};
use crate::trace::pet::Trace;
use std::collections::HashMap;

/// Configuration of the subsampled kernel.
#[derive(Clone, Debug)]
pub struct SubsampledConfig {
    /// Mini-batch size m.
    pub m: usize,
    /// Tolerance epsilon of the sequential test.
    pub eps: f64,
    pub proposal: Proposal,
    /// Evaluate every local section and decide exactly — the "standard
    /// MH" baseline sharing this code path (used by the benchmarks for a
    /// fair runtime comparison).
    pub exact: bool,
    /// Worker threads for batch replay (consumed by
    /// `PlannedEval::for_config`): `0` = auto (the `SUBPPL_THREADS`
    /// env var, else available parallelism), `1` = today's sequential
    /// behavior exactly, `n > 1` = shard large batches across the
    /// shared worker pool.  Purely a wall-clock knob — the parallel
    /// path is bitwise identical to the sequential one, so traces and
    /// acceptance decisions do not depend on it.
    pub threads: usize,
    /// Risk-adaptive mini-batch control (`--target-risk`).  When set,
    /// the value replaces `eps` as the sequential test's stopping
    /// threshold and a [`RiskController`] sizes each round's mini-batch
    /// toward that per-transition error bound (`m` becomes the probe /
    /// floor size).  When `None`, rounds are a fixed `m` sections and
    /// `eps` is used, exactly as before.
    pub target_risk: Option<f64>,
    /// Shard-watchdog result deadline in milliseconds for this config's
    /// parallel evaluator (`0` = the process default: the
    /// `SUBPPL_SHARD_TIMEOUT_MS` env var, else 1000ms).  Per-config so
    /// concurrent serve sessions can each pick their own deadline —
    /// env-only knobs don't compose across sessions in one process.
    /// Purely a recovery-latency knob: the watchdog's inline re-run is
    /// bitwise identical to the shard it replaces.
    pub shard_timeout_ms: u64,
    /// Column-store row self-check mode (`--store-verify`).  `None`
    /// falls back to the `SUBPPL_STORE_VERIFY` env var — per-config so
    /// concurrent serve sessions can each pick their own mode, the
    /// same promotion the shard watchdog deadline got.  Purely an
    /// integrity-vs-throughput knob: verification never changes
    /// scores, only whether corrupt panels are caught.
    pub store_verify: Option<crate::trace::colstore::VerifyMode>,
}

impl SubsampledConfig {
    pub fn paper_defaults() -> Self {
        SubsampledConfig {
            m: 100,
            eps: 0.01,
            proposal: Proposal::Drift(0.1),
            exact: false,
            threads: 0,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        }
    }
}

/// Pluggable mini-batch section scorer.
///
/// The transition hands each sampled mini-batch to `eval_sections` as
/// one call (never root-by-root): evaluators that batch — the default
/// `PlannedEval` groups the roots by section shape and replays one op
/// list per group, `FusedEval` dispatches whole batches to XLA — rely
/// on seeing the full mini-batch at once.
pub trait LocalEvaluator {
    /// l_i for each listed border child, under `new_v` pinned at `p.v`,
    /// in `roots` order.  Must not mutate trace values other than lazy
    /// freshening.
    fn eval_sections(
        &mut self,
        trace: &mut Trace,
        p: &Partition,
        roots: &[NodeId],
        new_v: &Value,
    ) -> Result<Vec<f64>, String>;

    fn name(&self) -> &'static str {
        "interpreter"
    }

    /// Snapshot of the evaluator's tier counters, for streaming
    /// per-interval diffs into the convergence monitor.  All-zero for
    /// evaluators that don't track traffic.
    fn stats(&self) -> EvalStats {
        EvalStats::default()
    }

    /// Realized risk of the transition decision that just completed:
    /// the sequential test's p-value at its stopping point, or `0.0`
    /// for exact decisions (exhaustion or `exact` mode).  Evaluators
    /// that track stats accumulate it into [`EvalStats`]; the default
    /// is a no-op.
    fn note_risk(&mut self, _realized: f64) {}
}

/// The general interpreter-walk evaluator.
#[derive(Default)]
pub struct InterpreterEval;

impl LocalEvaluator for InterpreterEval {
    fn eval_sections(
        &mut self,
        trace: &mut Trace,
        p: &Partition,
        roots: &[NodeId],
        new_v: &Value,
    ) -> Result<Vec<f64>, String> {
        // lazy refresh of everything these sections read
        for &r in roots {
            freshen_section(trace, r);
        }
        let mut ctx = OverrideCtx::new(trace);
        ctx.pin(p.v, new_v.clone());
        let mut out = Vec::with_capacity(roots.len());
        for &r in roots {
            let sec = discover_section(ctx.trace, r);
            out.push(ctx.section_ratio(&sec));
        }
        Ok(out)
    }
}

/// Freshen a local section's nodes and their parents.
///
/// Index-based walk: no per-node clone of `children` or `dyn_parents`
/// vectors, and no value clones (`ensure_fresh` instead of
/// `fresh_value`) — this runs for every visited section of every
/// mini-batch, so per-node allocations were a measurable constant
/// factor on the transition hot path.
pub fn freshen_section(trace: &mut Trace, root: NodeId) {
    let mut stack = vec![root];
    let mut parents: Vec<NodeId> = Vec::with_capacity(8);
    while let Some(n) = stack.pop() {
        // parents via the single definition of the parent set
        // (Node::for_each_dyn_parent), buffered into a reused scratch
        // because freshening needs &mut Trace
        parents.clear();
        trace.node(n).for_each_dyn_parent(|p| parents.push(p));
        for &p in &parents {
            trace.ensure_fresh(p);
        }
        if trace.node(n).is_stochastic() {
            continue;
        }
        trace.ensure_fresh(n);
        for i in 0..trace.node(n).children.len() {
            let c = trace.node(n).children[i];
            stack.push(c);
        }
    }
}

/// Sparse Fisher–Yates: draw distinct indices from [0, n) incrementally
/// in O(draws) time and memory — crucial for sublinearity at large N.
pub struct SparseSampler {
    n: usize,
    drawn: usize,
    map: HashMap<usize, usize>,
}

impl SparseSampler {
    pub fn new(n: usize) -> Self {
        SparseSampler {
            n,
            drawn: 0,
            map: HashMap::new(),
        }
    }

    pub fn remaining(&self) -> usize {
        self.n - self.drawn
    }

    pub fn next(&mut self, rng: &mut Pcg64) -> usize {
        assert!(self.drawn < self.n, "sampler exhausted");
        let j = self.drawn;
        let r = j + rng.below(self.n - j);
        let at = |m: &HashMap<usize, usize>, i: usize| *m.get(&i).unwrap_or(&i);
        let out = at(&self.map, r);
        let vj = at(&self.map, j);
        self.map.insert(r, vj);
        self.drawn += 1;
        out
    }
}

/// Adaptive mini-batch sizing toward a per-transition risk bound.
///
/// The fixed-`m` loop draws the same batch size every round regardless
/// of how decisive the stream looks, so easy decisions overshoot (the
/// last round wastes reads past the stopping point) and hard ones
/// crawl through many tiny rounds.  Given a target risk `delta`, this
/// controller probes with `m0` sections, then sizes each following
/// round by solving the test's stopping condition for `n` under a
/// normal approximation: the fpc-corrected standard error at which
/// `|mean - mu0|` sits exactly at the `1 - delta` critical value,
///
/// ```text
///   n* = base / (1 + base / N),   base = (z_{1-delta} * s / d)^2
/// ```
///
/// with `d = |mean - mu0|` and `s` the running std.  The next batch is
/// `n* - consumed`, clamped to `[m0, remaining]` — so it degrades to
/// the fixed-`m` behavior when the estimates are uninformative and to
/// exhaustion (an exact, zero-risk decision) when no sample size can
/// reach the bound.
pub struct RiskController {
    target: f64,
    n_total: usize,
    m0: usize,
}

impl RiskController {
    pub fn new(target: f64, n_total: usize, m0: usize) -> Self {
        assert!(
            target > 0.0 && target < 1.0,
            "target risk must lie in (0, 1), got {target}"
        );
        RiskController {
            target,
            n_total,
            // the probe must give the t-test a variance estimate
            m0: m0.max(2),
        }
    }

    /// Size of the next mini-batch, given the running test state.
    pub fn next_take(&self, test: &SequentialTest, remaining: usize) -> usize {
        let consumed = test.n();
        if consumed < 2 || test.std() == 0.0 {
            return self.m0.min(remaining);
        }
        let d = (test.mean() - test.mu0()).abs();
        let need = if d == 0.0 || !d.is_finite() {
            // dead-even stream (or infinite mu0): only exhaustion decides
            self.n_total
        } else {
            let z = inv_normal_cdf(1.0 - self.target);
            let base = (z * test.std() / d).powi(2);
            // finite population correction: solve n = base * (1 - n/N)
            (base / (1.0 + base / self.n_total as f64)).ceil() as usize
        };
        need.saturating_sub(consumed).max(self.m0).min(remaining)
    }
}

/// One subsampled MH transition for `v` (Alg. 3).  Falls back to exact
/// scaffold MH when the variable has no border partition.
pub fn subsampled_mh_transition(
    trace: &mut Trace,
    rng: &mut Pcg64,
    v: NodeId,
    cfg: &SubsampledConfig,
    evaluator: &mut dyn LocalEvaluator,
) -> Result<TransitionStats, String> {
    trace.fresh_value(v);
    let p = match trace.cached_partition(v) {
        Some(p) => p,
        None => return mh_transition(trace, rng, v, &cfg.proposal),
    };
    let p = &*p;
    freshen_partition(trace, p);
    let n_total = p.n();
    let current = trace.node(v).value.clone();

    // --- propose + global weight ---
    let (new_v, w_global) = match &cfg.proposal {
        Proposal::PriorResim => {
            let nv = sample_prior_value(trace, v, rng)?;
            (nv, 0.0) // prior terms cancel against q
        }
        Proposal::Drift(_) => {
            let nv = cfg
                .proposal
                .propose(&current, rng)
                .ok_or_else(|| format!("drift cannot handle {}", current.type_name()))?;
            let lp_new = prior_logpdf(trace, v, &nv);
            let lp_old = prior_logpdf(trace, v, &current);
            (nv, lp_new - lp_old)
        }
    };

    let mut stats = TransitionStats {
        accepted: false,
        scaffold_size: p.global_drg.len(),
        sections_evaluated: 0,
    };
    // infinite global weights short-circuit the test entirely
    if w_global == f64::NEG_INFINITY {
        return Ok(stats);
    }

    let u = rng.uniform_pos();
    let mu0 = (u.ln() - w_global) / n_total as f64;

    let accept = if cfg.exact {
        // full-population pass through the same evaluator (the
        // baseline); chunks are contiguous slices of the locals, so a
        // batching evaluator sees whole same-shaped runs at once
        let mut sum = 0.0;
        let mut idx = 0;
        let chunk = cfg.m.max(1);
        while idx < n_total {
            let end = (idx + chunk).min(n_total);
            let ls = evaluator.eval_sections(trace, p, &p.locals[idx..end], &new_v)?;
            sum += ls.iter().sum::<f64>();
            stats.sections_evaluated += end - idx;
            idx = end;
        }
        evaluator.note_risk(0.0);
        sum / n_total as f64 > mu0
    } else {
        let eps = cfg.target_risk.unwrap_or(cfg.eps);
        let ctrl = cfg
            .target_risk
            .map(|tr| RiskController::new(tr, n_total, cfg.m.max(1)));
        let mut test = SequentialTest::new(mu0, n_total, eps);
        let mut sampler = SparseSampler::new(n_total);
        let mut decided = None;
        // one reused mini-batch buffer: the whole batch goes to the
        // evaluator in a single call (PlannedEval groups it by shape
        // and replays one op list per group)
        let mut roots: Vec<NodeId> = Vec::with_capacity(cfg.m.max(1));
        while decided.is_none() {
            // deterministic mid-transition cancellation point: the
            // `cancel@k` fault flips every registered stop flag between
            // mini-batch rounds; the caller observes it at its next
            // sweep/draw boundary, after this transition commits or
            // rejects atomically (tests/serve.rs pins "never torn")
            if crate::runtime::faults::cancel_mid_transition_now() {
                crate::runtime::faults::trip_cancel_flags();
            }
            let take = match &ctrl {
                Some(c) => c.next_take(&test, sampler.remaining()),
                None => cfg.m.min(sampler.remaining()),
            };
            roots.clear();
            roots.extend((0..take).map(|_| p.locals[sampler.next(rng)]));
            let ls = evaluator.eval_sections(trace, p, &roots, &new_v)?;
            stats.sections_evaluated += roots.len();
            if let TestState::Decided(acc) = test.update(&ls) {
                decided = Some(acc);
            }
        }
        evaluator.note_risk(test.realized_risk());
        decided.unwrap()
    };

    stats.scaffold_size += stats.sections_evaluated;
    if accept {
        commit_global(trace, p, new_v);
        stats.accepted = true;
    }
    Ok(stats)
}

pub(crate) fn prior_logpdf(trace: &Trace, v: NodeId, value: &Value) -> f64 {
    let node = trace.node(v);
    let args: Vec<Value> = node
        .args
        .iter()
        .map(|a| trace.arg_value(a).clone())
        .collect();
    match &node.kind {
        NodeKind::StochFam(f) => f.logpdf(value, &args),
        NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
            let sp = trace.stoch_sp(v).unwrap();
            trace.sp(sp).logpdf(value, &args)
        }
        k => panic!("prior_logpdf on {k:?}"),
    }
}

pub(crate) fn sample_prior_value(
    trace: &mut Trace,
    v: NodeId,
    rng: &mut Pcg64,
) -> Result<Value, String> {
    let args: Vec<Value> = trace.arg_values(&trace.node(v).args);
    match &trace.node(v).kind {
        NodeKind::StochFam(f) => f.sample(rng, &args),
        NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
            let sp = trace.stoch_sp(v).unwrap();
            trace.sp(sp).sample(rng, &args)
        }
        k => Err(format!("sample_prior_value on {k:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::RunningMoments;

    fn lr_program(n: usize, data_seed: u64) -> String {
        let mut rng = Pcg64::new(data_seed, 77);
        let mut src = String::from(
            "[assume w (scope_include 'w 0 (multivariate_normal (vector 0 0) 0.5))]\n\
             [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n",
        );
        // true boundary w* = (1.5, -1)
        for _ in 0..n {
            let (a, b) = (rng.normal(), rng.normal());
            let p = 1.0 / (1.0 + (-(1.5 * a - b) as f64).exp());
            let lab = if rng.uniform() < p { "true" } else { "false" };
            src.push_str(&format!("[observe (f (vector {a} {b})) {lab}]\n"));
        }
        src
    }

    #[test]
    fn sparse_sampler_is_a_permutation() {
        let mut rng = Pcg64::seeded(0);
        let mut s = SparseSampler::new(100);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..100 {
            assert!(seen.insert(s.next(&mut rng)));
        }
        assert_eq!(seen.len(), 100);
        assert!(seen.iter().all(|&i| i < 100));
    }

    #[test]
    fn sparse_sampler_uniform_first_draw() {
        let mut rng = Pcg64::seeded(1);
        let mut counts = [0usize; 10];
        for _ in 0..20_000 {
            let mut s = SparseSampler::new(10);
            counts[s.next(&mut rng)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 2000.0).abs() < 250.0, "{counts:?}");
        }
    }

    #[test]
    fn subsampled_consumes_fraction_for_clear_decisions() {
        let src = lr_program(4000, 1);
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(2);
        t.run_program(&src, &mut rng).unwrap();
        let v = t.lookup_node("w").unwrap();
        // a large drift step is nearly always clearly good or bad
        let cfg = SubsampledConfig {
            m: 100,
            eps: 0.05,
            proposal: Proposal::Drift(0.5),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = InterpreterEval;
        let mut total = 0usize;
        let iters = 50;
        for _ in 0..iters {
            let s = subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut ev).unwrap();
            total += s.sections_evaluated;
        }
        let avg = total as f64 / iters as f64;
        assert!(avg < 2000.0, "avg sections/transition {avg} of 4000");
    }

    #[test]
    fn exact_mode_matches_scaffold_mh_posterior() {
        // Run exact-mode partitioned MH; posterior mean of w should move
        // towards the separator direction (1.5, -1).
        let src = lr_program(800, 3);
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(4);
        t.run_program(&src, &mut rng).unwrap();
        let v = t.lookup_node("w").unwrap();
        let cfg = SubsampledConfig {
            m: 256,
            eps: 0.01,
            proposal: Proposal::Drift(0.12),
            exact: true,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = InterpreterEval;
        let (mut m0, mut m1) = (RunningMoments::new(), RunningMoments::new());
        for i in 0..4000 {
            subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut ev).unwrap();
            if i > 500 {
                let w = t.fresh_value(v);
                let w = w.as_vector().unwrap().clone();
                m0.push(w[0]);
                m1.push(w[1]);
            }
        }
        assert!(m0.mean() > 0.5, "w0 mean {}", m0.mean());
        assert!(m1.mean() < -0.3, "w1 mean {}", m1.mean());
    }

    #[test]
    fn subsampled_posterior_close_to_exact() {
        // Same chain with the sequential test on: posterior must stay in
        // the same region (bias is controlled by eps).
        let src = lr_program(800, 3);
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(5);
        t.run_program(&src, &mut rng).unwrap();
        let v = t.lookup_node("w").unwrap();
        let cfg = SubsampledConfig {
            m: 100,
            eps: 0.01,
            proposal: Proposal::Drift(0.12),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = InterpreterEval;
        let (mut m0, mut m1) = (RunningMoments::new(), RunningMoments::new());
        for i in 0..4000 {
            subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut ev).unwrap();
            if i > 500 {
                let w = t.fresh_value(v);
                let w = w.as_vector().unwrap().clone();
                m0.push(w[0]);
                m1.push(w[1]);
            }
        }
        assert!(m0.mean() > 0.5, "w0 mean {}", m0.mean());
        assert!(m1.mean() < -0.3, "w1 mean {}", m1.mean());
    }

    #[test]
    fn out_of_support_drift_rejects_immediately() {
        let src = r#"
            [assume phi (beta 5 1)]
            [observe (normal phi 0.1) 0.9]
            [observe (normal phi 0.1) 0.95]
        "#;
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(6);
        t.run_program(src, &mut rng).unwrap();
        let v = t.lookup_node("phi").unwrap();
        // huge drift: frequently proposes phi outside (0,1)
        let cfg = SubsampledConfig {
            m: 1,
            eps: 0.01,
            proposal: Proposal::Drift(50.0),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = InterpreterEval;
        for _ in 0..50 {
            let s = subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut ev).unwrap();
            let phi = t.fresh_value(v).as_f64().unwrap();
            assert!((0.0..=1.0).contains(&phi), "phi left support: {phi} ({s:?})");
        }
    }

    #[test]
    fn no_partition_falls_back_to_exact_mh() {
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(7);
        t.run_program(
            "[assume x (normal 0 1)] [observe (normal x 1) 2.0]",
            &mut rng,
        )
        .unwrap();
        let v = t.lookup_node("x").unwrap();
        let cfg = SubsampledConfig::paper_defaults();
        let mut ev = InterpreterEval;
        // single dependent: no border; must not panic
        let s = subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut ev).unwrap();
        assert_eq!(s.sections_evaluated, 0);
    }

    #[test]
    fn risk_controller_probes_then_adapts() {
        let n_total = 10_000;
        let ctrl = RiskController::new(0.01, n_total, 50);
        // fresh test: probe round of m0
        let test = SequentialTest::new(0.0, n_total, 0.01);
        assert_eq!(ctrl.next_take(&test, n_total), 50);

        // decisive stream (mean far from mu0 in units of std): the
        // predicted requirement is below what's consumed, so the
        // controller returns the m0 floor
        let mut easy = SequentialTest::new(0.0, n_total, 1e-12);
        let vals: Vec<f64> = (0..60).map(|i| 5.0 + 0.01 * (i % 7) as f64).collect();
        easy.update(&vals);
        assert_eq!(ctrl.next_take(&easy, n_total - easy.n()), 50);

        // borderline stream: requirement far exceeds consumption, next
        // round must be larger than the floor (but capped by remaining)
        let mut hard = SequentialTest::new(0.0, n_total, 1e-12);
        let vals: Vec<f64> = (0..60)
            .map(|i| 0.001 + if i % 2 == 0 { 1.0 } else { -1.0 })
            .collect();
        hard.update(&vals);
        let take = ctrl.next_take(&hard, n_total - hard.n());
        assert!(take > 50, "borderline round was only {take}");
        assert!(take <= n_total - hard.n());

        // dead-even stream: only exhaustion decides
        let mut even = SequentialTest::new(1.0, n_total, 1e-12);
        even.update(&[0.0, 2.0, 0.0, 2.0]);
        assert_eq!(ctrl.next_take(&even, n_total - even.n()), n_total - even.n());
    }

    /// Captures each transition's realized risk via the trait hook.
    struct RiskCapture {
        inner: InterpreterEval,
        risks: Vec<f64>,
    }

    impl LocalEvaluator for RiskCapture {
        fn eval_sections(
            &mut self,
            trace: &mut Trace,
            p: &Partition,
            roots: &[NodeId],
            new_v: &Value,
        ) -> Result<Vec<f64>, String> {
            self.inner.eval_sections(trace, p, roots, new_v)
        }
        fn note_risk(&mut self, realized: f64) {
            self.risks.push(realized);
        }
    }

    #[test]
    fn realized_risk_stays_below_target_on_lr() {
        // the fig4 bench model: adaptive control must keep every
        // transition's realized risk at or below the requested bound
        let src = lr_program(2000, 1);
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(21);
        t.run_program(&src, &mut rng).unwrap();
        let v = t.lookup_node("w").unwrap();
        let target = 0.05;
        let cfg = SubsampledConfig {
            m: 50,
            eps: 0.01, // ignored: target_risk takes over as threshold
            proposal: Proposal::Drift(0.12),
            exact: false,
            threads: 1,
            target_risk: Some(target),
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = RiskCapture {
            inner: InterpreterEval,
            risks: Vec::new(),
        };
        for _ in 0..40 {
            subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut ev).unwrap();
        }
        assert_eq!(ev.risks.len(), 40, "one realized risk per transition");
        for &r in &ev.risks {
            assert!((0.0..=target).contains(&r), "realized risk {r} > {target}");
        }
        // sanity: the chain actually subsampled (not all exhaustion)
        assert!(
            ev.risks.iter().any(|&r| r > 0.0),
            "every transition exhausted; adaptive sizing never engaged"
        );
    }
}
