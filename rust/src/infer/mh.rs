//! Exact single-site Metropolis–Hastings on scaffolds (paper Alg. 1).

use crate::math::Pcg64;
use crate::ppl::value::Value;
use crate::trace::node::NodeId;
use crate::trace::pet::Trace;
use crate::trace::regen::{commit, detach, regen, rollback, Journal, RegenMode};
use crate::trace::scaffold::build_scaffold;
use std::rc::Rc;

/// Proposal distribution for a principal node.
#[derive(Clone, Debug)]
pub enum Proposal {
    /// Resimulate from the prior (q = p, prior terms cancel).
    PriorResim,
    /// Symmetric Gaussian random walk with the given std (reals and
    /// vectors, elementwise).
    Drift(f64),
}

impl Proposal {
    /// Draw a proposed value given the current one.  Returns None if the
    /// proposal type cannot handle the value's type.
    pub fn propose(&self, current: &Value, rng: &mut Pcg64) -> Option<Value> {
        match self {
            Proposal::PriorResim => None, // handled by RegenMode::Sample
            Proposal::Drift(sigma) => match current {
                Value::Real(x) => Some(Value::Real(x + sigma * rng.normal())),
                Value::Vector(v) => Some(Value::Vector(Rc::new(
                    v.iter().map(|x| x + sigma * rng.normal()).collect(),
                ))),
                _ => None,
            },
        }
    }
}

/// Statistics of one transition attempt.
#[derive(Clone, Copy, Debug, Default)]
pub struct TransitionStats {
    pub accepted: bool,
    /// Total scaffold size touched (|D| + |A|).
    pub scaffold_size: usize,
    /// Local sections evaluated (subsampled kernels; 0 otherwise).
    pub sections_evaluated: usize,
}

/// One exact MH transition for principal node `v`.
pub fn mh_transition(
    trace: &mut Trace,
    rng: &mut Pcg64,
    v: NodeId,
    proposal: &Proposal,
) -> Result<TransitionStats, String> {
    // lazy §3.5: make sure everything this scaffold reads is fresh
    trace.fresh_value(v);
    let scaffold = build_scaffold(trace, v);
    for &n in scaffold.drg.iter().chain(&scaffold.absorbing) {
        for p in trace.node(n).dyn_parents() {
            trace.fresh_value(p);
        }
    }
    let current = trace.node(v).value.clone();
    let mode = match proposal {
        Proposal::PriorResim => RegenMode::Sample,
        Proposal::Drift(_) => match proposal.propose(&current, rng) {
            Some(new_val) => RegenMode::Forced(new_val),
            None => {
                return Err(format!(
                    "drift proposal cannot handle a {}",
                    current.type_name()
                ))
            }
        },
    };
    // Rollback restores the exact pre-transition structure, so the
    // structure version is restored too — otherwise every rejected
    // structural proposal would spuriously invalidate the partition and
    // section-plan caches.  Safe because nothing builds cache entries
    // while a journal is open (caches are only written from the
    // subsampled/evaluator layer, never inside detach/regen).
    let structure_v0 = trace.structure_version;
    let mut j = Journal::new();
    let w_old = detach(trace, &scaffold, &mut j);
    let w_new = regen(trace, &scaffold, mode, None, rng, &mut j)?;
    // Eq. 3 with prior-regenerated transient sets:
    //  - PriorResim: the principal's prior and proposal terms cancel
    //  - Drift (symmetric): q terms cancel; prior terms remain
    let log_alpha = match proposal {
        Proposal::PriorResim => w_new.absorbed - w_old.absorbed,
        Proposal::Drift(_) => {
            (w_new.absorbed + w_new.principal) - (w_old.absorbed + w_old.principal)
        }
    };
    let accepted = log_alpha >= 0.0 || rng.uniform_pos().ln() < log_alpha;
    let stats = TransitionStats {
        accepted,
        scaffold_size: scaffold.size(),
        sections_evaluated: 0,
    };
    if accepted {
        commit(trace, j);
    } else {
        rollback(trace, j);
        trace.structure_version = structure_v0;
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::RunningMoments;

    fn setup(src: &str, seed: u64) -> (Trace, Pcg64) {
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(src, &mut rng).unwrap();
        (t, rng)
    }

    /// Normal-normal conjugate posterior check: mu ~ N(0,1), x|mu ~
    /// N(mu, 1), observe x = 2 => posterior N(1, 1/2).
    #[test]
    fn normal_normal_posterior_drift() {
        let (mut t, mut rng) = setup("[assume mu (normal 0 1)] [observe (normal mu 1) 2.0]", 1);
        let v = t.lookup_node("mu").unwrap();
        let prop = Proposal::Drift(0.8);
        let mut m = RunningMoments::new();
        for i in 0..60_000 {
            mh_transition(&mut t, &mut rng, v, &prop).unwrap();
            if i >= 5_000 {
                m.push(t.value(v).as_f64().unwrap());
            }
        }
        assert!((m.mean() - 1.0).abs() < 0.05, "mean {}", m.mean());
        assert!((m.variance() - 0.5).abs() < 0.06, "var {}", m.variance());
    }

    #[test]
    fn normal_normal_posterior_prior_resim() {
        let (mut t, mut rng) = setup("[assume mu (normal 0 1)] [observe (normal mu 1) 2.0]", 2);
        let v = t.lookup_node("mu").unwrap();
        let mut m = RunningMoments::new();
        for i in 0..120_000 {
            mh_transition(&mut t, &mut rng, v, &Proposal::PriorResim).unwrap();
            if i >= 5_000 {
                m.push(t.value(v).as_f64().unwrap());
            }
        }
        assert!((m.mean() - 1.0).abs() < 0.06, "mean {}", m.mean());
        assert!((m.variance() - 0.5).abs() < 0.08, "var {}", m.variance());
    }

    /// Fig. 1 program: structural transitions through the if-branch.
    /// Posterior over b: y=10 is 90 sigmas from mu=1 but gamma can reach
    /// 10, so b should be false nearly always after inference.
    #[test]
    fn fig1_branch_flips_to_gamma() {
        let src = r#"
            [assume b (bernoulli 0.5)]
            [assume mu (if b 1 (gamma 1 1))]
            [assume y (normal mu 0.1)]
            [observe y 10.0]
        "#;
        let (mut t, mut rng) = setup(src, 3);
        let b = t.lookup_node("b").unwrap();
        let mut false_count = 0;
        let total = 4_000;
        for _ in 0..total {
            mh_transition(&mut t, &mut rng, b, &Proposal::PriorResim).unwrap();
            // also move mu's gamma when present so the chain mixes
            let mu = t.lookup_node("mu").unwrap();
            if let crate::trace::node::NodeKind::If { branch, .. } = &t.node(mu).kind {
                if let Some(g) = branch.node() {
                    mh_transition(&mut t, &mut rng, g, &Proposal::Drift(0.5)).unwrap();
                }
            }
            if !t.value(b).as_bool().unwrap() {
                false_count += 1;
            }
        }
        assert!(
            false_count as f64 / total as f64 > 0.95,
            "b=false fraction {}",
            false_count as f64 / total as f64
        );
        // log_joint stays finite and consistent
        let lj = t.log_joint();
        assert!(lj.is_finite());
    }

    /// Rollback invariance: a rejected transition must restore the exact
    /// joint density.
    #[test]
    fn reject_restores_log_joint() {
        let src = r#"
            [assume b (bernoulli 0.5)]
            [assume mu (if b 1 (gamma 1 1))]
            [assume y (normal mu 0.1)]
            [observe y 10.0]
        "#;
        let (mut t, mut rng) = setup(src, 4);
        for _ in 0..200 {
            let before = t.log_joint();
            let b = t.lookup_node("b").unwrap();
            let stats = mh_transition(&mut t, &mut rng, b, &Proposal::PriorResim).unwrap();
            if !stats.accepted {
                let after = t.log_joint();
                assert!(
                    (before - after).abs() < 1e-9,
                    "rollback drift: {before} vs {after}"
                );
            }
        }
    }

    /// MH over the weights of a small logistic regression leaves the
    /// trace consistent and scaffold size equals 1 + 2N.
    #[test]
    fn logistic_weights_scaffold_size() {
        let mut src = String::from(
            "[assume w (multivariate_normal (vector 0 0) 0.5)]\n\
             [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n",
        );
        for i in 0..10 {
            let lab = if i % 2 == 0 { "true" } else { "false" };
            src.push_str(&format!("[observe (f (vector 1.0 {}.5)) {lab}]\n", i));
        }
        let (mut t, mut rng) = setup(&src, 5);
        let w = t.lookup_node("w").unwrap();
        let stats = mh_transition(&mut t, &mut rng, w, &Proposal::Drift(0.2)).unwrap();
        assert_eq!(stats.scaffold_size, 1 + 2 * 10);
    }

    /// CRP alpha via maker-AAA: transition must be O(K), not O(N), and
    /// the posterior should favor alpha consistent with the table count.
    #[test]
    fn crp_alpha_aaa_transition() {
        let src = r#"
            [assume alpha (gamma 1 1)]
            [assume crp (make_crp alpha)]
            [assume z (mem (lambda (i) (crp)))]
        "#;
        let mut prog = String::from(src);
        for i in 0..30 {
            prog.push_str(&format!("[assume z{i} (z {i})]\n"));
        }
        let (mut t, mut rng) = setup(&prog, 6);
        let alpha = t.lookup_node("alpha").unwrap();
        let stats = mh_transition(&mut t, &mut rng, alpha, &Proposal::Drift(0.3)).unwrap();
        // D = {alpha, maker}; A = {} (applications absorbed at the maker)
        assert!(
            stats.scaffold_size <= 3,
            "AAA failed: scaffold size {}",
            stats.scaffold_size
        );
        let mut m = RunningMoments::new();
        for _ in 0..4000 {
            mh_transition(&mut t, &mut rng, alpha, &Proposal::Drift(0.3)).unwrap();
            m.push(t.value(alpha).as_f64().unwrap());
        }
        assert!(m.mean() > 0.0);
        assert!(t.log_joint().is_finite());
    }
}
