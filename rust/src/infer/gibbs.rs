//! Enumerative single-site Gibbs.
//!
//! For a discrete principal node, every candidate value is scored by a
//! (journaled, rolled-back) forced regen of the scaffold; the new value
//! is drawn from the normalized weights, then the winning candidate is
//! replayed exactly (same auxiliary prior draws) and committed.  For CRP
//! applications this is Neal's Algorithm 8 with one auxiliary table:
//! candidates are the occupied tables after unincorporating the point,
//! plus the point's own (possibly freed) table, which retains its
//! cluster parameters through the mem cache.

use crate::math::Pcg64;
use crate::ppl::sp::SpState;
use crate::ppl::value::Value;
use crate::trace::node::{NodeId, NodeKind};
use crate::trace::pet::Trace;
use crate::trace::regen::{commit, detach, regen, rollback, Journal, RegenMode};
use crate::trace::scaffold::build_scaffold;
use std::collections::VecDeque;

/// Candidate values for an enumerable stochastic node, to be called
/// *after* the node has been detached (unincorporated).
fn candidates(trace: &Trace, v: NodeId) -> Result<Vec<Value>, String> {
    let node = trace.node(v);
    match &node.kind {
        NodeKind::StochFam(crate::ppl::sp::SpFamily::Bernoulli) => {
            Ok(vec![Value::Bool(false), Value::Bool(true)])
        }
        NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
            let sp = trace.stoch_sp(v).unwrap();
            match trace.sp(sp) {
                SpState::Crp { aux, .. } => {
                    let mut cands: Vec<Value> =
                        aux.tables().into_iter().map(Value::Int).collect();
                    let own = node.value.as_int().ok_or("crp value must be int")?;
                    if !cands.iter().any(|c| c.as_int() == Some(own)) {
                        // v was a singleton: its table acts as the
                        // auxiliary, retaining its cluster parameters
                        cands.push(Value::Int(own));
                    } else {
                        // auxiliary: one fresh table with prior-drawn params
                        cands.push(Value::Int(aux.fresh_table()));
                    }
                    Ok(cands)
                }
                _ => Err("gibbs: unsupported instance SP".into()),
            }
        }
        k => Err(format!("gibbs: cannot enumerate {k:?}")),
    }
}

/// One enumerative Gibbs transition for `v`.  Always "accepts".
pub fn gibbs_transition(
    trace: &mut Trace,
    rng: &mut Pcg64,
    v: NodeId,
) -> Result<crate::infer::mh::TransitionStats, String> {
    trace.fresh_value(v);
    let scaffold = build_scaffold(trace, v);
    for &n in scaffold.drg.iter().chain(&scaffold.absorbing) {
        for p in trace.node(n).dyn_parents() {
            trace.fresh_value(p);
        }
    }
    let mut j0 = Journal::new();
    let _w_old = detach(trace, &scaffold, &mut j0);
    let cands = candidates(trace, v)?;
    let mut weights = Vec::with_capacity(cands.len());
    let mut draws: Vec<Vec<Value>> = Vec::with_capacity(cands.len());
    for cand in &cands {
        // candidate scoring is a scratch evaluation: rollback restores
        // the exact structure, so restore the version stamp too — K
        // rolled-back candidate regens per transition would otherwise
        // invalidate the partition/plan caches on every gibbs step
        let structure_v0 = trace.structure_version;
        let mut jk = Journal::new();
        let w = regen(
            trace,
            &scaffold,
            RegenMode::Forced(cand.clone()),
            None,
            rng,
            &mut jk,
        )?;
        weights.push(w.absorbed + w.principal);
        draws.push(jk.draws.clone());
        rollback(trace, jk);
        trace.structure_version = structure_v0;
    }
    let pick = rng.categorical_log(&weights);
    let mut jf = Journal::new();
    let replay: VecDeque<Value> = draws[pick].iter().cloned().collect();
    regen(
        trace,
        &scaffold,
        RegenMode::Forced(cands[pick].clone()),
        Some(replay),
        rng,
        &mut jf,
    )?;
    commit(trace, j0);
    commit(trace, jf);
    Ok(crate::infer::mh::TransitionStats {
        accepted: true,
        scaffold_size: scaffold.size() * cands.len(),
        sections_evaluated: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(src: &str, seed: u64) -> (Trace, Pcg64) {
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(src, &mut rng).unwrap();
        (t, rng)
    }

    /// Bernoulli posterior by enumeration: b ~ Bern(0.5); y|b ~ N(b? 1 :
    /// -1, 1); observe y = 0.8 => p(b=1|y) = sig(2*0.8) ~ 0.832.
    #[test]
    fn bernoulli_gibbs_matches_enumeration() {
        let src = r#"
            [assume b (bernoulli 0.5)]
            [assume mu (if b 1.0 -1.0)]
            [observe (normal mu 1) 0.8]
        "#;
        let (mut t, mut rng) = setup(src, 1);
        let b = t.lookup_node("b").unwrap();
        let mut trues = 0;
        let total = 20_000;
        for _ in 0..total {
            gibbs_transition(&mut t, &mut rng, b).unwrap();
            if t.value(b).as_bool().unwrap() {
                trues += 1;
            }
        }
        let want = 1.0 / (1.0 + (-1.6f64).exp());
        let got = trues as f64 / total as f64;
        assert!((got - want).abs() < 0.02, "{got} vs {want}");
    }

    fn crp_mixture_src(xs: &[f64]) -> String {
        let mut src = String::from(
            r#"
            [assume crp (make_crp 1.0)]
            [assume z (mem (lambda (i) (crp)))]
            [assume muk (mem (lambda (k) (normal 0 10)))]
            [assume x (lambda (i) (normal (muk (z i)) 0.5))]
            "#,
        );
        for (i, x) in xs.iter().enumerate() {
            src.push_str(&format!("[observe (x {i}) {x}]\n"));
        }
        src
    }

    /// Two far-apart clusters: gibbs over z should separate them.
    #[test]
    fn crp_mixture_separates_clusters() {
        let xs = [-5.0, -5.2, -4.8, 5.0, 5.1, 4.9];
        let src = crp_mixture_src(&xs);
        let (mut t, mut rng) = setup(&src, 2);
        let zs: Vec<NodeId> = (0..xs.len())
            .map(|i| {
                // (z i) node: reach through the x_i observation's parents
                let src = format!("(z {i})");
                let expr = crate::ppl::parser::parse_expr(&src).unwrap();
                let mut ev = crate::trace::eval::Evaluator::new(&mut t, &mut rng);
                let env = ev.trace.global_env.clone();
                let r = ev.eval(&expr, &env).unwrap();
                r.node().expect("z_i should be a node")
            })
            .collect();
        for _ in 0..300 {
            for &z in &zs {
                gibbs_transition(&mut t, &mut rng, z).unwrap();
            }
        }
        // check final assignment: left trio together, right trio together
        let vals: Vec<i64> = zs.iter().map(|&z| t.value(z).as_int().unwrap()).collect();
        assert_eq!(vals[0], vals[1]);
        assert_eq!(vals[1], vals[2]);
        assert_eq!(vals[3], vals[4]);
        assert_eq!(vals[4], vals[5]);
        assert_ne!(vals[0], vals[3], "clusters merged: {vals:?}");
        assert!(t.log_joint().is_finite());
    }

    /// Trace consistency under long gibbs runs: cluster creation and
    /// destruction must not leak nodes or corrupt sufficient statistics.
    #[test]
    fn crp_gibbs_no_leaks() {
        let xs = [-1.0, 0.0, 1.0, -0.5, 0.5];
        let src = crp_mixture_src(&xs);
        let (mut t, mut rng) = setup(&src, 3);
        let zs: Vec<NodeId> = (0..xs.len())
            .map(|i| {
                let expr = crate::ppl::parser::parse_expr(&format!("(z {i})")).unwrap();
                let mut ev = crate::trace::eval::Evaluator::new(&mut t, &mut rng);
                let env = ev.trace.global_env.clone();
                ev.eval(&expr, &env).unwrap().node().unwrap()
            })
            .collect();
        let nodes_before = t.num_live_nodes();
        for _ in 0..500 {
            for &z in &zs {
                gibbs_transition(&mut t, &mut rng, z).unwrap();
            }
        }
        let nodes_after = t.num_live_nodes();
        // node count may fluctuate by the number of live clusters (each
        // has one muk node) but must not grow without bound
        assert!(
            nodes_after <= nodes_before + xs.len(),
            "{nodes_before} -> {nodes_after}"
        );
        // crp counts must equal the number of applications
        let crp_sp = match t.lookup_value("crp").unwrap() {
            Value::Sp(id) => id,
            v => panic!("{v}"),
        };
        assert_eq!(t.sp(crp_sp).crp_aux().unwrap().n(), xs.len());
        assert!(t.log_joint().is_finite());
    }
}
