//! Inference kernels and the inference-program interpreter.
//!
//! * `mh` — exact single-site MH on scaffolds (Alg. 1)
//! * `seqtest` — the sequential Student-t test (Alg. 2)
//! * `subsampled_mh` — sublinear approximate MH (Alg. 3)
//! * `planned` — the default arena-backed section scorer (cached plans)
//! * `gibbs` — enumerative single-site Gibbs (CRP reassignment)
//! * `pgibbs` — particle Gibbs (conditional SMC) over state chains
//! * `program` — the `(cycle (...) k)` inference-program interpreter

pub mod gibbs;
pub mod mh;
pub mod pgibbs;
pub mod planned;
pub mod program;
pub mod seqtest;
pub mod subsampled_mh;

pub use gibbs::gibbs_transition;
pub use mh::{mh_transition, Proposal, TransitionStats};
pub use pgibbs::pgibbs_transition;
pub use planned::{EvalStats, PlannedEval};
pub use program::{infer, parse_infer, run_command, BlockSel, InfCmd, InferStats};
pub use seqtest::{SequentialTest, TestState};
pub use subsampled_mh::{
    subsampled_mh_transition, InterpreterEval, LocalEvaluator, SubsampledConfig,
};
