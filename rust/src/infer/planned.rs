//! The planned local-section evaluator: the default hot path for
//! subsampled MH.
//!
//! `PlannedEval` scores mini-batches in tiers, cheapest first:
//!
//! 1. **batched** (default) — the sampled roots are grouped by
//!    [`ShapeKey`](crate::trace::batch::ShapeKey) through the trace's
//!    cached [`BatchPlanSet`](crate::trace::batch::BatchPlanSet).
//!    1a. **store** (default, `SUBPPL_COLSTORE=0` to disable) — each
//!    sampled group is served from the persistent column store
//!    (`trace/colstore.rs`): an O(|mini-batch|) index gather feeding
//!    the lane-blocked panel kernel, with per-member rows refreshed
//!    lazily on `value_version` changes — no per-transition trace
//!    reads in steady state.
//!    1b. **fresh pack** (the store's fallback and oracle) — the group
//!    is packed from the trace ([`PackedBatch`]) and replayed
//!    column-wise through an f64 [`RegFile`] — no `Value` enum
//!    dispatch, no per-section plan lookup.
//! 2. **scalar** — sections outside any batched group (non-f64 shapes,
//!    shape mismatches) replay their cached
//!    [`SectionPlan`](crate::trace::plan::SectionPlan) individually
//!    through the reusable [`ScorerArena`].
//! 3. **interpreter** — sections the lowering cannot express at all
//!    fall back to the `OverrideCtx` walk per root, with a
//!    structure-versioned negative cache so unplannable roots don't pay
//!    a failed lowering per mini-batch.
//!
//! The candidate value of the global section is computed once per batch
//! and shared by every tier.  Tier 1 has a *parallel* variant
//! ([`PlannedEval::with_pool`] / [`PlannedEval::auto`]): batches above
//! a cutoff are packed once and their kernel sharded across the
//! persistent worker pool (`runtime::pool`) — the fourth rung of the
//! differential ladder, bitwise identical to the sequential rungs
//! because shards run the very same kernel over disjoint sections.
//!
//! `InterpreterEval` remains the general path and the
//! differential-testing oracle: every planned tier must reproduce its
//! `l_i` values *bitwise* (the tests below, `tests/differential.rs`,
//! and `tests/parallel.rs` enforce this on all three paper model
//! families), because all paths perform the same float operations in
//! the same order.

use crate::infer::subsampled_mh::{InterpreterEval, LocalEvaluator, SubsampledConfig};
use crate::ppl::value::Value;
use crate::runtime::pool::{resolve_threads, ShardScorer, WorkerPool};
use crate::trace::batch::{BatchGroup, PackedBatch, RegFile};
use crate::trace::colstore::{
    colstore_enabled, ensure_group_members, ColumnStoreSet, LaneScratch, PanelBatch, VerifyMode,
};
use crate::trace::node::NodeId;
use crate::trace::partition::Partition;
use crate::trace::pet::Trace;
use crate::trace::plan::{candidate_globals, ScorerArena};
use std::cell::RefCell;
use std::collections::HashSet;
use std::rc::Rc;
use std::sync::Arc;

/// Point-in-time counters of one evaluator's scoring traffic, grouped
/// by tier — the monitor/reporting snapshot hook.  Cheap to copy;
/// subtract two snapshots ([`EvalStats::diff`]) to get per-interval
/// rates.  Every counter is monotonically non-decreasing over an
/// evaluator's lifetime — nothing resets them, not even a partition or
/// structural rebuild (pinned by `stats_stay_monotonic_across_rebuilds`
/// below), so interval diffs can never go negative.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct EvalStats {
    /// Sections scored through cached plans (scalar or batched tiers).
    pub planned: usize,
    /// Subset of `planned` replayed through a grouped column program.
    pub batched: usize,
    /// Subset of `batched` served by the persistent column store
    /// (index gather + lane-panel replay, no per-transition pack).
    pub gathered: usize,
    /// Sections that fell back to the interpreter walk.
    pub fallback: usize,
    /// Sections replayed through worker-pool shards.
    pub sharded: usize,
    /// Sections the dispatching thread replayed inline by work-stealing
    /// queued shards while waiting on the pool.
    pub stolen: usize,
    /// Times a column-store set was (re)built for this evaluator's
    /// traffic (1 on first use per structure; +1 per structural change
    /// that the store had to follow).
    pub store_rebuilds: usize,
    /// Shards lost to a worker panic and re-run inline by the pool's
    /// watchdog (recovery counter: results are unchanged, but the
    /// recovery path fired this many times).
    pub fallback_panics: usize,
    /// Shards that missed the result deadline and were re-run inline
    /// by the watchdog (recovery counter).
    pub requeued_shards: usize,
    /// Column-store groups quarantined after a refresh error, failed
    /// panel self-check, or NaN-score oracle mismatch (recovery
    /// counter: the group is scored through fresh packing from then
    /// on).
    pub store_quarantined: usize,
    /// Chains restarted from a checkpoint by the supervisor (recovery
    /// counter).  Always 0 at the evaluator level — the supervised
    /// multi-chain driver injects it when folding chain events.
    pub chains_restarted: usize,
    /// Column-store panels evicted because their principal's group
    /// layout was abandoned by a structural rebuild (DPM cluster
    /// churn); bounds the store's footprint on many-short-lived-cluster
    /// runs.
    pub store_evicted: usize,
    /// Subsampled transitions whose realized risk was recorded (the
    /// denominator for [`EvalStats::realized_risk`]).
    pub risk_transitions: usize,
    /// Sum of per-transition realized risk in micro-units (risk × 1e6,
    /// rounded; integer so the struct stays `Copy + Eq` and interval
    /// diffs stay exact).  `realized_risk()` turns the pair back into a
    /// mean probability.
    pub risk_micro: usize,
}

impl EvalStats {
    /// Field-wise sum (pooling several evaluators' snapshots).
    pub fn add(&self, o: &EvalStats) -> EvalStats {
        EvalStats {
            planned: self.planned + o.planned,
            batched: self.batched + o.batched,
            gathered: self.gathered + o.gathered,
            fallback: self.fallback + o.fallback,
            sharded: self.sharded + o.sharded,
            stolen: self.stolen + o.stolen,
            store_rebuilds: self.store_rebuilds + o.store_rebuilds,
            fallback_panics: self.fallback_panics + o.fallback_panics,
            requeued_shards: self.requeued_shards + o.requeued_shards,
            store_quarantined: self.store_quarantined + o.store_quarantined,
            chains_restarted: self.chains_restarted + o.chains_restarted,
            store_evicted: self.store_evicted + o.store_evicted,
            risk_transitions: self.risk_transitions + o.risk_transitions,
            risk_micro: self.risk_micro + o.risk_micro,
        }
    }

    /// Field-wise interval difference against an earlier snapshot.
    /// Counters are monotonic, so this is ordinary subtraction in
    /// correct use; saturating keeps a miswired pair of snapshots from
    /// wrapping into garbage instead of reading as zero traffic.
    pub fn diff(&self, prev: &EvalStats) -> EvalStats {
        EvalStats {
            planned: self.planned.saturating_sub(prev.planned),
            batched: self.batched.saturating_sub(prev.batched),
            gathered: self.gathered.saturating_sub(prev.gathered),
            fallback: self.fallback.saturating_sub(prev.fallback),
            sharded: self.sharded.saturating_sub(prev.sharded),
            stolen: self.stolen.saturating_sub(prev.stolen),
            store_rebuilds: self.store_rebuilds.saturating_sub(prev.store_rebuilds),
            fallback_panics: self.fallback_panics.saturating_sub(prev.fallback_panics),
            requeued_shards: self.requeued_shards.saturating_sub(prev.requeued_shards),
            store_quarantined: self.store_quarantined.saturating_sub(prev.store_quarantined),
            chains_restarted: self.chains_restarted.saturating_sub(prev.chains_restarted),
            store_evicted: self.store_evicted.saturating_sub(prev.store_evicted),
            risk_transitions: self.risk_transitions.saturating_sub(prev.risk_transitions),
            risk_micro: self.risk_micro.saturating_sub(prev.risk_micro),
        }
    }

    /// Mean realized risk over the transitions this snapshot covers
    /// (per-transition p-values at the sequential test's stopping
    /// point), or `None` when no subsampled transition reported one.
    /// On an interval diff this is the interval's mean realized risk.
    pub fn realized_risk(&self) -> Option<f64> {
        if self.risk_transitions == 0 {
            return None;
        }
        Some(self.risk_micro as f64 / 1e6 / self.risk_transitions as f64)
    }

    /// Whether any recovery path fired in this (interval) snapshot —
    /// the monitor prints the recovery counters only when there is
    /// something to report.
    pub fn any_recovery(&self) -> bool {
        self.fallback_panics > 0
            || self.requeued_shards > 0
            || self.store_quarantined > 0
            || self.chains_restarted > 0
    }
}

/// Why the store tier refused to score a group — drives the caller's
/// quarantine-vs-plain-fallback decision.  Every variant falls back to
/// fresh packing (bitwise identical by construction); only
/// `Integrity` additionally condemns the group's store.
enum StoreErr {
    /// The group was quarantined earlier: route to fresh pack, no
    /// counter bump (the quarantine was already counted once).
    Quarantined,
    /// Candidate-side refusal (e.g. a proposal changed a binding's
    /// type): benign, the store may serve this group again next batch.
    Candidate(#[allow(dead_code)] String),
    /// Store-side integrity failure (row refresh error, panel
    /// self-check mismatch, NaN-score oracle disagreement): the panel
    /// data cannot be trusted — quarantine the group until the next
    /// structural rebuild replaces it.
    Integrity(String),
}

/// Arena-backed batch scorer over cached section plans.
pub struct PlannedEval {
    arena: ScorerArena,
    regs: RegFile,
    /// Group sampled roots by shape and replay each group's column
    /// program (false = score every section individually; the
    /// differential harness runs both modes against the oracle).
    batched: bool,
    /// Serve batched groups from the persistent column store (an
    /// O(|mini-batch|) gather + lane-panel replay) with fresh
    /// `pack_into` as the fallback.  Defaults to the `SUBPPL_COLSTORE`
    /// kill switch (unset = on); results are bitwise identical either
    /// way — the differential suite runs under both settings.
    colstore: bool,
    /// Shard large packed batches across the worker pool (`None` =
    /// sequential replay; results are bitwise identical either way, so
    /// this is purely a wall-clock knob).
    shard: Option<ShardScorer>,
    /// Column-store row self-check override (`SubsampledConfig::
    /// store_verify` / `--store-verify`); `None` = the
    /// `SUBPPL_STORE_VERIFY` env fallback, resolved per gather.
    store_verify: Option<VerifyMode>,
    fallback: InterpreterEval,
    /// Roots whose lowering failed on trace `neg_trace` at structure
    /// version `neg_version` (skip retrying until the trace structure —
    /// or the trace itself — changes; `structure_version` alone is not
    /// unique when one evaluator is reused across traces).
    neg: HashSet<NodeId>,
    neg_trace: u64,
    neg_version: u64,
    /// Sections scored through plans (batched or scalar) vs the
    /// interpreter fallback (perf reporting / ablations).
    pub planned_sections: usize,
    /// Subset of `planned_sections` that went through a grouped
    /// column replay.
    pub batched_sections: usize,
    /// Subset of `batched_sections` served from the column store
    /// (gather + panel replay; no per-transition pack).
    pub gathered_sections: usize,
    /// Store member rows re-read from the trace (the store "miss"
    /// count: first touches and post-commit refreshes).  The hit rate
    /// is `1 - store_refreshed / gathered_sections`.
    pub store_refreshed: usize,
    /// Column-store sets built while this evaluator was driving.
    pub store_rebuilds: usize,
    /// Store groups this evaluator condemned after an integrity
    /// failure (row refresh error, panel self-check mismatch, or a
    /// NaN score the fresh-pack oracle disagrees with).  A
    /// quarantined group is scored through fresh packing until the
    /// next structural rebuild replaces its store.
    pub store_quarantined: usize,
    /// Column-store panels evicted under this evaluator's traffic
    /// (sampled as a delta around the trace's store-cache sweep).
    pub store_evicted: usize,
    /// Transitions that reported a realized risk / their summed risk in
    /// micro-units (see [`EvalStats::risk_micro`]).
    risk_transitions: usize,
    risk_micro: usize,
    pub fallback_sections: usize,
    /// Per-call scratch: for each group, the sampled (member, output
    /// position) pairs; reused so steady state allocates nothing.
    sel: Vec<Vec<(u32, u32)>>,
    batch_out: Vec<f64>,
    /// Reusable packed batch for the parallel rung: handed to the pool
    /// behind an `Arc` per dispatch and reclaimed afterwards, so the
    /// sharded path matches the sequential path's cleared-not-freed
    /// buffer discipline.
    packed_spare: Option<PackedBatch>,
    /// Reusable panel batch (the store path's analogue of
    /// `packed_spare`).
    panel_spare: Option<PanelBatch>,
    /// Lane-panel scratch for sequential store-path replays.
    lanes: LaneScratch,
}

impl Default for PlannedEval {
    fn default() -> Self {
        PlannedEval::new()
    }
}

impl PlannedEval {
    /// The default *sequential* evaluator: shape-grouped batch replay
    /// with scalar and interpreter fallbacks (exactly `threads = 1`).
    pub fn new() -> PlannedEval {
        PlannedEval {
            arena: ScorerArena::new(),
            regs: RegFile::new(),
            batched: true,
            colstore: colstore_enabled(),
            shard: None,
            store_verify: None,
            fallback: InterpreterEval,
            neg: HashSet::new(),
            neg_trace: 0,
            neg_version: 0,
            planned_sections: 0,
            batched_sections: 0,
            gathered_sections: 0,
            store_refreshed: 0,
            store_rebuilds: 0,
            store_quarantined: 0,
            store_evicted: 0,
            risk_transitions: 0,
            risk_micro: 0,
            fallback_sections: 0,
            sel: Vec::new(),
            batch_out: Vec::new(),
            packed_spare: None,
            panel_spare: None,
            lanes: LaneScratch::default(),
        }
    }

    /// Force the column-store path on or off regardless of the
    /// `SUBPPL_COLSTORE` environment default (the differential harness
    /// pins both settings explicitly).
    pub fn with_colstore(mut self, on: bool) -> PlannedEval {
        self.colstore = on;
        self
    }

    /// Score every section individually through its own plan (PR 1
    /// behavior) — the middle rung of the differential ladder.
    pub fn scalar() -> PlannedEval {
        PlannedEval {
            batched: false,
            ..PlannedEval::new()
        }
    }

    /// Batched evaluator that shards large replays across `pool` — the
    /// fourth rung of the differential ladder (interpreter → scalar →
    /// batched → parallel-batched), bitwise identical to all of them.
    /// A 1-thread pool degenerates to the sequential path.
    pub fn with_pool(pool: Arc<WorkerPool>) -> PlannedEval {
        PlannedEval {
            shard: Some(ShardScorer::new(pool)),
            ..PlannedEval::new()
        }
    }

    /// The auto-parallel evaluator: shares the process-wide pool sized
    /// by `SUBPPL_THREADS` / available parallelism.  Falls back to the
    /// sequential evaluator on single-core machines.
    pub fn auto() -> PlannedEval {
        if crate::runtime::pool::auto_threads() > 1 {
            PlannedEval::with_pool(WorkerPool::global().clone())
        } else {
            PlannedEval::new()
        }
    }

    /// Evaluator for a subsampled-MH config's thread knob: `0` = auto
    /// (available parallelism), `1` = today's sequential behavior
    /// exactly, `n > 1` = shard across the shared pool (which is sized
    /// at first use; a knob larger than the pool still uses the pool's
    /// worker count).
    pub fn for_config(cfg: &SubsampledConfig) -> PlannedEval {
        if resolve_threads(cfg.threads) > 1 {
            PlannedEval::with_pool(WorkerPool::global().clone())
                .with_shard_timeout(cfg.shard_timeout_ms)
                .with_store_verify(cfg.store_verify)
        } else {
            PlannedEval::new().with_store_verify(cfg.store_verify)
        }
    }

    /// Override the column-store row self-check mode for this evaluator
    /// (`None` keeps the `SUBPPL_STORE_VERIFY` env fallback).  Purely an
    /// integrity-vs-throughput knob: scoring results are bitwise
    /// identical under every mode.
    pub fn with_store_verify(mut self, v: Option<VerifyMode>) -> PlannedEval {
        self.store_verify = v;
        self
    }

    /// Override the shard-watchdog result deadline for this evaluator
    /// (`0` keeps the process default — `SUBPPL_SHARD_TIMEOUT_MS`, else
    /// 1000ms).  No-op for sequential evaluators.
    pub fn with_shard_timeout(mut self, ms: u64) -> PlannedEval {
        if ms > 0 {
            if let Some(s) = self.shard.as_mut() {
                s.timeout = std::time::Duration::from_millis(ms);
            }
        }
        self
    }

    /// Lower the parallel-dispatch cutoff (tests force the sharded path
    /// on small workloads with this).
    pub fn with_min_parallel(mut self, min_sections: usize) -> PlannedEval {
        if let Some(s) = self.shard.as_mut() {
            s.min_sections = min_sections;
        }
        self
    }

    /// Tag this evaluator's shard dispatches with a fair-scheduling
    /// lane: shards queue per `key` on the shared pool and are served
    /// by weighted deficit round-robin, so one session's huge batches
    /// cannot starve another's (serve wires each session's id and
    /// `weight` create-param through here).  Scheduling only reorders
    /// which lane's shards run next — results stay bitwise identical.
    /// No-op for sequential evaluators.
    pub fn with_session(mut self, key: u64, weight: u32) -> PlannedEval {
        if let Some(s) = self.shard.as_mut() {
            s.session_key = key;
            s.session_weight = weight.max(1);
        }
        self
    }

    /// Sections that went through pool shards (0 for sequential
    /// evaluators).
    pub fn sharded_sections(&self) -> usize {
        self.shard.as_ref().map_or(0, |s| s.sharded_sections)
    }

    /// Sections the dispatching thread replayed inline by work-stealing
    /// queued shards (0 for sequential evaluators).
    pub fn stolen_sections(&self) -> usize {
        self.shard.as_ref().map_or(0, |s| s.stolen_sections)
    }

    /// Enable/disable the work-stealing dispatcher (default on for pool
    /// evaluators; results are bitwise identical either way —
    /// `tests/parallel.rs` pins this).
    pub fn with_work_stealing(mut self, steal: bool) -> PlannedEval {
        if let Some(s) = self.shard.as_mut() {
            s.steal = steal;
        }
        self
    }

    /// Snapshot the scoring counters (the monitor/report hook): call at
    /// recording cadence and diff consecutive snapshots for
    /// per-interval tier traffic.
    pub fn stats(&self) -> EvalStats {
        EvalStats {
            planned: self.planned_sections,
            batched: self.batched_sections,
            gathered: self.gathered_sections,
            fallback: self.fallback_sections,
            sharded: self.sharded_sections(),
            stolen: self.stolen_sections(),
            store_rebuilds: self.store_rebuilds,
            fallback_panics: self.shard.as_ref().map_or(0, |s| s.fallback_panics),
            requeued_shards: self.shard.as_ref().map_or(0, |s| s.requeued_shards),
            store_quarantined: self.store_quarantined,
            // evaluators never restart chains; the supervised driver
            // injects this field when folding chain events
            chains_restarted: 0,
            store_evicted: self.store_evicted,
            risk_transitions: self.risk_transitions,
            risk_micro: self.risk_micro,
        }
    }

    /// Score one group selection through the column store into
    /// `self.batch_out`: ensure the sampled rows are fresh (lazy
    /// `value_version` refresh), resolve the candidate side, and run
    /// the lane-panel kernel — sequentially or sharded across the pool.
    /// `Err` sends the caller to the fresh-pack fallback; an
    /// [`StoreErr::Integrity`] error additionally condemns the group's
    /// store (quarantine) because its panel data can no longer be
    /// trusted.
    fn eval_group_store(
        &mut self,
        trace: &mut Trace,
        store: &Rc<RefCell<ColumnStoreSet>>,
        gi: usize,
        group: &BatchGroup,
        sel: &[(u32, u32)],
    ) -> Result<(), StoreErr> {
        if store.borrow().groups[gi].quarantined {
            return Err(StoreErr::Quarantined);
        }
        let refreshed = ensure_group_members(trace, store, gi, group, sel, self.store_verify)
            .map_err(StoreErr::Integrity)?;
        self.store_refreshed += refreshed;
        let panels = store.borrow().groups[gi].panels_arc();
        let mut pb = self.panel_spare.take().unwrap_or_default();
        if let Err(e) = pb.build_into(&panels, group, sel, &self.arena.globals) {
            pb.release_panels();
            self.panel_spare = Some(pb);
            // candidate-side refusal (e.g. a proposal changed a
            // binding's type) — the panel data itself is fine
            return Err(StoreErr::Candidate(e));
        }
        match self.shard.as_mut() {
            Some(sh) if sh.should_dispatch(sel.len()) => {
                let spare = sh
                    .replay_panel(pb, &mut self.batch_out)
                    .map_err(StoreErr::Candidate)?;
                // release the parked handle so the next row refresh can
                // Arc::make_mut the store in place instead of copying
                self.panel_spare = spare.map(|mut b| {
                    b.release_panels();
                    b
                });
            }
            _ => {
                self.batch_out.clear();
                self.batch_out.resize(sel.len(), 0.0);
                pb.replay_range(0, sel.len(), &mut self.lanes, &mut self.batch_out);
                pb.release_panels();
                self.panel_spare = Some(pb);
            }
        }
        if crate::runtime::faults::nan_score_now() {
            if let Some(x) = self.batch_out.first_mut() {
                *x = f64::NAN;
            }
        }
        if self.batch_out.iter().any(|x| x.is_nan()) {
            self.nan_cross_check(trace, group, sel)?;
        }
        Ok(())
    }

    /// A NaN coming out of the store tier is either a genuine NaN score
    /// (the scalar path would produce the same one) or silent panel
    /// corruption.  Re-score the selection through the fresh-pack
    /// oracle and compare bitwise: agreement passes the NaN through,
    /// disagreement condemns the panels.
    fn nan_cross_check(
        &mut self,
        trace: &mut Trace,
        group: &BatchGroup,
        sel: &[(u32, u32)],
    ) -> Result<(), StoreErr> {
        // the oracle reads the trace directly: freshen everything the
        // sampled slot tables touch (idempotent per epoch, so this is
        // cheap when the store refresh already did it)
        for &(mi, _) in sel {
            for &t in group.touch_of(mi as usize) {
                trace.ensure_fresh(t);
            }
        }
        let mut oracle = vec![0.0f64; sel.len()];
        self.regs
            .replay(trace, group, sel, &self.arena.globals, &mut oracle)
            .map_err(StoreErr::Candidate)?;
        let agree = self
            .batch_out
            .iter()
            .zip(&oracle)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        if agree {
            Ok(())
        } else {
            Err(StoreErr::Integrity(
                "NaN score disagrees with the fresh-pack oracle".to_string(),
            ))
        }
    }

    /// Scalar or interpreter scoring of one root into `out[pos]`.
    fn eval_one(
        &mut self,
        trace: &mut Trace,
        p: &Partition,
        r: NodeId,
        new_v: &Value,
        out: &mut [f64],
        pos: usize,
    ) -> Result<(), String> {
        if !self.neg.contains(&r) {
            match trace.cached_section_plan(p, r) {
                Ok(plan) => {
                    for &t in &plan.touch {
                        trace.ensure_fresh(t);
                    }
                    out[pos] = self.arena.section_ratio(trace, &plan)?;
                    self.planned_sections += 1;
                    return Ok(());
                }
                Err(_) => {
                    self.neg.insert(r);
                }
            }
        }
        // unplannable section: general interpreter walk for this root
        self.fallback_sections += 1;
        let ls = self.fallback.eval_sections(trace, p, &[r], new_v)?;
        out[pos] = ls[0];
        Ok(())
    }
}

impl LocalEvaluator for PlannedEval {
    fn eval_sections(
        &mut self,
        trace: &mut Trace,
        p: &Partition,
        roots: &[NodeId],
        new_v: &Value,
    ) -> Result<Vec<f64>, String> {
        if trace.structure_version != self.neg_version || trace.instance_id != self.neg_trace {
            self.neg.clear();
            self.neg_trace = trace.instance_id;
            self.neg_version = trace.structure_version;
        }
        // the global section is read by every plan: freshen it once and
        // compute its candidate values under the pin once per batch
        for &g in &p.global_drg {
            trace.ensure_fresh(g);
        }
        candidate_globals(trace, p, new_v, &mut self.arena.globals)?;
        let mut out = vec![0.0f64; roots.len()];
        // (output position, root) pairs left for the scalar tiers
        let mut rest: Vec<(usize, NodeId)> = Vec::new();
        if self.batched {
            let set = trace.cached_batch_plans(p);
            // the store mirrors the batch set group-for-group; a fresh
            // build means the structure moved (or this is first use)
            let store = if self.colstore && !set.groups.is_empty() {
                let evicted_before = trace.store_evictions();
                let (rc, built) = trace.cached_colstore(p, &set);
                if built {
                    self.store_rebuilds += 1;
                }
                // a fresh build sweeps stores whose principals were
                // abandoned by the structural rebuild; attribute those
                // evictions to the traffic that triggered the sweep
                self.store_evicted += (trace.store_evictions() - evicted_before) as usize;
                Some(rc)
            } else {
                None
            };
            if self.sel.len() < set.groups.len() {
                self.sel.resize_with(set.groups.len(), Vec::new);
            }
            for s in &mut self.sel {
                s.clear();
            }
            for (pos, &r) in roots.iter().enumerate() {
                match set.of_root.get(&r) {
                    Some(&(gi, mi)) => self.sel[gi as usize].push((mi, pos as u32)),
                    None => rest.push((pos, r)),
                }
            }
            for (gi, group) in set.groups.iter().enumerate() {
                if self.sel[gi].is_empty() {
                    continue;
                }
                let sel = std::mem::take(&mut self.sel[gi]);
                // tier 1a: gather from the persistent store (lazy
                // per-member value_version refresh inside) and run the
                // lane-panel kernel — bitwise identical to the packed
                // kernel per section
                let mut scored = match &store {
                    Some(rc) => match self.eval_group_store(trace, rc, gi, group, &sel) {
                        Ok(()) => true,
                        Err(StoreErr::Integrity(msg)) => {
                            // condemn the store for this group: fresh
                            // packing takes over (bitwise identical)
                            // until a structural rebuild replaces the
                            // panels.  Logged once — the quarantined
                            // flag short-circuits every later batch.
                            let mut cs = rc.borrow_mut();
                            let g = &mut cs.groups[gi];
                            if !g.quarantined {
                                g.quarantined = true;
                                self.store_quarantined += 1;
                                eprintln!(
                                    "[store] group {gi} quarantined: {msg} \
                                     (fresh-pack fallback; results unchanged)"
                                );
                            }
                            false
                        }
                        Err(_) => false,
                    },
                    None => false,
                };
                if scored {
                    self.gathered_sections += sel.len();
                }
                // tier 1b (and the store's fallback/oracle): fresh
                // pack + replay.  Parallel rung: pack once (into the
                // reclaimed spare batch), shard the kernel across the
                // pool; otherwise the sequential pack+replay.  All of
                // these run the same per-section scalar op sequence,
                // so results are bitwise identical.
                if !scored {
                    // lazy §3.5 refresh of everything the sampled slot
                    // tables read
                    for &(mi, _) in &sel {
                        for &t in group.touch_of(mi as usize) {
                            trace.ensure_fresh(t);
                        }
                    }
                    let replayed = match self.shard.as_mut() {
                        Some(sh) if sh.should_dispatch(sel.len()) => {
                            let mut pb = self.packed_spare.take().unwrap_or_default();
                            match pb.pack_into(trace, group, &sel, &self.arena.globals) {
                                Ok(()) => sh.replay(pb, &mut self.batch_out).map(|spare| {
                                    self.packed_spare = spare;
                                }),
                                Err(e) => {
                                    self.packed_spare = Some(pb);
                                    Err(e)
                                }
                            }
                        }
                        _ => self.regs.replay(
                            trace,
                            group,
                            &sel,
                            &self.arena.globals,
                            &mut self.batch_out,
                        ),
                    };
                    scored = replayed.is_ok();
                }
                if scored {
                    for (&(_, pos), &l) in sel.iter().zip(&self.batch_out) {
                        out[pos as usize] = l;
                    }
                    self.planned_sections += sel.len();
                    self.batched_sections += sel.len();
                } else {
                    // replay refused (a binding changed type): re-score
                    // this group's sample on the scalar path, which
                    // reproduces the oracle exactly
                    for &(_, pos) in &sel {
                        rest.push((pos as usize, roots[pos as usize]));
                    }
                }
                self.sel[gi] = sel;
            }
        } else {
            rest.extend(roots.iter().copied().enumerate());
        }
        for (pos, r) in rest {
            self.eval_one(trace, p, r, new_v, &mut out, pos)?;
        }
        Ok(out)
    }

    fn name(&self) -> &'static str {
        match (self.batched, self.shard.is_some()) {
            (true, true) => "planned-parallel",
            (true, false) => "planned-batched",
            (false, _) => "planned",
        }
    }

    fn stats(&self) -> EvalStats {
        PlannedEval::stats(self)
    }

    fn note_risk(&mut self, realized: f64) {
        self.risk_transitions += 1;
        self.risk_micro = self
            .risk_micro
            .saturating_add((realized.clamp(0.0, 1.0) * 1e6).round() as usize);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::chain::{build_bayes_lr, build_joint_dpm, build_sv};
    use crate::data::{dpm_data, sv_data, synth2d};
    use crate::infer::subsampled_mh::subsampled_mh_transition;
    use crate::infer::{gibbs_transition, Proposal, SubsampledConfig};
    use crate::math::Pcg64;
    use crate::stats::RunningMoments;

    fn assert_bitwise(planned: &[f64], interp: &[f64]) {
        assert_eq!(planned.len(), interp.len());
        for (i, (a, b)) in planned.iter().zip(interp).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "l[{i}] differs: planned {a} vs interpreter {b}"
            );
        }
    }

    /// Differential: logistic regression (Fig. 3), whole population —
    /// interpreter vs scalar plans vs shape-grouped batch replay.
    #[test]
    fn planned_matches_interpreter_bitwise_logistic() {
        let data = synth2d::generate(400, 1);
        let mut rng = Pcg64::seeded(2);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        let p = trace.cached_partition(w).unwrap();
        let cur = trace.fresh_value(w);
        for step in 0..5 {
            let new_w = Proposal::Drift(0.2).propose(&cur, &mut rng).unwrap();
            let roots = p.locals.clone();
            let mut interp = InterpreterEval;
            let want = interp.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
            let mut scalar = PlannedEval::scalar();
            let got = scalar.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
            assert_bitwise(&got, &want);
            assert_eq!(scalar.planned_sections, roots.len(), "step {step}");
            assert_eq!(scalar.batched_sections, 0);
            assert_eq!(scalar.fallback_sections, 0);
            let mut batched = PlannedEval::new();
            let got = batched.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
            assert_bitwise(&got, &want);
            assert_eq!(batched.planned_sections, roots.len(), "step {step}");
            assert_eq!(batched.batched_sections, roots.len(), "step {step}");
            assert_eq!(batched.fallback_sections, 0);
        }
    }

    /// The batched path must score a *sampled subset* (not just whole
    /// populations) identically to the oracle, in sampled order.
    #[test]
    fn batched_subset_matches_interpreter_bitwise() {
        let data = synth2d::generate(300, 11);
        let mut rng = Pcg64::seeded(12);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        let p = trace.cached_partition(w).unwrap();
        let cur = trace.fresh_value(w);
        let new_w = Proposal::Drift(0.15).propose(&cur, &mut rng).unwrap();
        // a shuffled, strict subset of the locals
        let idx = rng.sample_without_replacement(p.n(), 97);
        let roots: Vec<_> = idx.iter().map(|&i| p.locals[i]).collect();
        let mut interp = InterpreterEval;
        let want = interp.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
        let mut batched = PlannedEval::new();
        let got = batched.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
        assert_bitwise(&got, &want);
        assert_eq!(batched.batched_sections, roots.len());
    }

    /// Differential: JointDPM expert weights (Fig. 7 top) — sections
    /// route through MemApp nodes keyed by the cluster assignments.
    #[test]
    fn planned_matches_interpreter_bitwise_dpm() {
        let (data, _) = dpm_data::generate(60, 3);
        let mut rng = Pcg64::seeded(4);
        let mut trace = build_joint_dpm(&data, &mut rng);
        let ws = trace.scope_nodes("w");
        let mut checked = 0;
        for wk in ws {
            let Some(p) = trace.cached_partition(wk) else {
                continue; // singleton cluster: no border
            };
            let cur = trace.fresh_value(wk);
            let new_w = Proposal::Drift(0.3).propose(&cur, &mut rng).unwrap();
            let roots = p.locals.clone();
            let mut interp = InterpreterEval;
            let want = interp.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
            let mut planned = PlannedEval::new();
            let got = planned.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
            assert_bitwise(&got, &want);
            assert_eq!(planned.fallback_sections, 0);
            // DPM weight sections route a *vector* global through a
            // MemApp copy — they must still hit the columnar path
            assert_eq!(planned.batched_sections, roots.len());
            checked += 1;
        }
        assert!(checked > 0, "no DPM cluster had a border partition");
    }

    /// Differential: stochastic volatility (Fig. 7 bottom) for both phi
    /// (det mul sections) and sigma^2 (bare absorbing sections through a
    /// length-2 global path).
    #[test]
    fn planned_matches_interpreter_bitwise_sv() {
        let cfg = sv_data::SvConfig {
            series: 8,
            len: 5,
            ..Default::default()
        };
        let series = sv_data::generate(&cfg, 5);
        let mut rng = Pcg64::seeded(6);
        let (mut trace, phi, sig2) = build_sv(&series, &mut rng);
        for (v, sigma) in [(phi, 0.05), (sig2, 0.01)] {
            let p = trace.cached_partition(v).unwrap();
            let cur = trace.fresh_value(v);
            let new_v = Proposal::Drift(sigma).propose(&cur, &mut rng).unwrap();
            let roots = p.locals.clone();
            let mut interp = InterpreterEval;
            let want = interp.eval_sections(&mut trace, &p, &roots, &new_v).unwrap();
            let mut planned = PlannedEval::new();
            let got = planned.eval_sections(&mut trace, &p, &roots, &new_v).unwrap();
            assert_bitwise(&got, &want);
            assert_eq!(planned.planned_sections, roots.len());
            assert_eq!(planned.batched_sections, roots.len());
            assert_eq!(planned.fallback_sections, 0);
        }
    }

    /// Plans are reused while the structure is unchanged, and rebuilt —
    /// not reused — after a structural transition (gibbs resampling a
    /// mem application re-keys it between clusters).
    #[test]
    fn plans_invalidate_on_structural_change() {
        let n = 12;
        let mut rng = Pcg64::seeded(7);
        let mut src = String::from(
            "[assume crp (make_crp 2.0)]\n\
             [assume z (mem (lambda (i) (crp)))]\n\
             [assume muk (mem (lambda (k) (scope_include 'muk k (normal 0 3))))]\n\
             [assume x (lambda (i) (normal (muk (z i)) 0.8))]\n",
        );
        for i in 0..n {
            src.push_str(&format!("[observe (x {i}) {}]\n", (i % 5) as f64 - 2.0));
        }
        let mut trace = Trace::new();
        trace.run_program(&src, &mut rng).unwrap();
        let zs: Vec<NodeId> = (0..n)
            .map(|i| {
                let e = crate::ppl::parser::parse_expr(&format!("(z {i})")).unwrap();
                let mut ev = crate::trace::Evaluator::new(&mut trace, &mut rng);
                let env = ev.trace.global_env.clone();
                ev.eval(&e, &env).unwrap().node().unwrap()
            })
            .collect();
        let find_partitioned =
            |trace: &Trace| -> Option<(NodeId, std::rc::Rc<Partition>)> {
                trace
                    .scope_nodes("muk")
                    .into_iter()
                    .find_map(|mk| trace.cached_partition(mk).map(|p| (mk, p)))
            };
        let (mk, p) = find_partitioned(&trace).expect("no cluster with >= 2 points");
        let plan_a = trace.cached_section_plan(&p, p.locals[0]).unwrap();
        // same structure => same plan object, not a rebuild
        let plan_b = trace.cached_section_plan(&p, p.locals[0]).unwrap();
        assert!(std::rc::Rc::ptr_eq(&plan_a, &plan_b));
        let v0 = trace.structure_version;
        // churn cluster assignments until a committed re-key actually
        // changes the structure (rolled-back candidate evaluations
        // restore the version, so only real structural change counts)
        let mut changed = false;
        for step in 0..2000 {
            let z = zs[step % n];
            gibbs_transition(&mut trace, &mut rng, z).unwrap();
            if trace.structure_version != v0 {
                changed = true;
                break;
            }
        }
        assert!(changed, "gibbs churn never re-keyed a mem application");
        // stale plans must be rebuilt against the new structure
        let (mk2, p2) = find_partitioned(&trace).expect("all clusters died");
        let plan_c = trace.cached_section_plan(&p2, p2.locals[0]).unwrap();
        assert_eq!(plan_c.built_at, trace.structure_version);
        assert_ne!(plan_c.built_at, plan_a.built_at);
        // and the rebuilt plan still scores exactly like the oracle
        let cur = trace.fresh_value(mk2);
        let new_v = Proposal::Drift(0.5).propose(&cur, &mut rng).unwrap();
        let roots = p2.locals.clone();
        let mut interp = InterpreterEval;
        let want = interp.eval_sections(&mut trace, &p2, &roots, &new_v).unwrap();
        let mut planned = PlannedEval::new();
        let got = planned.eval_sections(&mut trace, &p2, &roots, &new_v).unwrap();
        assert_bitwise(&got, &want);
        let _ = mk;
    }

    /// The store tier serves repeat batches by pure gather (no
    /// refreshes) and stays bitwise identical to the fresh-pack path.
    #[test]
    fn store_tier_gathers_and_matches_pack_bitwise() {
        let data = synth2d::generate(250, 31);
        let mut rng = Pcg64::seeded(32);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        let p = trace.cached_partition(w).unwrap();
        let roots = p.locals.clone();
        let cur = trace.fresh_value(w);
        let new_w = Proposal::Drift(0.2).propose(&cur, &mut rng).unwrap();
        let mut packed = PlannedEval::new().with_colstore(false);
        let want = packed.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
        assert_eq!(packed.gathered_sections, 0, "kill switch must disable the store");
        let mut store = PlannedEval::new().with_colstore(true);
        let got = store.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
        assert_bitwise(&got, &want);
        assert_eq!(store.gathered_sections, roots.len());
        assert_eq!(store.batched_sections, roots.len());
        assert_eq!(store.store_rebuilds, 1);
        assert_eq!(store.store_refreshed, roots.len(), "first batch fills the rows");
        // second batch (no commit in between): pure gather, zero misses
        let new_w2 = Proposal::Drift(0.2).propose(&cur, &mut rng).unwrap();
        let mut interp = InterpreterEval;
        let want = interp.eval_sections(&mut trace, &p, &roots, &new_w2).unwrap();
        let got = store.eval_sections(&mut trace, &p, &roots, &new_w2).unwrap();
        assert_bitwise(&got, &want);
        assert_eq!(store.store_refreshed, roots.len(), "steady state must not re-read");
        assert_eq!(store.store_rebuilds, 1, "unchanged structure must not rebuild");
    }

    /// A quarantined store group keeps scoring bitwise identically —
    /// the evaluator routes it to fresh packing instead of its panels
    /// — and a structural rebuild lifts the quarantine.
    #[test]
    fn quarantined_group_scores_bitwise_via_fresh_pack() {
        let data = synth2d::generate(200, 41);
        let mut rng = Pcg64::seeded(42);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        let p = trace.cached_partition(w).unwrap();
        let roots = p.locals.clone();
        let cur = trace.fresh_value(w);
        let new_w = Proposal::Drift(0.2).propose(&cur, &mut rng).unwrap();
        let mut ev = PlannedEval::new().with_colstore(true);
        let first = ev.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
        assert_eq!(ev.gathered_sections, roots.len(), "store tier must engage first");
        // condemn every group, as an integrity failure would
        {
            let set = trace.cached_batch_plans(&p);
            let (store, built) = trace.cached_colstore(&p, &set);
            assert!(!built, "the first eval built the store");
            for g in &mut store.borrow_mut().groups {
                g.quarantined = true;
            }
        }
        let again = ev.eval_sections(&mut trace, &p, &roots, &new_w).unwrap();
        assert_bitwise(&again, &first);
        assert_eq!(
            ev.gathered_sections,
            roots.len(),
            "quarantined groups must not be served from panels"
        );
        assert_eq!(ev.batched_sections, 2 * roots.len(), "fresh pack took over");
        // a structural rebuild replaces the condemned store wholesale
        trace
            .run_program("[observe (f (vector 0.3 -0.2 1.0)) true]", &mut rng)
            .unwrap();
        let p2 = trace.cached_partition(w).unwrap();
        let roots2 = p2.locals.clone();
        let mut interp = InterpreterEval;
        let want = interp.eval_sections(&mut trace, &p2, &roots2, &new_w).unwrap();
        let before = ev.gathered_sections;
        let got = ev.eval_sections(&mut trace, &p2, &roots2, &new_w).unwrap();
        assert_bitwise(&got, &want);
        assert_eq!(
            ev.gathered_sections,
            before + roots2.len(),
            "rebuild must lift the quarantine"
        );
        assert_eq!(ev.store_rebuilds, 2);
    }

    /// Satellite audit: every `EvalStats` counter is monotonic across
    /// an evaluator's lifetime — including across structural rebuilds
    /// (new observation => partitions/plans/batch sets/store all
    /// rebuilt, neg cache reset) — so monitor per-interval diffs can
    /// never go negative.
    #[test]
    fn stats_stay_monotonic_across_rebuilds() {
        let data = synth2d::generate(200, 33);
        let mut rng = Pcg64::seeded(34);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        let cfg = SubsampledConfig {
            m: 40,
            eps: 0.01,
            proposal: Proposal::Drift(0.1),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = PlannedEval::new();
        let monotone = |a: &EvalStats, b: &EvalStats| {
            b.planned >= a.planned
                && b.batched >= a.batched
                && b.gathered >= a.gathered
                && b.fallback >= a.fallback
                && b.sharded >= a.sharded
                && b.stolen >= a.stolen
                && b.store_rebuilds >= a.store_rebuilds
                && b.fallback_panics >= a.fallback_panics
                && b.requeued_shards >= a.requeued_shards
                && b.store_quarantined >= a.store_quarantined
                && b.chains_restarted >= a.chains_restarted
                && b.store_evicted >= a.store_evicted
                && b.risk_transitions >= a.risk_transitions
                && b.risk_micro >= a.risk_micro
        };
        let mut prev = ev.stats();
        assert_eq!(prev, EvalStats::default());
        for step in 0..30 {
            subsampled_mh_transition(&mut trace, &mut rng, w, &cfg, &mut ev).unwrap();
            if step == 14 {
                // structural change mid-run: every structure-keyed
                // cache (and the store) rebuilds on next use
                trace
                    .run_program("[observe (f (vector 0.2 -0.1 1.0)) true]", &mut rng)
                    .unwrap();
            }
            let cur = ev.stats();
            assert!(monotone(&prev, &cur), "counters regressed at step {step}");
            // diff of consecutive snapshots is exact (no saturation hit)
            let d = cur.diff(&prev);
            assert_eq!(prev.add(&d), cur);
            prev = cur;
        }
        assert!(prev.gathered > 0, "store tier never engaged");
        assert!(prev.store_rebuilds >= 2, "rebuild after the structural change");
    }

    /// Satellite: on DPM-style runs with many short-lived clusters the
    /// column-store cache must not accumulate panels for abandoned
    /// principals — structural rebuilds sweep them (counted in
    /// `store_evictions`), keeping the footprint bounded by the live
    /// cluster count.
    #[test]
    fn store_cache_stays_bounded_under_cluster_churn() {
        let n = 16;
        let mut rng = Pcg64::seeded(51);
        let mut src = String::from(
            "[assume crp (make_crp 1.5)]\n\
             [assume z (mem (lambda (i) (crp)))]\n\
             [assume muk (mem (lambda (k) (scope_include 'muk k (normal 0 3))))]\n\
             [assume x (lambda (i) (normal (muk (z i)) 0.8))]\n",
        );
        for i in 0..n {
            src.push_str(&format!("[observe (x {i}) {}]\n", (i % 5) as f64 - 2.0));
        }
        let mut trace = Trace::new();
        trace.run_program(&src, &mut rng).unwrap();
        let zs: Vec<NodeId> = (0..n)
            .map(|i| {
                let e = crate::ppl::parser::parse_expr(&format!("(z {i})")).unwrap();
                let mut ev = crate::trace::Evaluator::new(&mut trace, &mut rng);
                let env = ev.trace.global_env.clone();
                ev.eval(&e, &env).unwrap().node().unwrap()
            })
            .collect();
        let cfg = SubsampledConfig {
            m: 4,
            eps: 0.05,
            proposal: Proposal::Drift(0.3),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = PlannedEval::new().with_colstore(true);
        let sample_live = |trace: &mut Trace, rng: &mut Pcg64, ev: &mut PlannedEval| {
            for mk in trace.scope_nodes("muk") {
                if trace.cached_partition(mk).is_some() {
                    subsampled_mh_transition(trace, rng, mk, &cfg, ev).unwrap();
                }
            }
        };
        // alternate: build stores for every live cluster, then churn
        // assignments until the structure actually moves
        let (mut churns, mut step) = (0, 0);
        while churns < 5 && step < 20_000 {
            sample_live(&mut trace, &mut rng, &mut ev);
            let v0 = trace.structure_version;
            while trace.structure_version == v0 && step < 20_000 {
                let z = zs[step % n];
                gibbs_transition(&mut trace, &mut rng, z).unwrap();
                step += 1;
            }
            if trace.structure_version == v0 {
                break;
            }
            churns += 1;
        }
        assert!(churns >= 5, "gibbs churn never re-keyed enough: {churns}");
        // one more pass so the last structural change gets its sweep
        sample_live(&mut trace, &mut rng, &mut ev);
        assert!(
            trace.store_evictions() > 0,
            "cluster churn never evicted an abandoned store"
        );
        assert_eq!(
            ev.store_evicted as u64,
            trace.store_evictions(),
            "the driving evaluator must observe every eviction delta"
        );
        let live = trace.scope_nodes("muk").len();
        assert!(
            trace.colstore_cache_len() <= live,
            "store cache holds {} entries for {} live clusters",
            trace.colstore_cache_len(),
            live
        );
    }

    /// End-to-end: the planned evaluator drives subsampled transitions
    /// to the same posterior region as the interpreter (LR separator).
    #[test]
    fn planned_subsampled_chain_finds_separator() {
        let data = synth2d::generate(1500, 8);
        let mut rng = Pcg64::seeded(9);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        let cfg = SubsampledConfig {
            m: 100,
            eps: 0.01,
            proposal: Proposal::Drift(0.08),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut ev = PlannedEval::new();
        let (mut m0, mut m1) = (RunningMoments::new(), RunningMoments::new());
        for i in 0..2000 {
            subsampled_mh_transition(&mut trace, &mut rng, w, &cfg, &mut ev).unwrap();
            if i > 400 {
                let wv = trace.fresh_value(w);
                let wv = wv.as_vector().unwrap().clone();
                m0.push(wv[0]);
                m1.push(wv[1]);
            }
        }
        assert!(ev.planned_sections > 0);
        assert!(ev.batched_sections > 0, "default evaluator must batch");
        assert_eq!(ev.fallback_sections, 0);
        // synth2d's separator points along (+1, +1)
        assert!(m0.mean() > 0.2, "w0 mean {}", m0.mean());
        assert!(m1.mean() > 0.2, "w1 mean {}", m1.mean());
        assert!(trace.log_joint().is_finite());
    }
}
