//! Tokenizer for the s-expression surface syntax.

#[derive(Clone, Debug, PartialEq)]
pub enum Token {
    LParen,
    RParen,
    LBracket,
    RBracket,
    Quote,
    Int(i64),
    Real(f64),
    Sym(String),
    Bool(bool),
}

/// Tokenize a program string.  `;` starts a line comment and `#` too
/// (the paper's listings use `#`).
pub fn tokenize(src: &str) -> Result<Vec<Token>, String> {
    let mut out = Vec::new();
    let mut chars = src.chars().peekable();
    while let Some(&c) = chars.peek() {
        match c {
            c if c.is_whitespace() => {
                chars.next();
            }
            ';' | '#' => {
                for c in chars.by_ref() {
                    if c == '\n' {
                        break;
                    }
                }
            }
            '(' => {
                chars.next();
                out.push(Token::LParen);
            }
            ')' => {
                chars.next();
                out.push(Token::RParen);
            }
            '[' => {
                chars.next();
                out.push(Token::LBracket);
            }
            ']' => {
                chars.next();
                out.push(Token::RBracket);
            }
            '\'' => {
                chars.next();
                out.push(Token::Quote);
            }
            _ => {
                let mut atom = String::new();
                while let Some(&c) = chars.peek() {
                    if c.is_whitespace() || "()[]';#".contains(c) {
                        break;
                    }
                    atom.push(c);
                    chars.next();
                }
                out.push(classify_atom(&atom)?);
            }
        }
    }
    Ok(out)
}

fn classify_atom(atom: &str) -> Result<Token, String> {
    if atom.is_empty() {
        return Err("empty atom".into());
    }
    match atom {
        "true" | "#t" => return Ok(Token::Bool(true)),
        "false" | "#f" => return Ok(Token::Bool(false)),
        _ => {}
    }
    // int?
    if let Ok(i) = atom.parse::<i64>() {
        return Ok(Token::Int(i));
    }
    // real?
    if let Ok(x) = atom.parse::<f64>() {
        // reject things like "-" or "+" that parse::<f64> would not
        return Ok(Token::Real(x));
    }
    Ok(Token::Sym(atom.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenizes_basic_program() {
        let toks = tokenize("[assume b (bernoulli 0.5)]").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::LBracket,
                Token::Sym("assume".into()),
                Token::Sym("b".into()),
                Token::LParen,
                Token::Sym("bernoulli".into()),
                Token::Real(0.5),
                Token::RParen,
                Token::RBracket,
            ]
        );
    }

    #[test]
    fn handles_comments_quotes_negatives() {
        let toks = tokenize("; comment\n(foo 'bar -2 -0.5) # trailing").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::LParen,
                Token::Sym("foo".into()),
                Token::Quote,
                Token::Sym("bar".into()),
                Token::Int(-2),
                Token::Real(-0.5),
                Token::RParen,
            ]
        );
    }

    #[test]
    fn booleans_and_symbols_with_specials() {
        let toks = tokenize("true false <= foo_bar? *").unwrap();
        assert_eq!(
            toks,
            vec![
                Token::Bool(true),
                Token::Bool(false),
                Token::Sym("<=".into()),
                Token::Sym("foo_bar?".into()),
                Token::Sym("*".into()),
            ]
        );
    }
}
