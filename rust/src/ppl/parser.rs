//! Parser: tokens -> directives / expressions.

use crate::ppl::ast::{Directive, Expr};
use crate::ppl::lexer::{tokenize, Token};
use crate::ppl::value::Value;
use std::rc::Rc;

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&Token> {
        self.toks.get(self.pos)
    }

    fn next(&mut self) -> Result<Token, String> {
        let t = self.toks.get(self.pos).cloned().ok_or("unexpected EOF")?;
        self.pos += 1;
        Ok(t)
    }

    fn expect(&mut self, t: Token) -> Result<(), String> {
        let got = self.next()?;
        if got == t {
            Ok(())
        } else {
            Err(format!("expected {t:?}, got {got:?}"))
        }
    }

    /// Parse one expression.
    fn expr(&mut self) -> Result<Rc<Expr>, String> {
        match self.next()? {
            Token::Int(i) => Ok(Expr::constant(Value::Int(i))),
            Token::Real(x) => Ok(Expr::constant(Value::Real(x))),
            Token::Bool(b) => Ok(Expr::constant(Value::Bool(b))),
            Token::Sym(s) => Ok(Expr::sym(&s)),
            Token::Quote => match self.next()? {
                Token::Sym(s) => Ok(Expr::constant(Value::sym(&s))),
                t => Err(format!("expected symbol after quote, got {t:?}")),
            },
            Token::LParen => self.form(),
            t => Err(format!("unexpected token {t:?}")),
        }
    }

    /// Parse the inside of a `( ... )` form (opening paren consumed).
    fn form(&mut self) -> Result<Rc<Expr>, String> {
        // special forms dispatch on the head symbol
        let head_is = |p: &Parser, s: &str| matches!(p.peek(), Some(Token::Sym(h)) if h == s);
        if head_is(self, "if") {
            self.next()?;
            let p = self.expr()?;
            let c = self.expr()?;
            let a = self.expr()?;
            self.expect(Token::RParen)?;
            return Ok(Rc::new(Expr::If(p, c, a)));
        }
        if head_is(self, "lambda") {
            self.next()?;
            self.expect(Token::LParen)?;
            let mut params = Vec::new();
            loop {
                match self.next()? {
                    Token::RParen => break,
                    Token::Sym(s) => params.push(Rc::from(s.as_str())),
                    t => return Err(format!("bad lambda param {t:?}")),
                }
            }
            let body = self.expr()?;
            self.expect(Token::RParen)?;
            return Ok(Rc::new(Expr::Lambda(params, body)));
        }
        if head_is(self, "let") {
            self.next()?;
            self.expect(Token::LParen)?;
            let mut binds = Vec::new();
            loop {
                match self.next()? {
                    Token::RParen => break,
                    Token::LParen => {
                        let name = match self.next()? {
                            Token::Sym(s) => Rc::from(s.as_str()),
                            t => return Err(format!("bad let name {t:?}")),
                        };
                        let e = self.expr()?;
                        self.expect(Token::RParen)?;
                        binds.push((name, e));
                    }
                    t => return Err(format!("bad let binding {t:?}")),
                }
            }
            let body = self.expr()?;
            self.expect(Token::RParen)?;
            return Ok(Rc::new(Expr::Let(binds, body)));
        }
        if head_is(self, "mem") {
            self.next()?;
            let inner = self.expr()?;
            self.expect(Token::RParen)?;
            return Ok(Rc::new(Expr::Mem(inner)));
        }
        if head_is(self, "scope_include") {
            self.next()?;
            let scope = self.expr()?;
            let block = self.expr()?;
            let body = self.expr()?;
            self.expect(Token::RParen)?;
            return Ok(Rc::new(Expr::ScopeInclude(scope, block, body)));
        }
        if head_is(self, "quote") {
            self.next()?;
            let v = match self.next()? {
                Token::Sym(s) => Value::sym(&s),
                Token::Int(i) => Value::Int(i),
                Token::Real(x) => Value::Real(x),
                t => return Err(format!("bad quote payload {t:?}")),
            };
            self.expect(Token::RParen)?;
            return Ok(Expr::constant(v));
        }
        // plain application
        let mut parts = Vec::new();
        loop {
            if matches!(self.peek(), Some(Token::RParen)) {
                self.next()?;
                break;
            }
            if self.peek().is_none() {
                return Err("unterminated form".into());
            }
            parts.push(self.expr()?);
        }
        if parts.is_empty() {
            return Err("empty application ()".into());
        }
        Ok(Expr::app(parts))
    }

    /// Parse a `[directive ...]`.
    fn directive(&mut self) -> Result<Directive, String> {
        // opening bracket consumed by caller
        let head = match self.next()? {
            Token::Sym(s) => s,
            t => return Err(format!("bad directive head {t:?}")),
        };
        let d = match head.as_str() {
            "assume" => {
                let name = match self.next()? {
                    Token::Sym(s) => Rc::from(s.as_str()),
                    t => return Err(format!("bad assume name {t:?}")),
                };
                let e = self.expr()?;
                Directive::Assume(name, e)
            }
            "observe" => {
                let e = self.expr()?;
                let v = self.literal_value()?;
                Directive::Observe(e, v)
            }
            "predict" => Directive::Predict(self.expr()?),
            other => return Err(format!("unknown directive [{other} ...]")),
        };
        self.expect(Token::RBracket)?;
        Ok(d)
    }

    /// Parse a literal value (for observe right-hand sides).
    fn literal_value(&mut self) -> Result<Value, String> {
        match self.next()? {
            Token::Int(i) => Ok(Value::Int(i)),
            Token::Real(x) => Ok(Value::Real(x)),
            Token::Bool(b) => Ok(Value::Bool(b)),
            Token::Quote => match self.next()? {
                Token::Sym(s) => Ok(Value::sym(&s)),
                t => Err(format!("bad quoted literal {t:?}")),
            },
            // (vector x1 x2 ...) literals, or (list ...)
            Token::LParen => {
                let head = match self.next()? {
                    Token::Sym(s) => s,
                    t => return Err(format!("bad literal form head {t:?}")),
                };
                let mut xs = Vec::new();
                loop {
                    match self.peek() {
                        Some(Token::RParen) => {
                            self.next()?;
                            break;
                        }
                        _ => xs.push(self.literal_value()?),
                    }
                }
                match head.as_str() {
                    "vector" | "array" => {
                        let nums: Option<Vec<f64>> = xs.iter().map(|v| v.as_f64()).collect();
                        nums.map(Value::vector)
                            .ok_or_else(|| "non-numeric vector literal".into())
                    }
                    "list" => Ok(Value::List(Rc::new(xs))),
                    other => Err(format!("unknown literal constructor ({other} ...)")),
                }
            }
            t => Err(format!("bad literal {t:?}")),
        }
    }
}

/// Parse a full program: a sequence of bracketed directives.
pub fn parse_program(src: &str) -> Result<Vec<Directive>, String> {
    let toks = tokenize(src)?;
    let mut p = Parser { toks, pos: 0 };
    let mut out = Vec::new();
    while let Some(t) = p.peek() {
        match t {
            Token::LBracket => {
                p.next()?;
                out.push(p.directive()?);
            }
            t => return Err(format!("expected [directive], got {t:?}")),
        }
    }
    Ok(out)
}

/// Parse a single expression (for tests and the infer mini-language).
pub fn parse_expr(src: &str) -> Result<Rc<Expr>, String> {
    let toks = tokenize(src)?;
    let mut p = Parser { toks, pos: 0 };
    let e = p.expr()?;
    if p.peek().is_some() {
        return Err("trailing tokens after expression".into());
    }
    Ok(e)
}

/// Parse a literal value.
pub fn parse_value(src: &str) -> Result<Value, String> {
    let toks = tokenize(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.literal_value()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_fig1_program() {
        let src = r#"
            [assume b (bernoulli 0.5)]
            [assume mu (if b 1 (gamma 1 1))]
            [assume y (normal mu 0.1)]
            [observe y 10.0]
        "#;
        let prog = parse_program(src).unwrap();
        assert_eq!(prog.len(), 4);
        assert!(matches!(&prog[0], Directive::Assume(n, _) if &**n == "b"));
        assert!(matches!(&prog[3], Directive::Observe(_, Value::Real(x)) if *x == 10.0));
    }

    #[test]
    fn parses_lambda_mem_scope() {
        let src = r#"
            [assume h (mem (lambda (t) (if (<= t 0) 0 (normal (* 0.9 (h (- t 1))) 0.1))))]
            [assume w (scope_include 'w 0 (normal 0 1))]
        "#;
        let prog = parse_program(src).unwrap();
        assert_eq!(prog.len(), 2);
        match &prog[0] {
            Directive::Assume(_, e) => assert!(matches!(&**e, Expr::Mem(_))),
            _ => panic!(),
        }
        match &prog[1] {
            Directive::Assume(_, e) => assert!(matches!(&**e, Expr::ScopeInclude(..))),
            _ => panic!(),
        }
    }

    #[test]
    fn parses_vector_observe() {
        let prog = parse_program("[observe (f 1) (vector 1.0 2 -3.5)]").unwrap();
        match &prog[0] {
            Directive::Observe(_, Value::Vector(v)) => {
                assert_eq!(***v, vec![1.0, 2.0, -3.5])
            }
            d => panic!("{d:?}"),
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_program("[assume]").is_err());
        assert!(parse_program("(not-a-directive)").is_err());
        assert!(parse_expr("(unclosed").is_err());
        assert!(parse_expr("()").is_err());
    }

    #[test]
    fn parses_let_and_quote() {
        let e = parse_expr("(let ((a 1) (b (f a))) (+ a b))").unwrap();
        assert!(matches!(&*e, Expr::Let(binds, _) if binds.len() == 2));
        let q = parse_expr("(quote foo)").unwrap();
        assert!(matches!(&*q, Expr::Const(Value::Sym(s)) if &**s == "foo"));
    }
}
