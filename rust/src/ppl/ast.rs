//! Abstract syntax of the modeling language.

use crate::ppl::value::Value;
use std::rc::Rc;

/// An expression.  `Rc<Expr>` is shared between the AST and the trace
/// nodes that need to re-evaluate it (If branches, mem bodies).
#[derive(Clone, Debug)]
pub enum Expr {
    /// Literal constant.
    Const(Value),
    /// Variable reference.
    Sym(Rc<str>),
    /// (if pred conseq alt)
    If(Rc<Expr>, Rc<Expr>, Rc<Expr>),
    /// (lambda (params...) body)
    Lambda(Vec<Rc<str>>, Rc<Expr>),
    /// (let ((name expr)...) body)
    Let(Vec<(Rc<str>, Rc<Expr>)>, Rc<Expr>),
    /// (mem proc-expr)
    Mem(Rc<Expr>),
    /// (scope_include 'scope block expr)
    ScopeInclude(Rc<Expr>, Rc<Expr>, Rc<Expr>),
    /// (op args...)
    App(Vec<Rc<Expr>>),
}

/// A top-level directive.
#[derive(Clone, Debug)]
pub enum Directive {
    /// [assume name expr]
    Assume(Rc<str>, Rc<Expr>),
    /// [observe expr value]
    Observe(Rc<Expr>, Value),
    /// [predict expr]
    Predict(Rc<Expr>),
}

impl Expr {
    pub fn constant(v: Value) -> Rc<Expr> {
        Rc::new(Expr::Const(v))
    }

    pub fn sym(s: &str) -> Rc<Expr> {
        Rc::new(Expr::Sym(Rc::from(s)))
    }

    pub fn app(parts: Vec<Rc<Expr>>) -> Rc<Expr> {
        Rc::new(Expr::App(parts))
    }
}
