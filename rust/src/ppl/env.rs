//! Lexical environments.
//!
//! A binding maps a name either to a *static* value (primitives,
//! closures, constants — things whose value can never change during
//! inference) or to a trace *node* (assumed random variables, closure
//! parameters backed by nodes).  Static bindings are what lets the
//! evaluator constant-fold pure sub-expressions instead of materializing
//! nodes for them.

use crate::ppl::value::Value;
use crate::trace::node::NodeId;
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

/// What a name resolves to.
#[derive(Clone, Debug)]
pub enum Binding {
    /// A value fixed for the lifetime of the trace.
    Static(Value),
    /// The node whose (mutable) value the name denotes.
    Node(NodeId),
}

/// One environment frame.
#[derive(Debug)]
pub struct Env {
    frame: RefCell<HashMap<Rc<str>, Binding>>,
    parent: Option<EnvRef>,
}

pub type EnvRef = Rc<Env>;

impl Env {
    /// Fresh root environment.
    pub fn root() -> EnvRef {
        Rc::new(Env {
            frame: RefCell::new(HashMap::new()),
            parent: None,
        })
    }

    /// Child environment extending `parent`.
    pub fn child(parent: &EnvRef) -> EnvRef {
        Rc::new(Env {
            frame: RefCell::new(HashMap::new()),
            parent: Some(parent.clone()),
        })
    }

    /// Define (or shadow) a name in this frame.
    pub fn define(self: &EnvRef, name: Rc<str>, b: Binding) {
        self.frame.borrow_mut().insert(name, b);
    }

    /// Resolve a name, walking outward.
    pub fn lookup(self: &EnvRef, name: &str) -> Option<Binding> {
        if let Some(b) = self.frame.borrow().get(name) {
            return Some(b.clone());
        }
        self.parent.as_ref().and_then(|p| p.lookup(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shadowing_and_parent_lookup() {
        let root = Env::root();
        root.define(Rc::from("x"), Binding::Static(Value::Int(1)));
        root.define(Rc::from("y"), Binding::Static(Value::Int(2)));
        let child = Env::child(&root);
        child.define(Rc::from("x"), Binding::Static(Value::Int(10)));
        match child.lookup("x") {
            Some(Binding::Static(Value::Int(10))) => {}
            b => panic!("{b:?}"),
        }
        match child.lookup("y") {
            Some(Binding::Static(Value::Int(2))) => {}
            b => panic!("{b:?}"),
        }
        assert!(child.lookup("z").is_none());
    }
}
