//! The modeling language: a Venture-style, Lisp-syntax probabilistic
//! programming language with `assume` / `observe` / `predict` / `infer`
//! directives, first-class stochastic procedures, `mem`, and
//! `scope_include` tags that inference programs address transitions to.

pub mod ast;
pub mod env;
pub mod lexer;
pub mod parser;
pub mod prim;
pub mod sp;
pub mod value;
