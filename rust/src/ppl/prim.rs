//! Deterministic builtin primitives.
//!
//! These are the `E_s`-only computations of the PET: pure functions of
//! their argument values.  `apply` must be deterministic and total over
//! the values the type checks admit — any failure is a program error
//! surfaced as `Err`.

use crate::ppl::value::Value;
use std::rc::Rc;

/// Identifier of a deterministic primitive.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Prim {
    Add,
    Sub,
    Mul,
    Div,
    Neg,
    Exp,
    Log,
    Sqrt,
    Pow,
    Abs,
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Not,
    And,
    Or,
    Min,
    Max,
    /// sigmoid(dot(w, x)) — the logistic link of the paper's programs.
    LinearLogistic,
    /// dot(w, x)
    Dot,
    /// (vector x1 ... xn)
    MakeVector,
    /// (list v1 ... vn)
    MakeList,
    VecGet,
    VecLen,
    Sigmoid,
    IntegerAdd1,
}

fn f(v: &Value, prim: Prim) -> Result<f64, String> {
    v.as_f64()
        .ok_or_else(|| format!("{prim:?}: expected number, got {}", v.type_name()))
}

fn need(args: &[Value], n: usize, prim: Prim) -> Result<(), String> {
    if args.len() != n {
        Err(format!("{prim:?}: expected {n} args, got {}", args.len()))
    } else {
        Ok(())
    }
}

impl Prim {
    /// Resolve a surface-syntax name to a primitive.
    pub fn from_name(name: &str) -> Option<Prim> {
        Some(match name {
            "+" | "add" => Prim::Add,
            "-" | "sub" => Prim::Sub,
            "*" | "mul" => Prim::Mul,
            "/" | "div" => Prim::Div,
            "neg" => Prim::Neg,
            "exp" => Prim::Exp,
            "log" => Prim::Log,
            "sqrt" => Prim::Sqrt,
            "pow" => Prim::Pow,
            "abs" => Prim::Abs,
            "<" | "lt" => Prim::Lt,
            "<=" | "lte" => Prim::Le,
            ">" | "gt" => Prim::Gt,
            ">=" | "gte" => Prim::Ge,
            "=" | "eq" => Prim::Eq,
            "not" => Prim::Not,
            "and" => Prim::And,
            "or" => Prim::Or,
            "min" => Prim::Min,
            "max" => Prim::Max,
            "linear_logistic" => Prim::LinearLogistic,
            "dot" => Prim::Dot,
            "vector" | "array" => Prim::MakeVector,
            "list" => Prim::MakeList,
            "lookup" | "vec_get" => Prim::VecGet,
            "size" | "vec_len" => Prim::VecLen,
            "sigmoid" => Prim::Sigmoid,
            "add1" => Prim::IntegerAdd1,
            _ => return None,
        })
    }

    /// Apply the primitive to argument values.
    pub fn apply(self, args: &[Value]) -> Result<Value, String> {
        use Prim::*;
        match self {
            Add | Mul | Min | Max => {
                if args.is_empty() {
                    return Err(format!("{self:?}: needs >=1 arg"));
                }
                // preserve int-ness when all args are ints and op is exact
                if matches!(self, Add | Mul)
                    && args.iter().all(|a| matches!(a, Value::Int(_)))
                {
                    let ints: Vec<i64> = args.iter().map(|a| a.as_int().unwrap()).collect();
                    let v = match self {
                        Add => ints.iter().sum::<i64>(),
                        Mul => ints.iter().product::<i64>(),
                        _ => unreachable!(),
                    };
                    return Ok(Value::Int(v));
                }
                let mut acc = f(&args[0], self)?;
                for a in &args[1..] {
                    let x = f(a, self)?;
                    acc = match self {
                        Add => acc + x,
                        Mul => acc * x,
                        Min => acc.min(x),
                        Max => acc.max(x),
                        _ => unreachable!(),
                    };
                }
                Ok(Value::Real(acc))
            }
            Sub => {
                need(args, 2, self).or_else(|_| need(args, 1, self))?;
                if args.len() == 1 {
                    return match &args[0] {
                        Value::Int(i) => Ok(Value::Int(-i)),
                        v => Ok(Value::Real(-f(v, self)?)),
                    };
                }
                if let (Value::Int(a), Value::Int(b)) = (&args[0], &args[1]) {
                    return Ok(Value::Int(a - b));
                }
                Ok(Value::Real(f(&args[0], self)? - f(&args[1], self)?))
            }
            Div => {
                need(args, 2, self)?;
                Ok(Value::Real(f(&args[0], self)? / f(&args[1], self)?))
            }
            Neg => {
                need(args, 1, self)?;
                match &args[0] {
                    Value::Int(i) => Ok(Value::Int(-i)),
                    v => Ok(Value::Real(-f(v, self)?)),
                }
            }
            Exp => {
                need(args, 1, self)?;
                Ok(Value::Real(f(&args[0], self)?.exp()))
            }
            Log => {
                need(args, 1, self)?;
                Ok(Value::Real(f(&args[0], self)?.ln()))
            }
            Sqrt => {
                need(args, 1, self)?;
                Ok(Value::Real(f(&args[0], self)?.sqrt()))
            }
            Abs => {
                need(args, 1, self)?;
                Ok(Value::Real(f(&args[0], self)?.abs()))
            }
            Pow => {
                need(args, 2, self)?;
                Ok(Value::Real(f(&args[0], self)?.powf(f(&args[1], self)?)))
            }
            Lt | Le | Gt | Ge => {
                need(args, 2, self)?;
                let (a, b) = (f(&args[0], self)?, f(&args[1], self)?);
                Ok(Value::Bool(match self {
                    Lt => a < b,
                    Le => a <= b,
                    Gt => a > b,
                    Ge => a >= b,
                    _ => unreachable!(),
                }))
            }
            Eq => {
                need(args, 2, self)?;
                Ok(Value::Bool(args[0].key_eq(&args[1])))
            }
            Not => {
                need(args, 1, self)?;
                let b = args[0]
                    .as_bool()
                    .ok_or_else(|| format!("not: expected bool, got {}", args[0].type_name()))?;
                Ok(Value::Bool(!b))
            }
            And | Or => {
                let mut acc = matches!(self, And);
                for a in args {
                    let b = a
                        .as_bool()
                        .ok_or_else(|| format!("{self:?}: expected bool"))?;
                    acc = if matches!(self, And) { acc && b } else { acc || b };
                }
                Ok(Value::Bool(acc))
            }
            LinearLogistic | Dot => {
                need(args, 2, self)?;
                let w = args[0]
                    .as_vector()
                    .ok_or_else(|| format!("{self:?}: arg0 must be vector"))?;
                let x = args[1]
                    .as_vector()
                    .ok_or_else(|| format!("{self:?}: arg1 must be vector"))?;
                if w.len() != x.len() {
                    return Err(format!("{self:?}: length mismatch {} vs {}", w.len(), x.len()));
                }
                let d: f64 = w.iter().zip(x.iter()).map(|(a, b)| a * b).sum();
                Ok(Value::Real(if matches!(self, Dot) {
                    d
                } else {
                    1.0 / (1.0 + (-d).exp())
                }))
            }
            Sigmoid => {
                need(args, 1, self)?;
                let z = f(&args[0], self)?;
                Ok(Value::Real(1.0 / (1.0 + (-z).exp())))
            }
            MakeVector => {
                let xs: Result<Vec<f64>, String> = args
                    .iter()
                    .map(|a| a.as_f64().ok_or_else(|| "vector: non-numeric".to_string()))
                    .collect();
                Ok(Value::Vector(Rc::new(xs?)))
            }
            MakeList => Ok(Value::List(Rc::new(args.to_vec()))),
            VecGet => {
                need(args, 2, self)?;
                let i = args[1]
                    .as_int()
                    .ok_or_else(|| "lookup: index must be int".to_string())?
                    as usize;
                match &args[0] {
                    Value::Vector(v) => v
                        .get(i)
                        .map(|&x| Value::Real(x))
                        .ok_or_else(|| format!("lookup: index {i} out of bounds {}", v.len())),
                    Value::List(l) => l
                        .get(i)
                        .cloned()
                        .ok_or_else(|| format!("lookup: index {i} out of bounds {}", l.len())),
                    v => Err(format!("lookup: expected vector/list, got {}", v.type_name())),
                }
            }
            VecLen => {
                need(args, 1, self)?;
                match &args[0] {
                    Value::Vector(v) => Ok(Value::Int(v.len() as i64)),
                    Value::List(l) => Ok(Value::Int(l.len() as i64)),
                    v => Err(format!("size: expected vector/list, got {}", v.type_name())),
                }
            }
            IntegerAdd1 => {
                need(args, 1, self)?;
                Ok(Value::Int(
                    args[0].as_int().ok_or_else(|| "add1: expected int".to_string())? + 1,
                ))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic_and_int_preservation() {
        assert!(matches!(
            Prim::Add.apply(&[Value::Int(1), Value::Int(2)]).unwrap(),
            Value::Int(3)
        ));
        assert!(matches!(
            Prim::Add.apply(&[Value::Int(1), Value::Real(2.5)]).unwrap(),
            Value::Real(x) if x == 3.5
        ));
        assert!(matches!(
            Prim::Sub.apply(&[Value::Int(5), Value::Int(7)]).unwrap(),
            Value::Int(-2)
        ));
        assert!(matches!(
            Prim::Mul.apply(&[Value::Real(3.0), Value::Real(4.0)]).unwrap(),
            Value::Real(x) if x == 12.0
        ));
    }

    #[test]
    fn linear_logistic_matches_formula() {
        let w = Value::vector(vec![1.0, -2.0]);
        let x = Value::vector(vec![0.5, 0.25]);
        let got = Prim::LinearLogistic.apply(&[w.clone(), x.clone()]).unwrap();
        let dot = 1.0 * 0.5 + (-2.0) * 0.25;
        let want = 1.0 / (1.0 + (-dot as f64).exp());
        assert!(matches!(got, Value::Real(p) if (p - want).abs() < 1e-15));
        let d = Prim::Dot.apply(&[w, x]).unwrap();
        assert!(matches!(d, Value::Real(v) if (v - dot).abs() < 1e-15));
    }

    #[test]
    fn comparisons_and_logic() {
        assert!(matches!(
            Prim::Le.apply(&[Value::Int(0), Value::Int(0)]).unwrap(),
            Value::Bool(true)
        ));
        assert!(matches!(
            Prim::Not.apply(&[Value::Bool(true)]).unwrap(),
            Value::Bool(false)
        ));
        assert!(Prim::Not.apply(&[Value::Int(1)]).is_err());
    }

    #[test]
    fn vector_ops() {
        let v = Prim::MakeVector
            .apply(&[Value::Int(1), Value::Real(2.5)])
            .unwrap();
        assert!(matches!(&v, Value::Vector(xs) if ***xs == vec![1.0, 2.5]));
        let got = Prim::VecGet.apply(&[v.clone(), Value::Int(1)]).unwrap();
        assert!(matches!(got, Value::Real(x) if x == 2.5));
        assert!(Prim::VecGet.apply(&[v.clone(), Value::Int(9)]).is_err());
        assert!(matches!(
            Prim::VecLen.apply(&[v]).unwrap(),
            Value::Int(2)
        ));
    }

    #[test]
    fn name_resolution() {
        assert_eq!(Prim::from_name("+"), Some(Prim::Add));
        assert_eq!(Prim::from_name("<="), Some(Prim::Le));
        assert_eq!(Prim::from_name("linear_logistic"), Some(Prim::LinearLogistic));
        assert_eq!(Prim::from_name("bernoulli"), None); // SPs are not prims
    }
}
