//! Stochastic procedures.
//!
//! Two flavors:
//! * **Families** — stateless SPs applied directly to argument values
//!   (`bernoulli`, `normal`, ...). Scoring is a pure function of
//!   (value, args).
//! * **Instances** — stateful SPs created by makers (`make_crp`,
//!   `make_collapsed_multivariate_normal`). Their applications are
//!   exchangeably coupled through an aux (sufficient statistics); the
//!   incorporate/unincorporate discipline is what gives the PET O(1)
//!   updates for these families (paper §1).

use crate::dist;
use crate::dist::{CollapsedNiw, CrpAux, MvNormal};
use crate::math::Pcg64;
use crate::ppl::value::Value;
use std::rc::Rc;

/// Stateless SP families.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SpFamily {
    Bernoulli,
    Normal,
    Gamma,
    InvGamma,
    Beta,
    UniformContinuous,
    MvNormal,
    StudentT,
}

/// Maker families (applications create SP instances).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum MakerFamily {
    Crp,
    CollapsedMvn,
}

pub fn family_from_name(name: &str) -> Option<SpFamily> {
    Some(match name {
        "bernoulli" | "flip" => SpFamily::Bernoulli,
        "normal" => SpFamily::Normal,
        "gamma" => SpFamily::Gamma,
        "inv_gamma" => SpFamily::InvGamma,
        "beta" => SpFamily::Beta,
        "uniform_continuous" | "uniform" => SpFamily::UniformContinuous,
        "multivariate_normal" => SpFamily::MvNormal,
        "student_t" => SpFamily::StudentT,
        _ => return None,
    })
}

pub fn maker_from_name(name: &str) -> Option<MakerFamily> {
    Some(match name {
        "make_crp" => MakerFamily::Crp,
        "make_collapsed_multivariate_normal" => MakerFamily::CollapsedMvn,
        _ => return None,
    })
}

fn num(args: &[Value], i: usize) -> f64 {
    args[i].as_f64().unwrap_or(f64::NAN)
}

impl SpFamily {
    /// Log density/mass of `value` given `args`.
    pub fn logpdf(self, value: &Value, args: &[Value]) -> f64 {
        match self {
            SpFamily::Bernoulli => match value.as_bool() {
                Some(b) => {
                    let p = if args.is_empty() { 0.5 } else { num(args, 0) };
                    dist::bernoulli_logpmf(b, p)
                }
                None => f64::NEG_INFINITY,
            },
            SpFamily::Normal => match value.as_f64() {
                Some(x) => dist::normal_logpdf(x, num(args, 0), num(args, 1)),
                None => f64::NEG_INFINITY,
            },
            SpFamily::Gamma => match value.as_f64() {
                Some(x) => dist::gamma_logpdf(x, num(args, 0), num(args, 1)),
                None => f64::NEG_INFINITY,
            },
            SpFamily::InvGamma => match value.as_f64() {
                Some(x) => dist::inv_gamma_logpdf(x, num(args, 0), num(args, 1)),
                None => f64::NEG_INFINITY,
            },
            SpFamily::Beta => match value.as_f64() {
                Some(x) => dist::beta_logpdf(x, num(args, 0), num(args, 1)),
                None => f64::NEG_INFINITY,
            },
            SpFamily::UniformContinuous => match value.as_f64() {
                Some(x) => dist::uniform_logpdf(x, num(args, 0), num(args, 1)),
                None => f64::NEG_INFINITY,
            },
            SpFamily::StudentT => match value.as_f64() {
                Some(x) => dist::student_t_logpdf(x, num(args, 0), num(args, 1), num(args, 2)),
                None => f64::NEG_INFINITY,
            },
            SpFamily::MvNormal => match value.as_vector() {
                Some(x) => match Self::mvn_from_args(args) {
                    Some(mvn) => mvn.logpdf(x),
                    None => f64::NEG_INFINITY,
                },
                None => f64::NEG_INFINITY,
            },
        }
    }

    /// Draw a value given args.
    pub fn sample(self, rng: &mut Pcg64, args: &[Value]) -> Result<Value, String> {
        use dist::Samplers;
        Ok(match self {
            SpFamily::Bernoulli => {
                let p = if args.is_empty() { 0.5 } else { num(args, 0) };
                Value::Bool(Samplers::bernoulli(rng, p))
            }
            SpFamily::Normal => Value::Real(Samplers::normal(rng, num(args, 0), num(args, 1))),
            SpFamily::Gamma => Value::Real(Samplers::gamma(rng, num(args, 0), num(args, 1))),
            SpFamily::InvGamma => {
                Value::Real(Samplers::inv_gamma(rng, num(args, 0), num(args, 1)))
            }
            SpFamily::Beta => Value::Real(Samplers::beta(rng, num(args, 0), num(args, 1))),
            SpFamily::UniformContinuous => {
                Value::Real(Samplers::uniform(rng, num(args, 0), num(args, 1)))
            }
            SpFamily::StudentT => Value::Real(Samplers::student_t(
                rng,
                num(args, 0),
                num(args, 1),
                num(args, 2),
            )),
            SpFamily::MvNormal => {
                let mvn = Self::mvn_from_args(args)
                    .ok_or_else(|| "multivariate_normal: bad args".to_string())?;
                Value::Vector(Rc::new(mvn.sample(rng)))
            }
        })
    }

    /// (multivariate_normal mean sig): sig may be a scalar (isotropic
    /// variance), a vector (diagonal variances), or a matrix (full cov).
    fn mvn_from_args(args: &[Value]) -> Option<MvNormal> {
        let mean = args.first()?.as_vector()?.as_ref().clone();
        match args.get(1)? {
            Value::Real(_) | Value::Int(_) => Some(MvNormal::isotropic(mean, args[1].as_f64()?)),
            Value::Vector(v) => Some(MvNormal::diagonal(mean, v.as_ref().clone())),
            Value::Matrix(m) => MvNormal::full(mean, m),
            _ => None,
        }
    }
}

/// State of an SP instance (in the trace's SP table).
#[derive(Clone, Debug)]
pub enum SpState {
    Crp { alpha: f64, aux: CrpAux },
    CollapsedMvn { niw: CollapsedNiw },
}

impl SpState {
    /// Create instance state from maker args.
    pub fn make(family: MakerFamily, args: &[Value]) -> Result<SpState, String> {
        match family {
            MakerFamily::Crp => {
                let alpha = args
                    .first()
                    .and_then(|v| v.as_f64())
                    .ok_or("make_crp: alpha must be numeric")?;
                if alpha <= 0.0 {
                    return Err(format!("make_crp: alpha must be > 0, got {alpha}"));
                }
                Ok(SpState::Crp {
                    alpha,
                    aux: CrpAux::new(),
                })
            }
            MakerFamily::CollapsedMvn => {
                let m0 = args
                    .first()
                    .and_then(|v| v.as_vector())
                    .ok_or("make_collapsed_multivariate_normal: m0 must be vector")?
                    .as_ref()
                    .clone();
                let k0 = args.get(1).and_then(|v| v.as_f64()).ok_or("bad k0")?;
                let v0 = args.get(2).and_then(|v| v.as_f64()).ok_or("bad v0")?;
                let s0 = match args.get(3) {
                    Some(Value::Matrix(m)) => m.as_ref().clone(),
                    Some(v) if v.as_f64().is_some() => {
                        // scalar -> s * I
                        let s = v.as_f64().unwrap();
                        let d = m0.len();
                        (0..d)
                            .map(|i| (0..d).map(|j| if i == j { s } else { 0.0 }).collect())
                            .collect()
                    }
                    _ => return Err("bad S0".into()),
                };
                Ok(SpState::CollapsedMvn {
                    niw: CollapsedNiw::new(m0, k0, v0, s0),
                })
            }
        }
    }

    /// Re-make parameters in place after a maker-argument change, keeping
    /// the aux (sufficient statistics) intact.
    pub fn update_params(&mut self, family: MakerFamily, args: &[Value]) -> Result<(), String> {
        match (self, family) {
            (SpState::Crp { alpha, .. }, MakerFamily::Crp) => {
                let new_alpha = args
                    .first()
                    .and_then(|v| v.as_f64())
                    .ok_or("make_crp: alpha must be numeric")?;
                *alpha = new_alpha;
                Ok(())
            }
            (SpState::CollapsedMvn { .. }, MakerFamily::CollapsedMvn) => {
                // Hyperparameter inference for NIW is not exercised by the
                // paper's programs; rebuilding stats-preserving state would
                // go here.
                Err("collapsed MVN hyperparameter updates not supported".into())
            }
            _ => Err("maker family mismatch".into()),
        }
    }

    /// Predictive log density of `value` given current aux (value itself
    /// must NOT be incorporated).
    pub fn logpdf(&self, value: &Value, _args: &[Value]) -> f64 {
        match self {
            SpState::Crp { alpha, aux } => match value.as_int() {
                Some(t) => {
                    if *alpha <= 0.0 {
                        return f64::NEG_INFINITY;
                    }
                    aux.predictive_logp(t, *alpha)
                }
                None => f64::NEG_INFINITY,
            },
            SpState::CollapsedMvn { niw } => match value.as_vector() {
                Some(x) => niw.predictive_logpdf(x),
                None => f64::NEG_INFINITY,
            },
        }
    }

    /// Sample from the predictive.
    pub fn sample(&self, rng: &mut Pcg64, _args: &[Value]) -> Result<Value, String> {
        Ok(match self {
            SpState::Crp { alpha, aux } => Value::Int(aux.sample(rng, *alpha)),
            SpState::CollapsedMvn { niw } => Value::Vector(Rc::new(niw.predictive_sample(rng))),
        })
    }

    /// Add `value` to the sufficient statistics.
    pub fn incorporate(&mut self, value: &Value) {
        match self {
            SpState::Crp { aux, .. } => aux.incorporate(value.as_int().expect("crp value")),
            SpState::CollapsedMvn { niw } => {
                niw.incorporate(value.as_vector().expect("mvn value"))
            }
        }
    }

    /// Remove `value` from the sufficient statistics.
    pub fn unincorporate(&mut self, value: &Value) {
        match self {
            SpState::Crp { aux, .. } => aux.unincorporate(value.as_int().expect("crp value")),
            SpState::CollapsedMvn { niw } => {
                niw.unincorporate(value.as_vector().expect("mvn value"))
            }
        }
    }

    /// Joint log density of everything currently incorporated — the AAA
    /// (absorbing-at-applications) score used when the *maker's* params
    /// change (e.g. MH on the CRP concentration alpha).
    pub fn logdensity_of_counts(&self) -> f64 {
        match self {
            SpState::Crp { alpha, aux } => aux.seating_logp(*alpha),
            SpState::CollapsedMvn { niw } => niw.marginal_loglik(),
        }
    }

    pub fn crp_aux(&self) -> Option<&CrpAux> {
        match self {
            SpState::Crp { aux, .. } => Some(aux),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_logpdfs_dispatch() {
        let lp = SpFamily::Normal.logpdf(&Value::Real(0.0), &[Value::Real(0.0), Value::Real(1.0)]);
        assert!((lp - dist::normal_logpdf(0.0, 0.0, 1.0)).abs() < 1e-14);
        let lp = SpFamily::Bernoulli.logpdf(&Value::Bool(true), &[Value::Real(0.25)]);
        assert!((lp - 0.25f64.ln()).abs() < 1e-14);
        // type mismatch scores -inf
        assert_eq!(
            SpFamily::Bernoulli.logpdf(&Value::Real(1.0), &[Value::Real(0.5)]),
            f64::NEG_INFINITY
        );
    }

    #[test]
    fn mvn_scalar_vector_matrix_args() {
        let mean = Value::vector(vec![0.0, 0.0]);
        let x = Value::vector(vec![0.5, -0.5]);
        let iso = SpFamily::MvNormal.logpdf(&x, &[mean.clone(), Value::Real(2.0)]);
        let diag = SpFamily::MvNormal.logpdf(&x, &[mean.clone(), Value::vector(vec![2.0, 2.0])]);
        let full = SpFamily::MvNormal.logpdf(
            &x,
            &[
                mean,
                Value::Matrix(Rc::new(vec![vec![2.0, 0.0], vec![0.0, 2.0]])),
            ],
        );
        assert!((iso - diag).abs() < 1e-12);
        assert!((iso - full).abs() < 1e-12);
    }

    #[test]
    fn crp_instance_roundtrip() {
        let mut sp = SpState::make(MakerFamily::Crp, &[Value::Real(1.0)]).unwrap();
        let v0 = Value::Int(0);
        let lp_first = sp.logpdf(&v0, &[]);
        assert!((lp_first - 0.0f64).abs() < 1e-12); // first customer: p=alpha/alpha=1... log 1 = 0
        sp.incorporate(&v0);
        sp.incorporate(&v0);
        let lp = sp.logpdf(&v0, &[]);
        assert!((lp - (2.0f64 / 3.0).ln()).abs() < 1e-12);
        sp.unincorporate(&v0);
        sp.unincorporate(&v0);
        assert_eq!(sp.crp_aux().unwrap().n(), 0);
    }

    #[test]
    fn maker_rejects_bad_args() {
        assert!(SpState::make(MakerFamily::Crp, &[Value::Real(-1.0)]).is_err());
        assert!(SpState::make(MakerFamily::Crp, &[Value::sym("x")]).is_err());
        assert!(SpState::make(MakerFamily::CollapsedMvn, &[Value::Real(1.0)]).is_err());
    }

    #[test]
    fn crp_alpha_update_keeps_counts() {
        let mut sp = SpState::make(MakerFamily::Crp, &[Value::Real(1.0)]).unwrap();
        sp.incorporate(&Value::Int(0));
        sp.incorporate(&Value::Int(1));
        let before = sp.logdensity_of_counts();
        sp.update_params(MakerFamily::Crp, &[Value::Real(2.0)]).unwrap();
        let after = sp.logdensity_of_counts();
        assert!(before != after);
        assert_eq!(sp.crp_aux().unwrap().n(), 2);
    }
}
