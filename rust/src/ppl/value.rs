//! Runtime values of the modeling language.
//!
//! Values are cheap to clone (heavyweight payloads behind `Rc`) because
//! the trace stores one per node and the regen machinery snapshots them
//! into the OmegaDB for rollback.

use crate::ppl::ast::Expr;
use crate::ppl::env::EnvRef;
use crate::ppl::prim::Prim;
use crate::ppl::sp::SpFamily;
use std::rc::Rc;

/// Identifier of a stateful SP instance living in the trace's SP table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SpId(pub u32);

/// Identifier of a memoized procedure living in the trace's mem table.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MemId(pub u32);

/// A lambda closure: parameter list + body + captured environment.
#[derive(Debug)]
pub struct Closure {
    pub params: Vec<Rc<str>>,
    pub body: Rc<Expr>,
    pub env: EnvRef,
}

/// A runtime value.
#[derive(Clone, Debug)]
pub enum Value {
    Bool(bool),
    Int(i64),
    Real(f64),
    Sym(Rc<str>),
    /// Dense numeric vector (feature rows, weight vectors, ...).
    Vector(Rc<Vec<f64>>),
    /// Dense numeric matrix, row major.
    Matrix(Rc<Vec<Vec<f64>>>),
    /// Heterogeneous list.
    List(Rc<Vec<Value>>),
    Closure(Rc<Closure>),
    /// Builtin deterministic primitive.
    Prim(Prim),
    /// Stateless stochastic-procedure family (`bernoulli`, `normal`, ...).
    SpFam(SpFamily),
    /// Maker family (`make_crp`, ...): applications create SP instances.
    MakerFam(crate::ppl::sp::MakerFamily),
    /// Stateful SP instance created by a maker (`make_crp`, ...).
    Sp(SpId),
    /// Memoized procedure created by `mem`.
    Mem(MemId),
}

impl Value {
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Bool(_) => "bool",
            Value::Int(_) => "int",
            Value::Real(_) => "real",
            Value::Sym(_) => "symbol",
            Value::Vector(_) => "vector",
            Value::Matrix(_) => "matrix",
            Value::List(_) => "list",
            Value::Closure(_) => "closure",
            Value::Prim(_) => "primitive",
            Value::SpFam(_) => "sp-family",
            Value::MakerFam(_) => "maker",
            Value::Sp(_) => "sp",
            Value::Mem(_) => "mem-proc",
        }
    }

    /// Numeric coercion: ints and reals both read as f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Real(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            Value::Bool(b) => Some(if *b { 1.0 } else { 0.0 }),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Real(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_vector(&self) -> Option<&Rc<Vec<f64>>> {
        match self {
            Value::Vector(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_matrix(&self) -> Option<&Rc<Vec<Vec<f64>>>> {
        match self {
            Value::Matrix(m) => Some(m),
            _ => None,
        }
    }

    pub fn real(x: f64) -> Value {
        Value::Real(x)
    }

    pub fn vector(xs: Vec<f64>) -> Value {
        Value::Vector(Rc::new(xs))
    }

    pub fn sym(s: &str) -> Value {
        Value::Sym(Rc::from(s))
    }

    /// Structural equality usable as a mem-cache / scope-block key.
    /// Reals compare by bit pattern (exact), which is what key semantics
    /// require: a key is equal iff it round-trips identically.
    pub fn key_eq(&self, other: &Value) -> bool {
        match (self, other) {
            (Value::Bool(a), Value::Bool(b)) => a == b,
            (Value::Int(a), Value::Int(b)) => a == b,
            (Value::Real(a), Value::Real(b)) => a.to_bits() == b.to_bits(),
            (Value::Int(a), Value::Real(b)) | (Value::Real(b), Value::Int(a)) => {
                b.fract() == 0.0 && *a == *b as i64
            }
            (Value::Sym(a), Value::Sym(b)) => a == b,
            (Value::Vector(a), Value::Vector(b)) => {
                a.len() == b.len()
                    && a.iter().zip(b.iter()).all(|(x, y)| x.to_bits() == y.to_bits())
            }
            (Value::List(a), Value::List(b)) => {
                a.len() == b.len() && a.iter().zip(b.iter()).all(|(x, y)| x.key_eq(y))
            }
            (Value::Sp(a), Value::Sp(b)) => a == b,
            (Value::Mem(a), Value::Mem(b)) => a == b,
            _ => false,
        }
    }

    fn key_hash_into(&self, h: &mut impl std::hash::Hasher) {
        use std::hash::Hash;
        match self {
            Value::Bool(b) => {
                0u8.hash(h);
                b.hash(h);
            }
            Value::Int(i) => {
                1u8.hash(h);
                (*i as f64).to_bits().hash(h);
            }
            Value::Real(x) => {
                1u8.hash(h); // same tag as Int so 1 and 1.0 collide (key_eq allows)
                x.to_bits().hash(h);
            }
            Value::Sym(s) => {
                2u8.hash(h);
                s.hash(h);
            }
            Value::Vector(v) => {
                3u8.hash(h);
                for x in v.iter() {
                    x.to_bits().hash(h);
                }
            }
            Value::List(l) => {
                4u8.hash(h);
                for v in l.iter() {
                    v.key_hash_into(h);
                }
            }
            Value::Sp(id) => {
                5u8.hash(h);
                id.0.hash(h);
            }
            Value::Mem(id) => {
                6u8.hash(h);
                id.0.hash(h);
            }
            other => panic!("value of type {} cannot be a key", other.type_name()),
        }
    }
}

/// A vector of values usable as a hash-map key (mem cache, scope blocks).
#[derive(Clone, Debug)]
pub struct KeyVec(pub Vec<Value>);

impl PartialEq for KeyVec {
    fn eq(&self, other: &Self) -> bool {
        self.0.len() == other.0.len()
            && self.0.iter().zip(&other.0).all(|(a, b)| a.key_eq(b))
    }
}
impl Eq for KeyVec {}

impl std::hash::Hash for KeyVec {
    fn hash<H: std::hash::Hasher>(&self, h: &mut H) {
        for v in &self.0 {
            v.key_hash_into(h);
        }
    }
}

impl std::fmt::Display for Value {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Value::Bool(b) => write!(f, "{b}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Real(x) => write!(f, "{x}"),
            Value::Sym(s) => write!(f, "'{s}"),
            Value::Vector(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Value::Matrix(m) => write!(f, "<matrix {}x{}>", m.len(), m.first().map_or(0, |r| r.len())),
            Value::List(l) => {
                write!(f, "(")?;
                for (i, v) in l.iter().enumerate() {
                    if i > 0 {
                        write!(f, " ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, ")")
            }
            Value::Closure(_) => write!(f, "<closure>"),
            Value::Prim(p) => write!(f, "<prim {p:?}>"),
            Value::SpFam(s) => write!(f, "<sp {s:?}>"),
            Value::MakerFam(m) => write!(f, "<maker {m:?}>"),
            Value::Sp(id) => write!(f, "<sp-instance {}>", id.0),
            Value::Mem(id) => write!(f, "<mem {}>", id.0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn key_eq_int_real_cross() {
        assert!(Value::Int(3).key_eq(&Value::Real(3.0)));
        assert!(!Value::Int(3).key_eq(&Value::Real(3.5)));
    }

    #[test]
    fn keyvec_hashmap_roundtrip() {
        let mut m: HashMap<KeyVec, i32> = HashMap::new();
        m.insert(KeyVec(vec![Value::Int(1), Value::sym("a")]), 10);
        m.insert(KeyVec(vec![Value::Int(2)]), 20);
        assert_eq!(m[&KeyVec(vec![Value::Real(1.0), Value::sym("a")])], 10);
        assert_eq!(m[&KeyVec(vec![Value::Int(2)])], 20);
        assert!(!m.contains_key(&KeyVec(vec![Value::Int(3)])));
    }

    #[test]
    fn as_f64_coercions() {
        assert_eq!(Value::Int(2).as_f64(), Some(2.0));
        assert_eq!(Value::Bool(true).as_f64(), Some(1.0));
        assert_eq!(Value::sym("x").as_f64(), None);
    }
}
