//! Dependency-free persistent worker pool + the sharded batch scorer.
//!
//! The engine's first concurrency subsystem: a fixed set of
//! `std::thread` workers, spawned once and reused across transitions
//! (thread spawn is ~10us — far more than a mini-batch replay — so a
//! per-batch scoped-thread design would erase the win).  Two job kinds
//! flow through one queue:
//!
//! * **shards** — contiguous ranges of a [`PackedBatch`]'s sections,
//!   replayed through the worker's private register scratch
//!   ([`ShardScorer`] below);
//! * **tasks** — arbitrary `FnOnce` closures, used by the multi-chain
//!   driver (`coordinator::multichain`) to run independent `Trace`s
//!   with per-chain PCG streams.
//!
//! # Fair scheduling (deficit round-robin)
//!
//! Shard jobs queue per *session* (the lane key a dispatcher sets via
//! [`ShardScorer::session_key`]; CLI evaluators all share lane 0), and
//! workers pop lanes by weighted deficit round-robin: each visit grants
//! a lane `weight × QUANTUM` sections of credit, a lane serves jobs
//! while its credit covers their section count, and drained lanes
//! retire without banking credit.  A huge model's thousand-section
//! shards can therefore no longer monopolize the queue ahead of a
//! small session's handful — each session gets throughput proportional
//! to its weight.  Generic tasks are served before shards (they are
//! chain *drivers*; parking them behind shard backlogs would deadlock
//! multichain runs on small pools).  Determinism is untouched:
//! scheduling only reorders *which session's* shards run next, never
//! the shard-indexed reduce inside one batch — every dispatcher still
//! lands its own shards into its own `out[lo..hi]` ranges.
//!
//! # Send boundaries
//!
//! `Trace`, `Value`, and the plan caches are `Rc`-based and never cross
//! a thread boundary.  The *only* data shared with workers is the
//! `Arc<PackedBatch>` — plain `f64` buffers produced by the pack stage
//! (`trace/batch.rs`), immutable for the duration of the dispatch — and
//! whatever a task closure owns outright.  Workers keep their scratch
//! (`RegFile`-equivalent register storage) thread-local, so the replay
//! inner loop takes no locks: the queue mutex is touched once per job,
//! not per section.
//!
//! # Determinism
//!
//! Sharding cannot reorder arithmetic: every section's `l_i` is a
//! function of its own packed column only, and each shard writes a
//! disjoint `out[lo..hi]` range addressed by shard index, so results
//! are assembled in deterministic shard order no matter which worker
//! finishes first.  `tests/parallel.rs` pins this with bitwise
//! lockstep runs against the sequential evaluator.
//!
//! # Fault tolerance (the shard watchdog)
//!
//! A dispatched shard can fail two ways: the kernel panics (the
//! `catch_unwind` in [`run_shard_job`] drops the result `Sender`
//! unsent, so the channel eventually reads disconnected), or the
//! worker wedges and the result simply never arrives.  Either way the
//! dispatcher must not hang and must not silently degrade: the wait
//! loop keeps a per-shard received-flag table, waits with a deadline
//! (`SUBPPL_SHARD_TIMEOUT_MS`, default 1000), and on panic or timeout
//! **re-runs every missing shard inline** — the same pure kernel over
//! the same disjoint range, so recovery is bitwise invisible.  A late
//! duplicate from a slow-but-alive worker is ignored by the flag
//! table; a genuinely wedged worker is replaced
//! ([`WorkerPool::add_worker`], capped at one replacement per original
//! worker).  Every recovery is counted
//! ([`ShardScorer::fallback_panics`] / [`ShardScorer::requeued_shards`],
//! surfaced through `EvalStats`) and logged once per batch.  The
//! scalar re-score fallback in `infer/planned.rs` remains only as the
//! last resort for errors raised *before* dispatch (pack failures).

use crate::runtime::faults;
use crate::trace::batch::PackedBatch;
use crate::trace::colstore::{LaneScratch, PanelBatch};
use std::cell::Cell;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;
use std::time::Duration;

thread_local! {
    /// Set inside pool worker threads.  A [`ShardScorer`] running *on*
    /// a worker (a multi-chain task whose evaluator is parallel) must
    /// not dispatch back into the pool — with every worker occupied by
    /// a blocking chain task, queued shards would never run (deadlock).
    /// Replay is bitwise identical either way, so the nested case just
    /// runs inline.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Whether the current thread is a pool worker.
pub fn in_pool_worker() -> bool {
    IN_POOL_WORKER.with(|c| c.get())
}

/// A generic closure job (multi-chain driver).
type Task = Box<dyn FnOnce() + Send + 'static>;

/// The two shardable batch kinds: a fresh-packed batch (the fallback /
/// oracle path) or a store-backed panel batch whose shards gather
/// their own lane panels from the shared column store.  Cloning bumps
/// the inner `Arc` only.
#[derive(Clone)]
enum ShardBatch {
    Packed(Arc<PackedBatch>),
    Panel(Arc<PanelBatch>),
}

impl ShardBatch {
    /// Replay `lo..hi` into `out` through the matching kernel — both
    /// kernels are pure per-section arithmetic, so the shard split is
    /// invisible to results either way.
    fn replay_range(&self, lo: usize, hi: usize, scratch: &mut ShardScratch, out: &mut [f64]) {
        match self {
            ShardBatch::Packed(b) => b.replay_range(lo, hi, &mut scratch.sregs, out),
            ShardBatch::Panel(b) => b.replay_range(lo, hi, &mut scratch.lanes, out),
        }
    }
}

/// Per-thread replay scratch covering both kernels (workers and the
/// stealing dispatcher each own one; cleared, not freed, between jobs).
#[derive(Default)]
struct ShardScratch {
    sregs: Vec<f64>,
    lanes: LaneScratch,
}

/// One shard of a batch: replay `lo..hi` and send the result back
/// tagged with the shard index.
struct ShardJob {
    batch: ShardBatch,
    lo: usize,
    hi: usize,
    shard: usize,
    done: Sender<(usize, Vec<f64>)>,
}

enum Job {
    Shard(ShardJob),
    Task(Task),
}

/// Deficit round-robin scheduling quantum: the credit (in sections) a
/// lane earns per round-robin visit, per unit of weight.
const QUANTUM: u64 = 256;

/// Cost clamp per job: one enormous shard cannot demand unbounded
/// credit (it would stall the round-robin while its lane saved up), and
/// a zero-section shard still costs something.  The clamp only skews
/// fairness for shards past 8 quanta — the dispatcher already splits
/// batches into ~per-thread shards well below that in practice.
const MAX_SHARD_COST: u64 = 8 * QUANTUM;

fn shard_cost(job: &ShardJob) -> u64 {
    ((job.hi - job.lo) as u64).clamp(1, MAX_SHARD_COST)
}

/// One session's shard backlog in the fair-scheduling queue.
struct SessLane {
    key: u64,
    weight: u32,
    /// DRR credit in sections; topped up by `weight × QUANTUM` per
    /// round-robin visit, spent by serving jobs, reset (not banked)
    /// when the lane drains.
    deficit: u64,
    jobs: VecDeque<ShardJob>,
}

#[derive(Default)]
struct QueueState {
    /// Generic tasks (chain drivers): always served before shards.
    tasks: VecDeque<Task>,
    /// Per-session shard lanes, scheduled by deficit round-robin.
    /// Lanes exist only while backlogged (drained lanes retire), so
    /// this stays a short Vec — linear key scans beat a map here.
    lanes: Vec<SessLane>,
    /// Round-robin position into `lanes`.
    cursor: usize,
    closed: bool,
}

impl QueueState {
    /// Pop the next shard by weighted deficit round-robin.  Each visit
    /// grants the lane `weight × QUANTUM` credit; a lane serves its
    /// head job when the credit covers its cost, and keeps serving on
    /// subsequent pops until broke (classic DRR burst), then the cursor
    /// moves on.  A single lane degenerates to exact FIFO.  Bounded:
    /// every iteration either returns or grants ≥ QUANTUM to a lane
    /// whose head costs ≤ MAX_SHARD_COST.
    fn pop_shard(&mut self) -> Option<ShardJob> {
        // drop drained lanes so the scan only sees backlogged ones
        // (their deficit deliberately dies with them — idle sessions
        // don't bank credit)
        if self.lanes.iter().any(|l| l.jobs.is_empty()) {
            let before_cursor = self
                .lanes
                .iter()
                .take(self.cursor)
                .filter(|l| l.jobs.is_empty())
                .count();
            self.lanes.retain(|l| !l.jobs.is_empty());
            self.cursor = self.cursor.saturating_sub(before_cursor);
        }
        if self.lanes.is_empty() {
            self.cursor = 0;
            return None;
        }
        loop {
            let i = self.cursor % self.lanes.len();
            let lane = &mut self.lanes[i];
            let cost = shard_cost(&lane.jobs[0]);
            if lane.deficit < cost {
                lane.deficit += lane.weight as u64 * QUANTUM;
                self.cursor = (i + 1) % self.lanes.len();
                continue;
            }
            lane.deficit -= cost;
            // invariant: the drain pass above and the retire branch
            // below keep every lane non-empty at loop entry
            let job = lane.jobs.pop_front().expect("lane is backlogged");
            if lane.jobs.is_empty() {
                self.lanes.remove(i);
                self.cursor = if self.lanes.is_empty() {
                    0
                } else {
                    i % self.lanes.len()
                };
            } else {
                // stay here: the lane serves until its credit runs out
                self.cursor = i;
            }
            return Some(job);
        }
    }

    fn push_shard(&mut self, job: ShardJob, key: u64, weight: u32) {
        match self.lanes.iter_mut().find(|l| l.key == key) {
            Some(lane) => {
                // latest weight wins — a session's weight is fixed at
                // create, so this only matters for lane-0 CLI traffic
                lane.weight = weight.max(1);
                lane.jobs.push_back(job);
            }
            None => self.lanes.push(SessLane {
                key,
                weight: weight.max(1),
                deficit: 0,
                jobs: VecDeque::from([job]),
            }),
        }
    }
}

struct Shared {
    queue: Mutex<QueueState>,
    available: Condvar,
}

impl Shared {
    /// Lock the queue, surviving poisoning.  The critical sections in
    /// this module only touch the queue state — none runs user code —
    /// so a poisoned mutex can only mean a panic *between* queue
    /// operations on a thread that held the guard across them (we never
    /// do).  Recovering the inner state is strictly better than
    /// cascading the panic into every thread that shares the pool.
    fn lock_queue(&self) -> MutexGuard<'_, QueueState> {
        self.queue.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn push_task(&self, task: Task) {
        let mut q = self.lock_queue();
        q.tasks.push_back(task);
        drop(q);
        self.available.notify_one();
    }

    fn push_shard(&self, job: ShardJob, key: u64, weight: u32) {
        let mut q = self.lock_queue();
        q.push_shard(job, key, weight);
        drop(q);
        self.available.notify_one();
    }

    /// Blocks until a job is available; `None` on shutdown.  Tasks
    /// first, then the DRR shard schedule.
    fn pop(&self) -> Option<Job> {
        let mut q = self.lock_queue();
        loop {
            if let Some(t) = q.tasks.pop_front() {
                return Some(Job::Task(t));
            }
            if let Some(s) = q.pop_shard() {
                return Some(Job::Shard(s));
            }
            if q.closed {
                return None;
            }
            q = self
                .available
                .wait(q)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    fn close(&self) {
        self.lock_queue().closed = true;
        self.available.notify_all();
    }

    fn closed(&self) -> bool {
        self.lock_queue().closed
    }

    /// Pop the next *shard* job by the same DRR schedule workers use,
    /// skipping generic tasks — the work-stealing dispatcher must never
    /// block itself on an arbitrary long-running chain task, but any
    /// unclaimed shard (its own or another session's) is a bounded,
    /// self-contained unit it can safely run inline.  Returns `None`
    /// when no shard is queued.
    fn steal_shard(&self) -> Option<ShardJob> {
        self.lock_queue().pop_shard()
    }
}

/// The persistent pool.  Dropping it shuts the workers down; the
/// process-wide [`WorkerPool::global`] instance lives for the process.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Behind a mutex so the watchdog can append replacement workers
    /// through the shared (`&self`) handle.
    handles: Mutex<Vec<JoinHandle<()>>>,
    threads: usize,
    /// Replacement workers spawned by the watchdog (capped at
    /// `threads`, so a misconfigured timeout cannot grow the pool
    /// without bound).
    replacements: AtomicUsize,
}

impl WorkerPool {
    /// Spawn a pool with `threads` workers (clamped to >= 1).  A
    /// 1-thread pool is valid but [`ShardScorer`] never dispatches to
    /// it — `threads == 1` means the sequential path, exactly.
    pub fn new(threads: usize) -> Arc<WorkerPool> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(QueueState::default()),
            available: Condvar::new(),
        });
        let handles = (0..threads)
            .map(|i| {
                let shared = shared.clone();
                std::thread::Builder::new()
                    .name(format!("subppl-worker-{i}"))
                    .spawn(move || worker_loop(&shared))
                    // invariant: thread spawn at pool construction can
                    // only fail on resource exhaustion, before any
                    // inference state exists — nothing to recover
                    .expect("worker spawn failed")
            })
            .collect();
        Arc::new(WorkerPool {
            shared,
            handles: Mutex::new(handles),
            threads,
            replacements: AtomicUsize::new(0),
        })
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Enqueue a generic task (the multi-chain driver's entry point).
    pub fn submit(&self, task: Task) {
        self.shared.push_task(task);
    }

    /// Enqueue one shard onto its session's DRR lane (`key` 0 /
    /// `weight` 1 for non-session dispatchers — the CLI path).
    fn submit_shard(&self, job: ShardJob, key: u64, weight: u32) {
        self.shared.push_shard(job, key, weight);
    }

    /// Spawn one replacement worker onto the shared queue — the
    /// watchdog's response to a worker that stopped picking up work.
    /// Capped at one replacement per original worker; returns whether a
    /// worker was actually added.  A replacement for a *slow* (not
    /// dead) worker is harmless: both drain the same queue.
    fn add_worker(&self) -> bool {
        let n = self.replacements.fetch_add(1, Ordering::SeqCst);
        if n >= self.threads {
            self.replacements.fetch_sub(1, Ordering::SeqCst);
            return false;
        }
        let shared = self.shared.clone();
        match std::thread::Builder::new()
            .name(format!("subppl-worker-r{n}"))
            .spawn(move || worker_loop(&shared))
        {
            Ok(h) => {
                self.handles
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .push(h);
                true
            }
            Err(_) => {
                self.replacements.fetch_sub(1, Ordering::SeqCst);
                false
            }
        }
    }

    /// The process-wide pool, spawned once on first use with
    /// [`auto_threads`] workers.  All auto-parallel evaluators and the
    /// multi-chain driver share it, so the process never oversubscribes
    /// the machine with per-evaluator thread sets.
    pub fn global() -> &'static Arc<WorkerPool> {
        static GLOBAL: OnceLock<Arc<WorkerPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| WorkerPool::new(auto_threads()))
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shared.close();
        let mut handles = self
            .handles
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Replay one shard job and report its result — shared by the worker
/// loop and the work-stealing dispatcher, so a stolen shard runs the
/// exact same code a worker would have run.
///
/// A panicking kernel must not kill the executing thread: the thread
/// survives, the unsent `Sender` drops, and the owning dispatcher's
/// wait loop reads the disconnect as a lost shard and re-runs the
/// missing range inline (see the watchdog notes on [`ShardScorer`]) —
/// never a hang on a pool that silently lost capacity.
fn run_shard_job(s: ShardJob, scratch: &mut ShardScratch) {
    let ShardJob {
        batch,
        lo,
        hi,
        shard,
        done,
    } = s;
    let result = catch_unwind(AssertUnwindSafe(|| {
        // fault injection (no-op unless the `fault-inject` feature is
        // on and a plan armed the `panic` fault): dies *inside* the
        // catch_unwind, exactly like a real kernel panic would
        if faults::shard_panic_now() {
            panic!("fault-inject: shard kernel panic");
        }
        let mut out = vec![0.0f64; hi - lo];
        batch.replay_range(lo, hi, scratch, &mut out);
        out
    }));
    // drop our Arc before reporting, so once the dispatcher holds every
    // result it also holds the only reference and can reclaim the
    // batch's buffers
    drop(batch);
    if let Ok(out) = result {
        // a dropped receiver (dispatcher gave up) is fine
        let _ = done.send((shard, out));
    }
}

/// The `stall` fault: hold a shard job hostage — never run it, never
/// report it — until the pool shuts down, simulating a worker that
/// wedged mid-shard.  Parking (instead of exiting) keeps the job's
/// result `Sender` alive so the dispatcher sees a *timeout*, not a
/// disconnect, and keeps the thread joinable at pool drop.
fn stall_with_job(shared: &Shared, job: ShardJob) {
    while !shared.closed() {
        std::thread::park_timeout(Duration::from_millis(10));
    }
    drop(job);
}

fn worker_loop(shared: &Shared) {
    IN_POOL_WORKER.with(|c| c.set(true));
    // per-worker scratch: the worker-private half of a RegFile / lane
    // panel (the shared batch supplies the immutable half)
    let mut scratch = ShardScratch::default();
    while let Some(job) = shared.pop() {
        match job {
            Job::Shard(s) => {
                if faults::shard_stall_now() {
                    stall_with_job(shared, s);
                    return;
                }
                run_shard_job(s, &mut scratch)
            }
            // a panicking task's owner observes the failure through its
            // own channel disconnecting
            Job::Task(f) => {
                let _ = catch_unwind(AssertUnwindSafe(f));
            }
        }
    }
}

/// Thread count for `threads = 0` (auto): `SUBPPL_THREADS` if set,
/// otherwise the machine's available parallelism.
pub fn auto_threads() -> usize {
    std::env::var("SUBPPL_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
}

/// Resolve a `SubsampledConfig::threads`-style knob: `0` = auto.
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        auto_threads()
    } else {
        threads
    }
}

/// Front-end that shards a packed batch across the pool and reduces the
/// per-shard `l_i` vectors back in deterministic shard order.  Owns the
/// dispatch policy: batches below [`min_sections`](Self::min_sections)
/// (or a 1-thread pool) replay inline on the calling thread — the same
/// kernel, so the choice is invisible to results.
///
/// While waiting for results the dispatcher *work-steals*: instead of
/// blocking on the result channel it pops unclaimed shard jobs off the
/// shared queue and runs the replay kernel inline (see
/// [`replay`](Self::replay)).  On small pools this removes the
/// idle-dispatcher bubble — with `t` workers the old design left the
/// `t+1`-th runnable thread (the dispatcher itself) parked on `recv`
/// while its own shards sat in the queue.  Results are unchanged: a
/// stolen shard runs the same kernel over the same disjoint range and
/// reports through the same shard-indexed reduce.
pub struct ShardScorer {
    pool: Arc<WorkerPool>,
    /// Smallest batch worth dispatching: below this, queue/channel
    /// overhead (~2us/shard) beats the arithmetic saved.  Lowered by
    /// tests to force the parallel path on small workloads.
    pub min_sections: usize,
    /// Whether the dispatching thread helps drain queued shards while
    /// waiting (default true; tests pin bitwise identity across both
    /// settings).
    pub steal: bool,
    /// Sections scored through pool shards (perf reporting).
    pub sharded_sections: usize,
    /// Sections the dispatching thread replayed inline by stealing
    /// queued shards — its own, or (when several dispatchers share the
    /// pool) another dispatcher's (perf reporting).
    pub stolen_sections: usize,
    /// Shards lost to a worker panic (result sender dropped unsent)
    /// and re-run inline by the watchdog.  Monotonic; surfaced through
    /// `EvalStats::fallback_panics`.
    pub fallback_panics: usize,
    /// Shards that missed the result deadline
    /// (`SUBPPL_SHARD_TIMEOUT_MS`) and were re-run inline by the
    /// watchdog.  Monotonic; surfaced through
    /// `EvalStats::requeued_shards`.
    pub requeued_shards: usize,
    /// Watchdog result deadline for this scorer's dispatches.
    /// Initialized from the process default ([`shard_timeout`]) and
    /// overridable per instance (`SubsampledConfig::shard_timeout_ms`,
    /// `--shard-timeout-ms`) so concurrent serve sessions can pick
    /// their own recovery latency without fighting over one env var.
    pub timeout: Duration,
    /// Fair-scheduling lane this scorer's shards queue on (a serve
    /// session id; 0 = the shared CLI lane).
    pub session_key: u64,
    /// DRR weight of the lane (≥ 1; only meaningful with a non-zero
    /// `session_key` — lane 0 traffic all shares one weight).
    pub session_weight: u32,
    /// Inline scratch for the non-dispatched and stolen-shard cases.
    scratch: ShardScratch,
}

/// Result-wait deadline for one dispatched batch.  Generous by
/// default — a shard is sub-millisecond work, so 1s only ever fires on
/// a genuinely wedged worker; a spurious firing on an overloaded
/// machine is harmless (the inline re-run is bitwise identical, it
/// just wastes the duplicate work).
fn shard_timeout() -> Duration {
    let ms = std::env::var("SUBPPL_SHARD_TIMEOUT_MS")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&ms| ms >= 1)
        .unwrap_or(1000);
    Duration::from_millis(ms)
}

impl ShardScorer {
    pub fn new(pool: Arc<WorkerPool>) -> ShardScorer {
        ShardScorer {
            pool,
            min_sections: 256,
            steal: true,
            sharded_sections: 0,
            stolen_sections: 0,
            fallback_panics: 0,
            requeued_shards: 0,
            timeout: shard_timeout(),
            session_key: 0,
            session_weight: 1,
            scratch: ShardScratch::default(),
        }
    }

    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Whether a batch of `w` sections is worth packing for dispatch
    /// (callers with a reusable sequential `RegFile` check this first
    /// to avoid allocating a throwaway packed batch).  Always false on
    /// a pool worker thread — see [`in_pool_worker`].
    pub fn should_dispatch(&self, w: usize) -> bool {
        self.pool.threads() > 1 && w >= self.min_sections && !in_pool_worker()
    }

    /// Replay a packed batch into `out`, sharding across the pool when
    /// the batch is large enough.  Bitwise identical to
    /// `RegFile::replay` on the same batch — both run
    /// `PackedBatch::replay_range` over the same columns.
    ///
    /// Returns the batch back (buffers intact) so the caller can reuse
    /// its allocations for the next pack; `None` only in the rare case
    /// a worker still held a reference when the last result landed.
    pub fn replay(
        &mut self,
        batch: PackedBatch,
        out: &mut Vec<f64>,
    ) -> Result<Option<PackedBatch>, String> {
        let w = batch.width();
        out.clear();
        out.resize(w, 0.0);
        if !self.should_dispatch(w) {
            batch.replay_range(0, w, &mut self.scratch.sregs, out);
            return Ok(Some(batch));
        }
        let arc = Arc::new(batch);
        self.dispatch(ShardBatch::Packed(arc.clone()), w, out);
        self.sharded_sections += w;
        // workers drop their Arc before sending, so after the last
        // result this is normally the only reference left
        Ok(Arc::try_unwrap(arc).ok())
    }

    /// [`replay`](Self::replay) for a store-backed [`PanelBatch`]: the
    /// same dispatch, reduce, and work-stealing machinery, with shards
    /// gathering their own lane panels from the shared column store —
    /// no single-threaded pack stage at all on this rung.
    pub fn replay_panel(
        &mut self,
        batch: PanelBatch,
        out: &mut Vec<f64>,
    ) -> Result<Option<PanelBatch>, String> {
        let w = batch.width();
        out.clear();
        out.resize(w, 0.0);
        if !self.should_dispatch(w) {
            batch.replay_range(0, w, &mut self.scratch.lanes, out);
            return Ok(Some(batch));
        }
        let arc = Arc::new(batch);
        self.dispatch(ShardBatch::Panel(arc.clone()), w, out);
        self.sharded_sections += w;
        Ok(Arc::try_unwrap(arc).ok())
    }

    /// Shard `batch` over the pool, work-steal while waiting, and
    /// reduce the per-shard results into `out` in deterministic shard
    /// order — the common engine behind both batch kinds.
    ///
    /// The wait loop is the watchdog: a per-shard flag table tracks
    /// which ranges have landed, blocking waits carry a deadline, and
    /// on a lost shard (worker panic → channel disconnect) or a missed
    /// deadline (wedged worker) every missing range is re-run inline
    /// through the same pure kernel — so the recovered result is
    /// bitwise identical to the clean run by construction, and a late
    /// duplicate from a slow worker is simply ignored.  Infallible
    /// once the jobs are queued.
    fn dispatch(&mut self, batch: ShardBatch, w: usize, out: &mut [f64]) {
        let shards = self.pool.threads().min(w);
        let chunk = w.div_ceil(shards);
        let (tx, rx) = channel();
        let mut sent = 0usize;
        let mut lo = 0usize;
        while lo < w {
            let hi = (lo + chunk).min(w);
            self.pool.submit_shard(
                ShardJob {
                    batch: batch.clone(),
                    lo,
                    hi,
                    shard: sent,
                    done: tx.clone(),
                },
                self.session_key,
                self.session_weight,
            );
            sent += 1;
            lo = hi;
        }
        drop(tx);
        // keep one reference so the watchdog can re-run missing shards
        // inline (dropped before return, preserving the reclaim-by-
        // try_unwrap discipline in replay/replay_panel)
        let local = batch;
        let mut got = vec![false; sent];
        let mut received = 0usize;
        let deadline = self.timeout;
        // land one shard result, ignoring duplicates (a watchdog-
        // recovered shard's late original is bitwise identical anyway)
        fn land(
            out: &mut [f64],
            chunk: usize,
            got: &mut [bool],
            received: &mut usize,
            shard: usize,
            ls: &[f64],
        ) {
            if got[shard] {
                return;
            }
            let off = shard * chunk;
            out[off..off + ls.len()].copy_from_slice(ls);
            got[shard] = true;
            *received += 1;
        }
        while received < sent {
            // drain whatever is already done without blocking (stop as
            // soon as everything arrived — after the last result every
            // sender is gone and one more try_recv would read the
            // disconnect as a failure)
            let mut lost = false;
            while received < sent {
                match rx.try_recv() {
                    Ok((shard, ls)) => land(out, chunk, &mut got, &mut received, shard, &ls),
                    Err(TryRecvError::Empty) => break,
                    // every sender dropped with results still missing:
                    // a shard kernel panicked (its catch_unwind dropped
                    // the sender unsent)
                    Err(TryRecvError::Disconnected) => {
                        lost = true;
                        break;
                    }
                }
            }
            if lost {
                let missing = self.recover_missing(&local, chunk, w, &mut got, &mut received, out);
                self.fallback_panics += missing;
                eprintln!(
                    "[pool] worker panic: re-ran {missing} lost shard(s) of {sent} inline \
                     (batch of {w} sections; results unchanged)"
                );
                continue;
            }
            if received >= sent {
                break;
            }
            // work-steal: run an unclaimed shard inline rather than
            // parking this thread while its own work sits in the queue.
            // The stolen shard goes through the identical `run_shard_job`
            // (same kernel, same disjoint range, same shard-indexed
            // reduce), so stealing is invisible to results.
            if self.steal {
                if let Some(job) = self.pool.shared.steal_shard() {
                    let sections = job.hi - job.lo;
                    run_shard_job(job, &mut self.scratch);
                    self.stolen_sections += sections;
                    continue;
                }
            }
            // nothing left to steal: the remaining shards are on
            // workers — block until one reports, with a deadline
            match rx.recv_timeout(deadline) {
                Ok((shard, ls)) => land(out, chunk, &mut got, &mut received, shard, &ls),
                Err(RecvTimeoutError::Timeout) => {
                    // watchdog: the deadline passed with shards still
                    // outstanding — re-run them inline and replace the
                    // (presumed wedged) worker.  If the worker was
                    // merely slow, its late duplicate is ignored and
                    // the replacement just drains the shared queue.
                    let missing =
                        self.recover_missing(&local, chunk, w, &mut got, &mut received, out);
                    self.requeued_shards += missing;
                    let replaced = self.pool.add_worker();
                    eprintln!(
                        "[pool] shard deadline ({deadline:?}) passed: re-ran {missing} overdue \
                         shard(s) of {sent} inline{} (batch of {w} sections; results unchanged)",
                        if replaced { ", replaced 1 worker" } else { "" }
                    );
                }
                Err(RecvTimeoutError::Disconnected) => {
                    let missing =
                        self.recover_missing(&local, chunk, w, &mut got, &mut received, out);
                    self.fallback_panics += missing;
                    eprintln!(
                        "[pool] worker panic: re-ran {missing} lost shard(s) of {sent} inline \
                         (batch of {w} sections; results unchanged)"
                    );
                }
            }
        }
        drop(local);
    }

    /// Re-run every not-yet-landed shard inline through the same pure
    /// kernel over the same disjoint range — the recovery primitive
    /// behind both the panic and the deadline path.  Returns how many
    /// shards were recovered.
    fn recover_missing(
        &mut self,
        batch: &ShardBatch,
        chunk: usize,
        w: usize,
        got: &mut [bool],
        received: &mut usize,
        out: &mut [f64],
    ) -> usize {
        let mut recovered = 0usize;
        for shard in 0..got.len() {
            if got[shard] {
                continue;
            }
            let lo = shard * chunk;
            let hi = (lo + chunk).min(w);
            batch.replay_range(lo, hi, &mut self.scratch, &mut out[lo..hi]);
            got[shard] = true;
            *received += 1;
            recovered += 1;
        }
        recovered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_tasks_and_shuts_down() {
        let pool = WorkerPool::new(3);
        assert_eq!(pool.threads(), 3);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..24 {
            let c = counter.clone();
            let tx = tx.clone();
            pool.submit(Box::new(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            }));
        }
        drop(tx);
        for _ in 0..24 {
            rx.recv().expect("task did not run");
        }
        assert_eq!(counter.load(Ordering::SeqCst), 24);
        drop(pool); // Drop joins the workers; must not hang
    }

    #[test]
    fn task_panic_does_not_kill_the_worker() {
        let pool = WorkerPool::new(1);
        pool.submit(Box::new(|| panic!("deliberate")));
        let (tx, rx) = channel();
        pool.submit(Box::new(move || {
            let _ = tx.send(42);
        }));
        assert_eq!(rx.recv().unwrap(), 42, "worker died after a task panic");
    }

    #[test]
    fn resolve_threads_semantics() {
        assert_eq!(resolve_threads(1), 1);
        assert_eq!(resolve_threads(5), 5);
        assert!(resolve_threads(0) >= 1);
    }

    fn test_shard(shard: usize, sections: usize, done: Sender<(usize, Vec<f64>)>) -> ShardJob {
        ShardJob {
            batch: ShardBatch::Packed(Arc::new(PackedBatch::default())),
            lo: 0,
            hi: sections,
            shard,
            done,
        }
    }

    #[test]
    fn steal_shard_skips_tasks() {
        // a queue holding [task, shard] must hand the shard to a
        // stealer and leave the task in place
        let shared = Shared {
            queue: Mutex::new(QueueState::default()),
            available: Condvar::new(),
        };
        assert!(shared.steal_shard().is_none(), "empty queue stole something");
        shared.push_task(Box::new(|| {}));
        let (tx, rx) = channel();
        shared.push_shard(test_shard(0, 0, tx), 7, 1);
        let job = shared.steal_shard().expect("shard not stolen past the task");
        assert_eq!(job.shard, 0);
        run_shard_job(job, &mut ShardScratch::default());
        let (shard, out) = rx.recv().unwrap();
        assert_eq!((shard, out.len()), (0, 0));
        // the task is still queued, the shard lane is drained
        {
            let mut q = shared.lock_queue();
            assert_eq!(q.tasks.len(), 1);
            assert!(q.lanes.is_empty(), "drained lanes retire");
            let _ = q.tasks.pop_front();
        }
        assert!(shared.steal_shard().is_none());
    }

    #[test]
    fn single_lane_degenerates_to_fifo() {
        let mut q = QueueState::default();
        let (tx, _rx) = channel();
        for i in 0..6 {
            // mixed sizes: FIFO within one lane must hold regardless
            q.push_shard(test_shard(i, 100 + 700 * (i % 3), tx.clone()), 1, 1);
        }
        let order: Vec<usize> = std::iter::from_fn(|| q.pop_shard()).map(|j| j.shard).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn drr_shares_throughput_by_weight() {
        // two backlogged sessions with equal-cost jobs (1 quantum each)
        // and weights 1:3 → popped throughput settles at 1:3
        let mut q = QueueState::default();
        let (tx, _rx) = channel();
        for i in 0..16 {
            q.push_shard(test_shard(i, QUANTUM as usize, tx.clone()), 1, 1);
            q.push_shard(test_shard(i, QUANTUM as usize, tx.clone()), 2, 3);
        }
        let mut served = [0usize; 2];
        for _ in 0..16 {
            let job = q.pop_shard().expect("both lanes are backlogged");
            // recover the lane from the job's shard tag parity-free:
            // lane 1 pushed shards 0..16, lane 2 pushed shards 0..16 —
            // count by which lane shrank instead
            drop(job);
            let l1 = q.lanes.iter().find(|l| l.key == 1).map_or(0, |l| l.jobs.len());
            let l2 = q.lanes.iter().find(|l| l.key == 2).map_or(0, |l| l.jobs.len());
            served[0] = 16 - l1;
            served[1] = 16 - l2;
        }
        assert_eq!(
            served[0] + served[1],
            16,
            "16 pops must serve 16 jobs"
        );
        assert_eq!(
            served[1],
            3 * served[0],
            "weight-3 session gets 3x the weight-1 session's throughput \
             (got {served:?})"
        );
    }

    #[test]
    fn tasks_serve_before_shards_and_close_drains() {
        let shared = Shared {
            queue: Mutex::new(QueueState::default()),
            available: Condvar::new(),
        };
        let (tx, _rx) = channel();
        shared.push_shard(test_shard(0, 10, tx), 1, 1);
        shared.push_task(Box::new(|| {}));
        shared.close();
        assert!(
            matches!(shared.pop(), Some(Job::Task(_))),
            "tasks are chain drivers: they outrank queued shards"
        );
        assert!(matches!(shared.pop(), Some(Job::Shard(_))));
        assert!(shared.pop().is_none(), "closed + empty = shutdown");
    }
}
