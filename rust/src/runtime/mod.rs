//! XLA/PJRT runtime: loads the AOT-compiled L1/L2 artifacts (HLO text
//! emitted by python/compile/aot.py) and serves batched log-likelihood
//! evaluations to the Layer-3 hot path.  Python never runs at inference
//! time: after `make artifacts` the Rust binary is self-contained.

pub mod artifacts;
pub mod client;

pub use artifacts::{ArtifactInfo, ArtifactRegistry};
pub use client::{Executable, Input, XlaRuntime};
