//! Execution runtimes beneath the Layer-3 hot path:
//!
//! * **XLA/PJRT** (`artifacts`/`client`) — loads the AOT-compiled L1/L2
//!   artifacts (HLO text emitted by python/compile/aot.py) and serves
//!   batched log-likelihood evaluations.  Python never runs at
//!   inference time: after `make artifacts` the Rust binary is
//!   self-contained.
//! * **Worker pool** (`pool`) — the dependency-free persistent thread
//!   pool behind the sharded batch scorer and the concurrent
//!   multi-chain driver.

pub mod artifacts;
pub mod client;
pub mod faults;
pub mod pool;

pub use artifacts::{ArtifactInfo, ArtifactRegistry};
pub use client::{Executable, Input, XlaRuntime};
pub use pool::{auto_threads, resolve_threads, ShardScorer, WorkerPool};
