//! Artifact registry: indexes the AOT manifest and lazily compiles the
//! right (kind, batch-size, dim) variant on demand.
//!
//! Artifacts are shape-monomorphic, so the registry keeps a ladder of
//! mini-batch sizes per kind and picks the smallest variant that fits a
//! request, padding the remainder with mask = 0 rows.

use crate::runtime::client::{Executable, XlaRuntime};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// One row of the manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactInfo {
    pub name: String,
    pub kind: String,
    pub path: String,
    pub m: usize,
    pub d: usize,
}

/// The registry: manifest + lazily compiled executables.
pub struct ArtifactRegistry {
    dir: PathBuf,
    runtime: XlaRuntime,
    infos: Vec<ArtifactInfo>,
    compiled: HashMap<String, Rc<Executable>>,
}

/// Parse the TSV manifest (written by python/compile/aot.py alongside
/// the JSON twin; TSV keeps the Rust side dependency-free).
pub fn parse_manifest(text: &str) -> Result<Vec<ArtifactInfo>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let cols: Vec<&str> = line.split('\t').collect();
        if cols.len() != 5 {
            return Err(format!(
                "manifest line {}: expected 5 columns, got {}",
                lineno + 1,
                cols.len()
            ));
        }
        out.push(ArtifactInfo {
            name: cols[0].to_string(),
            kind: cols[1].to_string(),
            path: cols[2].to_string(),
            m: cols[3].parse().map_err(|e| format!("bad m: {e}"))?,
            d: cols[4].parse().map_err(|e| format!("bad d: {e}"))?,
        });
    }
    Ok(out)
}

impl ArtifactRegistry {
    /// Open a registry over an artifacts directory.
    pub fn open(dir: &Path) -> Result<ArtifactRegistry, String> {
        let manifest = std::fs::read_to_string(dir.join("manifest.tsv"))
            .map_err(|e| format!("read manifest.tsv in {dir:?}: {e}"))?;
        let infos = parse_manifest(&manifest)?;
        let runtime = XlaRuntime::cpu()?;
        Ok(ArtifactRegistry {
            dir: dir.to_path_buf(),
            runtime,
            infos,
            compiled: HashMap::new(),
        })
    }

    /// Default location: `$SUBPPL_ARTIFACTS` or `<repo>/artifacts`.
    pub fn open_default() -> Result<ArtifactRegistry, String> {
        let dir = std::env::var("SUBPPL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        Self::open(&dir)
    }

    pub fn infos(&self) -> &[ArtifactInfo] {
        &self.infos
    }

    /// Smallest variant of `kind` (matching `d` if it has a feature dim)
    /// whose batch size fits `m_needed`; falls back to the largest.
    pub fn pick(&self, kind: &str, m_needed: usize, d: usize) -> Option<&ArtifactInfo> {
        let fits = self
            .infos
            .iter()
            .filter(|a| a.kind == kind && (a.d == d || a.d == 0))
            .filter(|a| a.m >= m_needed)
            .min_by_key(|a| a.m);
        fits.or_else(|| {
            self.infos
                .iter()
                .filter(|a| a.kind == kind && (a.d == d || a.d == 0))
                .max_by_key(|a| a.m)
        })
    }

    /// Compile (or fetch) the executable for an artifact name.
    pub fn executable(&mut self, name: &str) -> Result<Rc<Executable>, String> {
        if let Some(e) = self.compiled.get(name) {
            return Ok(e.clone());
        }
        let info = self
            .infos
            .iter()
            .find(|a| a.name == name)
            .ok_or_else(|| format!("unknown artifact {name}"))?;
        let exe = Rc::new(self.runtime.load_hlo_text(&self.dir.join(&info.path))?);
        self.compiled.insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// pick + compile in one step; returns (info, executable).
    pub fn pick_executable(
        &mut self,
        kind: &str,
        m_needed: usize,
        d: usize,
    ) -> Result<(ArtifactInfo, Rc<Executable>), String> {
        let info = self
            .pick(kind, m_needed, d)
            .ok_or_else(|| format!("no artifact for kind={kind} d={d}"))?
            .clone();
        let exe = self.executable(&info.name)?;
        Ok((info, exe))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_rows() {
        let text = "# name\tkind\tpath\tm\td\n\
                    logistic_ratio_m16_d3\tlogistic_ratio\tlogistic_ratio_m16_d3.hlo.txt\t16\t3\n\
                    gauss_ar1_ratio_m64\tgauss_ar1_ratio\tgauss_ar1_ratio_m64.hlo.txt\t64\t0\n";
        let infos = parse_manifest(text).unwrap();
        assert_eq!(infos.len(), 2);
        assert_eq!(infos[0].m, 16);
        assert_eq!(infos[1].kind, "gauss_ar1_ratio");
    }

    #[test]
    fn rejects_malformed_manifest() {
        assert!(parse_manifest("a\tb\tc\n").is_err());
        assert!(parse_manifest("a\tb\tc\tnot_a_number\t0\n").is_err());
    }

    #[test]
    fn pick_prefers_smallest_fitting() {
        let text = "\
            r16\tlogistic_ratio\tp\t16\t3\n\
            r128\tlogistic_ratio\tp\t128\t3\n\
            r1024\tlogistic_ratio\tp\t1024\t3\n";
        let infos = parse_manifest(text).unwrap();
        // emulate pick() logic without a runtime
        let pick = |needed: usize| {
            infos
                .iter()
                .filter(|a| a.m >= needed)
                .min_by_key(|a| a.m)
                .or_else(|| infos.iter().max_by_key(|a| a.m))
                .unwrap()
                .m
        };
        assert_eq!(pick(10), 16);
        assert_eq!(pick(100), 128);
        assert_eq!(pick(129), 1024);
        assert_eq!(pick(5000), 1024); // fall back to largest
    }

    #[test]
    fn open_and_compile_if_built() {
        let Ok(mut reg) = ArtifactRegistry::open_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert!(!reg.infos().is_empty());
        let (info, exe) = reg.pick_executable("logistic_ratio", 100, 50).unwrap();
        assert!(info.m >= 100);
        assert_eq!(info.d, 50);
        // compile is cached
        let again = reg.executable(&info.name).unwrap();
        assert!(Rc::ptr_eq(&exe, &again));
    }
}
