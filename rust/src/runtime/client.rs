//! PJRT client wrapper: loads AOT HLO-text artifacts and executes them.
//!
//! The interchange format is HLO *text* (not serialized protos): jax>=0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects, while
//! the text parser reassigns ids (see python/compile/aot.py and
//! /opt/xla-example/README.md).  Entry computations return 1-tuples
//! (`return_tuple=True`), unwrapped here with `to_tuple1`.
//!
//! The real client needs the external `xla` crate, which is not vendored
//! in this environment; it is gated behind the `xla-runtime` cargo
//! feature (see Cargo.toml).  Without the feature this module compiles a
//! stub whose constructors return `Err`, so every caller — the artifact
//! registry, `FusedEval`, the CLI — degrades gracefully to the pure-Rust
//! evaluators.

use std::path::Path;

/// An f32 input buffer with a shape.
pub struct Input<'a> {
    pub data: &'a [f32],
    pub shape: &'a [usize],
}

/// A PJRT client (CPU).
pub struct XlaRuntime {
    #[cfg(feature = "xla-runtime")]
    client: xla::PjRtClient,
    #[cfg(not(feature = "xla-runtime"))]
    _private: (),
}

/// One compiled executable with a fixed input signature.
pub struct Executable {
    #[cfg(feature = "xla-runtime")]
    exe: xla::PjRtLoadedExecutable,
    #[cfg(not(feature = "xla-runtime"))]
    _private: (),
}

#[cfg(feature = "xla-runtime")]
impl XlaRuntime {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<XlaRuntime, String> {
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PjRtClient::cpu: {e:?}"))?;
        Ok(XlaRuntime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable, String> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or("non-utf8 artifact path")?,
        )
        .map_err(|e| format!("HLO parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| format!("XLA compile {path:?}: {e:?}"))?;
        Ok(Executable { exe })
    }
}

#[cfg(feature = "xla-runtime")]
impl Executable {
    /// Execute with f32 inputs; returns the flattened f32 output (the
    /// single tuple element).
    pub fn run_f32(&self, inputs: &[Input]) -> Result<Vec<f32>, String> {
        let mut literals = Vec::with_capacity(inputs.len());
        for inp in inputs {
            let lit = xla::Literal::vec1(inp.data);
            let dims: Vec<i64> = inp.shape.iter().map(|&d| d as i64).collect();
            let lit = lit
                .reshape(&dims)
                .map_err(|e| format!("reshape {:?}: {e:?}", inp.shape))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| format!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("to_literal: {e:?}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| format!("to_tuple1: {e:?}"))?;
        out.to_vec::<f32>().map_err(|e| format!("to_vec: {e:?}"))
    }
}

#[cfg(not(feature = "xla-runtime"))]
impl XlaRuntime {
    /// Stub: the crate was built without the `xla-runtime` feature.
    pub fn cpu() -> Result<XlaRuntime, String> {
        Err("built without the `xla-runtime` feature; PJRT unavailable".into())
    }

    pub fn platform(&self) -> String {
        "unavailable".into()
    }

    pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable, String> {
        Err("built without the `xla-runtime` feature; PJRT unavailable".into())
    }
}

#[cfg(not(feature = "xla-runtime"))]
impl Executable {
    pub fn run_f32(&self, _inputs: &[Input]) -> Result<Vec<f32>, String> {
        Err("built without the `xla-runtime` feature; PJRT unavailable".into())
    }
}

#[cfg(all(test, feature = "xla-runtime"))]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::env::var("SUBPPL_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
        if dir.join("manifest.tsv").exists() {
            Some(dir)
        } else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            None
        }
    }

    #[test]
    fn loads_and_runs_logistic_ratio() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = XlaRuntime::cpu().unwrap();
        let exe = rt
            .load_hlo_text(&dir.join("logistic_ratio_m16_d3.hlo.txt"))
            .unwrap();
        let m = 16;
        let d = 3;
        let x: Vec<f32> = (0..m * d).map(|i| (i as f32) * 0.01 - 0.2).collect();
        let t: Vec<f32> = (0..m).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let mask = vec![1.0f32; m];
        let w_old = vec![0.1f32, -0.2, 0.3];
        let w_new = vec![0.2f32, 0.1, -0.1];
        let out = exe
            .run_f32(&[
                Input { data: &x, shape: &[m, d] },
                Input { data: &t, shape: &[m] },
                Input { data: &mask, shape: &[m] },
                Input { data: &w_old, shape: &[d] },
                Input { data: &w_new, shape: &[d] },
            ])
            .unwrap();
        assert_eq!(out.len(), m);
        // check against the Rust-side formula
        let logsig = |z: f64| crate::math::special::log_sigmoid(z);
        for i in 0..m {
            let xi = &x[i * d..(i + 1) * d];
            let dot = |w: &[f32]| -> f64 {
                xi.iter().zip(w).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
            };
            let want = logsig(t[i] as f64 * dot(&w_new)) - logsig(t[i] as f64 * dot(&w_old));
            assert!(
                (out[i] as f64 - want).abs() < 1e-5,
                "i={i}: {} vs {want}",
                out[i]
            );
        }
    }

    #[test]
    fn mask_zeroes_padding() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = XlaRuntime::cpu().unwrap();
        let exe = rt
            .load_hlo_text(&dir.join("gauss_ar1_ratio_m16.hlo.txt"))
            .unwrap();
        let m = 16;
        let h_prev: Vec<f32> = (0..m).map(|i| i as f32 * 0.1).collect();
        let h: Vec<f32> = (0..m).map(|i| i as f32 * 0.05).collect();
        let mut mask = vec![1.0f32; m];
        for v in mask.iter_mut().skip(10) {
            *v = 0.0;
        }
        let params = vec![0.95f32, 0.1, 0.5, 0.2];
        let out = exe
            .run_f32(&[
                Input { data: &h_prev, shape: &[m] },
                Input { data: &h, shape: &[m] },
                Input { data: &mask, shape: &[m] },
                Input { data: &params, shape: &[4] },
            ])
            .unwrap();
        for (i, &o) in out.iter().enumerate().skip(10) {
            assert_eq!(o, 0.0, "padding row {i} leaked: {o}");
        }
        assert!(out[1] != 0.0);
    }
}
