//! Deterministic fault injection: the chaos source every recovery path
//! is tested against.
//!
//! Gated twice.  At compile time the `fault-inject` cargo feature must
//! be on — without it every `*_now()` hook below is a literal `false`
//! the optimizer deletes, so production builds carry zero overhead and
//! zero risk.  At run time a [`FaultPlan`] must be armed, either
//! programmatically ([`install`], what the differential tests use) or
//! through the `SUBPPL_FAULTS` environment variable
//! (`panic@3,stall@1,poison@2,nan@4` — fire the named fault at the
//! k-th event of its kind).
//!
//! Each fault fires **exactly once**, at the k-th event of its kind,
//! counted by a process-wide atomic — so a plan names one deterministic
//! point in the event stream regardless of which thread reaches it.
//! Fire-once is also what makes recovery testable: when the watchdog
//! re-runs a faulted shard, the re-run cannot re-fault.
//!
//! The faults and where they hook in:
//!
//! | fault        | event counted                      | hook site                          |
//! |--------------|------------------------------------|------------------------------------|
//! | `panic`      | shard-job kernel execution         | `runtime/pool.rs::run_shard_job`   |
//! | `stall`      | shard job picked up by a worker    | `runtime/pool.rs::worker_loop`     |
//! | `poison`     | column-store member row refresh    | `trace/colstore.rs::refresh_member`|
//! | `nan`        | store-tier group evaluation        | `infer/planned.rs::eval_group_store`|
//! | `spanic`     | serve-session draw                 | `serve/session.rs::Session::step`  |
//! | `cancel`     | subsampled-MH mini-batch round     | `infer/subsampled_mh.rs` (trips all registered cancel flags) |
//! | `slowloris`  | streamed serve event write         | `serve/server.rs` (wedges the subscriber writer) |
//! | `disconnect` | streamed serve event write         | `serve/server.rs` (drops the client connection) |
//! | `torn-write` | session journal record write       | `serve/journal.rs` (writes a prefix of the record, then "dies") |
//! | `kill-recover` | session journal record write     | `serve/journal.rs` (writes nothing — a SIGKILL just before the write) |

#[cfg(feature = "fault-inject")]
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

/// Which event of each kind should fault (1-based; `0` = never).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Panic the shard kernel on the k-th shard job.
    pub panic_at: u64,
    /// Wedge the worker on the k-th shard job it picks up (the job is
    /// held unexecuted and unreported until pool shutdown).
    pub stall_at: u64,
    /// Corrupt the k-th column-store row refresh after its integrity
    /// hash is recorded (so the panel self-check catches it).
    pub poison_at: u64,
    /// Overwrite one section score with NaN on the k-th store-tier
    /// group evaluation (so the NaN cross-check fires).
    pub nan_at: u64,
    /// Panic a serve session's model step on its k-th draw (exercises
    /// the session supervisor's catch_unwind + checkpoint replay).
    pub spanic_at: u64,
    /// Trip every registered cancel flag ([`register_cancel_flag`]) at
    /// the k-th subsampled-MH mini-batch round — a deterministic
    /// mid-transition cancellation for torn-trace tests.
    pub cancel_at: u64,
    /// Wedge the serve subscriber writer on the k-th streamed event
    /// write (a client that stops reading — slowloris).
    pub slowloris_at: u64,
    /// Drop the serve client connection on the k-th streamed event
    /// write (mid-stream disconnect).
    pub disconnect_at: u64,
    /// Tear the k-th journal record write: a prefix of the record's
    /// bytes lands on disk and the journal handle goes dead, exactly as
    /// if the process was killed mid-`write(2)`.  Recovery must detect
    /// the torn tail, drop it at the last valid record boundary, and
    /// resume from the state before the torn write.
    pub torn_write_at: u64,
    /// Kill the journal on the k-th record write *before* any byte
    /// lands (a SIGKILL between the state change and the journal
    /// append): the journal stays clean but stale, and the un-acked
    /// operation must not survive recovery.
    pub kill_recover_at: u64,
}

impl FaultPlan {
    /// Parse the `SUBPPL_FAULTS` syntax: a comma-separated list of
    /// `kind@k` entries, kinds `panic` / `stall` / `poison` / `nan` /
    /// `spanic` / `cancel` / `slowloris` / `disconnect` / `torn-write`
    /// / `kill-recover`.
    pub fn parse(s: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for entry in s.split(',').map(str::trim).filter(|e| !e.is_empty()) {
            let (kind, at) = entry
                .split_once('@')
                .ok_or_else(|| format!("fault entry {entry:?}: expected kind@k"))?;
            let k: u64 = at
                .parse()
                .map_err(|_| format!("fault entry {entry:?}: bad event index {at:?}"))?;
            match kind.trim() {
                "panic" => plan.panic_at = k,
                "stall" => plan.stall_at = k,
                "poison" => plan.poison_at = k,
                "nan" => plan.nan_at = k,
                "spanic" => plan.spanic_at = k,
                "cancel" => plan.cancel_at = k,
                "slowloris" => plan.slowloris_at = k,
                "disconnect" => plan.disconnect_at = k,
                "torn-write" => plan.torn_write_at = k,
                "kill-recover" => plan.kill_recover_at = k,
                other => return Err(format!("unknown fault kind {other:?}")),
            }
        }
        Ok(plan)
    }
}

#[cfg(feature = "fault-inject")]
mod armed {
    use super::*;

    pub static PANIC_AT: AtomicU64 = AtomicU64::new(0);
    pub static PANIC_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static STALL_AT: AtomicU64 = AtomicU64::new(0);
    pub static STALL_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static POISON_AT: AtomicU64 = AtomicU64::new(0);
    pub static POISON_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static NAN_AT: AtomicU64 = AtomicU64::new(0);
    pub static NAN_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static SPANIC_AT: AtomicU64 = AtomicU64::new(0);
    pub static SPANIC_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static CANCEL_AT: AtomicU64 = AtomicU64::new(0);
    pub static CANCEL_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static SLOWLORIS_AT: AtomicU64 = AtomicU64::new(0);
    pub static SLOWLORIS_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static DISCONNECT_AT: AtomicU64 = AtomicU64::new(0);
    pub static DISCONNECT_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static TORN_WRITE_AT: AtomicU64 = AtomicU64::new(0);
    pub static TORN_WRITE_SEEN: AtomicU64 = AtomicU64::new(0);
    pub static KILL_RECOVER_AT: AtomicU64 = AtomicU64::new(0);
    pub static KILL_RECOVER_SEEN: AtomicU64 = AtomicU64::new(0);

    /// Set once [`install`] has been called, so the lazy `SUBPPL_FAULTS`
    /// read can never overwrite a programmatic plan.
    pub static INSTALLED: AtomicBool = AtomicBool::new(false);

    pub fn env_init() {
        static ONCE: std::sync::Once = std::sync::Once::new();
        ONCE.call_once(|| {
            if INSTALLED.load(Ordering::SeqCst) {
                return;
            }
            if let Ok(s) = std::env::var("SUBPPL_FAULTS") {
                match FaultPlan::parse(&s) {
                    Ok(plan) => set(plan),
                    Err(e) => eprintln!("[faults] ignoring SUBPPL_FAULTS: {e}"),
                }
            }
        });
    }

    pub fn set(plan: FaultPlan) {
        PANIC_AT.store(plan.panic_at, Ordering::SeqCst);
        PANIC_SEEN.store(0, Ordering::SeqCst);
        STALL_AT.store(plan.stall_at, Ordering::SeqCst);
        STALL_SEEN.store(0, Ordering::SeqCst);
        POISON_AT.store(plan.poison_at, Ordering::SeqCst);
        POISON_SEEN.store(0, Ordering::SeqCst);
        NAN_AT.store(plan.nan_at, Ordering::SeqCst);
        NAN_SEEN.store(0, Ordering::SeqCst);
        SPANIC_AT.store(plan.spanic_at, Ordering::SeqCst);
        SPANIC_SEEN.store(0, Ordering::SeqCst);
        CANCEL_AT.store(plan.cancel_at, Ordering::SeqCst);
        CANCEL_SEEN.store(0, Ordering::SeqCst);
        SLOWLORIS_AT.store(plan.slowloris_at, Ordering::SeqCst);
        SLOWLORIS_SEEN.store(0, Ordering::SeqCst);
        DISCONNECT_AT.store(plan.disconnect_at, Ordering::SeqCst);
        DISCONNECT_SEEN.store(0, Ordering::SeqCst);
        TORN_WRITE_AT.store(plan.torn_write_at, Ordering::SeqCst);
        TORN_WRITE_SEEN.store(0, Ordering::SeqCst);
        KILL_RECOVER_AT.store(plan.kill_recover_at, Ordering::SeqCst);
        KILL_RECOVER_SEEN.store(0, Ordering::SeqCst);
    }

    /// Count one event; true exactly when this is the k-th.
    pub fn fire(at: &AtomicU64, seen: &AtomicU64) -> bool {
        // relaxed is enough: the counters are independent monotone
        // event streams, not synchronization points
        let k = at.load(Ordering::Relaxed);
        if k == 0 {
            return false;
        }
        seen.fetch_add(1, Ordering::Relaxed) + 1 == k
    }
}

/// Arm a plan programmatically and reset the event counters.  Tests use
/// this instead of `SUBPPL_FAULTS` because environment variables are
/// process-global and racy across concurrently running tests.
#[cfg(feature = "fault-inject")]
pub fn install(plan: FaultPlan) {
    armed::INSTALLED.store(true, Ordering::SeqCst);
    armed::set(plan);
}

/// Disarm all faults (counters reset).
#[cfg(feature = "fault-inject")]
pub fn clear() {
    install(FaultPlan::default());
}

macro_rules! hook {
    ($(#[$doc:meta])* $name:ident, $at:ident, $seen:ident) => {
        $(#[$doc])*
        #[inline]
        pub fn $name() -> bool {
            #[cfg(feature = "fault-inject")]
            {
                armed::env_init();
                armed::fire(&armed::$at, &armed::$seen)
            }
            #[cfg(not(feature = "fault-inject"))]
            {
                false
            }
        }
    };
}

hook!(
    /// Should the shard kernel panic on this shard job?
    shard_panic_now,
    PANIC_AT,
    PANIC_SEEN
);
hook!(
    /// Should the worker wedge instead of running this shard job?
    shard_stall_now,
    STALL_AT,
    STALL_SEEN
);
hook!(
    /// Should this column-store row refresh be corrupted?
    poison_store_row_now,
    POISON_AT,
    POISON_SEEN
);
hook!(
    /// Should this store-tier group evaluation emit a NaN score?
    nan_score_now,
    NAN_AT,
    NAN_SEEN
);
hook!(
    /// Should this serve-session draw panic?
    session_panic_now,
    SPANIC_AT,
    SPANIC_SEEN
);
hook!(
    /// Should this mini-batch round trip every registered cancel flag?
    cancel_mid_transition_now,
    CANCEL_AT,
    CANCEL_SEEN
);
hook!(
    /// Should this streamed event write wedge (client stopped reading)?
    slowloris_write_now,
    SLOWLORIS_AT,
    SLOWLORIS_SEEN
);
hook!(
    /// Should this streamed event write drop the connection?
    disconnect_write_now,
    DISCONNECT_AT,
    DISCONNECT_SEEN
);
hook!(
    /// Should this journal record write land only a torn prefix and
    /// kill the journal handle?
    journal_torn_write_now,
    TORN_WRITE_AT,
    TORN_WRITE_SEEN
);
hook!(
    /// Should this journal record write land nothing (SIGKILL just
    /// before the append) and kill the journal handle?
    journal_kill_now,
    KILL_RECOVER_AT,
    KILL_RECOVER_SEEN
);

/// Registry of cancel flags the `cancel@k` fault trips.  Sessions (and
/// the cancellation-correctness test) register their stop flag here;
/// when the armed hook fires mid-transition it flips every live flag,
/// giving a deterministic mid-transition cancellation point.  Weak
/// references, so a finished session's flag just drops out.
#[cfg(feature = "fault-inject")]
mod cancel_registry {
    use std::sync::atomic::AtomicBool;
    use std::sync::{Arc, Mutex, Weak};

    static FLAGS: Mutex<Vec<Weak<AtomicBool>>> = Mutex::new(Vec::new());

    pub fn register(flag: &Arc<AtomicBool>) {
        FLAGS.lock().unwrap().push(Arc::downgrade(flag));
    }

    pub fn trip_all() {
        let mut flags = FLAGS.lock().unwrap();
        flags.retain(|w| match w.upgrade() {
            Some(f) => {
                f.store(true, std::sync::atomic::Ordering::SeqCst);
                true
            }
            None => false,
        });
    }
}

/// Register a stop flag with the `cancel@k` fault (no-op without the
/// `fault-inject` feature).
pub fn register_cancel_flag(flag: &std::sync::Arc<std::sync::atomic::AtomicBool>) {
    #[cfg(feature = "fault-inject")]
    cancel_registry::register(flag);
    #[cfg(not(feature = "fault-inject"))]
    let _ = flag;
}

/// Trip every registered cancel flag — called by the `cancel@k` hook
/// site when the fault fires (no-op without the feature).
pub fn trip_cancel_flags() {
    #[cfg(feature = "fault-inject")]
    cancel_registry::trip_all();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parses_every_kind() {
        let plan = FaultPlan::parse(
            "panic@3, stall@1,poison@2,nan@4,spanic@5,cancel@6,slowloris@7,disconnect@8,\
             torn-write@9,kill-recover@10",
        )
        .unwrap();
        assert_eq!(
            plan,
            FaultPlan {
                panic_at: 3,
                stall_at: 1,
                poison_at: 2,
                nan_at: 4,
                spanic_at: 5,
                cancel_at: 6,
                slowloris_at: 7,
                disconnect_at: 8,
                torn_write_at: 9,
                kill_recover_at: 10
            }
        );
        assert_eq!(FaultPlan::parse("").unwrap(), FaultPlan::default());
        assert!(FaultPlan::parse("explode@1").is_err());
        assert!(FaultPlan::parse("panic").is_err());
        assert!(FaultPlan::parse("panic@x").is_err());
    }

    #[cfg(not(feature = "fault-inject"))]
    #[test]
    fn hooks_are_inert_without_the_feature() {
        for _ in 0..4 {
            assert!(!shard_panic_now());
            assert!(!shard_stall_now());
            assert!(!poison_store_row_now());
            assert!(!nan_score_now());
            assert!(!session_panic_now());
            assert!(!cancel_mid_transition_now());
            assert!(!slowloris_write_now());
            assert!(!disconnect_write_now());
            assert!(!journal_torn_write_now());
            assert!(!journal_kill_now());
        }
    }

    #[cfg(feature = "fault-inject")]
    #[test]
    fn hooks_fire_exactly_once_at_k() {
        // serialized against other fault tests by being the only
        // in-crate test that arms a plan; the integration suite
        // (tests/faults.rs) uses its own mutex
        install(FaultPlan {
            panic_at: 3,
            ..FaultPlan::default()
        });
        let fired: Vec<bool> = (0..5).map(|_| shard_panic_now()).collect();
        assert_eq!(fired, vec![false, false, true, false, false]);
        assert!(!shard_stall_now(), "unarmed kinds must stay silent");
        clear();
        assert!(!shard_panic_now());
    }
}
