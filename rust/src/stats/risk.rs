//! Predictive-risk metrics for the Fig. 4 / Fig. 6 experiments.
//!
//! Following Korattikara et al. (2014), the "risk of the predictive
//! mean" at time t is the squared error of the running Monte-Carlo
//! average of the predictive probabilities against a long-run reference
//! predictive, averaged over the test set.  The harness computes the
//! reference from an extended exact-MH run.

/// Mean squared difference between a running predictive mean and a
/// reference predictive, averaged over test points.
pub fn predictive_risk(pred_mean: &[f64], reference: &[f64]) -> f64 {
    assert_eq!(pred_mean.len(), reference.len());
    assert!(!pred_mean.is_empty());
    pred_mean
        .iter()
        .zip(reference)
        .map(|(p, r)| (p - r) * (p - r))
        .sum::<f64>()
        / pred_mean.len() as f64
}

/// 0/1 classification error of thresholded predictive probabilities.
pub fn zero_one_error(probs: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    assert!(!probs.is_empty());
    let wrong = probs
        .iter()
        .zip(labels)
        .filter(|(p, &y)| (**p >= 0.5) != y)
        .count();
    wrong as f64 / probs.len() as f64
}

/// Average negative log-likelihood of labels under predictive probs.
pub fn log_loss(probs: &[f64], labels: &[bool]) -> f64 {
    assert_eq!(probs.len(), labels.len());
    assert!(!probs.is_empty());
    let eps = 1e-12;
    -probs
        .iter()
        .zip(labels)
        .map(|(p, &y)| {
            let p = p.clamp(eps, 1.0 - eps);
            if y {
                p.ln()
            } else {
                (1.0 - p).ln()
            }
        })
        .sum::<f64>()
        / probs.len() as f64
}

/// Accumulates the running average of per-test-point predictions over the
/// chain, so risk can be reported at any time point.
#[derive(Clone, Debug)]
pub struct PredictiveAccumulator {
    sum: Vec<f64>,
    n: usize,
}

impl PredictiveAccumulator {
    pub fn new(n_test: usize) -> Self {
        PredictiveAccumulator {
            sum: vec![0.0; n_test],
            n: 0,
        }
    }

    pub fn push(&mut self, probs: &[f64]) {
        assert_eq!(probs.len(), self.sum.len());
        for (s, p) in self.sum.iter_mut().zip(probs) {
            *s += p;
        }
        self.n += 1;
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> Vec<f64> {
        assert!(self.n > 0, "no predictions accumulated");
        self.sum.iter().map(|s| s / self.n as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn risk_zero_iff_equal() {
        let p = [0.2, 0.8, 0.5];
        assert_eq!(predictive_risk(&p, &p), 0.0);
        let q = [0.3, 0.8, 0.5];
        assert!((predictive_risk(&p, &q) - 0.01 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_one_counts_misclassifications() {
        let probs = [0.9, 0.1, 0.6, 0.4];
        let labels = [true, true, false, false];
        assert!((zero_one_error(&probs, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn log_loss_perfect_is_zero() {
        let probs = [1.0, 0.0];
        let labels = [true, false];
        assert!(log_loss(&probs, &labels) < 1e-10);
    }

    #[test]
    fn accumulator_averages() {
        let mut acc = PredictiveAccumulator::new(2);
        acc.push(&[0.0, 1.0]);
        acc.push(&[1.0, 1.0]);
        assert_eq!(acc.mean(), vec![0.5, 1.0]);
        assert_eq!(acc.n(), 2);
    }
}
