//! Seeded random-program generator for shape-key property tests.
//!
//! Produces logistic-regression-like programs whose observations draw
//! from a small family of *section shapes* over a shared principal
//! (plus a second principal at a different dimensionality), with
//! randomized constants and labels.  The shapes are chosen so the
//! `ShapeKey` contract is falsifiable from the outside:
//!
//! * same class, different constants/labels  -> keys must collide;
//! * different det chains (extra `exp`)      -> keys must differ;
//! * same det chain, different vector arity  -> keys must differ.
//!
//! The generator is deliberately deterministic (one `Pcg64` per seed):
//! property tests over `seed in 0..K` are reproducible in CI with no
//! external proptest dependency.

use crate::math::Pcg64;

/// Section-shape classes emitted by [`gen_program`], in the order of
/// the returned label vector.
pub const CLASS_LOGISTIC: u8 = 0;
pub const CLASS_GAUSS_DOT: u8 = 1;
pub const CLASS_GAUSS_EXP: u8 = 2;

/// A generated program over principal `w` (dimension `d`, classes 0-2
/// mixed at random) and principal `w2` (dimension `d + 1`, logistic
/// sections only — the arity counterexample).
pub struct GenProgram {
    pub src: String,
    /// Shape class of each `w`-observation, in observation (= border
    /// child) order.
    pub w_classes: Vec<u8>,
    /// Number of `w2` observations (all logistic at dimension d+1).
    pub n_w2: usize,
    pub d: usize,
}

fn vec_lit(rng: &mut Pcg64, d: usize) -> String {
    let xs: Vec<String> = (0..d).map(|_| format!("{:.4}", rng.normal())).collect();
    format!("(vector {})", xs.join(" "))
}

/// Generate a program with `n` observations on `w` (classes drawn at
/// random, but every class appears at least twice) and 2 observations
/// on `w2`.
pub fn gen_program(seed: u64, n: usize, d: usize) -> GenProgram {
    assert!(n >= 6, "need room for two of each class");
    let mut rng = Pcg64::new(seed, 0x5eed_ba7c);
    let zeros = vec!["0"; d].join(" ");
    let zeros2 = vec!["0"; d + 1].join(" ");
    let mut src = format!(
        "[assume w (scope_include 'w 0 (multivariate_normal (vector {zeros}) 0.5))]\n\
         [assume w2 (scope_include 'w2 0 (multivariate_normal (vector {zeros2}) 0.5))]\n\
         [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n\
         [assume gn (lambda (x s) (normal (dot w x) s))]\n\
         [assume ge (lambda (x s) (normal (exp (dot w x)) s))]\n\
         [assume f2 (lambda (x) (bernoulli (linear_logistic w2 x)))]\n"
    );
    // two of each class up front (so every key has a collision partner),
    // then uniform draws
    let mut classes: Vec<u8> = vec![
        CLASS_LOGISTIC,
        CLASS_LOGISTIC,
        CLASS_GAUSS_DOT,
        CLASS_GAUSS_DOT,
        CLASS_GAUSS_EXP,
        CLASS_GAUSS_EXP,
    ];
    while classes.len() < n {
        classes.push(rng.below(3) as u8);
    }
    for &c in &classes {
        let x = vec_lit(&mut rng, d);
        match c {
            CLASS_LOGISTIC => {
                let lab = if rng.bernoulli(0.5) { "true" } else { "false" };
                src.push_str(&format!("[observe (f {x}) {lab}]\n"));
            }
            CLASS_GAUSS_DOT => {
                let s = 0.5 + rng.uniform();
                src.push_str(&format!("[observe (gn {x} {s:.4}) {:.4}]\n", rng.normal()));
            }
            _ => {
                let s = 0.5 + rng.uniform();
                src.push_str(&format!("[observe (ge {x} {s:.4}) {:.4}]\n", rng.normal()));
            }
        }
    }
    let n_w2 = 2;
    for _ in 0..n_w2 {
        let x = vec_lit(&mut rng, d + 1);
        let lab = if rng.bernoulli(0.5) { "true" } else { "false" };
        src.push_str(&format!("[observe (f2 {x}) {lab}]\n"));
    }
    GenProgram {
        src,
        w_classes: classes,
        n_w2,
        d,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generated_program_parses_and_runs() {
        let gp = gen_program(3, 10, 3);
        let mut t = crate::trace::Trace::new();
        let mut rng = Pcg64::seeded(3);
        t.run_program(&gp.src, &mut rng).unwrap();
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        assert_eq!(p.n(), gp.w_classes.len());
        let w2 = t.lookup_node("w2").unwrap();
        let p2 = t.cached_partition(w2).unwrap();
        assert_eq!(p2.n(), gp.n_w2);
    }

    #[test]
    fn generator_is_deterministic_per_seed() {
        let a = gen_program(7, 12, 2);
        let b = gen_program(7, 12, 2);
        assert_eq!(a.src, b.src);
        assert_eq!(a.w_classes, b.w_classes);
        let c = gen_program(8, 12, 2);
        assert_ne!(a.src, c.src);
    }
}
