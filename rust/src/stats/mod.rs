//! MCMC diagnostics and experiment metrics: running moments,
//! autocorrelation / effective sample size, predictive risk, and the
//! §3.3 normality safeguard.

pub mod diagnostics;
pub mod normality;
pub mod risk;

pub use diagnostics::{autocorrelation, ess, RunningMoments};
pub use normality::{jarque_bera, NormalityReport};
pub use risk::{log_loss, predictive_risk, zero_one_error};
