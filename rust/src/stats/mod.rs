//! MCMC diagnostics and experiment metrics: running moments,
//! autocorrelation / effective sample size, predictive risk, the §3.3
//! normality safeguard, and the seeded program generator backing the
//! shape-key property tests.

pub mod diagnostics;
pub mod normality;
pub mod propgen;
pub mod risk;

pub use diagnostics::{
    autocorrelation, ess, ess_lazy, rank_normalized_rhat, split_rhat, RunningMoments,
    StreamingEss,
};
pub use normality::{jarque_bera, NormalityReport};
pub use risk::{log_loss, predictive_risk, zero_one_error};
