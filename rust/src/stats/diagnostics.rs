//! Chain diagnostics: running moments, autocorrelation, effective sample
//! size.  Fig. 9d of the paper reports autocorrelation vs *wall-clock lag*
//! and ESS per second; `ess` here is ESS per sample, and the harness
//! divides by measured runtime.

/// Numerically stable running mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct RunningMoments {
    n: usize,
    mean: f64,
    m2: f64,
}

impl RunningMoments {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (n-1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Sample autocorrelation function up to `max_lag` (inclusive), biased
/// (n-denominator) estimator as standard for ACF plots.
pub fn autocorrelation(xs: &[f64], max_lag: usize) -> Vec<f64> {
    let n = xs.len();
    if n < 2 {
        return vec![1.0];
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let c0: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    if c0 == 0.0 {
        return vec![1.0; max_lag.min(n - 1) + 1];
    }
    (0..=max_lag.min(n - 1))
        .map(|k| {
            let ck: f64 = (0..n - k)
                .map(|i| (xs[i] - mean) * (xs[i + k] - mean))
                .sum::<f64>()
                / n as f64;
            ck / c0
        })
        .collect()
}

/// Effective sample size via Geyer's initial positive sequence: truncate
/// the ACF at the first lag where the sum of an adjacent pair of
/// autocorrelations goes non-positive.
pub fn ess(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    let acf = autocorrelation(xs, n - 1);
    let mut sum_rho = 0.0;
    let mut k = 1;
    while k + 1 < acf.len() {
        let pair = acf[k] + acf[k + 1];
        if pair <= 0.0 {
            break;
        }
        sum_rho += pair;
        k += 2;
    }
    let tau = 1.0 + 2.0 * sum_rho;
    (n as f64 / tau).min(n as f64).max(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Pcg64;

    #[test]
    fn running_moments_match_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5];
        let mut rm = RunningMoments::new();
        for &x in &xs {
            rm.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((rm.mean() - mean).abs() < 1e-12);
        assert!((rm.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn acf_lag0_is_one_and_iid_decays() {
        let mut rng = Pcg64::seeded(42);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let acf = autocorrelation(&xs, 10);
        assert!((acf[0] - 1.0).abs() < 1e-12);
        for &a in &acf[1..] {
            assert!(a.abs() < 0.06, "iid acf too large: {a}");
        }
    }

    #[test]
    fn ess_iid_near_n() {
        let mut rng = Pcg64::seeded(43);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let e = ess(&xs);
        assert!(e > 2500.0, "iid ESS too small: {e}");
    }

    #[test]
    fn ess_ar1_much_smaller() {
        // AR(1) with rho=0.95: tau ~ (1+rho)/(1-rho) = 39
        let mut rng = Pcg64::seeded(44);
        let n = 20_000;
        let rho = 0.95;
        let mut xs = Vec::with_capacity(n);
        let mut x = 0.0;
        for _ in 0..n {
            x = rho * x + (1.0 - rho * rho as f64).sqrt() * rng.normal();
            xs.push(x);
        }
        let e = ess(&xs);
        let expected = n as f64 / ((1.0 + rho) / (1.0 - rho));
        assert!(
            e > 0.4 * expected && e < 2.5 * expected,
            "ESS {e} vs expected ~{expected}"
        );
    }
}
