//! Chain diagnostics: running moments, autocorrelation, effective sample
//! size, and the multi-chain convergence estimators behind the streaming
//! monitor (`coordinator::monitor`).  Fig. 9d of the paper reports
//! autocorrelation vs *wall-clock lag* and ESS per second; `ess` here is
//! ESS per sample, and the harness divides by measured runtime.
//!
//! The multi-chain estimators follow Gelman et al. (BDA3) / Vehtari et
//! al. (2021): [`split_rhat`] splits every chain in half so within-chain
//! non-stationarity shows up as between-"chain" variance, and
//! [`rank_normalized_rhat`] applies the same statistic to
//! rank-normalized draws so heavy tails cannot mask divergence.  Both
//! reduce over chains in *index order* — the streaming monitor feeds
//! them per-chain accumulators keyed by chain index, so results never
//! depend on worker arrival order.

use crate::math::inv_normal_cdf;

/// Numerically stable running mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct RunningMoments {
    n: usize,
    mean: f64,
    m2: f64,
}

impl RunningMoments {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Unbiased sample variance (n-1 denominator); 0 for n < 2.
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Sample autocorrelation function up to `max_lag` (inclusive), biased
/// (n-denominator) estimator as standard for ACF plots.
pub fn autocorrelation(xs: &[f64], max_lag: usize) -> Vec<f64> {
    let n = xs.len();
    if n < 2 {
        return vec![1.0];
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let c0: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    if c0 == 0.0 {
        return vec![1.0; max_lag.min(n - 1) + 1];
    }
    (0..=max_lag.min(n - 1))
        .map(|k| {
            let ck: f64 = (0..n - k)
                .map(|i| (xs[i] - mean) * (xs[i + k] - mean))
                .sum::<f64>()
                / n as f64;
            ck / c0
        })
        .collect()
}

/// Effective sample size via Geyer's initial positive sequence: truncate
/// the ACF at the first lag where the sum of an adjacent pair of
/// autocorrelations goes non-positive.  NaN draws yield NaN (the final
/// clamp would otherwise launder a NaN tau into the healthiest possible
/// ESS = n).
pub fn ess(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    if xs.iter().any(|x| x.is_nan()) {
        return f64::NAN;
    }
    let acf = autocorrelation(xs, n - 1);
    let mut sum_rho = 0.0;
    let mut k = 1;
    while k + 1 < acf.len() {
        let pair = acf[k] + acf[k + 1];
        if pair <= 0.0 {
            break;
        }
        sum_rho += pair;
        k += 2;
    }
    let tau = 1.0 + 2.0 * sum_rho;
    (n as f64 / tau).min(n as f64).max(1.0)
}

/// Geyer ESS with *lazily* computed autocovariances: identical
/// estimator (and bitwise-identical result) to [`ess`], but autocovariance
/// lags are computed one pair at a time and stop at the Geyer truncation
/// point instead of materializing the full O(n^2) ACF.  The streaming
/// monitor calls this per snapshot, where chains are long and the
/// truncation lag is short.
pub fn ess_lazy(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    // NaN poisons (mirrors `ess`): without this, every Geyer pair is
    // NaN so the loop never truncates (an O(n^2) walk) and the final
    // clamp turns the NaN tau into ESS = n — "fully converged"
    if xs.iter().any(|x| x.is_nan()) {
        return f64::NAN;
    }
    // same biased (n-denominator) estimator as `autocorrelation`, in the
    // same accumulation order, so the two paths agree bit-for-bit
    let mean = xs.iter().sum::<f64>() / n as f64;
    let c0: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    if c0 == 0.0 {
        // constant series: `autocorrelation` pins the ACF at 1, so the
        // Geyer sum never terminates usefully; match `ess` by walking
        // the same all-ones sequence
        let max_pairs = (n - 1).saturating_sub(1).div_ceil(2);
        let tau = 1.0 + 2.0 * (2 * max_pairs) as f64;
        return (n as f64 / tau).min(n as f64).max(1.0);
    }
    let rho = |k: usize| -> f64 {
        let ck: f64 = (0..n - k)
            .map(|i| (xs[i] - mean) * (xs[i + k] - mean))
            .sum::<f64>()
            / n as f64;
        ck / c0
    };
    let mut sum_rho = 0.0;
    let mut k = 1;
    // acf indices run 0..=n-1, so pairs exist while k + 1 <= n - 1
    while k + 1 < n {
        let pair = rho(k) + rho(k + 1);
        if pair <= 0.0 {
            break;
        }
        sum_rho += pair;
        k += 2;
    }
    let tau = 1.0 + 2.0 * sum_rho;
    (n as f64 / tau).min(n as f64).max(1.0)
}

/// Streaming ESS accumulator: push draws one at a time, read the current
/// Geyer estimate on demand.  The estimate is recomputed lazily (only
/// when draws arrived since the last read) via [`ess_lazy`], so reads at
/// monitor cadence cost O(n * tau) rather than O(n^2), and agree with
/// the batch [`ess`] of the same draws bit-for-bit.
///
/// The multi-chain monitor deliberately does *not* use this type: its
/// snapshots are computed over fixed per-chain prefixes (first `k *
/// every` draws) so contents stay deterministic under scheduling, while
/// this accumulator always reflects everything pushed so far.  It is
/// the right tool for single-stream consumers (harnesses tracking one
/// chain's ESS as it grows).
#[derive(Clone, Debug, Default)]
pub struct StreamingEss {
    xs: Vec<f64>,
    cached_at: usize,
    cached: f64,
}

impl StreamingEss {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    /// Current effective sample size of everything pushed so far.
    pub fn value(&mut self) -> f64 {
        if self.cached_at != self.xs.len() {
            self.cached = ess_lazy(&self.xs);
            self.cached_at = self.xs.len();
        }
        self.cached
    }
}

/// Split-R̂ (potential scale reduction) over `chains`, each truncated to
/// the shortest chain's length: every chain is split into two halves, so
/// the statistic also flags within-chain drift.  Returns NaN when no
/// chain has >= 4 draws.  A constant, identical set of chains returns
/// exactly 1.0; constant chains at *different* values return +inf.
///
/// Chains are reduced in slice order — callers that fold concurrent
/// chains must order them by chain index first (the streaming monitor
/// does) so the result is independent of worker scheduling.
pub fn split_rhat(chains: &[&[f64]]) -> f64 {
    let n = match chains.iter().map(|c| c.len()).min() {
        Some(n) if n >= 4 => n,
        _ => return f64::NAN,
    };
    let half = n / 2;
    // 2M half-chains of equal length (drop the middle draw when n is odd)
    let mut moments = Vec::with_capacity(2 * chains.len());
    for c in chains {
        for part in [&c[..half], &c[n - half..n]] {
            let mut rm = RunningMoments::new();
            for &x in part {
                rm.push(x);
            }
            moments.push(rm);
        }
    }
    let m = moments.len() as f64;
    let l = half as f64;
    let w = moments.iter().map(|rm| rm.variance()).sum::<f64>() / m;
    let mut between = RunningMoments::new();
    for rm in &moments {
        between.push(rm.mean());
    }
    let b = l * between.variance();
    if w <= 0.0 {
        return if b <= 0.0 { 1.0 } else { f64::INFINITY };
    }
    let var_plus = (l - 1.0) / l * w + b / l;
    (var_plus / w).sqrt()
}

/// Rank-normalized split-R̂ (Vehtari et al. 2021): pooled draws are
/// replaced by normal scores of their fractional ranks
/// (z = Phi^-1((r - 3/8) / (S + 1/4)), average ranks on ties) before the
/// split statistic, so heavy-tailed or skewed posteriors cannot hide a
/// location disagreement between chains.  NaN when no chain has >= 4
/// draws.
pub fn rank_normalized_rhat(chains: &[&[f64]]) -> f64 {
    let n = match chains.iter().map(|c| c.len()).min() {
        Some(n) if n >= 4 => n,
        _ => return f64::NAN,
    };
    // NaN draws must poison the result like they poison `split_rhat` —
    // ranking would launder them into ordinary scores (total_cmp groups
    // NaNs, giving a missing parameter a clean-looking rank-Rhat)
    if chains.iter().any(|c| c[..n].iter().any(|x| x.is_nan())) {
        return f64::NAN;
    }
    // pool the first n draws of every chain, remembering provenance
    let total = n * chains.len();
    let mut order: Vec<usize> = (0..total).collect();
    let at = |flat: usize| chains[flat / n][flat % n];
    order.sort_by(|&a, &b| at(a).total_cmp(&at(b)));
    let mut z = vec![0.0f64; total];
    let s = total as f64;
    let mut i = 0;
    while i < total {
        // average ranks over ties (total_cmp groups identical bit
        // patterns together; equal f64 values compare equal)
        let mut j = i + 1;
        while j < total && at(order[j]) == at(order[i]) {
            j += 1;
        }
        // 1-based ranks i+1 ..= j averaged
        let rank = (i + j + 1) as f64 / 2.0;
        let score = inv_normal_cdf((rank - 0.375) / (s + 0.25));
        for &flat in &order[i..j] {
            z[flat] = score;
        }
        i = j;
    }
    let normalized: Vec<&[f64]> = (0..chains.len()).map(|c| &z[c * n..(c + 1) * n]).collect();
    split_rhat(&normalized)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Pcg64;

    #[test]
    fn running_moments_match_direct() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5];
        let mut rm = RunningMoments::new();
        for &x in &xs {
            rm.push(x);
        }
        let mean = xs.iter().sum::<f64>() / 5.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((rm.mean() - mean).abs() < 1e-12);
        assert!((rm.variance() - var).abs() < 1e-12);
    }

    #[test]
    fn acf_lag0_is_one_and_iid_decays() {
        let mut rng = Pcg64::seeded(42);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let acf = autocorrelation(&xs, 10);
        assert!((acf[0] - 1.0).abs() < 1e-12);
        for &a in &acf[1..] {
            assert!(a.abs() < 0.06, "iid acf too large: {a}");
        }
    }

    #[test]
    fn ess_iid_near_n() {
        let mut rng = Pcg64::seeded(43);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let e = ess(&xs);
        assert!(e > 2500.0, "iid ESS too small: {e}");
    }

    #[test]
    fn ess_lazy_matches_batch_bitwise() {
        let mut rng = Pcg64::seeded(45);
        // iid, AR(1), short, and constant series must all agree exactly
        let iid: Vec<f64> = (0..3000).map(|_| rng.normal()).collect();
        let mut ar1 = Vec::with_capacity(3000);
        let mut x = 0.0;
        for _ in 0..3000 {
            x = 0.9 * x + rng.normal();
            ar1.push(x);
        }
        let short: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        let tiny = vec![1.0, 2.0, 3.0];
        let constant = vec![2.5; 100];
        let poisoned = vec![1.0, f64::NAN, 2.0, 3.0, 4.0];
        for (label, xs) in [
            ("iid", &iid),
            ("ar1", &ar1),
            ("short", &short),
            ("tiny", &tiny),
            ("constant", &constant),
            ("poisoned", &poisoned),
        ] {
            assert_eq!(
                ess(xs).to_bits(),
                ess_lazy(xs).to_bits(),
                "{label}: lazy ESS diverged from batch"
            );
        }
        // NaN draws must read as NaN, never as a healthy ESS = n
        assert!(ess(&poisoned).is_nan());
        assert!(ess_lazy(&poisoned).is_nan());
    }

    #[test]
    fn streaming_ess_agrees_with_batch() {
        let mut rng = Pcg64::seeded(46);
        let mut se = StreamingEss::new();
        let mut xs = Vec::new();
        let mut x = 0.0;
        for i in 0..2000 {
            x = 0.8 * x + rng.normal();
            se.push(x);
            xs.push(x);
            // read at several intermediate sizes: every read must equal
            // the batch estimator over the same prefix, bit-for-bit
            if [10usize, 100, 999, 2000].contains(&(i + 1)) {
                assert_eq!(se.value().to_bits(), ess(&xs).to_bits(), "n={}", i + 1);
                // a second read with no new draws hits the cache
                assert_eq!(se.value().to_bits(), ess(&xs).to_bits());
            }
        }
        assert_eq!(se.n(), 2000);
    }

    #[test]
    fn split_rhat_identical_chains_near_one() {
        // independent chains from the same stationary distribution
        let chains: Vec<Vec<f64>> = (0..4)
            .map(|c| {
                let mut rng = Pcg64::new(50, c);
                (0..800).map(|_| rng.normal()).collect()
            })
            .collect();
        let refs: Vec<&[f64]> = chains.iter().map(|c| c.as_slice()).collect();
        let r = split_rhat(&refs);
        assert!((0.98..1.02).contains(&r), "iid split-Rhat {r}");
        let rr = rank_normalized_rhat(&refs);
        assert!((0.98..1.02).contains(&rr), "iid rank-Rhat {rr}");
    }

    #[test]
    fn split_rhat_flags_mean_shift() {
        let mut chains: Vec<Vec<f64>> = (0..4)
            .map(|c| {
                let mut rng = Pcg64::new(51, c);
                (0..800).map(|_| rng.normal()).collect()
            })
            .collect();
        for x in chains[0].iter_mut() {
            *x += 4.0; // one chain stuck in a different mode
        }
        let refs: Vec<&[f64]> = chains.iter().map(|c| c.as_slice()).collect();
        let r = split_rhat(&refs);
        assert!(r > 1.5, "shifted split-Rhat only {r}");
        // the rank transform compresses a one-sided shift (the stuck
        // chain just owns the top quarter of ranks), so its expected
        // value here is ~1.5; it still must clearly exceed the null
        let rr = rank_normalized_rhat(&refs);
        assert!(rr > 1.25, "shifted rank-Rhat only {rr}");
    }

    #[test]
    fn rank_rhat_sees_through_heavy_tails() {
        // a shifted chain with infinite-variance (t_2) tails: the
        // occasional enormous outlier inflates the plain statistic's
        // within-chain variance, but the rank transform is immune to
        // tail magnitude — the location disagreement must still read as
        // a large rank-Rhat
        let mut rng = Pcg64::seeded(52);
        let a: Vec<f64> = (0..600).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..600).map(|_| 8.0 + rng.student_t(2.0)).collect();
        let rr = rank_normalized_rhat(&[&a, &b]);
        assert!(rr > 1.5, "rank-Rhat missed a gross shift: {rr}");
        // sanity: same-distribution heavy tails stay near 1
        let c: Vec<f64> = (0..600).map(|_| rng.student_t(2.0)).collect();
        let d: Vec<f64> = (0..600).map(|_| rng.student_t(2.0)).collect();
        let rr = rank_normalized_rhat(&[&c, &d]);
        assert!((0.97..1.05).contains(&rr), "heavy-tail null rank-Rhat {rr}");
    }

    #[test]
    fn split_rhat_edge_cases() {
        // fewer than 4 draws per chain: undefined
        assert!(split_rhat(&[&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0]]).is_nan());
        assert!(rank_normalized_rhat(&[&[1.0], &[2.0]]).is_nan());
        assert!(split_rhat(&[]).is_nan());
        // identical constant chains: exactly 1
        let c = vec![3.25; 64];
        assert_eq!(split_rhat(&[&c, &c, &c]), 1.0);
        // constant chains at different values: infinitely bad
        let d = vec![4.25; 64];
        assert_eq!(split_rhat(&[&c, &d]), f64::INFINITY);
        // within-chain drift is caught by the split halves even when the
        // chains agree with each other
        let drift: Vec<f64> = (0..1000).map(|i| i as f64 * 0.01).collect();
        let r = split_rhat(&[&drift, &drift]);
        assert!(r > 1.5, "split halves missed within-chain drift: {r}");
        // single chain is legal (two halves)
        let mut rng = Pcg64::seeded(53);
        let one: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let r = split_rhat(&[&one]);
        assert!((0.98..1.05).contains(&r), "single-chain split-Rhat {r}");
        // NaN draws (an unresolvable watched parameter) poison both
        // statistics instead of laundering into a clean rank-Rhat
        let bad = vec![f64::NAN; 64];
        assert!(split_rhat(&[&bad, &bad]).is_nan());
        assert!(rank_normalized_rhat(&[&bad, &bad]).is_nan());
        let mut partly = one.clone();
        partly[7] = f64::NAN;
        assert!(rank_normalized_rhat(&[&partly, &one]).is_nan());
    }

    #[test]
    fn ess_ar1_much_smaller() {
        // AR(1) with rho=0.95: tau ~ (1+rho)/(1-rho) = 39
        let mut rng = Pcg64::seeded(44);
        let n = 20_000;
        let rho = 0.95;
        let mut xs = Vec::with_capacity(n);
        let mut x = 0.0;
        for _ in 0..n {
            x = rho * x + (1.0 - rho * rho as f64).sqrt() * rng.normal();
            xs.push(x);
        }
        let e = ess(&xs);
        let expected = n as f64 / ((1.0 + rho) / (1.0 - rho));
        assert!(
            e > 0.4 * expected && e < 2.5 * expected,
            "ESS {e} vs expected ~{expected}"
        );
    }
}
