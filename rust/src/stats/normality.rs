//! Normality safeguard (§3.3): the sequential test assumes the CLT holds
//! for mini-batch means of the l_i; heavy-tailed l_i (the Bardenet
//! counter-example) break it.  We ship a Jarque–Bera test the harness can
//! run on trial-run mini-batch means and report alongside the chain.

use crate::math::special::ln_gamma;

/// Jarque–Bera statistic and approximate p-value (chi^2_2 tail).
#[derive(Clone, Copy, Debug)]
pub struct NormalityReport {
    pub n: usize,
    pub skewness: f64,
    pub excess_kurtosis: f64,
    pub jb_stat: f64,
    pub p_value: f64,
    /// true if normality is NOT rejected at the 1% level.
    pub plausibly_normal: bool,
}

/// Jarque–Bera normality test over a sample.
pub fn jarque_bera(xs: &[f64]) -> NormalityReport {
    let n = xs.len();
    assert!(n >= 8, "jarque_bera needs >= 8 samples");
    let nf = n as f64;
    let mean = xs.iter().sum::<f64>() / nf;
    let m2 = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / nf;
    let m3 = xs.iter().map(|x| (x - mean).powi(3)).sum::<f64>() / nf;
    let m4 = xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / nf;
    let (skew, kurt) = if m2 > 0.0 {
        (m3 / m2.powf(1.5), m4 / (m2 * m2) - 3.0)
    } else {
        (0.0, 0.0)
    };
    let jb = nf / 6.0 * (skew * skew + 0.25 * kurt * kurt);
    let p = chi2_sf(jb, 2.0);
    NormalityReport {
        n,
        skewness: skew,
        excess_kurtosis: kurt,
        jb_stat: jb,
        p_value: p,
        plausibly_normal: p > 0.01,
    }
}

/// Chi-squared survival function via the regularized upper incomplete
/// gamma; for k=2 it reduces to exp(-x/2) (used by JB).
fn chi2_sf(x: f64, k: f64) -> f64 {
    if x <= 0.0 {
        return 1.0;
    }
    if (k - 2.0).abs() < 1e-12 {
        return (-0.5 * x).exp();
    }
    1.0 - lower_reg_gamma(0.5 * k, 0.5 * x)
}

/// Regularized lower incomplete gamma P(a, x) (series + continued fraction).
fn lower_reg_gamma(a: f64, x: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        // series
        let mut sum = 1.0 / a;
        let mut term = sum;
        for n in 1..500 {
            term *= x / (a + n as f64);
            sum += term;
            if term.abs() < sum.abs() * 1e-15 {
                break;
            }
        }
        (a * x.ln() - x - ln_gamma(a)).exp() * sum
    } else {
        // continued fraction for Q(a,x)
        let mut b = x + 1.0 - a;
        let mut c = 1e300;
        let mut d = 1.0 / b;
        let mut h = d;
        for i in 1..500 {
            let an = -(i as f64) * (i as f64 - a);
            b += 2.0;
            d = an * d + b;
            if d.abs() < 1e-300 {
                d = 1e-300;
            }
            c = b + an / c;
            if c.abs() < 1e-300 {
                c = 1e-300;
            }
            d = 1.0 / d;
            let del = d * c;
            h *= del;
            if (del - 1.0).abs() < 1e-15 {
                break;
            }
        }
        1.0 - (a * x.ln() - x - ln_gamma(a)).exp() * h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Pcg64;

    #[test]
    fn gaussian_sample_passes() {
        let mut rng = Pcg64::seeded(7);
        let xs: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let rep = jarque_bera(&xs);
        assert!(rep.plausibly_normal, "{rep:?}");
        assert!(rep.skewness.abs() < 0.1);
    }

    #[test]
    fn heavy_tailed_sample_fails() {
        // Cauchy-ish: ratio of normals
        let mut rng = Pcg64::seeded(8);
        let xs: Vec<f64> = (0..5000)
            .map(|_| rng.normal() / rng.normal().abs().max(1e-3))
            .collect();
        let rep = jarque_bera(&xs);
        assert!(!rep.plausibly_normal, "{rep:?}");
    }

    #[test]
    fn skewed_sample_fails() {
        let mut rng = Pcg64::seeded(9);
        let xs: Vec<f64> = (0..5000).map(|_| rng.gamma(0.5)).collect();
        let rep = jarque_bera(&xs);
        assert!(!rep.plausibly_normal, "{rep:?}");
        assert!(rep.skewness > 1.0);
    }

    #[test]
    fn chi2_sf_known() {
        // chi2_2 sf(x) = exp(-x/2)
        assert!((chi2_sf(4.0, 2.0) - (-2.0f64).exp()).abs() < 1e-12);
        // chi2_1: sf(3.841) ~ 0.05
        assert!((chi2_sf(3.841, 1.0) - 0.05).abs() < 1e-3);
    }
}
