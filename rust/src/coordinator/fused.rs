//! Fused local-section evaluation: the XLA-batched `LocalEvaluator`.
//!
//! When every local section of a partition matches a recognized shape,
//! a mini-batch of sections reduces to one call into an AOT-compiled
//! JAX/Pallas kernel (Layer 1/2) through PJRT:
//!
//! * **Logistic** — `{linear_logistic (det), bernoulli (absorb)}`, the
//!   BayesLR / JointDPM weight sections → `logistic_ratio_m*_d*`.
//! * **AR(1)** — `{(* phi h_prev) (det), h_t (absorb normal)}` or a bare
//!   absorbing normal (sigma sections), the SV sections →
//!   `gauss_ar1_ratio_m*`.
//!
//! Shape recognition is structural and per-root; any mismatch falls back
//! to the planned arena scorer (`PlannedEval`, itself bitwise-equivalent
//! to the interpreter oracle) for that batch, so the fused path is
//! always semantics-preserving.

use crate::infer::planned::PlannedEval;
use crate::infer::subsampled_mh::{freshen_section, LocalEvaluator};
use crate::ppl::prim::Prim;
use crate::ppl::sp::SpFamily;
use crate::ppl::value::Value;
use crate::runtime::artifacts::ArtifactRegistry;
use crate::runtime::client::Input;
use crate::trace::batch::{ColAbsorb, ColOp, ColS, ColV};
use crate::trace::node::{ArgRef, NodeId, NodeKind};
use crate::trace::partition::{OverrideCtx, Partition};
use crate::trace::pet::Trace;

/// The XLA-fused evaluator; falls back to the interpreter when a batch
/// does not match a known section family.
pub struct FusedEval {
    pub registry: ArtifactRegistry,
    fallback: PlannedEval,
    /// Batches smaller than this go to the planned arena scorer: on the
    /// CPU PJRT client the per-call dispatch overhead (~150us) exceeds
    /// the arithmetic of a small mini-batch.  Note the fallback is now
    /// PlannedEval (several times faster per section than the old
    /// interpreter walk), so the XLA break-even batch is larger than the
    /// interpreter-era ablations suggest — re-measure with
    /// benches/ablations.rs before tuning.  Set to 0 to force XLA for
    /// every batch.
    pub min_fused_batch: usize,
    /// count of sections evaluated through XLA vs interpreter (perf
    /// reporting / ablations)
    pub fused_sections: usize,
    pub fallback_sections: usize,
}

/// Columnar inputs for the logistic kernel: `x` row-major `[n, d]`,
/// targets ±1 — exactly the buffers the XLA executable consumes, so
/// dispatch is a pad-and-copy, not a row loop.  The plan-aware
/// extractor fills these straight from `BatchGroup` slot tables
/// (`narrow_vbind_into`); the structural-walk fallback fills the same
/// layout row by row.
struct LogisticCols {
    x: Vec<f32>,
    t: Vec<f32>,
    d: usize,
}

/// Columnar inputs for the AR(1) kernel (SoA).  `phi_*` are (1, 1)
/// columns when the mean is folded into `h_prev` (sigma sections).
struct Ar1Cols {
    h_prev: Vec<f32>,
    h: Vec<f32>,
    phi_old: Vec<f32>,
    phi_new: Vec<f32>,
    sig_old: Vec<f32>,
    sig_new: Vec<f32>,
}

impl Ar1Cols {
    fn with_capacity(n: usize) -> Ar1Cols {
        Ar1Cols {
            h_prev: Vec::with_capacity(n),
            h: Vec::with_capacity(n),
            phi_old: Vec::with_capacity(n),
            phi_new: Vec::with_capacity(n),
            sig_old: Vec::with_capacity(n),
            sig_new: Vec::with_capacity(n),
        }
    }

    fn len(&self) -> usize {
        self.h.len()
    }
}

impl FusedEval {
    pub fn new(registry: ArtifactRegistry) -> Self {
        FusedEval {
            registry,
            fallback: PlannedEval::new(),
            min_fused_batch: 256,
            fused_sections: 0,
            fallback_sections: 0,
        }
    }

    /// Force every batch through XLA regardless of size (ablations).
    pub fn always_fused(mut self) -> Self {
        self.min_fused_batch = 0;
        self
    }

    pub fn open_default() -> Result<Self, String> {
        Ok(Self::new(ArtifactRegistry::open_default()?))
    }

    /// Plan-aware logistic extraction: when the batch's roots all live
    /// in one shape-keyed group whose column program is the logistic
    /// section (`sigmoid(dot(w, x))` + one bernoulli absorber), the
    /// kernel inputs are read straight out of the group's slot tables —
    /// no per-root node-structure walk.  `None` falls back to the
    /// structural walk below.
    fn extract_logistic_planned(
        trace: &Trace,
        p: &Partition,
        roots: &[NodeId],
    ) -> Option<LogisticCols> {
        let set = trace.cached_batch_plans(p);
        let &(gi, _) = set.of_root.get(roots.first()?)?;
        let g = &set.groups[gi as usize];
        let cols = &g.cols;
        // sigmoid(dot(w, x_j)): either directly on the global weight
        // vector (BayesLR) or through a vector copy of it (the JointDPM
        // MemApp routing)
        let xbind = match cols.ops.as_slice() {
            [ColOp::Dot { sigmoid: true, out, a: ColV::Global(0), b: ColV::Bind(b) }] => {
                (*out, *b)
            }
            [ColOp::CopyV { out: c, from: ColV::Global(0) }, ColOp::Dot { sigmoid: true, out, a: ColV::Slot(s), b: ColV::Bind(b) }]
                if s == c =>
            {
                (*out, *b)
            }
            _ => return None,
        };
        let (dot_out, xbind) = xbind;
        match cols.absorbers.as_slice() {
            [ColAbsorb { fam: SpFamily::Bernoulli, cand }]
                if matches!(cand.as_slice(), [ColS::Slot(s)] if *s == dot_out) => {}
            _ => return None,
        }
        let mut members = Vec::with_capacity(roots.len());
        for &root in roots {
            let &(gj, mi) = set.of_root.get(&root)?;
            if gj != gi {
                return None; // mixed shapes: one kernel cannot cover the batch
            }
            members.push(mi);
        }
        // columnar narrowing straight off the slot table
        let mut x = Vec::new();
        let d = g.narrow_vbind_into(trace, xbind, &members, &mut x)?;
        let mut t = Vec::with_capacity(members.len());
        for &m in &members {
            match trace.node(g.absorber_of(m as usize, 0)).value.as_bool() {
                Some(true) => t.push(1.0),
                Some(false) => t.push(-1.0),
                None => return None,
            }
        }
        Some(LogisticCols { x, t, d })
    }

    /// Structural-walk fallback: extract the same columnar buffers row
    /// by row from node structure; None on mismatch.
    fn extract_logistic(
        trace: &Trace,
        p: &Partition,
        roots: &[NodeId],
    ) -> Option<LogisticCols> {
        let mut x_col = Vec::new();
        let mut t_col = Vec::with_capacity(roots.len());
        let mut d = 0usize;
        for &root in roots {
            // root must be the linear_logistic det node...
            let node = trace.node(root);
            let lin = match &node.kind {
                NodeKind::Det(crate::ppl::prim::Prim::LinearLogistic) => root,
                // ...or a MemApp routing to the weights (JointDPM), whose
                // single det child is the linear_logistic
                NodeKind::MemApp { .. } => {
                    let kids = &node.children;
                    if kids.len() != 1 {
                        return None;
                    }
                    let k = kids[0];
                    match &trace.node(k).kind {
                        NodeKind::Det(crate::ppl::prim::Prim::LinearLogistic) => k,
                        _ => return None,
                    }
                }
                _ => return None,
            };
            let lin_node = trace.node(lin);
            // linear_logistic(w, x): x must be a constant vector
            let x = match &lin_node.args[1] {
                ArgRef::Const(Value::Vector(v)) => v.clone(),
                _ => return None,
            };
            if d == 0 {
                d = x.len();
            } else if d != x.len() {
                return None;
            }
            // single bernoulli child
            if lin_node.children.len() != 1 {
                return None;
            }
            let y = lin_node.children[0];
            let y_node = trace.node(y);
            if !matches!(y_node.kind, NodeKind::StochFam(SpFamily::Bernoulli)) {
                return None;
            }
            let t = match y_node.value.as_bool() {
                Some(true) => 1.0,
                Some(false) => -1.0,
                None => return None,
            };
            x_col.extend(x.iter().map(|&v| v as f32));
            t_col.push(t);
        }
        let _ = p;
        Some(LogisticCols { x: x_col, t: t_col, d })
    }

    /// Plan-aware AR(1) extraction (phi and sigma section shapes) from
    /// a group's slot tables, computing the candidate globals once per
    /// batch (the structural walk below re-runs an `OverrideCtx` per
    /// root).  `None` falls back to the structural walk.
    fn extract_ar1_planned(
        trace: &Trace,
        p: &Partition,
        roots: &[NodeId],
        new_v: &Value,
    ) -> Option<Ar1Cols> {
        let set = trace.cached_batch_plans(p);
        let &(gi, _) = set.of_root.get(roots.first()?)?;
        let g = &set.groups[gi as usize];
        let cols = &g.cols;
        #[derive(Clone, Copy)]
        enum SigSrc {
            Global(u32),
            Bind(u32),
        }
        let (phi_global, mean_bind, sig_src) =
            match (cols.ops.as_slice(), cols.absorbers.as_slice()) {
                // phi sections: (* phi h_prev) det + one absorbing normal
                (
                    [ColOp::Map { prim: Prim::Mul, out, args }],
                    [ColAbsorb { fam: SpFamily::Normal, cand }],
                ) => {
                    let (kphi, hb) = match args.as_slice() {
                        [ColS::Global(k), ColS::Bind(b)] => (*k, *b),
                        [ColS::Bind(b), ColS::Global(k)] => (*k, *b),
                        _ => return None,
                    };
                    let sig = match cand.as_slice() {
                        [ColS::Slot(s), ColS::Global(ks)] if s == out => SigSrc::Global(*ks),
                        [ColS::Slot(s), ColS::Bind(bs)] if s == out => SigSrc::Bind(*bs),
                        _ => return None,
                    };
                    (Some(kphi), hb, sig)
                }
                // sigma sections: the border child IS the absorbing
                // normal; the mean is folded into h_prev
                ([], [ColAbsorb { fam: SpFamily::Normal, cand }]) => match cand.as_slice() {
                    [ColS::Bind(bm), ColS::Global(ks)] => (None, *bm, SigSrc::Global(*ks)),
                    _ => return None,
                },
                _ => return None,
            };
        // candidate globals once per batch — the same code path the
        // interpreter oracle runs, so f32 narrowing is the only loss
        let mut globals = Vec::new();
        crate::trace::plan::candidate_globals(trace, p, new_v, &mut globals).ok()?;
        let (phi_old, phi_new) = match phi_global {
            Some(k) => (
                trace.value(p.global_drg[k as usize]).as_f64()? as f32,
                globals.get(k as usize)?.as_f64()? as f32,
            ),
            None => (1.0, 1.0),
        };
        let mut members = Vec::with_capacity(roots.len());
        for &root in roots {
            let &(gj, mi) = set.of_root.get(&root)?;
            if gj != gi {
                return None;
            }
            members.push(mi);
        }
        let n = members.len();
        let mut out = Ar1Cols::with_capacity(n);
        // h_prev column straight off the slot table
        g.narrow_sbind_into(trace, mean_bind, &members, &mut out.h_prev)?;
        // h + committed sig columns from the absorber nodes
        for &m in &members {
            let node = trace.node(g.absorber_of(m as usize, 0));
            out.h.push(node.value.as_f64()? as f32);
            out.sig_old.push(trace.arg_value(&node.args[1]).as_f64()? as f32);
        }
        // candidate sig column: batch-shared global or per-section bind
        match sig_src {
            SigSrc::Global(ks) => {
                let s = globals.get(ks as usize)?.as_f64()? as f32;
                out.sig_new.resize(n, s);
            }
            // an off-path sig cannot depend on the principal:
            // candidate == committed
            SigSrc::Bind(bs) => {
                g.narrow_sbind_into(trace, bs, &members, &mut out.sig_new)?;
            }
        }
        out.phi_old.resize(n, phi_old);
        out.phi_new.resize(n, phi_new);
        Some(out)
    }

    /// Structural-walk fallback for the AR(1) columns; None on mismatch.
    fn extract_ar1(
        trace: &mut Trace,
        p: &Partition,
        roots: &[NodeId],
        new_v: &Value,
    ) -> Option<Ar1Cols> {
        let mut out = Ar1Cols::with_capacity(roots.len());
        for &root in roots {
            let node = trace.node(root);
            match &node.kind {
                // sigma-sampling: border child IS the absorbing normal,
                // whose sig argument is in the global section
                NodeKind::StochFam(SpFamily::Normal) => {
                    let h = node.value.as_f64()? as f32;
                    let mean = trace.arg_value(&node.args[0]).as_f64()? as f32;
                    let sig_arg = node.args[1].clone();
                    let sig_old = trace.arg_value(&sig_arg).as_f64()? as f32;
                    let sig_new = {
                        let mut ctx = OverrideCtx::new(trace);
                        ctx.pin(p.v, new_v.clone());
                        ctx.arg_candidate(&sig_arg).as_f64()? as f32
                    };
                    out.h_prev.push(mean);
                    out.h.push(h);
                    out.phi_old.push(1.0);
                    out.phi_new.push(1.0);
                    out.sig_old.push(sig_old);
                    out.sig_new.push(sig_new);
                }
                // phi-sampling: border child is (* phi h_prev) with a
                // single absorbing normal child
                NodeKind::Det(crate::ppl::prim::Prim::Mul) => {
                    if node.args.len() != 2 || node.children.len() != 1 {
                        return None;
                    }
                    // which arg is the sampled phi (== p.v or in global)?
                    let (phi_arg, hp_arg) = match (&node.args[0], &node.args[1]) {
                        (ArgRef::Node(a), other) if p.global_drg.contains(a) => {
                            (ArgRef::Node(*a), other.clone())
                        }
                        (other, ArgRef::Node(b)) if p.global_drg.contains(b) => {
                            (ArgRef::Node(*b), other.clone())
                        }
                        _ => return None,
                    };
                    let h_prev = trace.arg_value(&hp_arg).as_f64()? as f32;
                    let phi_old = trace.arg_value(&phi_arg).as_f64()? as f32;
                    let child = node.children[0];
                    let cnode = trace.node(child);
                    if !matches!(cnode.kind, NodeKind::StochFam(SpFamily::Normal)) {
                        return None;
                    }
                    let h = cnode.value.as_f64()? as f32;
                    let sig_arg = cnode.args[1].clone();
                    let sig_old = trace.arg_value(&sig_arg).as_f64()? as f32;
                    let (phi_new, sig_new) = {
                        let mut ctx = OverrideCtx::new(trace);
                        ctx.pin(p.v, new_v.clone());
                        (
                            ctx.arg_candidate(&phi_arg).as_f64()? as f32,
                            ctx.arg_candidate(&sig_arg).as_f64()? as f32,
                        )
                    };
                    out.h_prev.push(h_prev);
                    out.h.push(h);
                    out.phi_old.push(phi_old);
                    out.phi_new.push(phi_new);
                    out.sig_old.push(sig_old);
                    out.sig_new.push(sig_new);
                }
                _ => return None,
            }
        }
        Some(out)
    }

    fn run_logistic(
        &mut self,
        cols: &LogisticCols,
        w_old: &[f64],
        w_new: &[f64],
    ) -> Result<Vec<f64>, String> {
        let wo: Vec<f32> = w_old.iter().map(|&v| v as f32).collect();
        let wn: Vec<f32> = w_new.iter().map(|&v| v as f32).collect();
        self.run_logistic_cols(&cols.x, &cols.t, cols.d, &wo, &wn)
    }

    fn run_logistic_cols(
        &mut self,
        x: &[f32],
        t: &[f32],
        d: usize,
        wo: &[f32],
        wn: &[f32],
    ) -> Result<Vec<f64>, String> {
        let n = t.len();
        let (info, exe) = self.registry.pick_executable("logistic_ratio", n, d)?;
        if info.m < n {
            // batch exceeds the largest artifact: split on row ranges
            // (the columnar layout makes chunks plain subslices)
            let mut out = Vec::with_capacity(n);
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + info.m).min(n);
                out.extend(self.run_logistic_cols(&x[lo * d..hi * d], &t[lo..hi], d, wo, wn)?);
                lo = hi;
            }
            return Ok(out);
        }
        let m = info.m;
        // pad to the artifact's batch: one copy per input, no row loop
        let mut xp = vec![0f32; m * d];
        xp[..n * d].copy_from_slice(x);
        let mut tp = vec![0f32; m];
        tp[..n].copy_from_slice(t);
        let mut mask = vec![0f32; m];
        mask[..n].fill(1.0);
        let out = exe.run_f32(&[
            Input { data: &xp, shape: &[m, d] },
            Input { data: &tp, shape: &[m] },
            Input { data: &mask, shape: &[m] },
            Input { data: wo, shape: &[d] },
            Input { data: wn, shape: &[d] },
        ])?;
        Ok(out[..n].iter().map(|&v| v as f64).collect())
    }

    fn run_ar1(&mut self, cols: &Ar1Cols) -> Result<Vec<f64>, String> {
        // sections share (phi_old, phi_new, sig_old, sig_new) in the SV
        // model; if they don't (mixed sections), fall back per-row via
        // the scalar formula — still exact, just not batched.
        let uniform = |c: &[f32]| c.windows(2).all(|w| w[0] == w[1]);
        let homogeneous = uniform(&cols.phi_old)
            && uniform(&cols.phi_new)
            && uniform(&cols.sig_old)
            && uniform(&cols.sig_new);
        if !homogeneous {
            return Ok((0..cols.len())
                .map(|i| {
                    let lp = |phi: f32, sig: f32| {
                        crate::dist::normal_logpdf(
                            cols.h[i] as f64,
                            (phi * cols.h_prev[i]) as f64,
                            sig as f64,
                        )
                    };
                    lp(cols.phi_new[i], cols.sig_new[i]) - lp(cols.phi_old[i], cols.sig_old[i])
                })
                .collect());
        }
        let params = [
            cols.phi_old[0],
            cols.sig_old[0],
            cols.phi_new[0],
            cols.sig_new[0],
        ];
        self.run_ar1_cols(&cols.h_prev, &cols.h, &params)
    }

    fn run_ar1_cols(
        &mut self,
        h_prev: &[f32],
        h: &[f32],
        params: &[f32; 4],
    ) -> Result<Vec<f64>, String> {
        let n = h.len();
        let (info, exe) = self.registry.pick_executable("gauss_ar1_ratio", n, 0)?;
        if info.m < n {
            let mut out = Vec::with_capacity(n);
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + info.m).min(n);
                out.extend(self.run_ar1_cols(&h_prev[lo..hi], &h[lo..hi], params)?);
                lo = hi;
            }
            return Ok(out);
        }
        let m = info.m;
        let mut hp = vec![0f32; m];
        hp[..n].copy_from_slice(h_prev);
        let mut hv = vec![0f32; m];
        hv[..n].copy_from_slice(h);
        let mut mask = vec![0f32; m];
        mask[..n].fill(1.0);
        let out = exe.run_f32(&[
            Input { data: &hp, shape: &[m] },
            Input { data: &hv, shape: &[m] },
            Input { data: &mask, shape: &[m] },
            Input { data: params, shape: &[4] },
        ])?;
        Ok(out[..n].iter().map(|&v| v as f64).collect())
    }

    /// Predictive probabilities for a test block (Fig. 4 risk metric).
    pub fn predict(&mut self, x_rows: &[Vec<f64>], w: &[f64]) -> Result<Vec<f64>, String> {
        let d = w.len();
        let n = x_rows.len();
        let (info, exe) = self.registry.pick_executable("logistic_predict", n, d)?;
        let m = info.m;
        let mut out_all = Vec::with_capacity(n);
        for chunk in x_rows.chunks(m) {
            let mut x = vec![0f32; m * d];
            for (i, row) in chunk.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    x[i * d + j] = v as f32;
                }
            }
            let wv: Vec<f32> = w.iter().map(|&v| v as f32).collect();
            let out = exe.run_f32(&[
                Input { data: &x, shape: &[m, d] },
                Input { data: &wv, shape: &[d] },
            ])?;
            out_all.extend(out[..chunk.len()].iter().map(|&v| v as f64));
        }
        Ok(out_all)
    }
}

impl LocalEvaluator for FusedEval {
    fn eval_sections(
        &mut self,
        trace: &mut Trace,
        p: &Partition,
        roots: &[NodeId],
        new_v: &Value,
    ) -> Result<Vec<f64>, String> {
        // small batches: PJRT dispatch overhead dominates; replay plans
        if roots.len() < self.min_fused_batch {
            self.fallback_sections += roots.len();
            return self.fallback.eval_sections(trace, p, roots, new_v);
        }
        // refresh lazily before structural inspection
        for &r in roots {
            freshen_section(trace, r);
        }
        // logistic family? (slot tables first, structural walk second)
        let logistic = match Self::extract_logistic_planned(trace, p, roots) {
            Some(rd) => Some(rd),
            None => Self::extract_logistic(trace, p, roots),
        };
        if let Some(cols) = logistic {
            let w_old = trace
                .fresh_value(p.v)
                .as_vector()
                .ok_or("logistic plan: principal must be a vector")?
                .as_ref()
                .clone();
            let w_new = new_v
                .as_vector()
                .ok_or("logistic plan: candidate must be a vector")?
                .as_ref()
                .clone();
            self.fused_sections += roots.len();
            return self.run_logistic(&cols, &w_old, &w_new);
        }
        // AR(1) family? (slot tables first, structural walk second)
        let ar1 = match Self::extract_ar1_planned(trace, p, roots, new_v) {
            Some(cols) => Some(cols),
            None => Self::extract_ar1(trace, p, roots, new_v),
        };
        if let Some(cols) = ar1 {
            self.fused_sections += roots.len();
            return self.run_ar1(&cols);
        }
        // generic fallback
        self.fallback_sections += roots.len();
        self.fallback.eval_sections(trace, p, roots, new_v)
    }

    fn name(&self) -> &'static str {
        "xla-fused"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::subsampled_mh::{InterpreterEval, LocalEvaluator};
    use crate::math::Pcg64;
    use crate::trace::partition::build_partition;

    fn lr_trace(n: usize, d: usize, seed: u64) -> Trace {
        let dims = (0..d).map(|_| "0".to_string()).collect::<Vec<_>>().join(" ");
        let mut src = format!(
            "[assume w (scope_include 'w 0 (multivariate_normal (vector {dims}) 0.5))]\n\
             [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n"
        );
        let mut rng = Pcg64::new(seed, 9);
        for _ in 0..n {
            let xs: Vec<String> = (0..d).map(|_| format!("{}", rng.normal())).collect();
            let lab = if rng.bernoulli(0.5) { "true" } else { "false" };
            src.push_str(&format!("[observe (f (vector {})) {lab}]\n", xs.join(" ")));
        }
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(&src, &mut rng).unwrap();
        t
    }

    fn have_artifacts() -> bool {
        if ArtifactRegistry::open_default().is_ok() {
            true
        } else {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            false
        }
    }

    #[test]
    fn fused_matches_interpreter_logistic() {
        if !have_artifacts() {
            return;
        }
        let mut t = lr_trace(60, 3, 1);
        let v = t.lookup_node("w").unwrap();
        let p = build_partition(&t, v).unwrap();
        let new_w = Value::vector(vec![0.4, -0.3, 0.2]);
        let roots: Vec<NodeId> = p.locals.clone();
        let mut interp = InterpreterEval;
        let want = interp.eval_sections(&mut t, &p, &roots, &new_w).unwrap();
        let mut fused = FusedEval::open_default().unwrap().always_fused();
        let got = fused.eval_sections(&mut t, &p, &roots, &new_w).unwrap();
        assert_eq!(fused.fused_sections, 60);
        assert_eq!(fused.fallback_sections, 0);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 2e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn fused_matches_interpreter_ar1() {
        if !have_artifacts() {
            return;
        }
        let src = r#"
            [assume sig (sqrt (inv_gamma 5 0.05))]
            [assume phi (scope_include 'phi 0 (beta 5 1))]
            [assume h (mem (lambda (t) (if (<= t 0) 0.0 (normal (* phi (h (- t 1))) sig))))]
            [assume x (lambda (t) (normal 0 (exp (/ (h t) 2))))]
            [observe (x 1) 0.1] [observe (x 2) -0.2]
            [observe (x 3) 0.05] [observe (x 4) 0.3]
            [observe (x 5) -0.15] [observe (x 6) 0.2]
        "#;
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(2);
        t.run_program(src, &mut rng).unwrap();
        let phi = t.lookup_node("phi").unwrap();
        let p = build_partition(&t, phi).unwrap();
        let roots = p.locals.clone();
        let new_phi = Value::Real(0.5);
        let mut interp = InterpreterEval;
        let want = interp.eval_sections(&mut t, &p, &roots, &new_phi).unwrap();
        let mut fused = FusedEval::open_default().unwrap().always_fused();
        let got = fused.eval_sections(&mut t, &p, &roots, &new_phi).unwrap();
        assert_eq!(fused.fused_sections, roots.len());
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 2e-4, "{g} vs {w}");
        }
        // sigma sections too: v = the inv_gamma node
        let sqrt_node = t.lookup_node("sig").unwrap();
        let s2 = t.node(sqrt_node).args[0].node().unwrap();
        let p2 = build_partition(&t, s2).unwrap();
        let roots2 = p2.locals.clone();
        let new_s2 = Value::Real(0.02);
        let want2 = interp.eval_sections(&mut t, &p2, &roots2, &new_s2).unwrap();
        let got2 = fused.eval_sections(&mut t, &p2, &roots2, &new_s2).unwrap();
        for (g, w) in got2.iter().zip(&want2) {
            assert!((g - w).abs() < 2e-4, "{g} vs {w}");
        }
    }

    #[test]
    fn fused_subsampled_transition_runs() {
        if !have_artifacts() {
            return;
        }
        let mut t = lr_trace(500, 3, 3);
        let v = t.lookup_node("w").unwrap();
        let mut rng = Pcg64::seeded(4);
        let cfg = crate::infer::SubsampledConfig {
            m: 100,
            eps: 0.01,
            proposal: crate::infer::Proposal::Drift(0.1),
            exact: false,
            threads: 1,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        let mut fused = FusedEval::open_default().unwrap().always_fused();
        let mut accepted = 0;
        for _ in 0..30 {
            let s = crate::infer::subsampled_mh_transition(&mut t, &mut rng, v, &cfg, &mut fused)
                .unwrap();
            if s.accepted {
                accepted += 1;
            }
        }
        assert!(fused.fused_sections > 0);
        assert!(t.log_joint().is_finite());
        let _ = accepted;
    }

    /// The slot-table fast path must produce exactly the rows the
    /// structural walk produces (runs without XLA artifacts: extraction
    /// is independent of the PJRT runtime).
    #[test]
    fn planned_extraction_matches_structural_walk_logistic() {
        let t = lr_trace(40, 3, 5);
        let v = t.lookup_node("w").unwrap();
        let p = build_partition(&t, v).unwrap();
        let roots = p.locals.clone();
        let walk = FusedEval::extract_logistic(&t, &p, &roots).unwrap();
        let plan =
            FusedEval::extract_logistic_planned(&t, &p, &roots).expect("planned path missed");
        assert_eq!(walk.d, plan.d);
        assert_eq!(walk.t, plan.t);
        assert_eq!(walk.x, plan.x, "columnar x buffers must be identical");
    }

    #[test]
    fn planned_extraction_matches_structural_walk_ar1() {
        let src = r#"
            [assume sig2 (scope_include 'sig2 0 (inv_gamma 5 0.05))]
            [assume sig (sqrt sig2)]
            [assume phi (scope_include 'phi 0 (beta 5 1))]
            [assume h (mem (lambda (t) (if (<= t 0) 0.0 (normal (* phi (h (- t 1))) sig))))]
            [assume x (lambda (t) (normal 0 (exp (/ (h t) 2))))]
            [observe (x 1) 0.1] [observe (x 2) -0.2]
            [observe (x 3) 0.05] [observe (x 4) 0.3]
        "#;
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(6);
        t.run_program(src, &mut rng).unwrap();
        // phi sections: (* phi h_prev) + absorbing normal
        let phi = t.lookup_node("phi").unwrap();
        let p = build_partition(&t, phi).unwrap();
        let roots = p.locals.clone();
        let new_phi = Value::Real(0.45);
        let plan =
            FusedEval::extract_ar1_planned(&t, &p, &roots, &new_phi).expect("planned path missed");
        let walk = FusedEval::extract_ar1(&mut t, &p, &roots, &new_phi).unwrap();
        assert_eq!(plan.len(), walk.len());
        assert_eq!(plan.h_prev, walk.h_prev);
        assert_eq!(plan.h, walk.h);
        assert_eq!(plan.phi_old, walk.phi_old);
        assert_eq!(plan.phi_new, walk.phi_new);
        assert_eq!(plan.sig_old, walk.sig_old);
        assert_eq!(plan.sig_new, walk.sig_new);
        // sigma sections: bare absorbing normal through the sqrt global
        let sig2 = t.lookup_node("sig2").unwrap();
        let p2 = build_partition(&t, sig2).unwrap();
        let roots2 = p2.locals.clone();
        let new_s2 = Value::Real(0.03);
        let plan =
            FusedEval::extract_ar1_planned(&t, &p2, &roots2, &new_s2).expect("planned path missed");
        let walk = FusedEval::extract_ar1(&mut t, &p2, &roots2, &new_s2).unwrap();
        assert_eq!(plan.len(), walk.len());
        assert_eq!(plan.h_prev, walk.h_prev);
        assert_eq!(plan.h, walk.h);
        assert!(plan.phi_old.iter().all(|&x| x == 1.0));
        assert!(plan.phi_new.iter().all(|&x| x == 1.0));
        assert_eq!(plan.sig_old, walk.sig_old);
        assert_eq!(plan.sig_new, walk.sig_new);
    }

    #[test]
    fn predict_matches_scalar_sigmoid() {
        if !have_artifacts() {
            return;
        }
        let mut fused = FusedEval::open_default().unwrap();
        let w = vec![0.5, -1.0];
        let xs: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64 * 0.3, 1.0]).collect();
        let probs = fused.predict(&xs, &w).unwrap();
        for (x, p) in xs.iter().zip(&probs) {
            let z = 0.5 * x[0] - x[1];
            let want = 1.0 / (1.0 + (-z).exp());
            assert!((p - want).abs() < 1e-5, "{p} vs {want}");
        }
    }
}
