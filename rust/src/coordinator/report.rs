//! Reporting: console tables and CSV series for the experiment harness
//! (dependency-free stand-in for a plotting stack — every figure is
//! regenerated as a CSV + aligned console table).

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |cells: &[String], out: &mut String| {
            for i in 0..ncol {
                let _ = write!(out, "{:<w$}  ", cells[i], w = widths[i]);
            }
            out.push('\n');
        };
        line(&self.headers, &mut out);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &mut out);
        for row in &self.rows {
            line(row, &mut out);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// CSV writer for figure series.
pub struct Csv {
    buf: String,
}

impl Csv {
    pub fn new(headers: &[&str]) -> Csv {
        Csv {
            buf: format!("{}\n", headers.join(",")),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        self.buf.push_str(&cells.join(","));
        self.buf.push('\n');
    }

    pub fn row_f(&mut self, cells: &[f64]) {
        let strs: Vec<String> = cells.iter().map(|v| format!("{v}")).collect();
        self.row(&strs);
    }

    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.buf.as_bytes())
    }

    pub fn contents(&self) -> &str {
        &self.buf
    }
}

/// Default results directory (`results/` at the repo root).
pub fn results_dir() -> std::path::PathBuf {
    std::env::var("SUBPPL_RESULTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results"))
}

/// Histogram helper for Fig. 9b/c: counts over equal bins.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<(f64, usize)> {
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            counts[((x - lo) / w) as usize] += 1;
        }
    }
    counts
        .into_iter()
        .enumerate()
        .map(|(i, c)| (lo + (i as f64 + 0.5) * w, c))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["long-name".into(), "2.5".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[2].starts_with("a"));
    }

    #[test]
    fn csv_roundtrip() {
        let mut c = Csv::new(&["x", "y"]);
        c.row_f(&[1.0, 2.5]);
        c.row_f(&[2.0, -3.0]);
        assert_eq!(c.contents(), "x,y\n1,2.5\n2,-3\n");
    }

    #[test]
    fn histogram_bins() {
        let xs = [0.1, 0.2, 0.55, 0.9, 1.5];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h[0].1, 2);
        assert_eq!(h[1].1, 2); // 1.5 out of range
    }
}
