//! Chain checkpoint/resume: periodic atomic snapshots of a chain's
//! mutable state, so a killed run — process crash, OOM, gate kill —
//! resumes from the last checkpoint and reproduces the uninterrupted
//! run bit-for-bit.
//!
//! # What a checkpoint is
//!
//! Given a fixed trace structure, a chain's entire mutable state is
//! (a) the committed value of every unobserved stochastic node and
//! (b) the position of its PCG stream.  Everything else is derived:
//! observed values are pinned by the program, deterministic nodes are
//! functions of the stochastic ones (recomputed lazily after an epoch
//! bump), and plan/store caches rebuild on demand.  So a
//! [`ChainCheckpoint`] records `(seed, chain, draw, rng state,
//! stochastic values by node id)` and nothing else.
//!
//! Resume rebuilds the trace from program source with the chain's
//! *original* stream `chain_rng(seed, chain)` — replaying the program
//! allocates the same node ids regardless of what the prior samples
//! were — then overwrites the stochastic values via
//! [`Trace::restore_stoch_state`] (same SP unincorporate/incorporate
//! discipline as `observe`) and swaps in the checkpointed RNG
//! position.  From draw `k+1` on, the resumed chain performs the
//! exact instruction stream of the uninterrupted one.
//!
//! **Restriction**: structure must be fixed between checkpoint and
//! resume — programs whose transitions re-key mem applications (e.g.
//! the DPM's cluster assignments) change node ids and are rejected at
//! restore with an explicit error.  Exchangeable aux state is
//! restored through the incorporate discipline, which is exact for
//! counting auxes (CRP); floating-point sufficient statistics are
//! restored only up to summation order.  The models the lockstep
//! tests pin (LR, SV) use stateless families, where resume is exact.
//!
//! # File format
//!
//! One text file per chain, `chain<k>.ckpt`, written
//! temp-then-rename so a crash mid-write can never corrupt the
//! previous checkpoint:
//!
//! ```text
//! subppl-checkpoint v1
//! seed 42
//! chain 0
//! draw 300
//! rng <state:32-hex> <inc:32-hex>
//! values <count>
//! <node-id> R <f64-bits:16-hex>
//! <node-id> V <len> <16-hex> <16-hex> ...
//! <node-id> B 0|1
//! <node-id> I <i64>
//! checksum <fnv1a:16-hex>
//! ```
//!
//! Reals are serialized as raw bit patterns (never decimal), so a
//! load is bitwise lossless; the trailing FNV-1a checksum over every
//! preceding byte rejects truncated or hand-edited files.

use crate::math::Pcg64;
use crate::ppl::value::Value;
use crate::trace::pet::Trace;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// FNV-1a over a byte string (same constants as the column store's row
/// hash; duplicated to keep the two modules dependency-free).  Shared
/// with the serve write-ahead journal (`serve/journal.rs`), which
/// frames its records with the same checksum so a torn tail is
/// detected the same way a torn checkpoint is.
pub(crate) fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// One chain's resumable state: see the module docs for what is (and
/// deliberately is not) in here.
#[derive(Clone, Debug)]
pub struct ChainCheckpoint {
    pub seed: u64,
    pub chain: usize,
    /// Draws completed when the snapshot was taken: resume continues
    /// at draw `draw + 1`.
    pub draw: usize,
    /// PCG stream position `(state, inc)` as of the end of draw
    /// `draw`.
    pub rng: (u128, u128),
    /// `(node id, committed value)` for every unobserved stochastic
    /// node, in node-id order ([`Trace::stoch_state`]).
    pub values: Vec<(u32, Value)>,
}

impl ChainCheckpoint {
    /// Snapshot a running chain after it completed `draw` draws.
    pub fn capture(
        seed: u64,
        chain: usize,
        draw: usize,
        trace: &Trace,
        rng: &Pcg64,
    ) -> ChainCheckpoint {
        ChainCheckpoint {
            seed,
            chain,
            draw,
            rng: rng.state_parts(),
            values: trace.stoch_state(),
        }
    }

    /// Restore onto a freshly rebuilt trace (same program, same
    /// `chain_rng(seed, chain)` stream): overwrite the stochastic
    /// values and return the checkpointed RNG, positioned exactly
    /// where the uninterrupted chain's was at the end of draw
    /// [`draw`](Self::draw).
    pub fn restore(&self, trace: &mut Trace) -> Result<Pcg64, String> {
        trace.restore_stoch_state(&self.values)?;
        Ok(Pcg64::from_parts(self.rng.0, self.rng.1))
    }

    /// Serialize to the checkpoint text format.  Errs on value kinds
    /// that have no serialization (closures, SP handles — those are
    /// structural, not chain state, and never appear in
    /// `stoch_state` of a supported model).
    pub fn encode(&self) -> Result<String, String> {
        let mut s = String::new();
        let _ = writeln!(s, "subppl-checkpoint v1");
        let _ = writeln!(s, "seed {}", self.seed);
        let _ = writeln!(s, "chain {}", self.chain);
        let _ = writeln!(s, "draw {}", self.draw);
        let _ = writeln!(s, "rng {:032x} {:032x}", self.rng.0, self.rng.1);
        let _ = writeln!(s, "values {}", self.values.len());
        for (id, v) in &self.values {
            match v {
                Value::Bool(b) => {
                    let _ = writeln!(s, "{id} B {}", *b as u8);
                }
                Value::Int(i) => {
                    let _ = writeln!(s, "{id} I {i}");
                }
                Value::Real(x) => {
                    let _ = writeln!(s, "{id} R {:016x}", x.to_bits());
                }
                Value::Vector(xs) => {
                    let _ = write!(s, "{id} V {}", xs.len());
                    for x in xs.iter() {
                        let _ = write!(s, " {:016x}", x.to_bits());
                    }
                    let _ = writeln!(s);
                }
                other => {
                    return Err(format!(
                        "checkpoint: node {id} holds a {} value, which has no \
                         serialization (unsupported model state)",
                        other.type_name()
                    ));
                }
            }
        }
        let _ = writeln!(s, "checksum {:016x}", fnv1a(s.as_bytes()));
        Ok(s)
    }

    /// Parse and validate (header, field syntax, count, checksum).
    pub fn decode(text: &str) -> Result<ChainCheckpoint, String> {
        let bad = |what: &str| format!("checkpoint: malformed file ({what})");
        // split off and verify the checksum line first
        let body_end = text
            .rfind("checksum ")
            .ok_or_else(|| bad("missing checksum"))?;
        let want = text[body_end..]
            .trim_start_matches("checksum ")
            .trim();
        let want = u64::from_str_radix(want, 16).map_err(|_| bad("unparsable checksum"))?;
        let got = fnv1a(text[..body_end].as_bytes());
        if got != want {
            return Err(format!(
                "checkpoint: checksum mismatch (file says {want:016x}, contents hash to \
                 {got:016x}) — truncated or corrupted file"
            ));
        }
        let mut lines = text[..body_end].lines();
        if lines.next() != Some("subppl-checkpoint v1") {
            return Err(bad("unknown header"));
        }
        let mut field = |name: &str| -> Result<String, String> {
            let line = lines.next().ok_or_else(|| bad("truncated header"))?;
            line.strip_prefix(name)
                .and_then(|r| r.strip_prefix(' '))
                .map(str::to_string)
                .ok_or_else(|| bad(&format!("expected `{name}` line")))
        };
        let seed: u64 = field("seed")?.parse().map_err(|_| bad("seed"))?;
        let chain: usize = field("chain")?.parse().map_err(|_| bad("chain"))?;
        let draw: usize = field("draw")?.parse().map_err(|_| bad("draw"))?;
        let rng_line = field("rng")?;
        let mut rp = rng_line.split_whitespace();
        let state = u128::from_str_radix(rp.next().ok_or_else(|| bad("rng"))?, 16)
            .map_err(|_| bad("rng state"))?;
        let inc = u128::from_str_radix(rp.next().ok_or_else(|| bad("rng"))?, 16)
            .map_err(|_| bad("rng inc"))?;
        let count: usize = field("values")?.parse().map_err(|_| bad("values count"))?;
        let mut values = Vec::with_capacity(count);
        for _ in 0..count {
            let line = lines.next().ok_or_else(|| bad("truncated values"))?;
            let mut parts = line.split_whitespace();
            let id: u32 = parts
                .next()
                .and_then(|t| t.parse().ok())
                .ok_or_else(|| bad("value node id"))?;
            let kind = parts.next().ok_or_else(|| bad("value kind"))?;
            let v = match kind {
                "B" => match parts.next() {
                    Some("0") => Value::Bool(false),
                    Some("1") => Value::Bool(true),
                    _ => return Err(bad("bool payload")),
                },
                "I" => Value::Int(
                    parts
                        .next()
                        .and_then(|t| t.parse().ok())
                        .ok_or_else(|| bad("int payload"))?,
                ),
                "R" => Value::Real(f64::from_bits(
                    parts
                        .next()
                        .and_then(|t| u64::from_str_radix(t, 16).ok())
                        .ok_or_else(|| bad("real payload"))?,
                )),
                "V" => {
                    let len: usize = parts
                        .next()
                        .and_then(|t| t.parse().ok())
                        .ok_or_else(|| bad("vector length"))?;
                    let mut xs = Vec::with_capacity(len);
                    for _ in 0..len {
                        xs.push(f64::from_bits(
                            parts
                                .next()
                                .and_then(|t| u64::from_str_radix(t, 16).ok())
                                .ok_or_else(|| bad("vector payload"))?,
                        ));
                    }
                    Value::Vector(Rc::new(xs))
                }
                _ => return Err(bad("unknown value kind")),
            };
            values.push((id, v));
        }
        Ok(ChainCheckpoint {
            seed,
            chain,
            draw,
            rng: (state, inc),
            values,
        })
    }

    /// The canonical on-disk location of chain `chain`'s checkpoint.
    pub fn path(dir: &Path, chain: usize) -> PathBuf {
        dir.join(format!("chain{chain}.ckpt"))
    }

    /// Atomically persist under `dir`: write `chain<k>.ckpt.tmp`, then
    /// rename over the final name.  A crash at any point leaves either
    /// the previous checkpoint or the new one, never a torn file.
    pub fn save(&self, dir: &Path) -> Result<(), String> {
        let text = self.encode()?;
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("checkpoint: create_dir {}: {e}", dir.display()))?;
        let fin = Self::path(dir, self.chain);
        let tmp = fin.with_extension("ckpt.tmp");
        std::fs::write(&tmp, &text)
            .map_err(|e| format!("checkpoint: write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &fin)
            .map_err(|e| format!("checkpoint: rename {}: {e}", fin.display()))?;
        Ok(())
    }

    /// Load chain `chain`'s checkpoint from `dir`.  `Ok(None)` when no
    /// checkpoint exists (a resume before the first cadence boundary
    /// starts from scratch); `Err` on unreadable or corrupt files —
    /// never silently start over on a file that *should* have parsed.
    pub fn load(dir: &Path, chain: usize) -> Result<Option<ChainCheckpoint>, String> {
        let p = Self::path(dir, chain);
        let text = match std::fs::read_to_string(&p) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("checkpoint: read {}: {e}", p.display())),
        };
        let ck = Self::decode(&text)?;
        if ck.chain != chain {
            return Err(format!(
                "checkpoint: {} records chain {}, expected {chain}",
                p.display(),
                ck.chain
            ));
        }
        Ok(Some(ck))
    }
}

/// Per-chain checkpoint handle handed to a supervised chain closure
/// (see `run_chains_supervised`): tells the chain when a checkpoint is
/// due, persists snapshots, and carries the checkpoint to resume from
/// (set by `--resume` or by a supervisor restart).
pub struct CheckpointCtl {
    every: usize,
    dir: Option<PathBuf>,
    seed: u64,
    chain: usize,
    resume: Option<ChainCheckpoint>,
}

impl CheckpointCtl {
    /// A handle that never checkpoints and never resumes — the
    /// unsupervised default, so one chain-closure shape serves both
    /// drivers.
    pub fn disabled() -> CheckpointCtl {
        CheckpointCtl {
            every: 0,
            dir: None,
            seed: 0,
            chain: 0,
            resume: None,
        }
    }

    /// Build chain `chain`'s handle.  `every == 0` or `dir == None`
    /// disables persistence; `resume` loads the chain's checkpoint
    /// from `dir` (absent file = fresh start, corrupt file = `Err`).
    pub fn new(
        every: usize,
        dir: Option<&Path>,
        seed: u64,
        chain: usize,
        resume: bool,
    ) -> Result<CheckpointCtl, String> {
        let loaded = match (resume, dir) {
            (true, Some(d)) => {
                let ck = ChainCheckpoint::load(d, chain)?;
                if let Some(ck) = &ck {
                    if ck.seed != seed {
                        return Err(format!(
                            "checkpoint: chain {chain} was checkpointed under seed {}, \
                             resumed under seed {seed}",
                            ck.seed
                        ));
                    }
                }
                ck
            }
            _ => None,
        };
        Ok(CheckpointCtl {
            every,
            dir: dir.map(Path::to_path_buf),
            seed,
            chain,
            resume: loaded,
        })
    }

    /// The checkpoint to resume from, if any.  The chain closure calls
    /// this once after rebuilding its trace, restores, and continues
    /// from `draw + 1`.
    pub fn take_resume(&mut self) -> Option<ChainCheckpoint> {
        self.resume.take()
    }

    /// Whether a checkpoint is due after completing `draw` draws.
    pub fn due(&self, draw: usize) -> bool {
        self.every > 0 && self.dir.is_some() && draw > 0 && draw % self.every == 0
    }

    /// Capture and persist a snapshot after `draw` completed draws.
    /// No-op when persistence is disabled.
    pub fn save(&self, draw: usize, trace: &Trace, rng: &Pcg64) -> Result<(), String> {
        let Some(dir) = &self.dir else {
            return Ok(());
        };
        ChainCheckpoint::capture(self.seed, self.chain, draw, trace, rng).save(dir)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> ChainCheckpoint {
        ChainCheckpoint {
            seed: 42,
            chain: 3,
            draw: 700,
            rng: (0x0123_4567_89ab_cdef_0011_2233_4455_6677, 0xdead_beef | 1),
            values: vec![
                (2, Value::Real(-0.0)),
                (5, Value::Vector(Rc::new(vec![1.5, f64::NAN, -2.25e-308]))),
                (9, Value::Bool(true)),
                (11, Value::Int(-42)),
            ],
        }
    }

    /// encode→decode is the identity, bit-for-bit — including -0.0,
    /// NaN, and subnormals, which a decimal round trip would mangle.
    #[test]
    fn encode_decode_roundtrips_bitwise() {
        let ck = sample();
        let text = ck.encode().unwrap();
        let back = ChainCheckpoint::decode(&text).unwrap();
        assert_eq!(back.seed, ck.seed);
        assert_eq!(back.chain, ck.chain);
        assert_eq!(back.draw, ck.draw);
        assert_eq!(back.rng, ck.rng);
        assert_eq!(back.values.len(), ck.values.len());
        for ((ia, va), (ib, vb)) in ck.values.iter().zip(&back.values) {
            assert_eq!(ia, ib);
            match (va, vb) {
                (Value::Real(a), Value::Real(b)) => assert_eq!(a.to_bits(), b.to_bits()),
                (Value::Vector(a), Value::Vector(b)) => {
                    assert_eq!(a.len(), b.len());
                    for (x, y) in a.iter().zip(b.iter()) {
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                }
                (Value::Bool(a), Value::Bool(b)) => assert_eq!(a, b),
                (Value::Int(a), Value::Int(b)) => assert_eq!(a, b),
                (a, b) => panic!("kind mismatch: {a:?} vs {b:?}"),
            }
        }
    }

    /// Any single-byte corruption must be rejected by the checksum (or
    /// fail to parse outright) — never silently load.
    #[test]
    fn corruption_is_rejected() {
        let text = sample().encode().unwrap();
        // flip one hex digit inside a value payload
        let pos = text.find("R ").unwrap() + 3;
        let mut bytes = text.clone().into_bytes();
        bytes[pos] = if bytes[pos] == b'0' { b'1' } else { b'0' };
        let corrupted = String::from_utf8(bytes).unwrap();
        assert!(ChainCheckpoint::decode(&corrupted).is_err());
        // truncation drops the checksum line entirely
        let truncated = &text[..text.len() / 2];
        assert!(ChainCheckpoint::decode(truncated).is_err());
    }

    /// save → load round-trips through the filesystem, and the rename
    /// leaves no temp file behind.
    #[test]
    fn save_load_roundtrips_atomically() {
        let dir = std::env::temp_dir().join(format!("subppl-ckpt-test-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ck = sample();
        ck.save(&dir).unwrap();
        assert!(
            !dir.join("chain3.ckpt.tmp").exists(),
            "temp file must be renamed away"
        );
        let back = ChainCheckpoint::load(&dir, 3).unwrap().expect("saved file loads");
        assert_eq!(back.draw, ck.draw);
        assert_eq!(back.rng, ck.rng);
        // a missing chain is Ok(None), not an error
        assert!(ChainCheckpoint::load(&dir, 4).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The ctl cadence: due at exact multiples of `every` only, and
    /// never when persistence is off.
    #[test]
    fn ctl_cadence_and_disable() {
        let dir = std::env::temp_dir();
        let ctl = CheckpointCtl::new(50, Some(&dir), 1, 0, false).unwrap();
        assert!(!ctl.due(0));
        assert!(!ctl.due(49));
        assert!(ctl.due(50));
        assert!(ctl.due(100));
        let mut off = CheckpointCtl::disabled();
        assert!(!off.due(50));
        assert!(off.take_resume().is_none());
    }

    /// `take_resume` on a mutable disabled handle (the unsupervised
    /// path) — and a seed mismatch on resume is an explicit error.
    #[test]
    fn resume_rejects_seed_mismatch() {
        let dir = std::env::temp_dir().join(format!("subppl-ckpt-seed-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let ck = sample();
        ck.save(&dir).unwrap();
        assert!(CheckpointCtl::new(10, Some(&dir), 42, 3, true).unwrap().resume.is_some());
        assert!(CheckpointCtl::new(10, Some(&dir), 43, 3, true).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
