//! Streaming multi-chain convergence monitor.
//!
//! Approximate transitions perturb the stationary distribution (§3.3 of
//! the paper bounds the perturbation but cannot see a stuck chain), so
//! any long subsampled run wants *online* convergence evidence rather
//! than end-of-run summaries.  The pieces:
//!
//! * chains running on the worker pool stream recorded draws through a
//!   [`ChainSink`] (the `ChainEvent` lane of
//!   `coordinator::multichain::run_chains_monitored`);
//! * the dispatching thread folds every event into this
//!   [`ConvergenceMonitor`] — per-chain, per-parameter accumulators
//!   keyed by *chain index*;
//! * whenever every chain has crossed the next `every`-draw boundary,
//!   the monitor emits a [`DiagSnapshot`]: split-R̂, rank-normalized R̂
//!   (Vehtari et al. 2021), and Geyer ESS per watched parameter
//!   (`stats::diagnostics`).
//!
//! # Determinism
//!
//! Chains report concurrently, so the *arrival order* of events is
//! scheduling-dependent — but snapshot contents are not: accumulators
//! are keyed by chain index, every snapshot is computed over exactly the
//! first `k * every` draws of *each* chain (reduced in chain-index
//! order), and boundaries only fire once the slowest chain has reached
//! them.  `tests/monitor.rs` pins snapshot bit-equality across reruns
//! and against a sequential fold of the same draws, and
//! `tests/parallel.rs` pins that monitoring never perturbs the chains
//! themselves (the sink is write-only).

use crate::coordinator::report::Csv;
use crate::infer::planned::EvalStats;
use crate::stats::{ess_lazy, rank_normalized_rhat, split_rhat};
use std::fmt::Write as _;

/// A batch of recorded draws from one chain: `draws[s][p]` is the value
/// of watched parameter `p` at recorded sample `s`.  Produced by a
/// [`ChainSink`](crate::coordinator::multichain::ChainSink), consumed by
/// [`ConvergenceMonitor::absorb`].
#[derive(Clone, Debug)]
pub struct ChainEvent {
    pub chain: usize,
    pub draws: Vec<Vec<f64>>,
    /// The chain evaluator's *cumulative* tier counters as of the last
    /// draw in this batch (`None` when the chain doesn't stream stats).
    /// Batch boundaries are deterministic in the seed (fixed buffer
    /// caps), so the monitor can attribute counters to per-chain draw
    /// counts and keep snapshot contents scheduling-independent.
    pub stats: Option<EvalStats>,
}

/// One parameter's diagnostics within a snapshot.
#[derive(Clone, Debug)]
pub struct ParamDiag {
    pub name: String,
    /// Pooled posterior mean over the snapshot window.
    pub mean: f64,
    /// Split-R̂ over the per-chain prefixes.
    pub rhat: f64,
    /// Rank-normalized split-R̂ (robust to heavy tails).
    pub rank_rhat: f64,
    /// Total effective sample size (sum of per-chain Geyer ESS).
    pub ess: f64,
}

/// Periodic diagnostics row: every watched parameter's convergence
/// state over the first `draws_per_chain` draws of each of `chains`
/// chains.
#[derive(Clone, Debug)]
pub struct DiagSnapshot {
    pub draws_per_chain: usize,
    pub chains: usize,
    pub params: Vec<ParamDiag>,
    /// Pooled evaluator-tier traffic since the previous snapshot
    /// (chains' streamed counters summed at this snapshot's horizon,
    /// then diffed against the last emitted snapshot's totals).  All
    /// zeros when no chain streams stats.
    pub eval: EvalStats,
}

impl DiagSnapshot {
    /// One console line per snapshot, e.g.
    /// `[monitor] n=200/chain  phi: R-hat=1.012 rank=1.009 ESS=312.4  sigma: ...`,
    /// with an evaluator-traffic tail (`eval: +planned=... +gathered=...`)
    /// when the chains stream tier counters.
    pub fn render(&self) -> String {
        let mut out = format!("[monitor] n={}/chain", self.draws_per_chain);
        for p in &self.params {
            let _ = write!(
                out,
                "  {}: R-hat={:.3} rank={:.3} ESS={:.1}",
                p.name, p.rhat, p.rank_rhat, p.ess
            );
        }
        if self.eval != EvalStats::default() {
            let e = &self.eval;
            let _ = write!(
                out,
                "  eval: +planned={} +batched={} +gathered={} +fallback={} +sharded={} +stolen={}",
                e.planned, e.batched, e.gathered, e.fallback, e.sharded, e.stolen
            );
            // recovery counters only when a recovery path actually
            // fired — the healthy-run line stays unchanged
            if e.any_recovery() {
                let _ = write!(
                    out,
                    " +panics={} +requeued={} +quarantined={} +restarts={}",
                    e.fallback_panics, e.requeued_shards, e.store_quarantined, e.chains_restarted
                );
            }
            // risk/eviction tail only when the interval reported any —
            // fixed-eps runs without churn keep the original line
            if let Some(r) = e.realized_risk() {
                let _ = write!(out, " risk={r:.2e}");
            }
            if e.store_evicted > 0 {
                let _ = write!(out, " +evicted={}", e.store_evicted);
            }
        }
        out
    }

    /// The `--monitor-gate` predicate: every watched parameter's
    /// rank-normalized R̂ is finite and strictly below `target`.  NaN
    /// (no usable draws) never reads as converged.
    pub fn gate_passed(&self, target: f64) -> bool {
        !self.params.is_empty()
            && self
                .params
                .iter()
                .all(|p| p.rank_rhat.is_finite() && p.rank_rhat < target)
    }

    /// Sections this snapshot's interval actually scored, whichever
    /// evaluator tier did the scoring (batched/gathered/fallback are
    /// tier splits of `planned`; sharded/stolen are placement splits) —
    /// the per-interval term of the draws-to-gate accounting.  Summing
    /// it over the snapshots up to a gate gives total compute-to-
    /// convergence, the number that makes fixed-eps and `--target-risk`
    /// runs comparable.
    pub fn sections_scored(&self) -> usize {
        self.eval.planned + self.eval.fallback
    }

    /// Worst (largest) R̂ across parameters, taking the rank-normalized
    /// variant into account — the single number to gate on.  NaN
    /// poisons the result (a parameter that produced no usable draws
    /// must never read as converged).
    pub fn max_rhat(&self) -> f64 {
        let mut worst = f64::NEG_INFINITY;
        for p in &self.params {
            for r in [p.rhat, p.rank_rhat] {
                if r.is_nan() {
                    return f64::NAN;
                }
                worst = worst.max(r);
            }
        }
        worst
    }
}

/// CSV of labeled snapshot sequences (one row per snapshot x
/// parameter), for experiment artifacts like `fig9_monitor.csv` where
/// several methods' monitor trajectories land in one file.
pub fn monitor_csv(groups: &[(&str, &[DiagSnapshot])]) -> Csv {
    let mut csv = Csv::new(&[
        "run",
        "draws_per_chain",
        "chains",
        "param",
        "mean",
        "rhat",
        "rank_rhat",
        "ess",
        "planned",
        "batched",
        "gathered",
        "fallback",
        "sharded",
        "stolen",
        "fallback_panics",
        "requeued_shards",
        "store_quarantined",
        "chains_restarted",
        "store_evicted",
        "risk_transitions",
        "realized_risk",
        "cum_sections",
    ]);
    for (label, snaps) in groups {
        let mut cum_sections = 0usize;
        for s in *snaps {
            cum_sections += s.sections_scored();
            for (pi, p) in s.params.iter().enumerate() {
                // the eval counters are snapshot-scoped, not
                // per-parameter: emit them on the snapshot's first row
                // only (zeros on the rest) so summing a counter column
                // over the file never multiplies interval traffic by
                // the number of watched parameters
                let ev = |v: usize| if pi == 0 { v.to_string() } else { "0".to_string() };
                csv.row(&[
                    label.to_string(),
                    s.draws_per_chain.to_string(),
                    s.chains.to_string(),
                    p.name.clone(),
                    p.mean.to_string(),
                    p.rhat.to_string(),
                    p.rank_rhat.to_string(),
                    p.ess.to_string(),
                    ev(s.eval.planned),
                    ev(s.eval.batched),
                    ev(s.eval.gathered),
                    ev(s.eval.fallback),
                    ev(s.eval.sharded),
                    ev(s.eval.stolen),
                    ev(s.eval.fallback_panics),
                    ev(s.eval.requeued_shards),
                    ev(s.eval.store_quarantined),
                    ev(s.eval.chains_restarted),
                    ev(s.eval.store_evicted),
                    ev(s.eval.risk_transitions),
                    // mean, not a count: blank (not 0) on non-first rows
                    if pi == 0 {
                        s.eval.realized_risk().map_or(String::new(), |r| r.to_string())
                    } else {
                        String::new()
                    },
                    // running total of sections scored by this run up
                    // to (and including) this snapshot — the
                    // compute-to-convergence axis for draws-to-gate
                    // comparisons (first-row only, like the counters)
                    ev(cum_sections),
                ]);
            }
        }
    }
    csv
}

/// Online fold of [`ChainEvent`]s into periodic [`DiagSnapshot`]s.
pub struct ConvergenceMonitor {
    every: usize,
    params: Vec<String>,
    /// `draws[chain][param]` — all draws recorded so far, keyed by chain
    /// index so fold order never depends on event arrival order.
    draws: Vec<Vec<Vec<f64>>>,
    /// Per-chain `(cumulative draw count, cumulative counters)` points,
    /// in recording order (mpsc preserves per-sender order).  Keyed by
    /// chain + draw count, so the totals attributed to a snapshot
    /// horizon are scheduling-independent, like the draws themselves.
    stats_points: Vec<Vec<(usize, EvalStats)>>,
    /// Totals attributed to the last emitted snapshot (diff base).
    last_stats: EvalStats,
    /// Next per-chain draw count at which a snapshot fires.
    next_boundary: usize,
    /// Horizon of the last snapshot handed out (boundary or final), so
    /// [`finish`](Self::finish) never duplicates the last boundary.
    last_emitted: usize,
}

impl ConvergenceMonitor {
    /// Monitor `chains` chains over the named parameters, snapshotting
    /// every `every` draws per chain (`every >= 1`).
    pub fn new(chains: usize, params: &[String], every: usize) -> ConvergenceMonitor {
        assert!(every >= 1, "monitor cadence must be >= 1");
        assert!(!params.is_empty(), "monitor needs at least one parameter");
        ConvergenceMonitor {
            every,
            params: params.to_vec(),
            draws: vec![vec![Vec::new(); params.len()]; chains],
            stats_points: vec![Vec::new(); chains],
            last_stats: EvalStats::default(),
            next_boundary: every,
            last_emitted: 0,
        }
    }

    pub fn params(&self) -> &[String] {
        &self.params
    }

    /// Fold one event into the per-chain accumulators.  Rows must have
    /// one value per watched parameter; mismatched rows are rejected so
    /// a miswired sink fails loudly rather than skewing diagnostics.
    pub fn absorb(&mut self, ev: ChainEvent) {
        let slot = &mut self.draws[ev.chain];
        for row in &ev.draws {
            assert_eq!(
                row.len(),
                self.params.len(),
                "chain {} sent a row of {} values for {} watched parameters",
                ev.chain,
                row.len(),
                self.params.len()
            );
            for (p, &x) in row.iter().enumerate() {
                slot[p].push(x);
            }
        }
        if let Some(st) = ev.stats {
            let at = slot[0].len();
            self.stats_points[ev.chain].push((at, st));
        }
    }

    /// Draws recorded so far by the slowest chain — the snapshot
    /// horizon.
    pub fn min_len(&self) -> usize {
        self.draws
            .iter()
            .map(|c| c[0].len())
            .min()
            .unwrap_or(0)
    }

    /// Snapshots whose boundary every chain has now crossed, in
    /// boundary order.  Call after each `absorb`; a batch that jumps
    /// several boundaries yields several snapshots.
    pub fn ready_snapshots(&mut self) -> Vec<DiagSnapshot> {
        let mut out = Vec::new();
        while self.min_len() >= self.next_boundary {
            out.push(self.snapshot_at(self.next_boundary));
            self.last_emitted = self.next_boundary;
            self.next_boundary += self.every;
        }
        out
    }

    /// The end-of-run snapshot: diagnostics over the first `min_len`
    /// draws of every chain, when that horizon wasn't already emitted
    /// as a boundary snapshot.  `None` until every chain has at least 4
    /// draws (or when the run ended exactly on the last boundary) —
    /// every call site wants exactly this dedup, so it lives here.
    pub fn finish(&mut self) -> Option<DiagSnapshot> {
        let n = self.min_len();
        if n < 4 || n == self.last_emitted {
            return None;
        }
        self.last_emitted = n;
        Some(self.snapshot_at(n))
    }

    /// Summed per-chain counters at horizon `n`: for each chain, the
    /// last streamed point whose draw count is <= n — a pure function
    /// of (chain streams, n), like the draw fold.
    fn stats_at(&self, n: usize) -> EvalStats {
        let mut tot = EvalStats::default();
        for pts in &self.stats_points {
            if let Some((_, st)) = pts.iter().rev().find(|(at, _)| *at <= n) {
                tot = tot.add(st);
            }
        }
        tot
    }

    /// Fold-order-normalized reduction: chains enter in index order,
    /// truncated to exactly the first `n` draws each, so the result is a
    /// pure function of (chain contents, n).
    fn snapshot_at(&mut self, n: usize) -> DiagSnapshot {
        let params = self
            .params
            .iter()
            .enumerate()
            .map(|(p, name)| {
                let series: Vec<&[f64]> =
                    self.draws.iter().map(|c| &c[p][..n]).collect();
                let total: f64 = series.iter().map(|s| s.iter().sum::<f64>()).sum();
                let ess = series.iter().map(|s| ess_lazy(s)).sum();
                ParamDiag {
                    name: name.clone(),
                    mean: total / (n * series.len()) as f64,
                    rhat: split_rhat(&series),
                    rank_rhat: rank_normalized_rhat(&series),
                    ess,
                }
            })
            .collect();
        let totals = self.stats_at(n);
        let eval = totals.diff(&self.last_stats);
        self.last_stats = totals;
        DiagSnapshot {
            draws_per_chain: n,
            chains: self.draws.len(),
            params,
            eval,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Pcg64;

    fn event(chain: usize, rows: &[[f64; 2]]) -> ChainEvent {
        ChainEvent {
            chain,
            draws: rows.iter().map(|r| r.to_vec()).collect(),
            stats: None,
        }
    }

    #[test]
    fn boundaries_fire_only_when_every_chain_crosses() {
        let names = vec!["a".to_string(), "b".to_string()];
        let mut mon = ConvergenceMonitor::new(2, &names, 4);
        let mut rng = Pcg64::seeded(7);
        let mut rows = |k: usize| -> Vec<[f64; 2]> {
            (0..k).map(|_| [rng.normal(), rng.normal()]).collect()
        };
        mon.absorb(event(0, &rows(10)));
        // chain 1 hasn't reported: nothing fires
        assert!(mon.ready_snapshots().is_empty());
        assert!(mon.finish().is_none());
        mon.absorb(event(1, &rows(5)));
        // min is now 5: the n=4 boundary fires, n=8 doesn't
        let snaps = mon.ready_snapshots();
        assert_eq!(snaps.len(), 1);
        assert_eq!(snaps[0].draws_per_chain, 4);
        assert_eq!(snaps[0].chains, 2);
        assert_eq!(snaps[0].params.len(), 2);
        // one batch can cross several boundaries at once
        mon.absorb(event(1, &rows(8)));
        let snaps = mon.ready_snapshots();
        assert_eq!(
            snaps.iter().map(|s| s.draws_per_chain).collect::<Vec<_>>(),
            vec![8]
        );
        mon.absorb(event(0, &rows(6)));
        let snaps = mon.ready_snapshots();
        assert_eq!(
            snaps.iter().map(|s| s.draws_per_chain).collect::<Vec<_>>(),
            vec![12]
        );
        // min is 13, one past the emitted boundary: finish() emits it
        // once and only once
        let fin = mon.finish().unwrap();
        assert_eq!(fin.draws_per_chain, 13);
        assert!(mon.finish().is_none(), "finish() must not re-emit");
        // a run ending exactly on a boundary yields no extra snapshot
        mon.absorb(event(0, &rows(3)));
        mon.absorb(event(1, &rows(3)));
        assert_eq!(mon.ready_snapshots().len(), 1); // boundary 16
        assert!(mon.finish().is_none(), "boundary-aligned end re-emitted");
    }

    /// Arrival order must not matter: the same draws delivered in
    /// scrambled chain order produce bit-identical snapshots.
    #[test]
    fn fold_order_normalized_by_chain_index() {
        let names = vec!["x".to_string()];
        let mut rng = Pcg64::seeded(8);
        let chains: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..40).map(|_| rng.normal()).collect())
            .collect();
        let ev = |c: usize, lo: usize, hi: usize| ChainEvent {
            chain: c,
            draws: chains[c][lo..hi].iter().map(|&x| vec![x]).collect(),
            stats: None,
        };
        // in-order delivery
        let mut a = ConvergenceMonitor::new(3, &names, 10);
        let mut a_snaps = Vec::new();
        for c in 0..3 {
            a.absorb(ev(c, 0, 40));
            a_snaps.extend(a.ready_snapshots());
        }
        // interleaved, reversed delivery in odd-sized batches
        let mut b = ConvergenceMonitor::new(3, &names, 10);
        let mut b_snaps = Vec::new();
        for (c, lo, hi) in [
            (2, 0, 7),
            (0, 0, 33),
            (1, 0, 40),
            (2, 7, 40),
            (0, 33, 40),
        ] {
            b.absorb(ev(c, lo, hi));
            b_snaps.extend(b.ready_snapshots());
        }
        assert_eq!(a_snaps.len(), 4);
        assert_eq!(a_snaps.len(), b_snaps.len());
        for (s, t) in a_snaps.iter().zip(&b_snaps) {
            assert_eq!(s.draws_per_chain, t.draws_per_chain);
            for (p, q) in s.params.iter().zip(&t.params) {
                assert_eq!(p.rhat.to_bits(), q.rhat.to_bits());
                assert_eq!(p.rank_rhat.to_bits(), q.rank_rhat.to_bits());
                assert_eq!(p.ess.to_bits(), q.ess.to_bits());
                assert_eq!(p.mean.to_bits(), q.mean.to_bits());
            }
        }
    }

    #[test]
    fn snapshot_flags_a_stuck_chain() {
        let names = vec!["x".to_string()];
        let mut mon = ConvergenceMonitor::new(2, &names, 200);
        let mut rng = Pcg64::seeded(9);
        let healthy: Vec<Vec<f64>> = (0..200).map(|_| vec![rng.normal()]).collect();
        let stuck: Vec<Vec<f64>> =
            (0..200).map(|_| vec![6.0 + 0.01 * rng.normal()]).collect();
        mon.absorb(ChainEvent { chain: 0, draws: healthy, stats: None });
        mon.absorb(ChainEvent { chain: 1, draws: stuck, stats: None });
        let snaps = mon.ready_snapshots();
        assert_eq!(snaps.len(), 1);
        let s = &snaps[0];
        assert!(s.max_rhat() > 2.0, "stuck chain not flagged: {}", s.max_rhat());
        let line = s.render();
        assert!(line.contains("[monitor] n=200/chain"), "{line}");
        assert!(line.contains("x: R-hat="), "{line}");
    }

    /// Streamed evaluator counters are attributed to per-chain draw
    /// counts and diffed between snapshots — a chain whose only stats
    /// point lies past the horizon contributes nothing yet, so the
    /// fold is a pure function of (streams, horizon) like the draws.
    #[test]
    fn stats_points_fold_into_interval_diffs() {
        let names = vec!["x".to_string()];
        let mut mon = ConvergenceMonitor::new(2, &names, 4);
        let st = |planned: usize| EvalStats {
            planned,
            ..EvalStats::default()
        };
        mon.absorb(ChainEvent {
            chain: 0,
            draws: vec![vec![0.1]; 4],
            stats: Some(st(40)),
        });
        mon.absorb(ChainEvent {
            chain: 0,
            draws: vec![vec![0.2]; 4],
            stats: Some(st(100)),
        });
        assert!(mon.ready_snapshots().is_empty());
        mon.absorb(ChainEvent {
            chain: 1,
            draws: vec![vec![0.3]; 8],
            stats: Some(st(70)),
        });
        let snaps = mon.ready_snapshots();
        assert_eq!(snaps.len(), 2);
        // boundary 4: chain 0's point at 4 counts; chain 1's only
        // point (at 8) is past the horizon
        assert_eq!(snaps[0].eval.planned, 40);
        // boundary 8: totals 100 + 70, minus the 40 already attributed
        assert_eq!(snaps[1].eval.planned, 130);
        let line = snaps[1].render();
        assert!(line.contains("eval: +planned=130"), "{line}");
    }

    /// The recovery counters appear in the rendered line only when a
    /// recovery path actually fired — healthy runs keep the original
    /// six-counter tail.
    #[test]
    fn render_shows_recovery_tail_only_when_recovery_fired() {
        let snap = |eval: EvalStats| DiagSnapshot {
            draws_per_chain: 8,
            chains: 2,
            params: Vec::new(),
            eval,
        };
        let healthy = snap(EvalStats {
            planned: 10,
            ..EvalStats::default()
        });
        let line = healthy.render();
        assert!(line.contains("eval: +planned=10"), "{line}");
        assert!(!line.contains("+panics="), "{line}");
        let hurt = snap(EvalStats {
            planned: 10,
            fallback_panics: 1,
            chains_restarted: 2,
            ..EvalStats::default()
        });
        let line = hurt.render();
        assert!(
            line.contains("+panics=1 +requeued=0 +quarantined=0 +restarts=2"),
            "{line}"
        );
    }

    /// The gate predicate: every rank-R̂ finite and strictly below the
    /// target; NaN or an empty parameter set never passes.
    #[test]
    fn gate_passed_requires_every_rank_rhat_finite_below_target() {
        let p = |rank: f64| ParamDiag {
            name: "p".into(),
            mean: 0.0,
            rhat: 1.0,
            rank_rhat: rank,
            ess: 10.0,
        };
        let snap = |params: Vec<ParamDiag>| DiagSnapshot {
            draws_per_chain: 8,
            chains: 2,
            params,
            eval: EvalStats::default(),
        };
        assert!(snap(vec![p(1.004), p(1.009)]).gate_passed(1.01));
        assert!(!snap(vec![p(1.004), p(1.02)]).gate_passed(1.01));
        assert!(!snap(vec![p(f64::NAN)]).gate_passed(1.01));
        assert!(!snap(Vec::new()).gate_passed(1.01));
    }

    #[test]
    fn monitor_csv_has_a_row_per_param() {
        let names = vec!["a".to_string(), "b".to_string()];
        let mut mon = ConvergenceMonitor::new(1, &names, 8);
        let mut rng = Pcg64::seeded(10);
        let rows: Vec<Vec<f64>> =
            (0..16).map(|_| vec![rng.normal(), rng.normal()]).collect();
        mon.absorb(ChainEvent { chain: 0, draws: rows, stats: None });
        let snaps = mon.ready_snapshots();
        assert_eq!(snaps.len(), 2);
        let csv = monitor_csv(&[("smoke", snaps.as_slice())]);
        assert_eq!(csv.contents().lines().count(), 1 + 2 * 2);
        assert!(csv.contents().starts_with("run,draws_per_chain,chains,param,"));
        assert!(csv.contents().lines().nth(1).unwrap().starts_with("smoke,8,1,a,"));
    }
}
