//! Experiment coordination: model builders, the XLA-fused section
//! evaluator, the concurrent multi-chain driver with its streaming
//! convergence monitor, and reporting (tables/CSV) for regenerating
//! every figure and table in the paper's evaluation.

pub mod chain;
pub mod checkpoint;
pub mod experiments;
pub mod fused;
pub mod monitor;
pub mod multichain;
pub mod report;

pub use chain::{build_bayes_lr, build_joint_dpm, build_sv, timed};
pub use checkpoint::{ChainCheckpoint, CheckpointCtl};
pub use fused::FusedEval;
pub use monitor::{monitor_csv, ChainEvent, ConvergenceMonitor, DiagSnapshot, ParamDiag};
pub use multichain::{
    chain_lane, chain_rng, run_chains, run_chains_gated, run_chains_global,
    run_chains_monitored, run_chains_supervised, BufferedSink, ChainLane, ChainSink,
    SupervisorConfig,
};
pub use report::{histogram, results_dir, Csv, Table};
