//! Model builders + chain driver for the paper's experiments.
//!
//! Traces with 10^4..10^6 observations are built programmatically
//! (constructing `Directive` values directly) rather than by formatting
//! and re-parsing program text, which would dominate setup time at
//! large N.

use crate::data::{sv_data::SvSeries, Dataset};
use crate::math::Pcg64;
use crate::ppl::ast::{Directive, Expr};
use crate::ppl::value::Value;
use crate::trace::node::NodeId;
use crate::trace::pet::Trace;
use std::rc::Rc;

/// The paper's Bayesian logistic regression program (Fig. 3):
/// w ~ N(0, prior_var I_D); y_i ~ Bernoulli(sigma(w . x_i)).
/// Returns the trace and the weight node (scope 'w, block 0).
pub fn build_bayes_lr(data: &Dataset, prior_var: f64, rng: &mut Pcg64) -> (Trace, NodeId) {
    let d = data.d();
    let mut trace = Trace::new();
    let header = format!(
        "[assume w (scope_include 'w 0 (multivariate_normal (vector {}) {prior_var}))]\n\
         [assume f (lambda (x) (bernoulli (linear_logistic w x)))]",
        vec!["0"; d].join(" ")
    );
    trace.run_program(&header, rng).unwrap();
    // observations built as Directive values (no string round-trip)
    let f_sym = Expr::sym("f");
    for (x, &y) in data.x.iter().zip(&data.y) {
        let obs = Directive::Observe(
            Expr::app(vec![
                f_sym.clone(),
                Expr::constant(Value::Vector(Rc::new(x.clone()))),
            ]),
            Value::Bool(y),
        );
        trace.execute(&obs, rng).unwrap();
    }
    let w = trace.lookup_node("w").unwrap();
    (trace, w)
}

/// The paper's JointDPM program (Fig. 7 top): CRP mixture of collapsed
/// NIW feature models with per-cluster logistic experts.
pub fn build_joint_dpm(data: &Dataset, rng: &mut Pcg64) -> Trace {
    let d = data.d();
    let zeros = vec!["0"; d].join(" ");
    let header = format!(
        "[assume alpha (scope_include 'hypers 0 (gamma 1 1))]\n\
         [assume crp (make_crp alpha)]\n\
         [assume z (mem (lambda (i) (scope_include 'z i (crp))))]\n\
         [assume w (mem (lambda (k) (scope_include 'w k \
            (multivariate_normal (vector {zeros}) 10.0))))]\n\
         [assume c (mem (lambda (k) (make_collapsed_multivariate_normal \
            (vector {zeros}) 1.0 {v0} 1.0)))]\n\
         [assume x (lambda (i) ((c (z i))))]\n\
         [assume y (lambda (i xv) (bernoulli (linear_logistic (w (z i)) xv)))]",
        v0 = d + 2
    );
    let mut trace = Trace::new();
    trace.run_program(&header, rng).unwrap();
    let x_sym = Expr::sym("x");
    let y_sym = Expr::sym("y");
    for (i, (x, &y)) in data.x.iter().zip(&data.y).enumerate() {
        let oi = Directive::Observe(
            Expr::app(vec![x_sym.clone(), Expr::constant(Value::Int(i as i64))]),
            Value::Vector(Rc::new(x.clone())),
        );
        trace.execute(&oi, rng).unwrap();
        let yi = Directive::Observe(
            Expr::app(vec![
                y_sym.clone(),
                Expr::constant(Value::Int(i as i64)),
                Expr::constant(Value::Vector(Rc::new(x.clone()))),
            ]),
            Value::Bool(y),
        );
        trace.execute(&yi, rng).unwrap();
    }
    trace
}

/// The paper's stochastic-volatility program (Fig. 7 bottom) for a set
/// of independent series sharing (phi, sigma).  States are tagged
/// `(scope h_<series> t)`; returns the phi node and the sigma^2 node.
pub fn build_sv(series: &[SvSeries], rng: &mut Pcg64) -> (Trace, NodeId, NodeId) {
    let mut trace = Trace::new();
    let header = "[assume sig2 (scope_include 'sig2 0 (inv_gamma 5 0.05))]\n\
         [assume sig (sqrt sig2)]\n\
         [assume phi (scope_include 'phi 0 (beta 5 1))]"
        .to_string();
    trace.run_program(&header, rng).unwrap();
    for (s, sv) in series.iter().enumerate() {
        let prog = format!(
            "[assume h{s} (mem (lambda (t) (scope_include 'h{s} t \
               (if (<= t 0) 0.0 (normal (* phi (h{s} (- t 1))) sig)))))]\n\
             [assume x{s} (lambda (t) (normal 0 (exp (/ (h{s} t) 2))))]"
        );
        trace.run_program(&prog, rng).unwrap();
        for (t, &xv) in sv.x.iter().enumerate() {
            let obs = Directive::Observe(
                Expr::app(vec![
                    Expr::sym(&format!("x{s}")),
                    Expr::constant(Value::Int((t + 1) as i64)),
                ]),
                Value::Real(xv),
            );
            trace.execute(&obs, rng).unwrap();
        }
    }
    let phi = trace.lookup_node("phi").unwrap();
    let sig2 = trace.lookup_node("sig2").unwrap();
    (trace, phi, sig2)
}

/// Wall-clock helper: run `f` and return (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let start = std::time::Instant::now();
    let r = f();
    (r, start.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{dpm_data, mnist_like, sv_data, synth2d};

    #[test]
    fn bayes_lr_builds_fast_and_correctly() {
        let data = synth2d::generate(2000, 0);
        let mut rng = Pcg64::seeded(1);
        let ((t, w), secs) = timed(|| build_bayes_lr(&data, 0.1, &mut rng));
        assert!(secs < 5.0, "trace construction too slow: {secs}s");
        assert_eq!(t.node(w).children.len(), 2000);
        assert_eq!(t.num_live_nodes(), 1 + 2 * 2000);
    }

    #[test]
    fn joint_dpm_builds_and_scores() {
        let (data, _) = dpm_data::generate(50, 0);
        let mut rng = Pcg64::seeded(2);
        let mut t = build_joint_dpm(&data, &mut rng);
        assert_eq!(t.scope_nodes("z").len(), 50);
        assert!(!t.scope_nodes("w").is_empty());
        assert!(t.log_joint().is_finite());
    }

    #[test]
    fn sv_builds_and_scores() {
        let cfg = sv_data::SvConfig {
            series: 5,
            len: 4,
            ..Default::default()
        };
        let series = sv_data::generate(&cfg, 0);
        let mut rng = Pcg64::seeded(3);
        let (mut t, phi, sig2) = build_sv(&series, &mut rng);
        assert!(t.node(phi).is_stochastic());
        assert!(t.node(sig2).is_stochastic());
        // phi's partition: 5 series x 4 states = 20 local sections
        let p = crate::trace::partition::build_partition(&t, phi).unwrap();
        assert_eq!(p.n(), 20);
        assert!(t.log_joint().is_finite());
    }

    #[test]
    fn mnist_like_scale_build() {
        let data = mnist_like::sized(12214, 50, 0);
        let mut rng = Pcg64::seeded(4);
        let ((t, _), secs) = timed(|| build_bayes_lr(&data, 0.1, &mut rng));
        assert_eq!(t.num_live_nodes(), 1 + 2 * 12214);
        assert!(secs < 30.0, "full-scale build too slow: {secs}s");
    }
}
