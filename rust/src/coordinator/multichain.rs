//! Concurrent multi-chain driver: independent replicas on the shared
//! worker pool.
//!
//! Repeated-trial experiments and Geweke-style harnesses run R
//! *independent* chains.  Each chain owns everything `Rc`-based —
//! `Trace`, evaluator, plan caches — outright inside its worker task
//! (nothing crosses the `Send` boundary except the task closure and the
//! plain-data result), and draws from its own PCG *stream*
//! (`Pcg64::new(seed, CHAIN_STREAM_BASE + index)`), so:
//!
//! * results are deterministic for a fixed seed regardless of worker
//!   scheduling — chains never share an RNG;
//! * results are identical to running the same chains sequentially
//!   (pinned by `tests/parallel.rs::multichain_matches_inline_runs`);
//! * chains reuse the same pool as the sharded batch scorer, so the
//!   process never oversubscribes the machine.
//!
//! Do not call [`run_chains`] from *inside* a pool task: the driver
//! blocks on its chains and a 1-thread pool would deadlock.

use crate::math::Pcg64;
use crate::runtime::pool::WorkerPool;
use std::sync::mpsc::channel;
use std::sync::Arc;

/// RNG stream offset for chain replicas, keeping them disjoint from the
/// streams experiments hand out by literal id (0..≈100).
pub const CHAIN_STREAM_BASE: u64 = 0x6368_0000; // "ch"

/// Seeded RNG for chain `index` of a run keyed by `seed`.
pub fn chain_rng(seed: u64, index: usize) -> Pcg64 {
    Pcg64::new(seed, CHAIN_STREAM_BASE + index as u64)
}

/// Run `chains` independent replicas of `f` concurrently on `pool`,
/// returning results in chain order (index 0 first, regardless of which
/// worker finished first).  `f(index, rng)` must build its own `Trace`
/// from the inputs it captures — typically a program source string or a
/// `Clone + Send` experiment config — and return plain data.
///
/// Errors if any chain's worker died without reporting (a panic inside
/// `f`); surviving chains' results are discarded in that case.
pub fn run_chains<T, F>(
    pool: &Arc<WorkerPool>,
    chains: usize,
    seed: u64,
    f: F,
) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64) -> T + Send + Sync + 'static,
{
    if chains == 0 {
        return Ok(Vec::new());
    }
    let f = Arc::new(f);
    let (tx, rx) = channel::<(usize, T)>();
    for c in 0..chains {
        let f = f.clone();
        let tx = tx.clone();
        pool.submit(Box::new(move || {
            let out = f(c, chain_rng(seed, c));
            // a dropped receiver just means the driver already bailed
            let _ = tx.send((c, out));
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..chains).map(|_| None).collect();
    for _ in 0..chains {
        match rx.recv() {
            Ok((c, out)) => slots[c] = Some(out),
            Err(_) => return Err("multichain: a chain worker panicked".into()),
        }
    }
    Ok(slots.into_iter().map(|s| s.expect("chain reported")).collect())
}

/// Convenience wrapper over the process-wide pool.
pub fn run_chains_global<T, F>(chains: usize, seed: u64, f: F) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64) -> T + Send + Sync + 'static,
{
    run_chains(WorkerPool::global(), chains, seed, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains_return_in_index_order_with_disjoint_streams() {
        let pool = WorkerPool::new(3);
        let draws = run_chains(&pool, 8, 7, |c, mut rng| (c, rng.next_u64())).unwrap();
        for (i, &(c, _)) in draws.iter().enumerate() {
            assert_eq!(i, c, "results must come back in chain order");
        }
        // disjoint streams: no two chains share a first draw
        let mut firsts: Vec<u64> = draws.iter().map(|&(_, x)| x).collect();
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), 8);
        // deterministic: a re-run reproduces the draws bit-for-bit
        let again = run_chains(&pool, 8, 7, |c, mut rng| (c, rng.next_u64())).unwrap();
        assert_eq!(draws, again);
    }

    #[test]
    fn chain_panic_surfaces_as_error() {
        let pool = WorkerPool::new(2);
        let r = run_chains(&pool, 3, 1, |c, _| {
            if c == 1 {
                panic!("deliberate chain failure");
            }
            c
        });
        assert!(r.is_err());
    }

    /// Chains build real traces and run real transitions concurrently;
    /// per-chain results must equal the same chain run inline.
    #[test]
    fn concurrent_traces_match_inline_execution() {
        use crate::infer::{subsampled_mh_transition, PlannedEval, SubsampledConfig};
        use crate::trace::Trace;
        let chain = |_c: usize, mut rng: Pcg64| -> Vec<u64> {
            let mut src = String::from(
                "[assume mu (scope_include 'mu 0 (normal 0 1))]\n\
                 [assume g (lambda () (normal mu 0.5))]\n",
            );
            for i in 0..12 {
                src.push_str(&format!("[observe (g) {}]\n", (i % 4) as f64 * 0.3));
            }
            let mut t = Trace::new();
            t.run_program(&src, &mut rng).unwrap();
            let mu = t.lookup_node("mu").unwrap();
            let cfg = SubsampledConfig::paper_defaults();
            let mut ev = PlannedEval::for_config(&cfg);
            let mut bits = Vec::new();
            for _ in 0..50 {
                subsampled_mh_transition(&mut t, &mut rng, mu, &cfg, &mut ev).unwrap();
                bits.push(t.fresh_value(mu).as_f64().unwrap().to_bits());
            }
            bits
        };
        let pool = WorkerPool::new(4);
        let parallel = run_chains(&pool, 4, 99, chain).unwrap();
        for (c, got) in parallel.iter().enumerate() {
            let want = chain(c, chain_rng(99, c));
            assert_eq!(got, &want, "chain {c} diverged from its inline run");
        }
    }
}
