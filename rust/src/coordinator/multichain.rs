//! Concurrent multi-chain driver: independent replicas on the shared
//! worker pool.
//!
//! Repeated-trial experiments and Geweke-style harnesses run R
//! *independent* chains.  Each chain owns everything `Rc`-based —
//! `Trace`, evaluator, plan caches — outright inside its worker task
//! (nothing crosses the `Send` boundary except the task closure and the
//! plain-data result), and draws from its own PCG *stream*
//! (`Pcg64::new(seed, CHAIN_STREAM_BASE + index)`), so:
//!
//! * results are deterministic for a fixed seed regardless of worker
//!   scheduling — chains never share an RNG;
//! * results are identical to running the same chains sequentially
//!   (pinned by `tests/parallel.rs::multichain_matches_inline_runs`);
//! * chains reuse the same pool as the sharded batch scorer, so the
//!   process never oversubscribes the machine.
//!
//! Do not call [`run_chains`] from *inside* a pool task: the driver
//! blocks on its chains and a 1-thread pool would deadlock.
//!
//! [`run_chains_monitored`] adds a *ChainEvent lane*: each chain gets a
//! [`ChainSink`] through which it streams recorded draws while running,
//! and the dispatching thread folds those events (typically into a
//! [`ConvergenceMonitor`](crate::coordinator::monitor::ConvergenceMonitor))
//! between result arrivals.  The lane is write-only from the chain's
//! point of view, so monitoring cannot perturb chain results — pinned by
//! `tests/monitor.rs`.

use crate::coordinator::checkpoint::CheckpointCtl;
use crate::coordinator::monitor::ChainEvent;
use crate::infer::planned::EvalStats;
use crate::math::Pcg64;
use crate::runtime::pool::WorkerPool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

/// RNG stream offset for chain replicas, keeping them disjoint from the
/// streams experiments hand out by literal id (0..≈100).
pub const CHAIN_STREAM_BASE: u64 = 0x6368_0000; // "ch"

/// Seeded RNG for chain `index` of a run keyed by `seed`.
pub fn chain_rng(seed: u64, index: usize) -> Pcg64 {
    Pcg64::new(seed, CHAIN_STREAM_BASE + index as u64)
}

/// Run `chains` independent replicas of `f` concurrently on `pool`,
/// returning results in chain order (index 0 first, regardless of which
/// worker finished first).  `f(index, rng)` must build its own `Trace`
/// from the inputs it captures — typically a program source string or a
/// `Clone + Send` experiment config — and return plain data.
///
/// Errors if any chain's worker died without reporting (a panic inside
/// `f`); surviving chains' results are discarded in that case.
pub fn run_chains<T, F>(
    pool: &Arc<WorkerPool>,
    chains: usize,
    seed: u64,
    f: F,
) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64) -> T + Send + Sync + 'static,
{
    if chains == 0 {
        return Ok(Vec::new());
    }
    let f = Arc::new(f);
    let (tx, rx) = channel::<(usize, T)>();
    for c in 0..chains {
        let f = f.clone();
        let tx = tx.clone();
        pool.submit(Box::new(move || {
            let out = f(c, chain_rng(seed, c));
            // a dropped receiver just means the driver already bailed
            let _ = tx.send((c, out));
        }));
    }
    drop(tx);
    let mut slots: Vec<Option<T>> = (0..chains).map(|_| None).collect();
    for _ in 0..chains {
        match rx.recv() {
            Ok((c, out)) => slots[c] = Some(out),
            Err(_) => return Err("multichain: a chain worker panicked".into()),
        }
    }
    Ok(slots.into_iter().map(|s| s.expect("chain reported")).collect())
}

/// Convenience wrapper over the process-wide pool.
pub fn run_chains_global<T, F>(chains: usize, seed: u64, f: F) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64) -> T + Send + Sync + 'static,
{
    run_chains(WorkerPool::global(), chains, seed, f)
}

/// Messages on the event lane: draw batches while a chain runs, one
/// `Done` marker when it finishes.  mpsc preserves per-sender order, so
/// every event a chain sent precedes its own `Done`.
enum MonitorMsg {
    Event(ChainEvent),
    Done,
}

/// A chain's handle on the event lane of [`run_chains_monitored`]:
/// write-only, clonable, and fire-and-forget (a dropped receiver means
/// the driver already bailed — sends are silently discarded, never an
/// error the chain has to handle).
#[derive(Clone)]
pub struct ChainSink {
    chain: usize,
    tx: Sender<MonitorMsg>,
    stop: Arc<AtomicBool>,
    /// Supervisor restarts of this chain so far: folded into the
    /// `chains_restarted` field of every stats snapshot this sink
    /// forwards, so the recovery shows up in `[monitor]` lines.
    /// Always 0 under the unsupervised drivers.
    restarts: usize,
}

impl ChainSink {
    /// The chain index this sink reports as.
    pub fn chain(&self) -> usize {
        self.chain
    }

    /// Whether the driver has asked chains to wind down early (a
    /// `--monitor-gate` fired; see [`run_chains_gated`]).  Chains check
    /// this at a convenient boundary — a sweep, a recorded sample — and
    /// return.  The stop is best-effort: *when* each chain notices is
    /// scheduling-dependent, so a gated run trades tail-length
    /// determinism for wall clock; the snapshot stream up to the gate
    /// remains deterministic in the seed.
    pub fn cancelled(&self) -> bool {
        self.stop.load(Ordering::Relaxed)
    }

    /// Stream a batch of recorded draws (`rows[s][p]` = watched
    /// parameter `p` at recorded sample `s`).  Empty batches are
    /// dropped.
    pub fn send(&self, rows: Vec<Vec<f64>>) {
        self.send_with_stats(rows, None);
    }

    /// [`send`](Self::send) carrying the chain evaluator's cumulative
    /// tier counters as of the batch's last draw — the monitor streams
    /// their per-interval diffs into its snapshots.
    pub fn send_with_stats(&self, rows: Vec<Vec<f64>>, stats: Option<EvalStats>) {
        if rows.is_empty() {
            return;
        }
        let stats = stats.map(|mut s| {
            s.chains_restarted += self.restarts;
            s
        });
        let _ = self.tx.send(MonitorMsg::Event(ChainEvent {
            chain: self.chain,
            draws: rows,
            stats,
        }));
    }

    /// Wrap this sink in a row buffer that flushes every `cap` rows and
    /// on drop — the one place the batching-with-trailing-flush pattern
    /// lives, so call sites cannot forget the tail rows.
    pub fn buffered(self, cap: usize) -> BufferedSink {
        BufferedSink {
            sink: self,
            cap: cap.max(1),
            rows: Vec::new(),
            stats: None,
        }
    }

    /// Set the restart count folded into every subsequent stats
    /// snapshot (the serve session supervisor bumps this after each
    /// `catch_unwind` recovery, mirroring what
    /// [`run_chains_supervised`] does when it rebuilds a sink).
    pub fn set_restarts(&mut self, restarts: usize) {
        self.restarts = restarts;
    }
}

/// A standalone event lane for one chain outside the multichain
/// drivers — the serve daemon gives each session its own.  The returned
/// [`ChainSink`] is the write end (identical plumbing to
/// [`run_chains_monitored`]'s, including the shared stop flag) and the
/// [`ChainLane`] is the read end the owner drains at draw boundaries.
pub fn chain_lane(chain: usize, stop: Arc<AtomicBool>) -> (ChainSink, ChainLane) {
    let (tx, rx) = channel::<MonitorMsg>();
    (
        ChainSink {
            chain,
            tx,
            stop,
            restarts: 0,
        },
        ChainLane { rx },
    )
}

/// Read end of a [`chain_lane`].
pub struct ChainLane {
    rx: Receiver<MonitorMsg>,
}

impl ChainLane {
    /// Every event flushed so far (non-blocking).  `Done` markers are
    /// skipped — a standalone lane lives exactly as long as its
    /// session, so there is no multi-chain completion protocol here.
    pub fn drain(&self) -> Vec<ChainEvent> {
        let mut out = Vec::new();
        while let Ok(msg) = self.rx.try_recv() {
            if let MonitorMsg::Event(ev) = msg {
                out.push(ev);
            }
        }
        out
    }
}

/// Row-buffering wrapper over a [`ChainSink`] (see
/// [`ChainSink::buffered`]): amortizes the channel send over `cap`
/// recorded rows, and the `Drop` impl flushes whatever is pending, so
/// the monitor always sees every recorded draw.
pub struct BufferedSink {
    sink: ChainSink,
    cap: usize,
    rows: Vec<Vec<f64>>,
    /// Evaluator counters as of the most recent pushed row (flushed
    /// alongside the rows; `None` when the chain doesn't stream stats).
    stats: Option<EvalStats>,
}

impl BufferedSink {
    /// Record one row of watched-parameter values.
    pub fn push(&mut self, row: Vec<f64>) {
        self.rows.push(row);
        if self.rows.len() >= self.cap {
            self.flush();
        }
    }

    /// [`push`](Self::push) carrying the chain evaluator's cumulative
    /// counters as of this row (the last pushed snapshot rides along
    /// with the flush).
    pub fn push_with_stats(&mut self, row: Vec<f64>, stats: EvalStats) {
        self.stats = Some(stats);
        self.push(row);
    }

    /// Whether the driver has asked chains to wind down early (see
    /// [`ChainSink::cancelled`]).
    pub fn cancelled(&self) -> bool {
        self.sink.cancelled()
    }

    /// Send everything buffered so far (also runs on drop).
    pub fn flush(&mut self) {
        self.sink
            .send_with_stats(std::mem::take(&mut self.rows), self.stats.take());
    }
}

impl Drop for BufferedSink {
    fn drop(&mut self) {
        self.flush();
    }
}

/// [`run_chains`] with a streaming event lane: `f(index, rng, sink)`
/// additionally receives a [`ChainSink`] and may stream recorded draws
/// through it at any point; the dispatching thread calls `on_event` for
/// every batch (in arrival order) while it waits for chains to finish.
/// Chain results are returned in chain order exactly as `run_chains`
/// does, and are unaffected by the sink — it carries copies out, never
/// state in.
///
/// `on_event` runs on the *calling* thread, so it can borrow local
/// mutable state (a `ConvergenceMonitor`, a progress printer) without
/// any `Send` bound.
pub fn run_chains_monitored<T, F, E>(
    pool: &Arc<WorkerPool>,
    chains: usize,
    seed: u64,
    f: F,
    mut on_event: E,
) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64, ChainSink) -> T + Send + Sync + 'static,
    E: FnMut(ChainEvent),
{
    run_chains_gated(pool, chains, seed, f, move |ev| {
        on_event(ev);
        true
    })
}

/// [`run_chains_monitored`] with an early-stop gate: `on_event` returns
/// `false` to ask every chain to wind down (e.g. once a convergence
/// snapshot crosses the `--monitor-gate` target).  The driver raises
/// the shared stop flag — observable through [`ChainSink::cancelled`] —
/// and keeps folding events until every chain has actually finished, so
/// the final [`ConvergenceMonitor::finish`] snapshot still sees every
/// recorded draw.  Chains that never check the flag simply run to
/// completion; the gate can only shorten runs, never corrupt them.
///
/// [`ConvergenceMonitor::finish`]: crate::coordinator::monitor::ConvergenceMonitor::finish
pub fn run_chains_gated<T, F, E>(
    pool: &Arc<WorkerPool>,
    chains: usize,
    seed: u64,
    f: F,
    mut on_event: E,
) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64, ChainSink) -> T + Send + Sync + 'static,
    E: FnMut(ChainEvent) -> bool,
{
    if chains == 0 {
        return Ok(Vec::new());
    }
    let f = Arc::new(f);
    let stop = Arc::new(AtomicBool::new(false));
    let (rtx, rrx) = channel::<(usize, T)>();
    let (etx, erx) = channel::<MonitorMsg>();
    for c in 0..chains {
        let f = f.clone();
        let rtx = rtx.clone();
        let etx = etx.clone();
        let stop = stop.clone();
        pool.submit(Box::new(move || {
            let sink = ChainSink {
                chain: c,
                tx: etx.clone(),
                stop,
                restarts: 0,
            };
            let out = f(c, chain_rng(seed, c), sink);
            // result first, then the Done marker: by the time the driver
            // has seen every Done, every result is already in flight
            let _ = rtx.send((c, out));
            let _ = etx.send(MonitorMsg::Done);
        }));
    }
    drop(rtx);
    drop(etx);
    let mut done = 0usize;
    while done < chains {
        match erx.recv() {
            Ok(MonitorMsg::Event(ev)) => {
                if !on_event(ev) {
                    stop.store(true, Ordering::Relaxed);
                }
            }
            Ok(MonitorMsg::Done) => done += 1,
            // all event senders dropped before every chain reported: a
            // chain panicked (its catch_unwind dropped the senders)
            Err(_) => return Err("multichain: a chain worker panicked".into()),
        }
    }
    // per-sender FIFO means no events can trail a chain's own Done, but
    // a clone held by a still-unwinding closure costs nothing to drain
    while let Ok(MonitorMsg::Event(ev)) = erx.try_recv() {
        if !on_event(ev) {
            stop.store(true, Ordering::Relaxed);
        }
    }
    let mut slots: Vec<Option<T>> = (0..chains).map(|_| None).collect();
    for _ in 0..chains {
        match rrx.recv() {
            Ok((c, out)) => slots[c] = Some(out),
            Err(_) => return Err("multichain: a chain worker panicked".into()),
        }
    }
    Ok(slots.into_iter().map(|s| s.expect("chain reported")).collect())
}

/// Knobs for [`run_chains_supervised`].
#[derive(Clone, Debug)]
pub struct SupervisorConfig {
    /// Checkpoint cadence in draws (`0` = never checkpoint).
    pub every: usize,
    /// Checkpoint directory (`None` = no persistence; crashes then
    /// restart the chain from scratch).
    pub dir: Option<std::path::PathBuf>,
    /// Start every chain from its on-disk checkpoint (`--resume`).
    pub resume: bool,
    /// Restarts the supervisor grants each chain before declaring it
    /// permanently failed.
    pub max_restarts: usize,
}

impl Default for SupervisorConfig {
    fn default() -> SupervisorConfig {
        SupervisorConfig {
            every: 0,
            dir: None,
            resume: false,
            max_restarts: 2,
        }
    }
}

/// [`run_chains_gated`] under a per-chain supervisor: each chain
/// closure additionally receives a
/// [`CheckpointCtl`](crate::coordinator::checkpoint::CheckpointCtl)
/// through which it checkpoints every `sup.every` draws and learns
/// where to resume from.  A chain that *panics* is restarted from its
/// last on-disk checkpoint (up to `sup.max_restarts` times, then the
/// whole run errors); because a checkpoint pins the exact trace state
/// and RNG position, the restarted chain reproduces the uninterrupted
/// run's remaining draws bit-for-bit — pinned by `tests/checkpoint.rs`.
///
/// Restart bookkeeping: the chain's [`ChainSink`] folds its restart
/// count into the `chains_restarted` field of every stats snapshot it
/// forwards, and the supervisor emits one draw-less marker event per
/// restart, so `[monitor]` lines surface the recovery even when the
/// chain streams no further stats.  Event delivery across a restart is
/// at-least-once — draws between the last checkpoint and the crash are
/// re-streamed after the restart, so monitor *draw counts* can inflate
/// slightly; chain *results* stay exactly-once and bitwise
/// deterministic.
pub fn run_chains_supervised<T, F, E>(
    pool: &Arc<WorkerPool>,
    chains: usize,
    seed: u64,
    sup: SupervisorConfig,
    f: F,
    mut on_event: E,
) -> Result<Vec<T>, String>
where
    T: Send + 'static,
    F: Fn(usize, Pcg64, ChainSink, &mut CheckpointCtl) -> T + Send + Sync + 'static,
    E: FnMut(ChainEvent) -> bool,
{
    if chains == 0 {
        return Ok(Vec::new());
    }
    let f = Arc::new(f);
    let stop = Arc::new(AtomicBool::new(false));
    let (rtx, rrx) = channel::<(usize, Option<T>)>();
    let (etx, erx) = channel::<MonitorMsg>();
    for c in 0..chains {
        let f = f.clone();
        let rtx = rtx.clone();
        let etx = etx.clone();
        let stop = stop.clone();
        let sup = sup.clone();
        pool.submit(Box::new(move || {
            let mut restarts = 0usize;
            let out = loop {
                // resume from disk on an explicit --resume, and always
                // after a crash (the dead attempt's checkpoints are the
                // whole point)
                let want_resume = sup.resume || restarts > 0;
                let mut ctl = match CheckpointCtl::new(
                    sup.every,
                    sup.dir.as_deref(),
                    seed,
                    c,
                    want_resume,
                ) {
                    Ok(ctl) => ctl,
                    Err(e) => {
                        eprintln!("[supervisor] chain {c}: {e}");
                        break None;
                    }
                };
                let sink = ChainSink {
                    chain: c,
                    tx: etx.clone(),
                    stop: stop.clone(),
                    restarts,
                };
                // the chain owns everything it touches (trace, caches,
                // evaluator are rebuilt per attempt), so resuming after
                // an unwind observes no broken invariants
                match catch_unwind(AssertUnwindSafe(|| f(c, chain_rng(seed, c), sink, &mut ctl))) {
                    Ok(out) => break Some(out),
                    Err(_) => {
                        restarts += 1;
                        if restarts > sup.max_restarts {
                            eprintln!(
                                "[supervisor] chain {c}: giving up after {} restart(s)",
                                sup.max_restarts
                            );
                            break None;
                        }
                        eprintln!(
                            "[supervisor] chain {c} died; restarting from its last \
                             checkpoint (attempt {restarts}/{})",
                            sup.max_restarts
                        );
                        // draw-less marker so the monitor sees the
                        // restart even if no stats-bearing rows follow
                        let _ = etx.send(MonitorMsg::Event(ChainEvent {
                            chain: c,
                            draws: Vec::new(),
                            stats: Some(EvalStats {
                                chains_restarted: restarts,
                                ..EvalStats::default()
                            }),
                        }));
                    }
                }
            };
            let _ = rtx.send((c, out));
            let _ = etx.send(MonitorMsg::Done);
        }));
    }
    drop(rtx);
    drop(etx);
    let mut done = 0usize;
    while done < chains {
        match erx.recv() {
            Ok(MonitorMsg::Event(ev)) => {
                if !on_event(ev) {
                    stop.store(true, Ordering::Relaxed);
                }
            }
            Ok(MonitorMsg::Done) => done += 1,
            Err(_) => return Err("multichain: a supervisor task died".into()),
        }
    }
    while let Ok(MonitorMsg::Event(ev)) = erx.try_recv() {
        if !on_event(ev) {
            stop.store(true, Ordering::Relaxed);
        }
    }
    let mut slots: Vec<Option<Option<T>>> = (0..chains).map(|_| None).collect();
    for _ in 0..chains {
        match rrx.recv() {
            Ok((c, out)) => slots[c] = Some(out),
            Err(_) => return Err("multichain: a supervisor task died".into()),
        }
    }
    let mut out = Vec::with_capacity(chains);
    for (c, slot) in slots.into_iter().enumerate() {
        match slot.expect("supervisor reported") {
            Some(t) => out.push(t),
            None => {
                return Err(format!(
                    "multichain: chain {c} failed permanently (exhausted {} restarts)",
                    sup.max_restarts
                ))
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chains_return_in_index_order_with_disjoint_streams() {
        let pool = WorkerPool::new(3);
        let draws = run_chains(&pool, 8, 7, |c, mut rng| (c, rng.next_u64())).unwrap();
        for (i, &(c, _)) in draws.iter().enumerate() {
            assert_eq!(i, c, "results must come back in chain order");
        }
        // disjoint streams: no two chains share a first draw
        let mut firsts: Vec<u64> = draws.iter().map(|&(_, x)| x).collect();
        firsts.sort_unstable();
        firsts.dedup();
        assert_eq!(firsts.len(), 8);
        // deterministic: a re-run reproduces the draws bit-for-bit
        let again = run_chains(&pool, 8, 7, |c, mut rng| (c, rng.next_u64())).unwrap();
        assert_eq!(draws, again);
    }

    #[test]
    fn chain_panic_surfaces_as_error() {
        let pool = WorkerPool::new(2);
        let r = run_chains(&pool, 3, 1, |c, _| {
            if c == 1 {
                panic!("deliberate chain failure");
            }
            c
        });
        assert!(r.is_err());
    }

    #[test]
    fn monitored_chains_stream_events_and_return_in_order() {
        let pool = WorkerPool::new(3);
        let mut per_chain_rows = vec![0usize; 4];
        let results = run_chains_monitored(
            &pool,
            4,
            23,
            |c, mut rng, sink| {
                let mut last = 0.0;
                for _ in 0..3 {
                    let rows: Vec<Vec<f64>> = (0..5)
                        .map(|_| {
                            last = rng.normal();
                            vec![last]
                        })
                        .collect();
                    sink.send(rows);
                }
                sink.send(Vec::new()); // empty batches are dropped
                (c, last)
            },
            |ev| {
                for row in &ev.draws {
                    assert_eq!(row.len(), 1);
                }
                per_chain_rows[ev.chain] += ev.draws.len();
            },
        )
        .unwrap();
        // every chain's 15 draws arrived, results in chain order
        assert_eq!(per_chain_rows, vec![15; 4]);
        for (i, &(c, _)) in results.iter().enumerate() {
            assert_eq!(i, c);
        }
        // deterministic: the same run reproduces results bit-for-bit
        let again = run_chains_monitored(
            &pool,
            4,
            23,
            |c, mut rng, sink| {
                let mut last = 0.0;
                for _ in 0..15 {
                    last = rng.normal();
                }
                sink.send(vec![vec![last]]);
                (c, last)
            },
            |_| {},
        )
        .unwrap();
        for (a, b) in results.iter().zip(&again) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1.to_bits(), b.1.to_bits());
        }
    }

    #[test]
    fn buffered_sink_flushes_tail_on_drop() {
        let pool = WorkerPool::new(2);
        let mut batches = Vec::new();
        run_chains_monitored(
            &pool,
            1,
            3,
            |_c, _rng, sink| {
                let mut b = sink.buffered(4);
                for i in 0..10 {
                    b.push(vec![i as f64]);
                }
                // drop flushes the trailing partial batch
            },
            |ev| batches.push(ev.draws.len()),
        )
        .unwrap();
        assert_eq!(batches, vec![4, 4, 2], "tail rows lost or re-batched");
    }

    /// A `false` from the gated driver's callback must raise the stop
    /// flag, and chains polling `ChainSink::cancelled` must wind down
    /// well before their nominal length.
    #[test]
    fn gate_stops_chains_early() {
        let pool = WorkerPool::new(2);
        let mut events = 0usize;
        let results = run_chains_gated(
            &pool,
            2,
            11,
            |_c, mut rng, sink| {
                let mut n = 0usize;
                for _ in 0..100_000 {
                    if sink.cancelled() {
                        break;
                    }
                    sink.send(vec![vec![rng.normal()]]);
                    n += 1;
                }
                n
            },
            |_ev| {
                events += 1;
                events < 10 // gate fires on the 10th event
            },
        )
        .unwrap();
        assert!(events >= 10, "gate never evaluated: {events} events");
        assert!(
            results.iter().all(|&n| n < 100_000),
            "gate never stopped a chain: {results:?}"
        );
    }

    #[test]
    fn monitored_chain_panic_surfaces_as_error() {
        let pool = WorkerPool::new(2);
        let mut events = 0usize;
        let r = run_chains_monitored(
            &pool,
            3,
            1,
            |c, _, sink| {
                sink.send(vec![vec![c as f64]]);
                if c == 1 {
                    panic!("deliberate chain failure");
                }
                c
            },
            |_| events += 1,
        );
        assert!(r.is_err());
        assert!(events <= 3, "saw {events} events from 3 chains");
    }

    /// Chains build real traces and run real transitions concurrently;
    /// per-chain results must equal the same chain run inline.
    #[test]
    fn concurrent_traces_match_inline_execution() {
        use crate::infer::{subsampled_mh_transition, PlannedEval, SubsampledConfig};
        use crate::trace::Trace;
        let chain = |_c: usize, mut rng: Pcg64| -> Vec<u64> {
            let mut src = String::from(
                "[assume mu (scope_include 'mu 0 (normal 0 1))]\n\
                 [assume g (lambda () (normal mu 0.5))]\n",
            );
            for i in 0..12 {
                src.push_str(&format!("[observe (g) {}]\n", (i % 4) as f64 * 0.3));
            }
            let mut t = Trace::new();
            t.run_program(&src, &mut rng).unwrap();
            let mu = t.lookup_node("mu").unwrap();
            let cfg = SubsampledConfig::paper_defaults();
            let mut ev = PlannedEval::for_config(&cfg);
            let mut bits = Vec::new();
            for _ in 0..50 {
                subsampled_mh_transition(&mut t, &mut rng, mu, &cfg, &mut ev).unwrap();
                bits.push(t.fresh_value(mu).as_f64().unwrap().to_bits());
            }
            bits
        };
        let pool = WorkerPool::new(4);
        let parallel = run_chains(&pool, 4, 99, chain).unwrap();
        for (c, got) in parallel.iter().enumerate() {
            let want = chain(c, chain_rng(99, c));
            assert_eq!(got, &want, "chain {c} diverged from its inline run");
        }
    }
}
