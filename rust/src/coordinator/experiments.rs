//! Experiment implementations for every table and figure in the paper's
//! evaluation (§4).  Examples and benches are thin wrappers over these,
//! so the regeneration logic lives (and is tested) in one place.
//!
//! | paper artifact | function | regenerates |
//! |---|---|---|
//! | Table 1 | `table1_scaling` | exact-MH per-transition scaling in N |
//! | Fig. 4  | `fig4_risk` | risk of predictive mean vs compute, BayesLR |
//! | Fig. 5  | `fig5_sublinear` | #subsampled sections + time vs N |
//! | Fig. 6  | `fig6_dpm` | JointDPM accuracy vs compute |
//! | Fig. 9  | `fig9_sv` | SV posterior hists + autocorr + ESS/s |

use crate::coordinator::chain::{build_bayes_lr, build_joint_dpm, build_sv};
use crate::coordinator::monitor::{ConvergenceMonitor, DiagSnapshot};
use crate::coordinator::multichain::ChainSink;
use crate::coordinator::report::{histogram, Csv};
use crate::data::{dpm_data, mnist_like, sv_data, synth2d, Dataset};
use crate::infer::{
    gibbs_transition, mh_transition, pgibbs_transition, subsampled_mh_transition,
    LocalEvaluator, PlannedEval, Proposal, SubsampledConfig,
};
use crate::math::Pcg64;
use crate::ppl::ast::{Directive, Expr};
use crate::ppl::value::Value;
use crate::stats::risk::PredictiveAccumulator;
use crate::stats::{ess, jarque_bera, predictive_risk, zero_one_error};
use crate::trace::node::{ArgRef, NodeId};
use crate::trace::pet::Trace;
use std::time::Instant;

// ---------------------------------------------------------------------
// Fig. 5 — sublinearity
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig5Config {
    pub ns: Vec<usize>,
    /// transitions averaged per N
    pub iters: usize,
    pub m: usize,
    pub eps: f64,
    pub sigma: f64,
    pub seed: u64,
}

impl Default for Fig5Config {
    fn default() -> Self {
        Fig5Config {
            ns: vec![1_000, 3_000, 10_000, 30_000, 100_000],
            iters: 100,
            m: 100,
            eps: 0.01,
            sigma: 0.1,
            seed: 7,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub n: usize,
    /// empirical mean sections per subsampled transition
    pub avg_sections: f64,
    /// simulated expectation of the sequential test's stopping size for
    /// a fixed (theta, theta*) (the paper's "theoretical" curve uses
    /// Eq. 19 of Korattikara et al.; we estimate the same expectation by
    /// replaying the test on the realized l_i population)
    pub expected_sections: f64,
    /// mean seconds per subsampled transition
    pub time_sub: f64,
    /// mean seconds per exact (full-scan) transition
    pub time_exact: f64,
}

pub fn fig5_sublinear(cfg: &Fig5Config, evaluator: &mut dyn LocalEvaluator) -> Vec<Fig5Row> {
    let mut rows = Vec::new();
    for &n in &cfg.ns {
        let data = synth2d::generate(n, cfg.seed);
        let mut rng = Pcg64::new(cfg.seed, n as u64);
        let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
        // burn a few exact transitions so theta is in a sensible region
        let warm = SubsampledConfig {
            m: cfg.m,
            eps: cfg.eps,
            proposal: Proposal::Drift(cfg.sigma),
            exact: true,
            threads: 0,
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
        };
        for _ in 0..5 {
            subsampled_mh_transition(&mut trace, &mut rng, w, &warm, evaluator).unwrap();
        }
        let sub = SubsampledConfig {
            exact: false,
            ..warm.clone()
        };
        // empirical average over transitions
        let mut sections = 0usize;
        let t0 = Instant::now();
        for _ in 0..cfg.iters {
            let s = subsampled_mh_transition(&mut trace, &mut rng, w, &sub, evaluator).unwrap();
            sections += s.sections_evaluated;
        }
        let time_sub = t0.elapsed().as_secs_f64() / cfg.iters as f64;
        // exact baseline timing (fewer iters at large N)
        let ex_iters = cfg.iters.min(20).max(3);
        let t0 = Instant::now();
        for _ in 0..ex_iters {
            subsampled_mh_transition(&mut trace, &mut rng, w, &warm, evaluator).unwrap();
        }
        let time_exact = t0.elapsed().as_secs_f64() / ex_iters as f64;
        // expected stopping size at a fixed (theta, theta*): replay the
        // sequential test over the realized l_i population
        let expected = expected_stop_size(&mut trace, w, cfg, &mut rng, evaluator);
        rows.push(Fig5Row {
            n,
            avg_sections: sections as f64 / cfg.iters as f64,
            expected_sections: expected,
            time_sub,
            time_exact,
        });
    }
    rows
}

/// Fixed-proposal expected stopping size: draw one proposal, materialize
/// all l_i, then simulate Alg. 2 many times over fresh u / permutations.
fn expected_stop_size(
    trace: &mut Trace,
    w: NodeId,
    cfg: &Fig5Config,
    rng: &mut Pcg64,
    evaluator: &mut dyn LocalEvaluator,
) -> f64 {
    use crate::infer::seqtest::{SequentialTest, TestState};
    let p = match crate::trace::partition::build_partition(trace, w) {
        Some(p) => p,
        None => return 0.0,
    };
    let current = trace.fresh_value(w);
    let proposal = Proposal::Drift(cfg.sigma);
    let new_v = proposal.propose(&current, rng).unwrap();
    let ls = {
        let mut all = Vec::with_capacity(p.n());
        for chunk in p.locals.chunks(4096) {
            all.extend(
                evaluator
                    .eval_sections(trace, &p, chunk, &new_v)
                    .unwrap(),
            );
        }
        all
    };
    let w_global = crate::infer::subsampled_mh::prior_logpdf(trace, w, &new_v)
        - crate::infer::subsampled_mh::prior_logpdf(trace, w, &current);
    let reps = 60;
    let mut total = 0usize;
    for _ in 0..reps {
        let u: f64 = rng.uniform_pos();
        let mu0 = (u.ln() - w_global) / ls.len() as f64;
        let mut test = SequentialTest::new(mu0, ls.len(), cfg.eps);
        let mut sampler = crate::infer::subsampled_mh::SparseSampler::new(ls.len());
        loop {
            let take = cfg.m.min(sampler.remaining());
            let batch: Vec<f64> = (0..take).map(|_| ls[sampler.next(rng)]).collect();
            if let TestState::Decided(_) = test.update(&batch) {
                break;
            }
        }
        total += test.n();
    }
    total as f64 / reps as f64
}

// ---------------------------------------------------------------------
// Fig. 4 — risk vs compute (BayesLR on the MNIST surrogate)
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig4Config {
    pub n_train: usize,
    pub n_test: usize,
    pub d: usize,
    pub steps: usize,
    pub m: usize,
    pub eps: f64,
    pub sigma: f64,
    pub seed: u64,
    /// record risk every k transitions
    pub record_every: usize,
    /// when set, add a risk-adaptive curve: the controller retunes the
    /// mini-batch per transition toward this per-transition risk bound
    pub target_risk: Option<f64>,
}

impl Default for Fig4Config {
    fn default() -> Self {
        Fig4Config {
            n_train: mnist_like::TRAIN_N,
            n_test: mnist_like::TEST_N,
            d: mnist_like::DIM,
            steps: 400,
            m: 100,
            eps: 0.01,
            sigma: 0.05,
            seed: 11,
            record_every: 10,
            target_risk: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct RiskCurve {
    pub label: String,
    /// (seconds, risk, zero-one error) samples
    pub points: Vec<(f64, f64, f64)>,
    pub transitions: usize,
    pub accepted: usize,
    /// Jarque-Bera safeguard (§3.3) over trial mini-batch means
    pub normality_p: f64,
}

/// Reference predictive for the risk metric: long exact run.
pub fn fig4_reference(
    cfg: &Fig4Config,
    test: &Dataset,
    evaluator: &mut dyn LocalEvaluator,
) -> Vec<f64> {
    let train = mnist_like::sized(cfg.n_train, cfg.d, cfg.seed);
    let mut rng = Pcg64::new(cfg.seed, 1);
    let (mut trace, w) = build_bayes_lr(&train, 0.1, &mut rng);
    let exact = SubsampledConfig {
        m: 1024,
        eps: cfg.eps,
        proposal: Proposal::Drift(cfg.sigma),
        exact: true,
        threads: 0,
        target_risk: None,
        shard_timeout_ms: 0,
        store_verify: None,
    };
    let mut acc = PredictiveAccumulator::new(test.n());
    for i in 0..(cfg.steps * 2) {
        subsampled_mh_transition(&mut trace, &mut rng, w, &exact, evaluator).unwrap();
        if i >= cfg.steps / 2 {
            let wv = trace.fresh_value(w);
            let probs = predict_probs(test, wv.as_vector().unwrap());
            acc.push(&probs);
        }
    }
    acc.mean()
}

/// Scalar predictive probabilities (pure Rust; the XLA predict path is
/// exercised separately by FusedEval::predict).
pub fn predict_probs(test: &Dataset, w: &[f64]) -> Vec<f64> {
    test.x
        .iter()
        .map(|x| {
            let z: f64 = x.iter().zip(w).map(|(a, b)| a * b).sum();
            1.0 / (1.0 + (-z).exp())
        })
        .collect()
}

/// One risk-vs-time curve for a method.
pub fn fig4_curve(
    cfg: &Fig4Config,
    label: &str,
    exact: bool,
    eps: f64,
    target_risk: Option<f64>,
    reference: &[f64],
    test: &Dataset,
    evaluator: &mut dyn LocalEvaluator,
) -> RiskCurve {
    let train = mnist_like::sized(cfg.n_train, cfg.d, cfg.seed);
    let mut rng = Pcg64::new(cfg.seed, 2);
    let (mut trace, w) = build_bayes_lr(&train, 0.1, &mut rng);
    let kcfg = SubsampledConfig {
        m: cfg.m,
        eps,
        proposal: Proposal::Drift(cfg.sigma),
        exact,
        threads: 0,
        target_risk,
        shard_timeout_ms: 0,
        store_verify: None,
    };
    let mut acc = PredictiveAccumulator::new(test.n());
    let mut points = Vec::new();
    let mut accepted = 0usize;
    let mut batch_means = Vec::new();
    let t0 = Instant::now();
    for i in 0..cfg.steps {
        let s = subsampled_mh_transition(&mut trace, &mut rng, w, &kcfg, evaluator).unwrap();
        if s.accepted {
            accepted += 1;
        }
        let wv = trace.fresh_value(w);
        let probs = predict_probs(test, wv.as_vector().unwrap());
        acc.push(&probs);
        if (i + 1) % cfg.record_every == 0 {
            let mean = acc.mean();
            points.push((
                t0.elapsed().as_secs_f64(),
                predictive_risk(&mean, reference),
                zero_one_error(&mean, &test.y),
            ));
        }
        // §3.3 safeguard material: mini-batch means of l_i under a fresh
        // proposal (collected sparsely)
        if i % 20 == 0 {
            if let Some(p) = crate::trace::partition::build_partition(&trace, w) {
                let cur = trace.fresh_value(w);
                if let Some(nv) = kcfg.proposal.propose(&cur, &mut rng) {
                    let mut roots = Vec::with_capacity(cfg.m);
                    for _ in 0..cfg.m.min(p.n()) {
                        roots.push(p.locals[rng.below(p.n())]);
                    }
                    if let Ok(ls) = evaluator.eval_sections(&mut trace, &p, &roots, &nv) {
                        batch_means.push(ls.iter().sum::<f64>() / ls.len() as f64);
                    }
                }
            }
        }
    }
    let normality_p = if batch_means.len() >= 8 {
        jarque_bera(&batch_means).p_value
    } else {
        f64::NAN
    };
    RiskCurve {
        label: label.to_string(),
        points,
        transitions: cfg.steps,
        accepted,
        normality_p,
    }
}

/// The full Fig. 4 experiment: exact baseline + subsampled curves.
pub fn fig4_risk(cfg: &Fig4Config, evaluator: &mut dyn LocalEvaluator) -> Vec<RiskCurve> {
    let test = mnist_like::sized(cfg.n_test, cfg.d, cfg.seed + 1);
    let reference = fig4_reference(cfg, &test, evaluator);
    let mut curves = Vec::new();
    curves.push(fig4_curve(
        cfg, "exact-mh", true, cfg.eps, None, &reference, &test, evaluator,
    ));
    for &eps in &[0.01, 0.1, 0.5] {
        curves.push(fig4_curve(
            cfg,
            &format!("subsampled-eps{eps}"),
            false,
            eps,
            None,
            &reference,
            &test,
            evaluator,
        ));
    }
    // risk-adaptive variant: the controller retunes the mini-batch each
    // transition so the realized per-transition risk stays under the bound
    if let Some(tr) = cfg.target_risk {
        curves.push(fig4_curve(
            cfg,
            &format!("subsampled-risk{tr}"),
            false,
            tr,
            Some(tr),
            &reference,
            &test,
            evaluator,
        ));
    }
    curves
}

// ---------------------------------------------------------------------
// Fig. 6 — JointDPM
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig6Config {
    pub n_train: usize,
    pub n_test: usize,
    pub sweeps: usize,
    pub m: usize,
    pub eps: f64,
    pub sigma: f64,
    pub step_z: usize,
    pub seed: u64,
}

impl Default for Fig6Config {
    fn default() -> Self {
        Fig6Config {
            n_train: 1000,
            n_test: 500,
            sweeps: 30,
            m: 100,
            eps: 0.3,
            sigma: 0.2,
            step_z: 50,
            seed: 13,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Fig6Point {
    pub seconds: f64,
    pub accuracy: f64,
    pub clusters: usize,
}

/// Run the JointDPM inference program (Fig. 7 top) and track test
/// accuracy vs time.  `eps = 0` means exact MH over weights.
pub fn fig6_dpm(cfg: &Fig6Config, subsampled: bool) -> Vec<Fig6Point> {
    let (train, _) = dpm_data::generate(cfg.n_train, cfg.seed);
    let (test, _) = dpm_data::generate(cfg.n_test, cfg.seed + 1);
    let mut rng = Pcg64::new(cfg.seed, 3);
    let mut trace = build_joint_dpm(&train, &mut rng);
    let kcfg = SubsampledConfig {
        m: cfg.m,
        eps: cfg.eps,
        proposal: Proposal::Drift(cfg.sigma),
        exact: !subsampled,
        threads: 0,
        target_risk: None,
        shard_timeout_ms: 0,
        store_verify: None,
    };
    let mut ev = PlannedEval::for_config(&kcfg);
    let alpha = trace.lookup_node("alpha").unwrap();
    let mut points = Vec::new();
    let t0 = Instant::now();
    for _ in 0..cfg.sweeps {
        // (mh alpha all 1)
        mh_transition(&mut trace, &mut rng, alpha, &Proposal::Drift(0.3)).unwrap();
        // (gibbs z one step_z)
        let zs = trace.scope_nodes("z");
        for _ in 0..cfg.step_z {
            let z = zs[rng.below(zs.len())];
            gibbs_transition(&mut trace, &mut rng, z).unwrap();
        }
        // (subsampled_mh w one ...) — one randomly chosen expert
        let ws = trace.scope_nodes("w");
        if !ws.is_empty() {
            let wk = ws[rng.below(ws.len())];
            subsampled_mh_transition(&mut trace, &mut rng, wk, &kcfg, &mut ev).unwrap();
        }
        let acc = dpm_accuracy(&mut trace, &train, &test);
        points.push(Fig6Point {
            seconds: t0.elapsed().as_secs_f64(),
            accuracy: acc,
            clusters: live_cluster_count(&trace),
        });
    }
    points
}

fn live_cluster_count(trace: &Trace) -> usize {
    trace
        .scope("w")
        .map(|s| s.live_blocks().len())
        .unwrap_or(0)
}

/// Classify test points with the current trace state: assign each test
/// point to the max-predictive cluster (NIW feature model x CRP prior),
/// then apply that cluster's expert.
pub fn dpm_accuracy(trace: &mut Trace, train: &Dataset, test: &Dataset) -> f64 {
    let _ = train;
    // collect live clusters: (table, w vector, niw sp)
    let crp_sp = match trace.lookup_value("crp") {
        Some(Value::Sp(id)) => id,
        _ => return f64::NAN,
    };
    let aux = trace.sp(crp_sp).crp_aux().unwrap().clone();
    let alpha = trace.lookup_value("alpha").unwrap().as_f64().unwrap();
    let mut clusters: Vec<(i64, Vec<f64>, crate::ppl::value::SpId)> = Vec::new();
    for table in aux.tables() {
        // (w table) / (c table) through the mem caches
        let w_val = mem_cache_value(trace, "w", table);
        let c_sp = mem_cache_sp(trace, "c", table);
        if let (Some(wv), Some(sp)) = (w_val, c_sp) {
            clusters.push((table, wv, sp));
        }
    }
    if clusters.is_empty() {
        return f64::NAN;
    }
    let mut correct = 0usize;
    for (x, &y) in test.x.iter().zip(&test.y) {
        let mut best = (f64::NEG_INFINITY, 0usize);
        for (ci, (table, _, c_sp)) in clusters.iter().enumerate() {
            let lp = aux.predictive_logp(*table, alpha)
                + trace.sp(*c_sp).logpdf(&Value::Vector(x.clone().into()), &[]);
            if lp > best.0 {
                best = (lp, ci);
            }
        }
        let w = &clusters[best.1].1;
        let z: f64 = x.iter().zip(w).map(|(a, b)| a * b).sum();
        if (z > 0.0) == y {
            correct += 1;
        }
    }
    correct as f64 / test.n() as f64
}

fn mem_cache_value(trace: &mut Trace, name: &str, key: i64) -> Option<Vec<f64>> {
    let mem = match trace.lookup_value(name)? {
        Value::Mem(id) => id,
        _ => return None,
    };
    let entry = trace
        .mem(mem)
        .cache
        .get(&crate::ppl::value::KeyVec(vec![Value::Int(key)]))?;
    let target = entry.target.clone();
    let v = trace.result_value(&target);
    v.as_vector().map(|r| r.as_ref().clone())
}

fn mem_cache_sp(trace: &mut Trace, name: &str, key: i64) -> Option<crate::ppl::value::SpId> {
    let mem = match trace.lookup_value(name)? {
        Value::Mem(id) => id,
        _ => return None,
    };
    let entry = trace
        .mem(mem)
        .cache
        .get(&crate::ppl::value::KeyVec(vec![Value::Int(key)]))?;
    match trace.result_value(&entry.target) {
        Value::Sp(id) => Some(id),
        _ => None,
    }
}

// ---------------------------------------------------------------------
// Fig. 9 — stochastic volatility
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig9Config {
    pub series: usize,
    pub len: usize,
    pub sweeps: usize,
    pub particles: usize,
    pub m: usize,
    pub eps: f64,
    pub seed: u64,
    /// latent-state sweeps per parameter sweep (paper: 10x)
    pub h_per_param: usize,
    /// when set, the subsampled parameter moves run under risk-adaptive
    /// mini-batch control instead of a fixed m/eps schedule
    pub target_risk: Option<f64>,
}

impl Default for Fig9Config {
    fn default() -> Self {
        Fig9Config {
            series: 200,
            len: 5,
            sweeps: 300,
            particles: 10,
            m: 100,
            eps: 1e-3,
            seed: 17,
            h_per_param: 2,
            target_risk: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Fig9Result {
    pub label: String,
    pub phi_samples: Vec<f64>,
    pub sig_samples: Vec<f64>,
    pub seconds: f64,
    pub phi_ess_per_sec: f64,
    pub sig_ess_per_sec: f64,
}

pub fn fig9_sv(cfg: &Fig9Config, subsampled: bool) -> Fig9Result {
    fig9_sv_monitored(cfg, subsampled, None)
}

/// [`fig9_sv`] with an optional [`ChainSink`]: when monitored, every
/// sweep's (phi, sigma) draw is streamed to the convergence monitor in
/// small batches.  The sink is write-only, so the monitored run's
/// samples are bitwise identical to the unmonitored run's.
pub fn fig9_sv_monitored(
    cfg: &Fig9Config,
    subsampled: bool,
    sink: Option<&ChainSink>,
) -> Fig9Result {
    let data_cfg = sv_data::SvConfig {
        series: cfg.series,
        len: cfg.len,
        ..Default::default()
    };
    let series = sv_data::generate(&data_cfg, cfg.seed);
    let mut rng = Pcg64::new(cfg.seed, 4);
    let (mut trace, phi, sig2) = build_sv(&series, &mut rng);
    let kcfg = SubsampledConfig {
        m: cfg.m,
        eps: cfg.eps,
        proposal: Proposal::Drift(0.02),
        exact: !subsampled,
        threads: 0,
        target_risk: if subsampled { cfg.target_risk } else { None },
        shard_timeout_ms: 0,
        store_verify: None,
    };
    let mut ev = PlannedEval::for_config(&kcfg);
    let mut phi_samples = Vec::with_capacity(cfg.sweeps);
    let mut sig_samples = Vec::with_capacity(cfg.sweeps);
    // 16 rows per channel send; BufferedSink flushes the tail on drop
    let mut buf = sink.map(|s| s.clone().buffered(16));
    let t0 = Instant::now();
    let blocks: Vec<Value> = (1..=cfg.len as i64).map(Value::Int).collect();
    for _ in 0..cfg.sweeps {
        // a fired monitor gate asks chains to wind down at the next
        // sweep boundary (best-effort; see ChainSink::cancelled)
        if buf.as_ref().is_some_and(|b| b.cancelled()) {
            break;
        }
        // particle gibbs over a few random series' state chains
        for _ in 0..cfg.h_per_param {
            let s = rng.below(cfg.series);
            pgibbs_transition(
                &mut trace,
                &mut rng,
                &format!("h{s}"),
                &blocks,
                cfg.particles,
            )
            .unwrap();
        }
        // (subsampled_mh sig2 ...) (subsampled_mh phi ...)
        subsampled_mh_transition(&mut trace, &mut rng, sig2, &kcfg, &mut ev).unwrap();
        subsampled_mh_transition(&mut trace, &mut rng, phi, &kcfg, &mut ev).unwrap();
        let phi_v = trace.fresh_value(phi).as_f64().unwrap();
        let sig_v = trace.fresh_value(sig2).as_f64().unwrap().sqrt();
        phi_samples.push(phi_v);
        sig_samples.push(sig_v);
        if let Some(b) = buf.as_mut() {
            // draws + the evaluator's cumulative tier counters, so the
            // monitor can stream per-interval EvalStats diffs
            b.push_with_stats(vec![phi_v, sig_v], ev.stats());
        }
    }
    drop(buf); // flush the tail before the result is reported
    let seconds = t0.elapsed().as_secs_f64();
    Fig9Result {
        label: if !subsampled {
            "exact-mh".into()
        } else if let Some(tr) = cfg.target_risk {
            format!("subsampled-risk{tr}")
        } else {
            format!("subsampled-eps{}", cfg.eps)
        },
        phi_ess_per_sec: ess(&phi_samples) / seconds,
        sig_ess_per_sec: ess(&sig_samples) / seconds,
        phi_samples,
        sig_samples,
        seconds,
    }
}

/// Repeated-trial Fig. 9: `trials` independent replicas, run
/// concurrently on the shared worker pool (one `Trace` per worker task,
/// per-trial seeds) — the multi-chain driver's experiment entry point.
/// Results come back in trial order and are deterministic for a fixed
/// seed regardless of worker scheduling, because every trial derives
/// its RNG streams from its own seed.
pub fn fig9_repeated(
    cfg: &Fig9Config,
    subsampled: bool,
    trials: usize,
) -> Result<Vec<Fig9Result>, String> {
    fig9_repeated_monitored(cfg, subsampled, trials, 0, None).map(|(rs, _)| rs)
}

/// [`fig9_repeated`] with streaming convergence diagnostics: when
/// `monitor_every > 0`, every trial streams its per-sweep (phi, sigma)
/// draws over the ChainEvent lane, and the returned snapshots record
/// split-R̂ / rank-R̂ / ESS across trials at every `monitor_every`-sweep
/// boundary (plus the end-of-run snapshot).  Snapshot contents are
/// deterministic in the seed — the monitor folds chains by index over
/// fixed prefixes — and trial results are bitwise identical to the
/// unmonitored run's.
/// `monitor_gate`: when `Some(r)` and monitoring is on, the run stops
/// early — via the gated multichain driver's shared stop flag, observed
/// at each trial's sweep boundary — once a snapshot reports every
/// watched parameter's rank-normalized R̂ finite and below `r`.  The
/// final [`ConvergenceMonitor::finish`] snapshot is still folded and
/// emitted over everything the chains recorded before stopping.
pub fn fig9_repeated_monitored(
    cfg: &Fig9Config,
    subsampled: bool,
    trials: usize,
    monitor_every: usize,
    monitor_gate: Option<f64>,
) -> Result<(Vec<Fig9Result>, Vec<DiagSnapshot>), String> {
    let base = cfg.clone();
    let chain = move |c: usize, sink: Option<ChainSink>| -> Fig9Result {
        // fig9_sv derives all of its randomness from cfg.seed, so each
        // trial just gets a distinct seed
        let mut cfg = base.clone();
        cfg.seed = base.seed.wrapping_add(1 + c as u64);
        fig9_sv_monitored(&cfg, subsampled, sink.as_ref())
    };
    if monitor_every == 0 {
        let rs = crate::coordinator::multichain::run_chains_global(
            trials,
            cfg.seed,
            move |c, _rng| chain(c, None),
        )?;
        return Ok((rs, Vec::new()));
    }
    let params = vec!["phi".to_string(), "sigma".to_string()];
    let mut mon = ConvergenceMonitor::new(trials, &params, monitor_every);
    let mut snaps = Vec::new();
    let rs = crate::coordinator::multichain::run_chains_gated(
        crate::runtime::pool::WorkerPool::global(),
        trials,
        cfg.seed,
        move |c, _rng, sink| chain(c, Some(sink)),
        |ev| {
            mon.absorb(ev);
            let mut keep_going = true;
            for s in mon.ready_snapshots() {
                if monitor_gate.is_some_and(|r| s.gate_passed(r)) {
                    keep_going = false;
                }
                snaps.push(s);
            }
            keep_going
        },
    )?;
    // end-of-run snapshot when the run didn't end exactly on a boundary
    snaps.extend(mon.finish());
    Ok((rs, snaps))
}

// ---------------------------------------------------------------------
// Fig. 9 (streaming) — windowed SV over a live tick stream
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Fig9StreamingConfig {
    pub series: usize,
    /// Ticks (time points per series) live at any moment.
    pub window: usize,
    /// Streaming steps: each appends one tick across all series and
    /// retires the oldest.
    pub ticks: usize,
    /// Parameter sweeps between consecutive ticks.
    pub sweeps_per_tick: usize,
    pub particles: usize,
    pub m: usize,
    pub eps: f64,
    pub seed: u64,
    pub target_risk: Option<f64>,
}

impl Default for Fig9StreamingConfig {
    fn default() -> Self {
        Fig9StreamingConfig {
            series: 50,
            window: 8,
            ticks: 6,
            sweeps_per_tick: 20,
            particles: 10,
            m: 100,
            eps: 1e-3,
            seed: 17,
            target_risk: None,
        }
    }
}

/// One streaming step's accounting.
#[derive(Clone, Debug)]
pub struct Fig9StreamingRow {
    pub tick: usize,
    /// Observations appended / retired this tick (= `series` each).
    pub appended: usize,
    pub retired: usize,
    pub append_seconds: f64,
    pub retire_seconds: f64,
    pub sweep_seconds: f64,
    /// Posterior means over this tick's sweeps.
    pub phi_mean: f64,
    pub sig_mean: f64,
    /// Live observations after the tick (stays at `series * window`).
    pub live_obs: usize,
}

/// The windowed SV trace for the streaming experiment: same model as
/// [`build_sv`], but observations land **tick-major** (time outer,
/// series inner) so [`Trace::retire_observations`] retires whole ticks
/// — the k oldest observe records are exactly the oldest tick across
/// every series.
fn build_sv_streaming(
    series: &[sv_data::SvSeries],
    window: usize,
    rng: &mut Pcg64,
) -> (Trace, NodeId, NodeId) {
    let mut trace = Trace::new();
    let header = "[assume sig2 (scope_include 'sig2 0 (inv_gamma 5 0.05))]\n\
         [assume sig (sqrt sig2)]\n\
         [assume phi (scope_include 'phi 0 (beta 5 1))]"
        .to_string();
    trace.run_program(&header, rng).unwrap();
    for s in 0..series.len() {
        let prog = format!(
            "[assume h{s} (mem (lambda (t) (scope_include 'h{s} t \
               (if (<= t 0) 0.0 (normal (* phi (h{s} (- t 1))) sig)))))]\n\
             [assume x{s} (lambda (t) (normal 0 (exp (/ (h{s} t) 2))))]"
        );
        trace.run_program(&prog, rng).unwrap();
    }
    for t in 0..window {
        for (s, sv) in series.iter().enumerate() {
            trace.execute(&sv_observe(s, t, sv.x[t]), rng).unwrap();
        }
    }
    let phi = trace.lookup_node("phi").unwrap();
    let sig2 = trace.lookup_node("sig2").unwrap();
    (trace, phi, sig2)
}

/// The observe directive for series `s` at (0-based) time `t` — the
/// same construction for the initial build and every streamed append,
/// so append-vs-fresh comparisons execute identical directives.
fn sv_observe(s: usize, t: usize, xv: f64) -> Directive {
    Directive::Observe(
        Expr::app(vec![
            Expr::sym(&format!("x{s}")),
            Expr::constant(Value::Int((t + 1) as i64)),
        ]),
        Value::Real(xv),
    )
}

/// Streaming SV: "ticks in, posterior out" over a sliding window.
/// Every tick appends one new observation per series through the
/// O(|append|) fast path ([`Trace::append_directive`]: plans, batch
/// groups and column-store panels for existing data stay cached), then
/// retires the oldest tick in one batched structural change
/// ([`Trace::retire_observations`]), then sweeps the parameters.
/// Latent volatility states of retired ticks stay alive — successor
/// states reference them through the mem route — so the state chains
/// keep their full history while the observation window slides.
pub fn fig9_streaming(cfg: &Fig9StreamingConfig) -> Vec<Fig9StreamingRow> {
    let data_cfg = sv_data::SvConfig {
        series: cfg.series,
        len: cfg.window + cfg.ticks,
        ..Default::default()
    };
    let series = sv_data::generate(&data_cfg, cfg.seed);
    let mut rng = Pcg64::new(cfg.seed, 4);
    let (mut trace, phi, sig2) = build_sv_streaming(&series, cfg.window, &mut rng);
    let kcfg = SubsampledConfig {
        m: cfg.m,
        eps: cfg.eps,
        proposal: Proposal::Drift(0.02),
        exact: false,
        threads: 0,
        target_risk: cfg.target_risk,
        shard_timeout_ms: 0,
        store_verify: None,
    };
    let mut ev = PlannedEval::for_config(&kcfg);
    let mut rows = Vec::with_capacity(cfg.ticks);
    for tick in 0..cfg.ticks {
        let t_new = cfg.window + tick;
        let t0 = Instant::now();
        for (s, sv) in series.iter().enumerate() {
            trace
                .append_directive(&sv_observe(s, t_new, sv.x[t_new]), &mut rng)
                .unwrap();
        }
        let append_seconds = t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let retired = trace.retire_observations(cfg.series).unwrap();
        let retire_seconds = t0.elapsed().as_secs_f64();
        let blocks: Vec<Value> = (1..=(t_new + 1) as i64).map(Value::Int).collect();
        let mut phi_sum = 0.0;
        let mut sig_sum = 0.0;
        let t0 = Instant::now();
        for _ in 0..cfg.sweeps_per_tick {
            let s = rng.below(cfg.series);
            pgibbs_transition(&mut trace, &mut rng, &format!("h{s}"), &blocks, cfg.particles)
                .unwrap();
            subsampled_mh_transition(&mut trace, &mut rng, sig2, &kcfg, &mut ev).unwrap();
            subsampled_mh_transition(&mut trace, &mut rng, phi, &kcfg, &mut ev).unwrap();
            phi_sum += trace.fresh_value(phi).as_f64().unwrap();
            sig_sum += trace.fresh_value(sig2).as_f64().unwrap().sqrt();
        }
        let sweep_seconds = t0.elapsed().as_secs_f64();
        rows.push(Fig9StreamingRow {
            tick,
            appended: cfg.series,
            retired,
            append_seconds,
            retire_seconds,
            sweep_seconds,
            phi_mean: phi_sum / cfg.sweeps_per_tick.max(1) as f64,
            sig_mean: sig_sum / cfg.sweeps_per_tick.max(1) as f64,
            live_obs: trace.observations().len(),
        });
    }
    rows
}

/// CSV of the streaming rows (`fig9_streaming.csv`).
pub fn fig9_streaming_csv(rows: &[Fig9StreamingRow]) -> Csv {
    let mut csv = Csv::new(&[
        "tick",
        "appended",
        "retired",
        "append_seconds",
        "retire_seconds",
        "sweep_seconds",
        "phi_mean",
        "sig_mean",
        "live_obs",
    ]);
    for r in rows {
        csv.row(&[
            r.tick.to_string(),
            r.appended.to_string(),
            r.retired.to_string(),
            format!("{:.6}", r.append_seconds),
            format!("{:.6}", r.retire_seconds),
            format!("{:.6}", r.sweep_seconds),
            format!("{:.5}", r.phi_mean),
            format!("{:.5}", r.sig_mean),
            r.live_obs.to_string(),
        ]);
    }
    csv
}

// ---------------------------------------------------------------------
// Table 1 — scaling overview
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
pub struct Table1Row {
    pub model: String,
    pub n_small: usize,
    pub n_large: usize,
    pub t_small: f64,
    pub t_large: f64,
    /// measured exponent log(t_large/t_small)/log(n_large/n_small)
    pub exponent: f64,
}

/// Verify Table 1: exact-MH transition time scales ~linearly in the
/// scaling parameter (N / N_k / T) for all three models.
pub fn table1_scaling(seed: u64) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    let mut ev = PlannedEval::auto();
    // BayesLR: scaling N
    {
        let mut time_at = |n: usize| {
            let data = synth2d::generate(n, seed);
            let mut rng = Pcg64::new(seed, n as u64);
            let (mut trace, w) = build_bayes_lr(&data, 0.1, &mut rng);
            let cfg = SubsampledConfig {
                m: 1024,
                eps: 0.01,
                proposal: Proposal::Drift(0.1),
                exact: true,
                threads: 0,
                target_risk: None,
                shard_timeout_ms: 0,
                store_verify: None,
            };
            let iters = 10;
            let t0 = Instant::now();
            for _ in 0..iters {
                subsampled_mh_transition(&mut trace, &mut rng, w, &cfg, &mut ev).unwrap();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let (n0, n1) = (2_000, 20_000);
        let (t0v, t1v) = (time_at(n0), time_at(n1));
        rows.push(Table1Row {
            model: "BayesLR (N)".into(),
            n_small: n0,
            n_large: n1,
            t_small: t0v,
            t_large: t1v,
            exponent: (t1v / t0v).ln() / (n1 as f64 / n0 as f64).ln(),
        });
    }
    // SV: scaling T (series length)
    {
        let mut time_at = |len: usize| {
            let cfg = sv_data::SvConfig {
                series: 1,
                len,
                ..Default::default()
            };
            let series = sv_data::generate(&cfg, seed);
            let mut rng = Pcg64::new(seed, len as u64);
            let (mut trace, phi, _) = build_sv(&series, &mut rng);
            let kcfg = SubsampledConfig {
                m: 1024,
                eps: 0.01,
                proposal: Proposal::Drift(0.02),
                exact: true,
                threads: 0,
                target_risk: None,
                shard_timeout_ms: 0,
                store_verify: None,
            };
            let iters = 10;
            let t0 = Instant::now();
            for _ in 0..iters {
                subsampled_mh_transition(&mut trace, &mut rng, phi, &kcfg, &mut ev).unwrap();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let (n0, n1) = (200, 2_000);
        let (t0v, t1v) = (time_at(n0), time_at(n1));
        rows.push(Table1Row {
            model: "SV (T)".into(),
            n_small: n0,
            n_large: n1,
            t_small: t0v,
            t_large: t1v,
            exponent: (t1v / t0v).ln() / (n1 as f64 / n0 as f64).ln(),
        });
    }
    // JointDPM: scaling N_k — a single-cluster dataset makes N_k = N
    {
        let mut time_at = |n: usize| {
            let data = Dataset {
                x: (0..n).map(|i| vec![(i % 7) as f64 * 0.1, 0.5]).collect(),
                y: (0..n).map(|i| i % 2 == 0).collect(),
            };
            let mut rng = Pcg64::new(seed, n as u64);
            let mut trace = build_joint_dpm(&data, &mut rng);
            // force all points into one cluster via gibbs? too slow;
            // instead sample whichever expert exists
            let ws = trace.scope_nodes("w");
            let wk = ws[0];
            let kcfg = SubsampledConfig {
                m: 1024,
                eps: 0.01,
                proposal: Proposal::Drift(0.1),
                exact: true,
                threads: 0,
                target_risk: None,
                shard_timeout_ms: 0,
                store_verify: None,
            };
            let iters = 5;
            let t0 = Instant::now();
            for _ in 0..iters {
                subsampled_mh_transition(&mut trace, &mut rng, wk, &kcfg, &mut ev).unwrap();
            }
            t0.elapsed().as_secs_f64() / iters as f64
        };
        let (n0, n1) = (500, 5_000);
        let (t0v, t1v) = (time_at(n0), time_at(n1));
        rows.push(Table1Row {
            model: "JointDPM (N_k)".into(),
            n_small: n0,
            n_large: n1,
            t_small: t0v,
            t_large: t1v,
            exponent: (t1v / t0v).ln() / (n1 as f64 / n0 as f64).ln(),
        });
    }
    rows
}

// ---------------------------------------------------------------------
// CSV emission helpers (each figure's series)
// ---------------------------------------------------------------------

pub fn fig5_csv(rows: &[Fig5Row]) -> Csv {
    let mut csv = Csv::new(&[
        "n",
        "avg_sections",
        "expected_sections",
        "time_subsampled_s",
        "time_exact_s",
    ]);
    for r in rows {
        csv.row_f(&[
            r.n as f64,
            r.avg_sections,
            r.expected_sections,
            r.time_sub,
            r.time_exact,
        ]);
    }
    csv
}

pub fn fig4_csv(curves: &[RiskCurve]) -> Csv {
    let mut csv = Csv::new(&["label", "seconds", "risk", "zero_one_error"]);
    for c in curves {
        for (s, r, e) in &c.points {
            csv.row(&[c.label.clone(), s.to_string(), r.to_string(), e.to_string()]);
        }
    }
    csv
}

pub fn fig9_csv(results: &[Fig9Result], bins: usize) -> (Csv, Csv) {
    let mut hist = Csv::new(&["label", "param", "bin_center", "count"]);
    for r in results {
        for (c, n) in histogram(&r.phi_samples, 0.5, 1.05, bins) {
            hist.row(&[r.label.clone(), "phi".into(), c.to_string(), n.to_string()]);
        }
        for (c, n) in histogram(&r.sig_samples, 0.0, 0.4, bins) {
            hist.row(&[r.label.clone(), "sigma".into(), c.to_string(), n.to_string()]);
        }
    }
    let mut acf = Csv::new(&["label", "param", "lag", "autocorr"]);
    for r in results {
        for (k, a) in crate::stats::autocorrelation(&r.phi_samples, 40)
            .iter()
            .enumerate()
        {
            acf.row(&[r.label.clone(), "phi".into(), k.to_string(), a.to_string()]);
        }
        for (k, a) in crate::stats::autocorrelation(&r.sig_samples, 40)
            .iter()
            .enumerate()
        {
            acf.row(&[r.label.clone(), "sigma".into(), k.to_string(), a.to_string()]);
        }
    }
    (hist, acf)
}

// used by the quickstart example to show the PET (Fig. 1 / Fig. 2a)
pub fn describe_pet(trace: &Trace) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    for i in 0..trace.nodes.len() {
        let id = NodeId(i as u32);
        if !trace.nodes[i].alive {
            continue;
        }
        let n = trace.node(id);
        let kind = match &n.kind {
            crate::trace::node::NodeKind::Det(p) => format!("det:{p:?}"),
            crate::trace::node::NodeKind::StochFam(f) => format!("stoch:{f:?}"),
            crate::trace::node::NodeKind::StochDyn { .. } => "stoch:instance".into(),
            crate::trace::node::NodeKind::StochInst { .. } => "stoch:instance".into(),
            crate::trace::node::NodeKind::Maker { family, .. } => format!("maker:{family:?}"),
            crate::trace::node::NodeKind::MemApp { .. } => "memapp".into(),
            crate::trace::node::NodeKind::If { .. } => "if".into(),
            crate::trace::node::NodeKind::Inner { .. } => "inner".into(),
        };
        let parents: Vec<u32> = n.dyn_parents().iter().map(|p| p.0).collect();
        let args: Vec<String> = n
            .args
            .iter()
            .map(|a| match a {
                ArgRef::Const(v) => format!("{v}"),
                ArgRef::Node(p) => format!("#{}", p.0),
            })
            .collect();
        let _ = writeln!(
            out,
            "#{:<3} {:<18} value={:<22} args=[{}] parents={:?}{}",
            id.0,
            kind,
            format!("{}", n.value),
            args.join(", "),
            parents,
            if n.observed { "  [observed]" } else { "" }
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_smoke() {
        let cfg = Fig5Config {
            ns: vec![500, 2000],
            iters: 10,
            ..Default::default()
        };
        let mut ev = PlannedEval::new();
        let rows = fig5_sublinear(&cfg, &mut ev);
        assert_eq!(rows.len(), 2);
        // subsampled evaluates fewer sections than N at the larger size
        assert!(rows[1].avg_sections < 2000.0);
        assert!(rows[1].expected_sections > 0.0);
    }

    #[test]
    fn fig6_smoke() {
        let cfg = Fig6Config {
            n_train: 120,
            n_test: 60,
            sweeps: 3,
            step_z: 10,
            ..Default::default()
        };
        let pts = fig6_dpm(&cfg, true);
        assert_eq!(pts.len(), 3);
        for p in &pts {
            assert!(p.accuracy.is_nan() || (0.0..=1.0).contains(&p.accuracy));
            assert!(p.clusters >= 1);
        }
    }

    #[test]
    fn fig9_smoke() {
        let cfg = Fig9Config {
            series: 5,
            len: 4,
            sweeps: 10,
            particles: 5,
            h_per_param: 1,
            ..Default::default()
        };
        let r = fig9_sv(&cfg, true);
        assert_eq!(r.phi_samples.len(), 10);
        assert!(r.phi_samples.iter().all(|p| (0.0..=1.0).contains(p)));
        assert!(r.sig_samples.iter().all(|s| *s > 0.0));
    }

    #[test]
    fn fig9_repeated_monitored_smoke() {
        let cfg = Fig9Config {
            series: 3,
            len: 3,
            sweeps: 12,
            particles: 4,
            h_per_param: 1,
            ..Default::default()
        };
        let (rs, snaps) = fig9_repeated_monitored(&cfg, true, 2, 5, None).unwrap();
        assert_eq!(rs.len(), 2);
        // boundaries at 5 and 10 sweeps, plus the end-of-run snapshot
        assert_eq!(
            snaps.iter().map(|s| s.draws_per_chain).collect::<Vec<_>>(),
            vec![5, 10, 12]
        );
        for s in &snaps {
            assert_eq!(s.chains, 2);
            let names: Vec<&str> = s.params.iter().map(|p| p.name.as_str()).collect();
            assert_eq!(names, vec!["phi", "sigma"]);
        }
        // the sink is write-only: monitored trials must reproduce the
        // unmonitored ones bit-for-bit
        let plain = fig9_repeated(&cfg, true, 2).unwrap();
        for (a, b) in rs.iter().zip(&plain) {
            let bits =
                |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&a.phi_samples), bits(&b.phi_samples));
            assert_eq!(bits(&a.sig_samples), bits(&b.sig_samples));
        }
    }

    /// An absurdly loose gate fires on the first snapshot; the chains
    /// poll the stop flag at sweep boundaries and must come home well
    /// short of their nominal length (the margin is huge — the gate
    /// fires within the first flush of a 600-sweep run).
    #[test]
    fn fig9_monitor_gate_stops_early() {
        let cfg = Fig9Config {
            series: 3,
            len: 3,
            sweeps: 600,
            particles: 4,
            h_per_param: 1,
            ..Default::default()
        };
        let (rs, snaps) = fig9_repeated_monitored(&cfg, true, 2, 5, Some(1e6)).unwrap();
        assert_eq!(rs.len(), 2);
        assert!(
            snaps.iter().any(|s| s.gate_passed(1e6)),
            "no snapshot ever passed a gate at 1e6"
        );
        let lens: Vec<usize> = rs.iter().map(|r| r.phi_samples.len()).collect();
        // on a 1-worker pool the trials run sequentially and the first
        // one finishes before the gate can fire (the monitor needs
        // draws from every chain), so require only that *some* trial
        // was cut short and none ran long
        assert!(
            lens.iter().any(|&n| n < cfg.sweeps),
            "gate never shortened a trial: {lens:?}"
        );
        assert!(lens.iter().all(|&n| n <= cfg.sweeps), "a trial overran: {lens:?}");
        // monitored fig9 trials stream evaluator stats: some snapshot
        // must carry nonzero per-interval planned-section traffic
        assert!(
            snaps.iter().any(|s| s.eval.planned > 0),
            "no snapshot carried evaluator stats"
        );
    }

    #[test]
    fn fig9_streaming_window_stays_fixed() {
        let cfg = Fig9StreamingConfig {
            series: 4,
            window: 3,
            ticks: 3,
            sweeps_per_tick: 2,
            particles: 5,
            ..Default::default()
        };
        let rows = fig9_streaming(&cfg);
        assert_eq!(rows.len(), cfg.ticks);
        for r in &rows {
            assert_eq!(r.appended, cfg.series);
            assert_eq!(r.retired, cfg.series, "retirement must keep pace");
            assert_eq!(
                r.live_obs,
                cfg.series * cfg.window,
                "the observation window must stay fixed at tick {}",
                r.tick
            );
            assert!(r.phi_mean.is_finite() && r.sig_mean.is_finite());
        }
        let csv = fig9_streaming_csv(&rows);
        assert_eq!(csv.contents().lines().count(), cfg.ticks + 1);
    }

    #[test]
    fn table1_row_math() {
        // exponent calculation only (full timing runs live in benches)
        let r = Table1Row {
            model: "m".into(),
            n_small: 100,
            n_large: 1000,
            t_small: 0.01,
            t_large: 0.1,
            exponent: (0.1f64 / 0.01).ln() / 10f64.ln(),
        };
        assert!((r.exponent - 1.0).abs() < 1e-12);
    }
}
