//! Numerical foundations: special functions and deterministic PRNG.
//!
//! Everything here is implemented from scratch (no external numeric
//! crates) so the engine is self-contained and bit-reproducible.

pub mod rng;
pub mod special;

pub use rng::Pcg64;
pub use special::{
    erf, erfc, inv_normal_cdf, ln_beta, ln_gamma, log1p_exp, log_add_exp, log_sigmoid,
    normal_cdf, reg_inc_beta, student_t_cdf, student_t_sf,
};
