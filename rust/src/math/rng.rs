//! Deterministic PRNG (PCG64-DXSM style) plus the distribution samplers
//! the engine needs.  One seeded generator per chain gives bit-for-bit
//! reproducible experiments on a fixed platform.

/// PCG-64 DXSM generator (128-bit state, 64-bit output).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Pcg64 {
            state: 0,
            inc,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Snapshot the raw generator state for checkpointing.  Together
    /// with [`Pcg64::from_parts`] this round-trips the stream position
    /// exactly: a restored generator produces the identical output
    /// sequence from the next call on.
    pub fn state_parts(&self) -> (u128, u128) {
        (self.state, self.inc)
    }

    /// Reconstruct a generator from a [`Pcg64::state_parts`] snapshot.
    pub fn from_parts(state: u128, inc: u128) -> Self {
        Pcg64 { state, inc }
    }

    /// Next raw 64 bits (DXSM output function).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let mut hi = (self.state >> 64) as u64;
        let lo = (self.state as u64) | 1;
        hi ^= hi >> 32;
        hi = hi.wrapping_mul(0xda94_2042_e4dd_58b5);
        hi ^= hi >> 48;
        hi.wrapping_mul(lo)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in (0, 1) — never exactly zero (safe for log()).
    #[inline]
    pub fn uniform_pos(&mut self) -> f64 {
        loop {
            let u = self.uniform();
            if u > 0.0 {
                return u;
            }
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection method.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal (Marsaglia polar method).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// N(mu, sigma^2).
    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// Gamma(shape, scale=1) via Marsaglia–Tsang, with boost for shape < 1.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0, "gamma shape must be > 0");
        if shape < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^{1/a}
            let g = self.gamma(shape + 1.0);
            let u = self.uniform_pos();
            return g * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v = v * v * v;
            let u = self.uniform_pos();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Gamma(shape, scale).
    pub fn gamma_scaled(&mut self, shape: f64, scale: f64) -> f64 {
        self.gamma(shape) * scale
    }

    /// Beta(a, b).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Chi-squared with nu dof.
    pub fn chi2(&mut self, nu: f64) -> f64 {
        2.0 * self.gamma(0.5 * nu)
    }

    /// Student-t with nu dof.
    pub fn student_t(&mut self, nu: f64) -> f64 {
        self.normal() / (self.chi2(nu) / nu).sqrt()
    }

    /// Bernoulli(p) -> bool.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalized log-weights (Gumbel-free; uses
    /// normalized CDF inversion for determinism).
    pub fn categorical_log(&mut self, log_w: &[f64]) -> usize {
        let m = log_w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let ws: Vec<f64> = log_w.iter().map(|l| (l - m).exp()).collect();
        self.categorical(&ws)
    }

    /// Sample an index proportional to nonnegative weights.
    pub fn categorical(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        assert!(total > 0.0 && total.is_finite(), "categorical: bad weights");
        let mut u = self.uniform() * total;
        for (i, &wi) in w.iter().enumerate() {
            u -= wi;
            if u <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }

    /// Floyd's algorithm: k distinct indices from [0, n), order randomized.
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        // Fisher-Yates shuffle for unbiased order
        for i in (1..out.len()).rev() {
            let j = self.below(i + 1);
            out.swap(i, j);
        }
        out
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seeded(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_mean() {
        let mut rng = Pcg64::seeded(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 5e-3);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(2);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Pcg64::seeded(3);
        for &shape in &[0.5, 1.0, 3.0, 10.0] {
            let n = 100_000;
            let mut s1 = 0.0;
            for _ in 0..n {
                s1 += rng.gamma(shape);
            }
            let mean = s1 / n as f64;
            assert!(
                (mean - shape).abs() < 0.05 * shape.max(1.0),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn beta_moments() {
        let mut rng = Pcg64::seeded(4);
        let (a, b) = (5.0, 1.0);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            let x = rng.beta(a, b);
            assert!((0.0..=1.0).contains(&x));
            s += x;
        }
        assert!((s / n as f64 - a / (a + b)).abs() < 5e-3);
    }

    #[test]
    fn below_uniformity() {
        let mut rng = Pcg64::seeded(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn swr_distinct_and_complete() {
        let mut rng = Pcg64::seeded(6);
        for _ in 0..100 {
            let ids = rng.sample_without_replacement(50, 13);
            assert_eq!(ids.len(), 13);
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), 13);
            assert!(ids.iter().all(|&i| i < 50));
        }
        // k == n returns a permutation
        let ids = rng.sample_without_replacement(10, 10);
        let mut sorted = ids.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn swr_is_uniform() {
        // Every element appears ~ k/n of the time.
        let mut rng = Pcg64::seeded(7);
        let (n, k, trials) = (20, 5, 40_000);
        let mut counts = vec![0usize; n];
        for _ in 0..trials {
            for i in rng.sample_without_replacement(n, k) {
                counts[i] += 1;
            }
        }
        let expect = trials as f64 * k as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < 0.05 * expect, "{counts:?}");
        }
    }

    #[test]
    fn categorical_log_matches_weights() {
        let mut rng = Pcg64::seeded(8);
        let log_w = [0.0f64.ln(), 1.0f64.ln(), 3.0f64.ln()];
        let log_w = [f64::NEG_INFINITY, log_w[1], log_w[2]];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical_log(&log_w)] += 1;
        }
        assert_eq!(counts[0], 0);
        let frac2 = counts[2] as f64 / 40_000.0;
        assert!((frac2 - 0.75).abs() < 0.02);
    }
}
