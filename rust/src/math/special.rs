//! Special functions: ln-gamma, erf/erfc, regularized incomplete beta,
//! and the Student-t CDF used by the sequential test (Alg. 2).
//!
//! Accuracy targets are ~1e-12 relative for ln_gamma and ~1e-10 absolute
//! for the beta/t functions — comfortably below the 1e-2..1e-3 tolerance
//! levels ε at which the sequential test operates, so the test's decision
//! boundary is limited by statistics, not by these approximations.

/// Natural log of the Gamma function (Lanczos approximation, g=7, n=9).
pub fn ln_gamma(x: f64) -> f64 {
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection: Gamma(x) Gamma(1-x) = pi / sin(pi x)
        let s = (std::f64::consts::PI * x).sin();
        return std::f64::consts::PI.ln() - s.abs().ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + 7.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// ln B(a, b) = ln Gamma(a) + ln Gamma(b) - ln Gamma(a+b).
pub fn ln_beta(a: f64, b: f64) -> f64 {
    ln_gamma(a) + ln_gamma(b) - ln_gamma(a + b)
}

/// Error function, Abramowitz & Stegun 7.1.26-style rational + series;
/// we use the complementary-function continued fraction for accuracy.
pub fn erf(x: f64) -> f64 {
    1.0 - erfc(x)
}

/// Complementary error function (relative error < 1.2e-7 everywhere,
/// much better near 0 via the series branch).
pub fn erfc(x: f64) -> f64 {
    if x < 0.0 {
        return 2.0 - erfc(-x);
    }
    if x < 0.5 {
        // erf via Taylor-like series: erf(x) = 2/sqrt(pi) * sum
        let mut term = x;
        let mut sum = x;
        let x2 = x * x;
        let mut n = 0u32;
        while term.abs() > 1e-17 * sum.abs() && n < 200 {
            n += 1;
            term *= -x2 / n as f64;
            sum += term / (2 * n + 1) as f64;
        }
        return 1.0 - 2.0 / std::f64::consts::PI.sqrt() * sum;
    }
    // Continued fraction (Lentz) for erfc(x) = exp(-x^2)/(x sqrt(pi)) * CF
    let x2 = x * x;
    let mut f = x;
    let mut c = x;
    let mut d = 0.0f64;
    let tiny = 1e-300;
    for i in 1..300 {
        let a = 0.5 * i as f64;
        // CF: x + a1/(x + a2/(x + ...)), a_i = i/2
        d = x + a * d;
        if d.abs() < tiny {
            d = tiny;
        }
        c = x + a / c;
        if c.abs() < tiny {
            c = tiny;
        }
        d = 1.0 / d;
        let delta = c * d;
        f *= delta;
        if (delta - 1.0).abs() < 1e-16 {
            break;
        }
    }
    (-x2).exp() / (f * std::f64::consts::PI.sqrt())
}

/// Regularized incomplete beta function I_x(a, b), continued fraction
/// (Numerical Recipes `betacf`), valid for 0 <= x <= 1.
pub fn reg_inc_beta(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x), "reg_inc_beta: x={x} outside [0,1]");
    if x == 0.0 {
        return 0.0;
    }
    if x == 1.0 {
        return 1.0;
    }
    let ln_front = -ln_beta(a, b) + a * x.ln() + b * (1.0 - x).ln();
    if x < (a + 1.0) / (a + b + 2.0) {
        (ln_front.exp()) * beta_cf(a, b, x) / a
    } else {
        1.0 - (ln_front.exp()) * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const MAX_IT: usize = 300;
    const EPS: f64 = 3e-15;
    const FPMIN: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < FPMIN {
        d = FPMIN;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..=MAX_IT {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < FPMIN {
            d = FPMIN;
        }
        c = 1.0 + aa / c;
        if c.abs() < FPMIN {
            c = FPMIN;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < EPS {
            break;
        }
    }
    h
}

/// CDF of the Student-t distribution with `nu` degrees of freedom.
pub fn student_t_cdf(t: f64, nu: f64) -> f64 {
    assert!(nu > 0.0);
    if t.is_infinite() {
        return if t > 0.0 { 1.0 } else { 0.0 };
    }
    let x = nu / (nu + t * t);
    let p = 0.5 * reg_inc_beta(0.5 * nu, 0.5, x);
    if t > 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Survival function 1 - CDF (more accurate in the tail we test against).
pub fn student_t_sf(t: f64, nu: f64) -> f64 {
    if t.is_infinite() {
        return if t > 0.0 { 0.0 } else { 1.0 };
    }
    let x = nu / (nu + t * t);
    let p = 0.5 * reg_inc_beta(0.5 * nu, 0.5, x);
    if t > 0.0 {
        p
    } else {
        1.0 - p
    }
}

/// log(1 + exp(x)) without overflow.
pub fn log1p_exp(x: f64) -> f64 {
    if x > 35.0 {
        x
    } else if x < -35.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Numerically stable log(sigmoid(x)) = -log(1 + exp(-x)).
pub fn log_sigmoid(x: f64) -> f64 {
    -log1p_exp(-x)
}

/// log(exp(a) + exp(b)) without overflow.
pub fn log_add_exp(a: f64, b: f64) -> f64 {
    if a == f64::NEG_INFINITY {
        return b;
    }
    if b == f64::NEG_INFINITY {
        return a;
    }
    let m = a.max(b);
    m + ((a - m).exp() + (b - m).exp()).ln()
}

/// log-sum-exp of a slice.
pub fn log_sum_exp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m == f64::NEG_INFINITY {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Standard normal CDF via the complementary error function.
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Inverse standard normal CDF (probit), Acklam's rational
/// approximation (|relative error| < 1.15e-9 on (0, 1)) — used by the
/// rank-normalization step of the convergence diagnostics.
pub fn inv_normal_cdf(p: f64) -> f64 {
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p.is_nan() || !(0.0..=1.0).contains(&p) {
        return f64::NAN;
    }
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        // Gamma(n) = (n-1)!
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-11);
        assert!((ln_gamma(10.0) - 362880f64.ln()).abs() < 1e-10);
        // Gamma(0.5) = sqrt(pi)
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-12);
        // Reflection branch
        assert!((ln_gamma(0.3) - 2.991_568_987_687_59f64.ln()).abs() < 1e-9);
    }

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(1.0) - 0.842_700_792_949_714_9).abs() < 1e-10);
        assert!((erf(2.0) - 0.995_322_265_018_952_7).abs() < 1e-10);
        assert!((erf(-1.0) + 0.842_700_792_949_714_9).abs() < 1e-10);
        assert!((erfc(3.0) - 2.209_049_699_858_544e-5).abs() < 1e-12);
    }

    #[test]
    fn inc_beta_symmetry_and_known() {
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        for &(a, b, x) in &[(2.0, 3.0, 0.4), (0.5, 0.5, 0.3), (5.0, 1.0, 0.9)] {
            let lhs = reg_inc_beta(a, b, x);
            let rhs = 1.0 - reg_inc_beta(b, a, 1.0 - x);
            assert!((lhs - rhs).abs() < 1e-12, "({a},{b},{x})");
        }
        // I_x(1,1) = x
        assert!((reg_inc_beta(1.0, 1.0, 0.37) - 0.37).abs() < 1e-12);
        // scipy.special.betainc(2, 3, 0.4) = 0.5248
        assert!((reg_inc_beta(2.0, 3.0, 0.4) - 0.5248).abs() < 1e-10);
    }

    #[test]
    fn student_t_cdf_known_values() {
        // nu=1 is Cauchy: CDF(t) = 1/2 + atan(t)/pi
        for &t in &[-3.0f64, -1.0, 0.0, 0.5, 2.0] {
            let want = 0.5 + t.atan() / std::f64::consts::PI;
            assert!((student_t_cdf(t, 1.0) - want).abs() < 1e-10, "t={t}");
        }
        // symmetric
        assert!((student_t_cdf(0.0, 7.0) - 0.5).abs() < 1e-12);
        // scipy.stats.t.cdf(1.5, 10) = 0.917745...
        assert!((student_t_cdf(1.5, 10.0) - 0.917_746_87).abs() < 1e-6);
        // large nu approaches normal: t.cdf(1.96, 1e6) ~ 0.975
        assert!((student_t_cdf(1.96, 1e6) - 0.975).abs() < 2e-4);
    }

    #[test]
    fn student_t_sf_complements_cdf() {
        for &t in &[-4.0, -0.3, 0.0, 1.2, 8.0] {
            for &nu in &[1.0, 4.0, 30.0] {
                let s = student_t_sf(t, nu) + student_t_cdf(t, nu);
                assert!((s - 1.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn log_sigmoid_stable() {
        assert!(log_sigmoid(1000.0).abs() < 1e-12);
        assert!((log_sigmoid(-1000.0) + 1000.0).abs() < 1e-9);
        assert!((log_sigmoid(0.0) + 2f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn log_add_exp_basic() {
        assert!((log_add_exp(0.0, 0.0) - 2f64.ln()).abs() < 1e-12);
        assert!((log_add_exp(f64::NEG_INFINITY, 3.0) - 3.0).abs() < 1e-12);
        assert!((log_add_exp(1000.0, 1000.0) - (1000.0 + 2f64.ln())).abs() < 1e-9);
        assert!((log_sum_exp(&[0.0, 0.0, 0.0]) - 3f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn inv_normal_cdf_round_trips() {
        assert!(inv_normal_cdf(0.5).abs() < 1e-9);
        // scipy.stats.norm.ppf(0.975) = 1.959963984540054
        assert!((inv_normal_cdf(0.975) - 1.959_963_984_540_054).abs() < 1e-7);
        // probit is the inverse of the erf-based CDF across both branches
        for &x in &[-3.5, -1.0, -0.1, 0.0, 0.4, 2.0, 3.2] {
            let p = normal_cdf(x);
            assert!((inv_normal_cdf(p) - x).abs() < 1e-5, "x={x}");
        }
        // antisymmetric
        assert!((inv_normal_cdf(0.01) + inv_normal_cdf(0.99)).abs() < 1e-9);
        assert!(inv_normal_cdf(0.0) == f64::NEG_INFINITY);
        assert!(inv_normal_cdf(1.0) == f64::INFINITY);
        assert!(inv_normal_cdf(-0.1).is_nan());
    }
}
