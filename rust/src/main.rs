//! subppl CLI — run probabilistic programs and regenerate the paper's
//! experiments.
//!
//! ```text
//! subppl run <program.vnt> [--infer "<program>"] [--seed N] [--watch a,b]
//!            [--target-risk R] [--threads T] [--chains R]
//!            [--monitor-every K] [--monitor-gate R]
//!            [--store-verify off|refreshed|full]
//!            [--checkpoint-every K --checkpoint-dir D] [--resume]
//! subppl experiment <table1|fig4|fig5|fig6|fig9|fig9_streaming>
//!            [--fast] [--fused]
//!            [--target-risk R] [--threads T] [--chains R]
//!            [--monitor-every K] [--monitor-gate R]
//! subppl serve [--addr HOST:PORT] [--max-sessions N]
//!            [--session-deadline-ms MS] [--drain-timeout-ms MS]
//!            [--seed N] [--queue-cap N] [--checkpoint-dir D]
//!            [--shard-timeout-ms MS] [--store-verify MODE] [--threads T]
//!            [--state-dir D] [--recover] [--max-frame-bytes N]
//!            [--journal-every K] [--max-trace-nodes N]
//!            [--max-journal-bytes N]
//! subppl artifacts                 # list the AOT artifact registry
//! ```
//!
//! `--threads` sets the batch-replay worker count (default: auto via
//! `SUBPPL_THREADS` or available parallelism; `1` = sequential; results
//! are bitwise identical either way).  `--chains R` runs R independent
//! replicas concurrently on the same pool (per-chain PCG streams).
//! `--monitor-every K` streams convergence diagnostics while the chains
//! run: every K recorded draws (per chain) a `[monitor]` line reports
//! split-R-hat, rank-normalized R-hat, total ESS, and per-interval
//! evaluator-tier traffic for each watched parameter.  Snapshot
//! contents are deterministic in the seed.  `--monitor-gate R` stops a
//! monitored run early once every watched parameter's rank-normalized
//! R-hat is finite and below R (chains wind down at their next sample
//! boundary; the final snapshot is still emitted).
//!
//! `--target-risk R` (R in (0,1)) switches every `subsampled_mh`
//! command to risk-adaptive mini-batch control: instead of a fixed
//! mini-batch size `m`, the controller retunes each transition's batch
//! toward the largest size whose sequential test can still decide with
//! per-transition error below R, and the run reports the mean realized
//! risk.  On `experiment fig4`/`fig9` the same flag adds a
//! `subsampled-risk{R}` curve/run next to the fixed-eps ones.
//!
//! `--store-verify off|refreshed|full` sets the column-store row
//! self-check mode for the run/daemon (default: the
//! `SUBPPL_STORE_VERIFY` env var, else `refreshed`).  Purely an
//! integrity-vs-throughput knob — results are bitwise identical under
//! every mode.
//!
//! `--checkpoint-every K --checkpoint-dir D` snapshots each chain's
//! state (stochastic values + RNG position) to `D/chain<c>.ckpt` every
//! K draws, atomically (write-temp-then-rename).  `--resume` restarts
//! from those checkpoints; because a checkpoint pins the exact trace
//! state and RNG position, the resumed run's remaining draws are
//! bitwise identical to the uninterrupted run's.  With `--chains R > 1`
//! the checkpointed run is also *supervised*: a chain that panics is
//! restarted from its last checkpoint instead of failing the run.

use std::io::Read;
use std::sync::Arc;
use subppl::coordinator::checkpoint::CheckpointCtl;
use subppl::coordinator::experiments as exp;
use subppl::coordinator::monitor::{monitor_csv, ConvergenceMonitor, DiagSnapshot};
use subppl::coordinator::multichain::{ChainSink, SupervisorConfig};
use subppl::coordinator::report::{results_dir, Table};
use subppl::coordinator::{multichain, FusedEval};
use subppl::infer::planned::EvalStats;
use subppl::infer::{parse_infer, run_command, LocalEvaluator, PlannedEval};
use subppl::math::Pcg64;
use subppl::runtime::pool::{resolve_threads, WorkerPool};
use subppl::trace::Trace;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// Parse a `--store-verify off|refreshed|full` flag into a
/// [`VerifyMode`] (absent flag = `None`: env fallback).
fn store_verify_opt(args: &[String]) -> Result<Option<subppl::trace::colstore::VerifyMode>, String> {
    match opt(args, "--store-verify") {
        Some(s) => subppl::trace::colstore::VerifyMode::parse(s)
            .map(Some)
            .ok_or_else(|| format!("bad --store-verify {s:?} (off|refreshed|full)")),
        None => Ok(None),
    }
}

fn run(args: &[String]) -> Result<(), String> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("experiment") => cmd_experiment(args),
        Some("artifacts") => cmd_artifacts(),
        Some("serve") => cmd_serve(args),
        _ => {
            eprintln!(
                "usage:\n  subppl run <program.vnt> [--infer \"(cycle ...)\"] [--seed N] [--samples K] [--watch a,b] [--target-risk R] [--shard-timeout-ms MS] [--store-verify off|refreshed|full] [--threads T] [--chains R] [--monitor-every K] [--monitor-gate R] [--checkpoint-every K --checkpoint-dir D] [--resume]\n  subppl experiment <table1|fig4|fig5|fig6|fig9|fig9_streaming> [--fast] [--fused] [--target-risk R] [--threads T] [--chains R] [--monitor-every K] [--monitor-gate R]\n  subppl serve [--addr HOST:PORT] [--max-sessions N] [--session-deadline-ms MS] [--drain-timeout-ms MS] [--seed N] [--queue-cap N] [--checkpoint-dir D] [--shard-timeout-ms MS] [--store-verify MODE] [--threads T] [--state-dir D] [--recover] [--max-frame-bytes N] [--journal-every K] [--max-trace-nodes N] [--max-journal-bytes N]\n  subppl artifacts"
            );
            Err("missing or unknown subcommand".into())
        }
    }
}

/// `subppl serve`: the inference-as-a-service daemon (see
/// `serve/server.rs` for the robustness ladder: admission control,
/// bounded queues, deadlines, panic isolation, graceful drain; with
/// `--state-dir` a per-session write-ahead journal makes acknowledged
/// work crash-durable, and `--recover` rebuilds sessions bitwise-
/// identically on restart).
fn cmd_serve(args: &[String]) -> Result<(), String> {
    let parse_u64 = |name: &str, default: u64| -> Result<u64, String> {
        match opt(args, name) {
            Some(s) => s.parse().map_err(|_| format!("bad {name}")),
            None => Ok(default),
        }
    };
    let session_deadline_ms = parse_u64("--session-deadline-ms", 0)?;
    let cfg = subppl::serve::ServeCfg {
        addr: opt(args, "--addr").unwrap_or("127.0.0.1:7777").to_string(),
        max_sessions: parse_u64("--max-sessions", 64)? as usize,
        session_deadline: (session_deadline_ms > 0)
            .then(|| std::time::Duration::from_millis(session_deadline_ms)),
        drain_timeout: std::time::Duration::from_millis(parse_u64("--drain-timeout-ms", 5000)?),
        seed: parse_u64("--seed", 0)?,
        queue_cap: parse_u64("--queue-cap", 4)? as usize,
        checkpoint_dir: opt(args, "--checkpoint-dir").map(std::path::PathBuf::from),
        shard_timeout_ms: parse_u64("--shard-timeout-ms", 0)?,
        store_verify: store_verify_opt(args)?,
        // sessions shard intra-draw scoring across the shared pool
        // unless --threads resolves to a single worker
        use_pool: pool_for(args).is_some(),
        state_dir: opt(args, "--state-dir").map(std::path::PathBuf::from),
        recover: flag(args, "--recover"),
        max_frame_bytes: match parse_u64("--max-frame-bytes", 1 << 20)? {
            0 => return Err("--max-frame-bytes must be > 0".into()),
            n => n as usize,
        },
        journal_every: parse_u64("--journal-every", 0)? as usize,
        max_trace_nodes: parse_u64("--max-trace-nodes", 0)? as usize,
        max_journal_bytes: parse_u64("--max-journal-bytes", 0)?,
    };
    if cfg.recover && cfg.state_dir.is_none() {
        return Err("--recover requires --state-dir".into());
    }
    subppl::serve::serve(cfg)
}

/// Draws-to-gate accounting line: with a gate, reports where it fired
/// and the total sections the run scored getting there — the
/// compute-to-convergence number that makes fixed-eps and
/// `--target-risk` runs comparable (ROADMAP "Draws-to-gate
/// accounting").  Without a gate it still reports total sections.
fn print_gate_summary(gate: Option<f64>, gated_at: Option<usize>, cum_sections: usize) {
    match (gate, gated_at) {
        (Some(r), Some(n)) => println!(
            "[monitor] draws-to-gate: {n}/chain (rank R-hat < {r}), \
             sections scored: {cum_sections}"
        ),
        (Some(r), None) => println!(
            "[monitor] gate rank R-hat < {r} not reached; sections scored: {cum_sections}"
        ),
        (None, _) if cum_sections > 0 => {
            println!("[monitor] sections scored: {cum_sections}")
        }
        _ => {}
    }
}

/// Result of one `subppl run` chain.
struct ChainReport {
    live: usize,
    initial_lj: f64,
    means: Vec<f64>,
    final_lj: f64,
    /// First-iteration inference stats: (transitions, acceptance rate).
    per_iter: Option<(usize, f64)>,
    /// The evaluator's cumulative tier/recovery counters at the end of
    /// the run (all-zero when no inference ran).
    eval: EvalStats,
}

/// One chain's worth of `subppl run`: build the trace, optionally run
/// the inference program, and report watched posterior means.  When a
/// `sink` is given, every recorded sample's watched values are also
/// streamed to the convergence monitor (write-only: the sink cannot
/// change what the chain computes).
fn run_one_chain(
    src: &str,
    infer_prog: Option<&str>,
    target_risk: Option<f64>,
    shard_timeout_ms: u64,
    store_verify: Option<subppl::trace::colstore::VerifyMode>,
    names: &[String],
    samples: usize,
    pool: Option<Arc<WorkerPool>>,
    sink: Option<&ChainSink>,
    ctl: &mut CheckpointCtl,
    rng: &mut Pcg64,
) -> Result<ChainReport, String> {
    let mut trace = Trace::new();
    trace.run_program(src, rng)?;
    let live = trace.num_live_nodes();
    let initial_lj = trace.log_joint();
    let mut means = vec![0.0; names.len()];
    let mut per_iter = None;
    let mut eval = EvalStats::default();
    if let Some(prog) = infer_prog {
        let mut cmd = parse_infer(prog)?;
        if let Some(tr) = target_risk {
            // one program-wide risk bound; only subsampled_mh commands
            // in the inference program are affected
            cmd.set_target_risk(tr);
        }
        if shard_timeout_ms > 0 {
            cmd.set_shard_timeout_ms(shard_timeout_ms);
        }
        if let Some(v) = store_verify {
            cmd.set_store_verify(v);
        }
        let mut ev: Box<dyn LocalEvaluator> = match pool {
            Some(p) => Box::new(
                PlannedEval::with_pool(p)
                    .with_shard_timeout(shard_timeout_ms)
                    .with_store_verify(store_verify),
            ),
            None => Box::new(PlannedEval::new().with_store_verify(store_verify)),
        };
        let mut sums: Vec<f64> = vec![0.0; names.len()];
        // 32 rows per channel send; BufferedSink flushes the tail on drop
        let mut buf = sink.map(|s| s.clone().buffered(32));
        let mut recorded = 0usize;
        // resume: overwrite the freshly built trace's stochastic state
        // and RNG position from the checkpoint, then continue at the
        // next draw — bitwise identical to never having stopped.
        // (posterior means are over post-resume draws only.)
        let mut start = 0usize;
        if let Some(ck) = ctl.take_resume() {
            *rng = ck.restore(&mut trace)?;
            start = ck.draw.min(samples);
            eprintln!("[checkpoint] resumed at draw {start}/{samples}");
        }
        for s in start..samples {
            // a fired --monitor-gate asks chains to wind down at the
            // next sample boundary (best-effort early stop)
            if buf.as_ref().is_some_and(|b| b.cancelled()) {
                break;
            }
            let stats = run_command(&mut trace, rng, &cmd, ev.as_mut())?;
            if s == start {
                per_iter = Some((stats.transitions, stats.acceptance_rate()));
            }
            let mut row = Vec::with_capacity(names.len());
            for (i, n) in names.iter().enumerate() {
                match trace.lookup_value(n).and_then(|v| v.as_f64()) {
                    Some(v) => {
                        sums[i] += v;
                        row.push(v);
                    }
                    None => row.push(f64::NAN),
                }
            }
            recorded += 1;
            if let Some(b) = buf.as_mut() {
                // draws + cumulative tier counters: the monitor streams
                // per-interval EvalStats diffs into its [monitor] lines
                b.push_with_stats(row, ev.stats());
            }
            // snapshot AFTER the draw is recorded, so `draw` always
            // means "draws fully completed and streamed"
            if ctl.due(s + 1) {
                ctl.save(s + 1, &trace, rng)?;
            }
        }
        for (i, s) in sums.iter().enumerate() {
            means[i] = s / recorded.max(1) as f64;
        }
        eval = ev.stats();
    }
    Ok(ChainReport {
        live,
        initial_lj,
        means,
        final_lj: trace.log_joint(),
        per_iter,
        eval,
    })
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let path = args.get(1).ok_or("run: missing program path")?;
    let mut src = String::new();
    if path == "-" {
        std::io::stdin()
            .read_to_string(&mut src)
            .map_err(|e| e.to_string())?;
    } else {
        src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    }
    let seed: u64 = opt(args, "--seed")
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --seed")?;
    let samples: usize = opt(args, "--samples")
        .unwrap_or("100")
        .parse()
        .map_err(|_| "bad --samples")?;
    let chains: usize = opt(args, "--chains")
        .unwrap_or("1")
        .parse()
        .map_err(|_| "bad --chains")?;
    let names: Vec<String> = opt(args, "--watch")
        .map(|p| p.split(',').map(|s| s.to_string()).collect())
        .unwrap_or_default();
    let infer_prog = opt(args, "--infer").map(|s| s.to_string());
    let target_risk: Option<f64> = match opt(args, "--target-risk") {
        Some(s) => {
            let v: f64 = s.parse().map_err(|_| "bad --target-risk")?;
            if !(v > 0.0 && v < 1.0) {
                return Err("--target-risk must be in (0, 1)".into());
            }
            Some(v)
        }
        None => None,
    };
    if target_risk.is_some() && infer_prog.is_none() {
        return Err("--target-risk needs --infer (it tunes subsampled_mh mini-batches)".into());
    }
    // per-run shard-watchdog deadline (satellite: the env var is
    // process-global and doesn't compose across concurrent sessions)
    let shard_timeout_ms: u64 = opt(args, "--shard-timeout-ms")
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --shard-timeout-ms")?;
    // per-run column-store verify mode (same promotion rationale)
    let store_verify = store_verify_opt(args)?;
    let monitor_every: usize = opt(args, "--monitor-every")
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --monitor-every")?;
    let monitor_gate: Option<f64> = match opt(args, "--monitor-gate") {
        Some(s) => Some(s.parse().map_err(|_| "bad --monitor-gate")?),
        None => None,
    };
    if monitor_every > 0 && names.is_empty() {
        return Err("--monitor-every needs --watch to name the monitored parameters".into());
    }
    if monitor_every > 0 && infer_prog.is_none() {
        return Err("--monitor-every needs --infer (no transitions, no draws to monitor)".into());
    }
    if monitor_every > 0 && chains < 2 {
        return Err("--monitor-every compares chains: use --chains 2 or more".into());
    }
    if monitor_gate.is_some() && monitor_every == 0 {
        return Err("--monitor-gate needs --monitor-every to produce snapshots to gate on".into());
    }
    let ck_every: usize = opt(args, "--checkpoint-every")
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --checkpoint-every")?;
    let ck_dir = opt(args, "--checkpoint-dir").map(std::path::PathBuf::from);
    let resume = flag(args, "--resume");
    if ck_every > 0 && ck_dir.is_none() {
        return Err("--checkpoint-every needs --checkpoint-dir to write into".into());
    }
    if resume && ck_dir.is_none() {
        return Err("--resume needs --checkpoint-dir to read from".into());
    }
    if (ck_every > 0 || resume) && infer_prog.is_none() {
        return Err("checkpointing needs --infer (no transitions, nothing to checkpoint)".into());
    }

    if chains > 1 {
        // concurrent replicas: one Trace per pool worker, per-chain PCG
        // streams; deterministic in (seed, chain index)
        let pool = WorkerPool::global().clone();
        let src = src.clone();
        let names_c = names.clone();
        let chain =
            move |_c: usize, mut rng: Pcg64, sink: Option<ChainSink>, ctl: &mut CheckpointCtl| {
                run_one_chain(
                    &src,
                    infer_prog.as_deref(),
                    target_risk,
                    shard_timeout_ms,
                    store_verify,
                    &names_c,
                    samples,
                    None,
                    sink.as_ref(),
                    ctl,
                    &mut rng,
                )
            };
        let results = if ck_every > 0 || resume {
            // checkpointed multi-chain runs are supervised: a chain
            // that panics restarts from its last checkpoint; monitor
            // lines (when requested) surface `+restarts=` counters
            let sup = SupervisorConfig {
                every: ck_every,
                dir: ck_dir.clone(),
                resume,
                max_restarts: 2,
            };
            let use_sink = monitor_every > 0;
            let mut mon = use_sink.then(|| ConvergenceMonitor::new(chains, &names, monitor_every));
            let mut gated_at: Option<usize> = None;
            // draws-to-gate accounting: total sections scored across
            // all snapshots (compute-to-convergence when a gate fires)
            let mut cum_sections = 0usize;
            let results = multichain::run_chains_supervised(
                &pool,
                chains,
                seed,
                sup,
                move |c, rng, sink, ctl| chain(c, rng, use_sink.then_some(sink), ctl),
                |ev| {
                    let mut keep_going = true;
                    if let Some(m) = mon.as_mut() {
                        m.absorb(ev);
                        for snap in m.ready_snapshots() {
                            println!("{}", snap.render());
                            cum_sections += snap.sections_scored();
                            let fired = gated_at.is_none()
                                && monitor_gate.is_some_and(|r| snap.gate_passed(r));
                            if fired {
                                gated_at = Some(snap.draws_per_chain);
                                keep_going = false;
                                println!(
                                    "[monitor] gate: every watched rank R-hat below target \
                                     at n={}/chain — stopping early",
                                    snap.draws_per_chain
                                );
                            }
                        }
                    }
                    keep_going
                },
            )?;
            if let Some(fin) = mon.as_mut().and_then(|m| m.finish()) {
                println!("{}", fin.render());
                cum_sections += fin.sections_scored();
            }
            print_gate_summary(monitor_gate, gated_at, cum_sections);
            results
        } else if monitor_every > 0 {
            // live convergence lines as every chain crosses each
            // monitor_every-sample boundary; contents deterministic in
            // the seed (fold-order normalized by chain index).  With a
            // gate, the driver raises the shared stop flag once every
            // watched parameter's rank-R-hat is below the target.
            let mut mon = ConvergenceMonitor::new(chains, &names, monitor_every);
            let mut gated_at: Option<usize> = None;
            let mut cum_sections = 0usize;
            let results = multichain::run_chains_gated(
                &pool,
                chains,
                seed,
                move |c, rng, sink| chain(c, rng, Some(sink), &mut CheckpointCtl::disabled()),
                |ev| {
                    mon.absorb(ev);
                    let mut keep_going = true;
                    for snap in mon.ready_snapshots() {
                        println!("{}", snap.render());
                        cum_sections += snap.sections_scored();
                        let fired = gated_at.is_none()
                            && monitor_gate.is_some_and(|r| snap.gate_passed(r));
                        if fired {
                            gated_at = Some(snap.draws_per_chain);
                            keep_going = false;
                            println!(
                                "[monitor] gate: every watched rank R-hat below target \
                                 at n={}/chain — stopping early",
                                snap.draws_per_chain
                            );
                        }
                    }
                    keep_going
                },
            )?;
            // end-of-run snapshot (deduped against the last boundary)
            if let Some(fin) = mon.finish() {
                println!("{}", fin.render());
                cum_sections += fin.sections_scored();
            }
            print_gate_summary(monitor_gate, gated_at, cum_sections);
            results
        } else {
            multichain::run_chains(&pool, chains, seed, move |c, rng| {
                chain(c, rng, None, &mut CheckpointCtl::disabled())
            })?
        };
        let mut t = Table::new(&["chain", "live nodes", "final log joint"]);
        let mut pooled = vec![0.0; names.len()];
        for (c, r) in results.iter().enumerate() {
            let rep = r.as_ref().map_err(|e| e.clone())?;
            t.row(&[
                c.to_string(),
                rep.live.to_string(),
                format!("{:.4}", rep.final_lj),
            ]);
            for (i, m) in rep.means.iter().enumerate() {
                pooled[i] += m;
            }
        }
        t.print();
        for (i, n) in names.iter().enumerate() {
            println!(
                "posterior mean {n} (pooled over {chains} chains): {:.5}",
                pooled[i] / chains as f64
            );
        }
        return Ok(());
    }

    let pool = pool_for(args);
    let mut rng = Pcg64::seeded(seed);
    let mut ctl = CheckpointCtl::new(ck_every, ck_dir.as_deref(), seed, 0, resume)?;
    let rep = run_one_chain(
        &src,
        infer_prog.as_deref(),
        target_risk,
        shard_timeout_ms,
        store_verify,
        &names,
        samples,
        pool,
        None,
        &mut ctl,
        &mut rng,
    )?;
    println!("trace: {} live nodes", rep.live);
    println!("log joint: {:.4}", rep.initial_lj);
    if let Some((transitions, acceptance)) = rep.per_iter {
        print!("per-iteration: {transitions} transitions, acceptance {acceptance:.3}");
        if rep.eval.any_recovery() {
            // satellite: surface recovery counters on the stats line so
            // an absorbed fault is visible even without --monitor-every
            print!(
                ", recovered: {} worker panic(s), {} requeued shard(s), {} quarantined store group(s)",
                rep.eval.fallback_panics, rep.eval.requeued_shards, rep.eval.store_quarantined
            );
        }
        if let Some(r) = rep.eval.realized_risk() {
            // mean realized per-transition risk over all sequential-test
            // decisions; --target-risk guarantees r <= the bound
            print!(", realized risk {r:.2e}");
        }
        println!();
    }
    if infer_prog.is_some() {
        for (i, n) in names.iter().enumerate() {
            println!("posterior mean {n}: {:.5}", rep.means[i]);
        }
        println!("final log joint: {:.4}", rep.final_lj);
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let reg = subppl::runtime::ArtifactRegistry::open_default()?;
    let mut t = Table::new(&["name", "kind", "m", "d"]);
    for a in reg.infos() {
        t.row(&[
            a.name.clone(),
            a.kind.clone(),
            a.m.to_string(),
            a.d.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

/// The shared worker pool when `--threads` (default: auto) resolves to
/// more than one worker; `None` means sequential replay.
fn pool_for(args: &[String]) -> Option<Arc<WorkerPool>> {
    let threads: usize = opt(args, "--threads")
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    if resolve_threads(threads) > 1 {
        Some(WorkerPool::global().clone())
    } else {
        None
    }
}

fn evaluator_for(args: &[String]) -> Box<dyn LocalEvaluator> {
    if flag(args, "--fused") {
        match FusedEval::open_default() {
            Ok(f) => return Box::new(f),
            Err(e) => eprintln!("--fused unavailable ({e}); falling back to planned evaluator"),
        }
    }
    match pool_for(args) {
        Some(pool) => Box::new(PlannedEval::with_pool(pool)),
        None => Box::new(PlannedEval::new()),
    }
}

fn cmd_experiment(args: &[String]) -> Result<(), String> {
    let which = args.get(1).ok_or("experiment: missing name")?;
    let fast = flag(args, "--fast");
    // fig4/fig9 only: adds a risk-adaptive mini-batch curve/run
    let target_risk: Option<f64> = match opt(args, "--target-risk") {
        Some(s) => {
            let v: f64 = s.parse().map_err(|_| "bad --target-risk")?;
            if !(v > 0.0 && v < 1.0) {
                return Err("--target-risk must be in (0, 1)".into());
            }
            Some(v)
        }
        None => None,
    };
    let mut evaluator = evaluator_for(args);
    let outdir = results_dir();
    match which.as_str() {
        "table1" => {
            let rows = exp::table1_scaling(3);
            let mut t = Table::new(&[
                "model",
                "N_small",
                "N_large",
                "t_small(s)",
                "t_large(s)",
                "exponent",
            ]);
            for r in &rows {
                t.row(&[
                    r.model.clone(),
                    r.n_small.to_string(),
                    r.n_large.to_string(),
                    format!("{:.5}", r.t_small),
                    format!("{:.5}", r.t_large),
                    format!("{:.2}", r.exponent),
                ]);
            }
            t.print();
        }
        "fig5" => {
            let cfg = if fast {
                exp::Fig5Config {
                    ns: vec![1_000, 3_000, 10_000],
                    iters: 30,
                    ..Default::default()
                }
            } else {
                exp::Fig5Config::default()
            };
            let rows = exp::fig5_sublinear(&cfg, evaluator.as_mut());
            let mut t =
                Table::new(&["N", "sections/iter", "E[sections]", "t_sub(s)", "t_exact(s)"]);
            for r in &rows {
                t.row(&[
                    r.n.to_string(),
                    format!("{:.1}", r.avg_sections),
                    format!("{:.1}", r.expected_sections),
                    format!("{:.5}", r.time_sub),
                    format!("{:.5}", r.time_exact),
                ]);
            }
            t.print();
            exp::fig5_csv(&rows)
                .write_to(&outdir.join("fig5_sublinear.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig5_sublinear.csv").display());
        }
        "fig4" => {
            let mut cfg = if fast {
                exp::Fig4Config {
                    n_train: 2000,
                    n_test: 500,
                    steps: 100,
                    record_every: 5,
                    ..Default::default()
                }
            } else {
                exp::Fig4Config::default()
            };
            cfg.target_risk = target_risk;
            let curves = exp::fig4_risk(&cfg, evaluator.as_mut());
            let mut t = Table::new(&[
                "method",
                "transitions",
                "accept%",
                "final risk",
                "final 0-1",
                "JB p",
            ]);
            for c in &curves {
                let last = c.points.last().copied().unwrap_or((0.0, f64::NAN, f64::NAN));
                t.row(&[
                    c.label.clone(),
                    c.transitions.to_string(),
                    format!("{:.1}", 100.0 * c.accepted as f64 / c.transitions as f64),
                    format!("{:.5}", last.1),
                    format!("{:.4}", last.2),
                    format!("{:.3}", c.normality_p),
                ]);
            }
            t.print();
            exp::fig4_csv(&curves)
                .write_to(&outdir.join("fig4_risk.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig4_risk.csv").display());
        }
        "fig6" => {
            let cfg = if fast {
                exp::Fig6Config {
                    n_train: 300,
                    n_test: 150,
                    sweeps: 10,
                    step_z: 30,
                    ..Default::default()
                }
            } else {
                exp::Fig6Config::default()
            };
            let mut t = Table::new(&["method", "sweep", "seconds", "accuracy", "clusters"]);
            for (label, sub) in [("exact-mh", false), ("subsampled-eps0.3", true)] {
                let pts = exp::fig6_dpm(&cfg, sub);
                for (i, p) in pts.iter().enumerate() {
                    t.row(&[
                        label.to_string(),
                        i.to_string(),
                        format!("{:.2}", p.seconds),
                        format!("{:.4}", p.accuracy),
                        p.clusters.to_string(),
                    ]);
                }
            }
            t.print();
        }
        "fig9_streaming" => {
            let mut cfg = if fast {
                exp::Fig9StreamingConfig {
                    series: 10,
                    window: 4,
                    ticks: 3,
                    sweeps_per_tick: 10,
                    ..Default::default()
                }
            } else {
                exp::Fig9StreamingConfig::default()
            };
            cfg.target_risk = target_risk;
            let rows = exp::fig9_streaming(&cfg);
            let mut t = Table::new(&[
                "tick",
                "append(s)",
                "retire(s)",
                "sweeps(s)",
                "phi mean",
                "sig mean",
                "live obs",
            ]);
            for r in &rows {
                t.row(&[
                    r.tick.to_string(),
                    format!("{:.5}", r.append_seconds),
                    format!("{:.5}", r.retire_seconds),
                    format!("{:.3}", r.sweep_seconds),
                    format!("{:.4}", r.phi_mean),
                    format!("{:.4}", r.sig_mean),
                    r.live_obs.to_string(),
                ]);
            }
            t.print();
            exp::fig9_streaming_csv(&rows)
                .write_to(&outdir.join("fig9_streaming.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig9_streaming.csv").display());
        }
        "fig9" => {
            let mut cfg = if fast {
                exp::Fig9Config {
                    series: 30,
                    sweeps: 60,
                    ..Default::default()
                }
            } else {
                exp::Fig9Config::default()
            };
            cfg.target_risk = target_risk;
            let chains: usize = opt(args, "--chains")
                .and_then(|s| s.parse().ok())
                .unwrap_or(1);
            let monitor_every: usize = match opt(args, "--monitor-every") {
                Some(s) => s.parse().map_err(|_| "bad --monitor-every")?,
                None => 0,
            };
            let monitor_gate: Option<f64> = match opt(args, "--monitor-gate") {
                Some(s) => Some(s.parse().map_err(|_| "bad --monitor-gate")?),
                None => None,
            };
            if monitor_every > 0 && chains < 2 {
                return Err(
                    "--monitor-every on fig9 compares repeated trials: use --chains 2 or more"
                        .into(),
                );
            }
            if monitor_gate.is_some() && monitor_every == 0 {
                return Err(
                    "--monitor-gate needs --monitor-every to produce snapshots to gate on"
                        .into(),
                );
            }
            if chains > 1 {
                // repeated trials, run concurrently on the worker pool,
                // with streaming cross-trial convergence snapshots when
                // --monitor-every is given
                let mut t = Table::new(&["method", "trial", "seconds", "phi ESS/s", "sig ESS/s"]);
                let mut all_snaps = Vec::new();
                for (label, sub) in [("exact-mh", false), ("subsampled", true)] {
                    let (rs, snaps) = exp::fig9_repeated_monitored(
                        &cfg,
                        sub,
                        chains,
                        monitor_every,
                        monitor_gate,
                    )?;
                    for (i, r) in rs.iter().enumerate() {
                        t.row(&[
                            label.to_string(),
                            i.to_string(),
                            format!("{:.2}", r.seconds),
                            format!("{:.3}", r.phi_ess_per_sec),
                            format!("{:.3}", r.sig_ess_per_sec),
                        ]);
                    }
                    for s in &snaps {
                        println!("{label} {}", s.render());
                    }
                    // draws-to-gate accounting per method: where the
                    // gate fired and the sections consumed up to it /
                    // in total, so fixed-eps and --target-risk methods
                    // compare on compute-to-convergence (the same
                    // running total lands in fig9_monitor.csv's
                    // cum_sections column)
                    let total_sections: usize =
                        snaps.iter().map(|s| s.sections_scored()).sum();
                    match monitor_gate {
                        Some(r) => {
                            let mut to_gate = 0usize;
                            let mut gate_draws = None;
                            for s in &snaps {
                                to_gate += s.sections_scored();
                                if s.gate_passed(r) {
                                    gate_draws = Some(s.draws_per_chain);
                                    break;
                                }
                            }
                            match gate_draws {
                                Some(n) => println!(
                                    "{label}: draws-to-gate {n}/trial (rank R-hat < {r}), \
                                     sections-to-gate {to_gate}, total sections {total_sections}"
                                ),
                                None => println!(
                                    "{label}: gate rank R-hat < {r} not reached, \
                                     total sections {total_sections}"
                                ),
                            }
                        }
                        None if total_sections > 0 => {
                            println!("{label}: total sections {total_sections}")
                        }
                        None => {}
                    }
                    all_snaps.push((label, snaps));
                }
                t.print();
                if all_snaps.iter().any(|(_, s)| !s.is_empty()) {
                    let groups: Vec<(&str, &[DiagSnapshot])> = all_snaps
                        .iter()
                        .map(|(l, s)| (*l, s.as_slice()))
                        .collect();
                    let csv = monitor_csv(&groups);
                    csv.write_to(&outdir.join("fig9_monitor.csv"))
                        .map_err(|e| e.to_string())?;
                    println!("wrote {}", outdir.join("fig9_monitor.csv").display());
                }
                return Ok(());
            }
            let exact = exp::fig9_sv(&cfg, false);
            let sub = exp::fig9_sv(&cfg, true);
            let mut t = Table::new(&[
                "method",
                "seconds",
                "phi mean",
                "sig mean",
                "phi ESS/s",
                "sig ESS/s",
            ]);
            for r in [&exact, &sub] {
                let pm = r.phi_samples.iter().sum::<f64>() / r.phi_samples.len() as f64;
                let sm = r.sig_samples.iter().sum::<f64>() / r.sig_samples.len() as f64;
                t.row(&[
                    r.label.clone(),
                    format!("{:.2}", r.seconds),
                    format!("{:.4}", pm),
                    format!("{:.4}", sm),
                    format!("{:.3}", r.phi_ess_per_sec),
                    format!("{:.3}", r.sig_ess_per_sec),
                ]);
            }
            t.print();
            let (hist, acf) = exp::fig9_csv(&[exact, sub], 30);
            hist.write_to(&outdir.join("fig9_hist.csv"))
                .map_err(|e| e.to_string())?;
            acf.write_to(&outdir.join("fig9_acf.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig9_hist.csv").display());
        }
        other => return Err(format!("unknown experiment {other}")),
    }
    Ok(())
}
