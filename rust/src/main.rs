//! subppl CLI — run probabilistic programs and regenerate the paper's
//! experiments.
//!
//! ```text
//! subppl run <program.vnt> [--infer "<program>"] [--seed N] [--watch a,b]
//! subppl experiment <table1|fig4|fig5|fig6|fig9> [--fast] [--fused]
//! subppl artifacts                 # list the AOT artifact registry
//! ```

use std::io::Read;
use subppl::coordinator::experiments as exp;
use subppl::coordinator::report::{results_dir, Table};
use subppl::coordinator::FusedEval;
use subppl::infer::{infer, parse_infer, LocalEvaluator, PlannedEval};
use subppl::math::Pcg64;
use subppl::trace::Trace;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

fn run(args: &[String]) -> Result<(), String> {
    match args.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("experiment") => cmd_experiment(args),
        Some("artifacts") => cmd_artifacts(),
        _ => {
            eprintln!(
                "usage:\n  subppl run <program.vnt> [--infer \"(cycle ...)\"] [--seed N] [--samples K] [--watch a,b]\n  subppl experiment <table1|fig4|fig5|fig6|fig9> [--fast] [--fused]\n  subppl artifacts"
            );
            Err("missing or unknown subcommand".into())
        }
    }
}

fn cmd_run(args: &[String]) -> Result<(), String> {
    let path = args.get(1).ok_or("run: missing program path")?;
    let mut src = String::new();
    if path == "-" {
        std::io::stdin()
            .read_to_string(&mut src)
            .map_err(|e| e.to_string())?;
    } else {
        src = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
    }
    let seed: u64 = opt(args, "--seed")
        .unwrap_or("0")
        .parse()
        .map_err(|_| "bad --seed")?;
    let samples: usize = opt(args, "--samples")
        .unwrap_or("100")
        .parse()
        .map_err(|_| "bad --samples")?;
    let mut trace = Trace::new();
    let mut rng = Pcg64::seeded(seed);
    trace.run_program(&src, &mut rng)?;
    println!("trace: {} live nodes", trace.num_live_nodes());
    println!("log joint: {:.4}", trace.log_joint());
    if let Some(prog) = opt(args, "--infer") {
        let cmd = parse_infer(prog)?;
        let names: Vec<String> = opt(args, "--watch")
            .map(|p| p.split(',').map(|s| s.to_string()).collect())
            .unwrap_or_default();
        let mut sums: Vec<f64> = vec![0.0; names.len()];
        for s in 0..samples {
            let stats = infer(&mut trace, &mut rng, &cmd)?;
            if s == 0 {
                println!(
                    "per-iteration: {} transitions, acceptance {:.3}",
                    stats.transitions,
                    stats.acceptance_rate()
                );
            }
            for (i, n) in names.iter().enumerate() {
                if let Some(v) = trace.lookup_value(n).and_then(|v| v.as_f64()) {
                    sums[i] += v;
                }
            }
        }
        for (i, n) in names.iter().enumerate() {
            println!("posterior mean {n}: {:.5}", sums[i] / samples as f64);
        }
        println!("final log joint: {:.4}", trace.log_joint());
    }
    Ok(())
}

fn cmd_artifacts() -> Result<(), String> {
    let reg = subppl::runtime::ArtifactRegistry::open_default()?;
    let mut t = Table::new(&["name", "kind", "m", "d"]);
    for a in reg.infos() {
        t.row(&[
            a.name.clone(),
            a.kind.clone(),
            a.m.to_string(),
            a.d.to_string(),
        ]);
    }
    t.print();
    Ok(())
}

fn evaluator_for(args: &[String]) -> Box<dyn LocalEvaluator> {
    if flag(args, "--fused") {
        match FusedEval::open_default() {
            Ok(f) => return Box::new(f),
            Err(e) => eprintln!("--fused unavailable ({e}); falling back to planned evaluator"),
        }
    }
    Box::new(PlannedEval::new())
}

fn cmd_experiment(args: &[String]) -> Result<(), String> {
    let which = args.get(1).ok_or("experiment: missing name")?;
    let fast = flag(args, "--fast");
    let mut evaluator = evaluator_for(args);
    let outdir = results_dir();
    match which.as_str() {
        "table1" => {
            let rows = exp::table1_scaling(3);
            let mut t = Table::new(&["model", "N_small", "N_large", "t_small(s)", "t_large(s)", "exponent"]);
            for r in &rows {
                t.row(&[
                    r.model.clone(),
                    r.n_small.to_string(),
                    r.n_large.to_string(),
                    format!("{:.5}", r.t_small),
                    format!("{:.5}", r.t_large),
                    format!("{:.2}", r.exponent),
                ]);
            }
            t.print();
        }
        "fig5" => {
            let cfg = if fast {
                exp::Fig5Config {
                    ns: vec![1_000, 3_000, 10_000],
                    iters: 30,
                    ..Default::default()
                }
            } else {
                exp::Fig5Config::default()
            };
            let rows = exp::fig5_sublinear(&cfg, evaluator.as_mut());
            let mut t = Table::new(&["N", "sections/iter", "E[sections]", "t_sub(s)", "t_exact(s)"]);
            for r in &rows {
                t.row(&[
                    r.n.to_string(),
                    format!("{:.1}", r.avg_sections),
                    format!("{:.1}", r.expected_sections),
                    format!("{:.5}", r.time_sub),
                    format!("{:.5}", r.time_exact),
                ]);
            }
            t.print();
            exp::fig5_csv(&rows)
                .write_to(&outdir.join("fig5_sublinear.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig5_sublinear.csv").display());
        }
        "fig4" => {
            let cfg = if fast {
                exp::Fig4Config {
                    n_train: 2000,
                    n_test: 500,
                    steps: 100,
                    record_every: 5,
                    ..Default::default()
                }
            } else {
                exp::Fig4Config::default()
            };
            let curves = exp::fig4_risk(&cfg, evaluator.as_mut());
            let mut t = Table::new(&[
                "method",
                "transitions",
                "accept%",
                "final risk",
                "final 0-1",
                "JB p",
            ]);
            for c in &curves {
                let last = c.points.last().copied().unwrap_or((0.0, f64::NAN, f64::NAN));
                t.row(&[
                    c.label.clone(),
                    c.transitions.to_string(),
                    format!("{:.1}", 100.0 * c.accepted as f64 / c.transitions as f64),
                    format!("{:.5}", last.1),
                    format!("{:.4}", last.2),
                    format!("{:.3}", c.normality_p),
                ]);
            }
            t.print();
            exp::fig4_csv(&curves)
                .write_to(&outdir.join("fig4_risk.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig4_risk.csv").display());
        }
        "fig6" => {
            let cfg = if fast {
                exp::Fig6Config {
                    n_train: 300,
                    n_test: 150,
                    sweeps: 10,
                    step_z: 30,
                    ..Default::default()
                }
            } else {
                exp::Fig6Config::default()
            };
            let mut t = Table::new(&["method", "sweep", "seconds", "accuracy", "clusters"]);
            for (label, sub) in [("exact-mh", false), ("subsampled-eps0.3", true)] {
                let pts = exp::fig6_dpm(&cfg, sub);
                for (i, p) in pts.iter().enumerate() {
                    t.row(&[
                        label.to_string(),
                        i.to_string(),
                        format!("{:.2}", p.seconds),
                        format!("{:.4}", p.accuracy),
                        p.clusters.to_string(),
                    ]);
                }
            }
            t.print();
        }
        "fig9" => {
            let cfg = if fast {
                exp::Fig9Config {
                    series: 30,
                    sweeps: 60,
                    ..Default::default()
                }
            } else {
                exp::Fig9Config::default()
            };
            let exact = exp::fig9_sv(&cfg, false);
            let sub = exp::fig9_sv(&cfg, true);
            let mut t = Table::new(&[
                "method",
                "seconds",
                "phi mean",
                "sig mean",
                "phi ESS/s",
                "sig ESS/s",
            ]);
            for r in [&exact, &sub] {
                let pm = r.phi_samples.iter().sum::<f64>() / r.phi_samples.len() as f64;
                let sm = r.sig_samples.iter().sum::<f64>() / r.sig_samples.len() as f64;
                t.row(&[
                    r.label.clone(),
                    format!("{:.2}", r.seconds),
                    format!("{:.4}", pm),
                    format!("{:.4}", sm),
                    format!("{:.3}", r.phi_ess_per_sec),
                    format!("{:.3}", r.sig_ess_per_sec),
                ]);
            }
            t.print();
            let (hist, acf) = exp::fig9_csv(&[exact, sub], 30);
            hist.write_to(&outdir.join("fig9_hist.csv"))
                .map_err(|e| e.to_string())?;
            acf.write_to(&outdir.join("fig9_acf.csv"))
                .map_err(|e| e.to_string())?;
            println!("wrote {}", outdir.join("fig9_hist.csv").display());
        }
        other => return Err(format!("unknown experiment {other}")),
    }
    Ok(())
}
