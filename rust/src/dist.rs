//! Probability distributions: scalar log densities, samplers, and the
//! sufficient-statistics state of the exchangeable families (CRP,
//! collapsed normal-inverse-Wishart).
//!
//! Conventions:
//! * `gamma(a, b)` is shape/rate; `inv_gamma(a, b)` is shape/scale, so
//!   `1/X ~ InvGamma(a, b)` when `X ~ Gamma(a, rate = b)`.
//! * `normal(mu, sigma)` takes the standard deviation.
//! * Out-of-support values score `-inf` rather than erroring, so MH
//!   proposals that leave the support are rejected by the ratio.

use crate::math::special::{ln_beta, ln_gamma, log_sigmoid};
use crate::math::Pcg64;
use std::collections::BTreeMap;

const LN_2PI: f64 = 1.837_877_066_409_345_3;
const LN_PI: f64 = 1.144_729_885_849_400_2;

// ---------------------------------------------------------------------
// scalar log densities
// ---------------------------------------------------------------------

pub fn bernoulli_logpmf(b: bool, p: f64) -> f64 {
    if !(0.0..=1.0).contains(&p) {
        return f64::NEG_INFINITY;
    }
    if b {
        p.ln()
    } else {
        (1.0 - p).ln()
    }
}

/// log Bernoulli(b | sigmoid(z)) without forming the probability —
/// numerically stable for |z| large (the fused-kernel formula).
pub fn bernoulli_logit_logpmf(b: bool, z: f64) -> f64 {
    if b {
        log_sigmoid(z)
    } else {
        log_sigmoid(-z)
    }
}

pub fn normal_logpdf(x: f64, mu: f64, sigma: f64) -> f64 {
    if !(sigma > 0.0) {
        return f64::NEG_INFINITY;
    }
    let z = (x - mu) / sigma;
    -0.5 * z * z - sigma.ln() - 0.5 * LN_2PI
}

/// Gamma(shape a, rate b).
pub fn gamma_logpdf(x: f64, a: f64, b: f64) -> f64 {
    if !(a > 0.0 && b > 0.0) || !(x > 0.0) {
        return f64::NEG_INFINITY;
    }
    a * b.ln() + (a - 1.0) * x.ln() - b * x - ln_gamma(a)
}

/// InvGamma(shape a, scale b).
pub fn inv_gamma_logpdf(x: f64, a: f64, b: f64) -> f64 {
    if !(a > 0.0 && b > 0.0) || !(x > 0.0) {
        return f64::NEG_INFINITY;
    }
    a * b.ln() - (a + 1.0) * x.ln() - b / x - ln_gamma(a)
}

pub fn beta_logpdf(x: f64, a: f64, b: f64) -> f64 {
    if !(a > 0.0 && b > 0.0) || !(0.0..=1.0).contains(&x) {
        return f64::NEG_INFINITY;
    }
    // guard 0 * ln(0) at the support edges when an exponent is exactly 0
    let t1 = if a == 1.0 { 0.0 } else { (a - 1.0) * x.ln() };
    let t2 = if b == 1.0 { 0.0 } else { (b - 1.0) * (1.0 - x).ln() };
    t1 + t2 - ln_beta(a, b)
}

pub fn uniform_logpdf(x: f64, a: f64, b: f64) -> f64 {
    if !(b > a) || x < a || x > b {
        return f64::NEG_INFINITY;
    }
    -(b - a).ln()
}

/// Student-t with `nu` dof, location `loc`, scale `scale`.
pub fn student_t_logpdf(x: f64, nu: f64, loc: f64, scale: f64) -> f64 {
    if !(nu > 0.0 && scale > 0.0) {
        return f64::NEG_INFINITY;
    }
    let z = (x - loc) / scale;
    ln_gamma(0.5 * (nu + 1.0)) - ln_gamma(0.5 * nu)
        - 0.5 * (nu * std::f64::consts::PI).ln()
        - scale.ln()
        - 0.5 * (nu + 1.0) * (z * z / nu).ln_1p()
}

// ---------------------------------------------------------------------
// samplers (thin, convention-fixing wrappers over math::Pcg64)
// ---------------------------------------------------------------------

/// Namespaced samplers matching the log densities above.
pub struct Samplers;

impl Samplers {
    pub fn bernoulli(rng: &mut Pcg64, p: f64) -> bool {
        rng.bernoulli(p)
    }

    pub fn normal(rng: &mut Pcg64, mu: f64, sigma: f64) -> f64 {
        rng.normal_scaled(mu, sigma)
    }

    /// Gamma(shape, rate).
    pub fn gamma(rng: &mut Pcg64, shape: f64, rate: f64) -> f64 {
        rng.gamma(shape) / rate
    }

    /// InvGamma(shape, scale).
    pub fn inv_gamma(rng: &mut Pcg64, shape: f64, scale: f64) -> f64 {
        scale / rng.gamma(shape)
    }

    pub fn beta(rng: &mut Pcg64, a: f64, b: f64) -> f64 {
        rng.beta(a, b)
    }

    pub fn uniform(rng: &mut Pcg64, a: f64, b: f64) -> f64 {
        a + (b - a) * rng.uniform()
    }

    pub fn student_t(rng: &mut Pcg64, nu: f64, loc: f64, scale: f64) -> f64 {
        loc + scale * rng.student_t(nu)
    }
}

// ---------------------------------------------------------------------
// CRP sufficient statistics
// ---------------------------------------------------------------------

/// Seating counts of a Chinese restaurant process instance.
///
/// Tables are `i64` ids; a `BTreeMap` keeps enumeration order
/// deterministic (bit-reproducible categorical draws and gibbs
/// candidate lists).  Fresh tables come from a monotone counter so a
/// freed id is never silently resurrected with stale mem-cache state.
#[derive(Clone, Debug, Default)]
pub struct CrpAux {
    counts: BTreeMap<i64, usize>,
    n: usize,
    next_table: i64,
}

impl CrpAux {
    pub fn new() -> CrpAux {
        CrpAux::default()
    }

    /// Total number of incorporated customers.
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn count(&self, table: i64) -> usize {
        self.counts.get(&table).copied().unwrap_or(0)
    }

    /// Occupied tables in ascending id order.
    pub fn tables(&self) -> Vec<i64> {
        self.counts.keys().copied().collect()
    }

    pub fn num_tables(&self) -> usize {
        self.counts.len()
    }

    /// An id no table has ever used (safe as a gibbs auxiliary table).
    pub fn fresh_table(&self) -> i64 {
        self.next_table
    }

    pub fn incorporate(&mut self, table: i64) {
        *self.counts.entry(table).or_insert(0) += 1;
        self.n += 1;
        self.next_table = self.next_table.max(table + 1);
    }

    pub fn unincorporate(&mut self, table: i64) {
        let c = self
            .counts
            .get_mut(&table)
            .expect("crp unincorporate: table has no customers");
        *c -= 1;
        if *c == 0 {
            self.counts.remove(&table);
        }
        self.n -= 1;
    }

    /// Predictive log probability of seating the next customer at
    /// `table` (which may be unoccupied => the alpha/new-table term).
    pub fn predictive_logp(&self, table: i64, alpha: f64) -> f64 {
        let denom = self.n as f64 + alpha;
        match self.count(table) {
            0 => (alpha / denom).ln(),
            c => (c as f64 / denom).ln(),
        }
    }

    /// Draw the next customer's table from the predictive.
    pub fn sample(&self, rng: &mut Pcg64, alpha: f64) -> i64 {
        let total = self.n as f64 + alpha;
        let mut u = rng.uniform() * total;
        for (&t, &c) in &self.counts {
            u -= c as f64;
            if u <= 0.0 {
                return t;
            }
        }
        self.next_table
    }

    /// Joint log probability of the current seating (EPPF): the product
    /// of the predictive chain in any insertion order,
    /// `alpha^K prod_t (c_t - 1)! / prod_{i<n} (alpha + i)`.
    pub fn seating_logp(&self, alpha: f64) -> f64 {
        if !(alpha > 0.0) {
            return f64::NEG_INFINITY;
        }
        let mut lp = self.counts.len() as f64 * alpha.ln();
        for &c in self.counts.values() {
            lp += ln_gamma(c as f64);
        }
        lp + ln_gamma(alpha) - ln_gamma(alpha + self.n as f64)
    }
}

// ---------------------------------------------------------------------
// small dense matrix helpers (d is 2..50 in the paper's programs)
// ---------------------------------------------------------------------

/// Lower-triangular Cholesky factor of a symmetric positive-definite
/// matrix; None if the matrix is not PD (or not square).
fn cholesky(a: &[Vec<f64>]) -> Option<Vec<Vec<f64>>> {
    let d = a.len();
    let mut l = vec![vec![0.0; d]; d];
    for i in 0..d {
        if a[i].len() != d {
            return None;
        }
        for j in 0..=i {
            let mut s = a[i][j];
            for k in 0..j {
                s -= l[i][k] * l[j][k];
            }
            if i == j {
                if s <= 0.0 || !s.is_finite() {
                    return None;
                }
                l[i][i] = s.sqrt();
            } else {
                l[i][j] = s / l[j][j];
            }
        }
    }
    Some(l)
}

/// log |A| from A's Cholesky factor.
fn chol_logdet(l: &[Vec<f64>]) -> f64 {
    2.0 * l.iter().enumerate().map(|(i, row)| row[i].ln()).sum::<f64>()
}

/// Solve L y = b (forward substitution) and return |y|^2 = b' A^-1 b.
fn chol_quadform(l: &[Vec<f64>], b: &[f64]) -> f64 {
    let d = b.len();
    let mut y = vec![0.0; d];
    let mut q = 0.0;
    for i in 0..d {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i][k] * y[k];
        }
        let yi = s / l[i][i];
        y[i] = yi;
        q += yi * yi;
    }
    q
}

// ---------------------------------------------------------------------
// multivariate normal
// ---------------------------------------------------------------------

/// A multivariate normal with precomputed Cholesky factor of the
/// covariance.  Degenerate parameterizations (non-positive variances)
/// build an invalid instance that scores `-inf` everywhere.
#[derive(Clone, Debug)]
pub struct MvNormal {
    mean: Vec<f64>,
    /// Lower-triangular Cholesky factor of the covariance; empty when
    /// the parameterization is invalid.
    chol: Vec<Vec<f64>>,
    log_det: f64,
    valid: bool,
}

impl MvNormal {
    /// Covariance `var * I`.
    pub fn isotropic(mean: Vec<f64>, var: f64) -> MvNormal {
        let d = mean.len();
        Self::diagonal(mean, vec![var; d])
    }

    /// Diagonal covariance.
    pub fn diagonal(mean: Vec<f64>, vars: Vec<f64>) -> MvNormal {
        let d = mean.len();
        if vars.len() != d || vars.iter().any(|&v| !(v > 0.0)) {
            return MvNormal {
                mean,
                chol: Vec::new(),
                log_det: f64::NAN,
                valid: false,
            };
        }
        let mut chol = vec![vec![0.0; d]; d];
        let mut log_det = 0.0;
        for i in 0..d {
            chol[i][i] = vars[i].sqrt();
            log_det += vars[i].ln();
        }
        MvNormal {
            mean,
            chol,
            log_det,
            valid: true,
        }
    }

    /// Full covariance matrix; None on shape mismatch or non-PD input.
    pub fn full(mean: Vec<f64>, cov: &[Vec<f64>]) -> Option<MvNormal> {
        if cov.len() != mean.len() {
            return None;
        }
        let chol = cholesky(cov)?;
        let log_det = chol_logdet(&chol);
        Some(MvNormal {
            mean,
            chol,
            log_det,
            valid: true,
        })
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    pub fn logpdf(&self, x: &[f64]) -> f64 {
        if !self.valid || x.len() != self.mean.len() {
            return f64::NEG_INFINITY;
        }
        let d = self.mean.len();
        let diff: Vec<f64> = x.iter().zip(&self.mean).map(|(a, b)| a - b).collect();
        let q = chol_quadform(&self.chol, &diff);
        -0.5 * q - 0.5 * self.log_det - 0.5 * d as f64 * LN_2PI
    }

    pub fn sample(&self, rng: &mut Pcg64) -> Vec<f64> {
        let d = self.mean.len();
        if !self.valid {
            return vec![f64::NAN; d];
        }
        let z: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut out = self.mean.clone();
        for i in 0..d {
            for (k, &zk) in z.iter().enumerate().take(i + 1) {
                out[i] += self.chol[i][k] * zk;
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// collapsed normal-inverse-Wishart
// ---------------------------------------------------------------------

/// Sufficient statistics of a collapsed NIW feature model (the JointDPM
/// per-cluster density).  Incorporate/unincorporate are O(d^2); scoring
/// is O(d^3) on the (tiny) per-cluster posterior matrices.
///
/// Formulas follow Murphy, "Conjugate Bayesian analysis of the Gaussian
/// distribution": posterior (k_n, v_n, m_n, S_n), multivariate-t
/// predictive, and the closed-form marginal likelihood.
#[derive(Clone, Debug)]
pub struct CollapsedNiw {
    pub m0: Vec<f64>,
    pub k0: f64,
    pub v0: f64,
    pub s0: Vec<Vec<f64>>,
    n: usize,
    /// sum_i x_i
    sum: Vec<f64>,
    /// sum_i x_i x_i'
    sumsq: Vec<Vec<f64>>,
}

impl CollapsedNiw {
    pub fn new(m0: Vec<f64>, k0: f64, v0: f64, s0: Vec<Vec<f64>>) -> CollapsedNiw {
        let d = m0.len();
        assert!(k0 > 0.0, "NIW k0 must be > 0");
        assert!(v0 > d as f64 - 1.0, "NIW v0 must exceed d - 1");
        assert_eq!(s0.len(), d, "NIW S0 must be d x d");
        CollapsedNiw {
            m0,
            k0,
            v0,
            s0,
            n: 0,
            sum: vec![0.0; d],
            sumsq: vec![vec![0.0; d]; d],
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.m0.len()
    }

    pub fn incorporate(&mut self, x: &[f64]) {
        assert_eq!(x.len(), self.d(), "NIW incorporate: dim mismatch");
        self.n += 1;
        for i in 0..x.len() {
            self.sum[i] += x[i];
            for j in 0..x.len() {
                self.sumsq[i][j] += x[i] * x[j];
            }
        }
    }

    pub fn unincorporate(&mut self, x: &[f64]) {
        assert!(self.n > 0, "NIW unincorporate on empty state");
        assert_eq!(x.len(), self.d(), "NIW unincorporate: dim mismatch");
        self.n -= 1;
        for i in 0..x.len() {
            self.sum[i] -= x[i];
            for j in 0..x.len() {
                self.sumsq[i][j] -= x[i] * x[j];
            }
        }
    }

    /// Posterior hyperparameters (k_n, v_n, m_n, S_n) from the current
    /// sufficient statistics:
    ///   S_n = S_0 + sumsq + k_0 m_0 m_0' - k_n m_n m_n'.
    fn posterior(&self) -> (f64, f64, Vec<f64>, Vec<Vec<f64>>) {
        let d = self.d();
        let kn = self.k0 + self.n as f64;
        let vn = self.v0 + self.n as f64;
        let mn: Vec<f64> = (0..d)
            .map(|i| (self.k0 * self.m0[i] + self.sum[i]) / kn)
            .collect();
        let mut sn = self.s0.clone();
        for i in 0..d {
            for j in 0..d {
                sn[i][j] += self.sumsq[i][j] + self.k0 * self.m0[i] * self.m0[j]
                    - kn * mn[i] * mn[j];
            }
        }
        (kn, vn, mn, sn)
    }

    /// Predictive density: multivariate Student-t with
    /// nu = v_n - d + 1, location m_n, scale S_n (k_n + 1)/(k_n nu).
    pub fn predictive_logpdf(&self, x: &[f64]) -> f64 {
        let d = self.d();
        if x.len() != d {
            return f64::NEG_INFINITY;
        }
        let (kn, vn, mn, sn) = self.posterior();
        let nu = vn - d as f64 + 1.0;
        let scale = (kn + 1.0) / (kn * nu);
        let sigma: Vec<Vec<f64>> = sn
            .iter()
            .map(|row| row.iter().map(|v| v * scale).collect())
            .collect();
        let Some(l) = cholesky(&sigma) else {
            return f64::NEG_INFINITY;
        };
        let diff: Vec<f64> = x.iter().zip(&mn).map(|(a, b)| a - b).collect();
        let q = chol_quadform(&l, &diff);
        ln_gamma(0.5 * (nu + d as f64)) - ln_gamma(0.5 * nu)
            - 0.5 * d as f64 * (nu.ln() + LN_PI)
            - 0.5 * chol_logdet(&l)
            - 0.5 * (nu + d as f64) * (q / nu).ln_1p()
    }

    /// Draw from the multivariate-t predictive.
    pub fn predictive_sample(&self, rng: &mut Pcg64) -> Vec<f64> {
        let d = self.d();
        let (kn, vn, mn, sn) = self.posterior();
        let nu = vn - d as f64 + 1.0;
        let scale = (kn + 1.0) / (kn * nu);
        let sigma: Vec<Vec<f64>> = sn
            .iter()
            .map(|row| row.iter().map(|v| v * scale).collect())
            .collect();
        let Some(l) = cholesky(&sigma) else {
            return vec![f64::NAN; d];
        };
        // x = m_n + L z sqrt(nu / w), w ~ chi2(nu)
        let w = rng.chi2(nu);
        let s = (nu / w).sqrt();
        let z: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut out = mn;
        for i in 0..d {
            for (k, &zk) in z.iter().enumerate().take(i + 1) {
                out[i] += l[i][k] * zk * s;
            }
        }
        out
    }

    /// Closed-form marginal log likelihood of everything incorporated
    /// (the AAA score when the maker's hyperparameters are in D):
    ///   log p(X) = -(n d / 2) log pi
    ///            + lnGamma_d(v_n/2) - lnGamma_d(v_0/2)
    ///            + (v_0/2) log|S_0| - (v_n/2) log|S_n|
    ///            + (d/2)(log k_0 - log k_n).
    pub fn marginal_loglik(&self) -> f64 {
        let d = self.d();
        if self.n == 0 {
            return 0.0;
        }
        let (kn, vn, _, sn) = self.posterior();
        let (Some(l0), Some(ln_)) = (cholesky(&self.s0), cholesky(&sn)) else {
            return f64::NEG_INFINITY;
        };
        -0.5 * (self.n * d) as f64 * LN_PI
            + ln_multigamma(d, 0.5 * vn)
            - ln_multigamma(d, 0.5 * self.v0)
            + 0.5 * self.v0 * chol_logdet(&l0)
            - 0.5 * vn * chol_logdet(&ln_)
            + 0.5 * d as f64 * (self.k0.ln() - kn.ln())
    }
}

/// Multivariate log-gamma: ln Gamma_d(a).
fn ln_multigamma(d: usize, a: f64) -> f64 {
    let mut s = 0.25 * (d * (d - 1)) as f64 * LN_PI;
    for j in 1..=d {
        s += ln_gamma(a + 0.5 * (1.0 - j as f64));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normal_logpdf_known() {
        // standard normal at 0: -0.5 ln(2 pi)
        assert!((normal_logpdf(0.0, 0.0, 1.0) + 0.918_938_533_204_672_7).abs() < 1e-12);
        // scaling: N(1, 2^2) at 3 = phi(1)/2
        let want = -0.5 - 2f64.ln() - 0.5 * LN_2PI;
        assert!((normal_logpdf(3.0, 1.0, 2.0) - want).abs() < 1e-12);
        assert_eq!(normal_logpdf(0.0, 0.0, 0.0), f64::NEG_INFINITY);
        assert_eq!(normal_logpdf(0.0, 0.0, -1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn bernoulli_variants_agree() {
        for &z in &[-3.0, -0.5, 0.0, 0.7, 4.0] {
            let p = 1.0 / (1.0 + (-z as f64).exp());
            for &b in &[true, false] {
                let a = bernoulli_logpmf(b, p);
                let c = bernoulli_logit_logpmf(b, z);
                assert!((a - c).abs() < 1e-12, "z={z} b={b}: {a} vs {c}");
            }
        }
        assert_eq!(bernoulli_logpmf(true, 1.5), f64::NEG_INFINITY);
    }

    #[test]
    fn gamma_inv_gamma_consistency() {
        // scipy.stats.gamma(2, scale=1/3).logpdf(0.5) = ln(9*0.5*e^-1.5)
        let want = (9.0f64 * 0.5).ln() - 1.5;
        assert!((gamma_logpdf(0.5, 2.0, 3.0) - want).abs() < 1e-12);
        // if X ~ Gamma(a, rate b) then Y = 1/X ~ InvGamma(a, b):
        // f_Y(y) = f_X(1/y) / y^2
        for &(a, b, y) in &[(2.0, 3.0, 0.7), (5.0, 0.05, 0.01), (1.0, 1.0, 2.0)] {
            let lhs = inv_gamma_logpdf(y, a, b);
            let rhs = gamma_logpdf(1.0 / y, a, b) - 2.0 * y.ln();
            assert!((lhs - rhs).abs() < 1e-10, "({a},{b},{y})");
        }
        assert_eq!(gamma_logpdf(-1.0, 2.0, 1.0), f64::NEG_INFINITY);
    }

    #[test]
    fn beta_logpdf_known_and_edges() {
        // Beta(2,2) at 0.5: ln(6 * 0.25)
        assert!((beta_logpdf(0.5, 2.0, 2.0) - 1.5f64.ln()).abs() < 1e-12);
        // Beta(5,1) at 1.0: density 5 x^4 -> ln 5 (edge must not NaN)
        assert!((beta_logpdf(1.0, 5.0, 1.0) - 5f64.ln()).abs() < 1e-12);
        assert_eq!(beta_logpdf(-0.1, 2.0, 2.0), f64::NEG_INFINITY);
        assert_eq!(beta_logpdf(1.1, 2.0, 2.0), f64::NEG_INFINITY);
    }

    #[test]
    fn student_t_matches_cauchy_and_normal_limits() {
        // nu=1 is Cauchy: ln(1/(pi (1 + x^2)))
        let want = -(std::f64::consts::PI * (1.0 + 4.0)).ln();
        assert!((student_t_logpdf(2.0, 1.0, 0.0, 1.0) - want).abs() < 1e-10);
        // large nu approaches the normal
        let t = student_t_logpdf(0.7, 1e7, 0.0, 1.0);
        let n = normal_logpdf(0.7, 0.0, 1.0);
        assert!((t - n).abs() < 1e-5, "{t} vs {n}");
    }

    #[test]
    fn samplers_match_densities_in_moments() {
        let mut rng = Pcg64::seeded(1);
        let n = 60_000;
        // Gamma(3, rate 2): mean 1.5
        let m: f64 = (0..n).map(|_| Samplers::gamma(&mut rng, 3.0, 2.0)).sum::<f64>() / n as f64;
        assert!((m - 1.5).abs() < 0.03, "gamma mean {m}");
        // InvGamma(5, scale 0.05): mean 0.05/4
        let m: f64 =
            (0..n).map(|_| Samplers::inv_gamma(&mut rng, 5.0, 0.05)).sum::<f64>() / n as f64;
        assert!((m - 0.0125).abs() < 2e-4, "inv_gamma mean {m}");
        // Uniform(-1, 3): mean 1
        let m: f64 = (0..n).map(|_| Samplers::uniform(&mut rng, -1.0, 3.0)).sum::<f64>() / n as f64;
        assert!((m - 1.0).abs() < 0.02, "uniform mean {m}");
    }

    #[test]
    fn crp_roundtrip_and_eppf() {
        let mut aux = CrpAux::new();
        let alpha = 1.3;
        assert_eq!(aux.predictive_logp(0, alpha), 0.0); // first customer
        aux.incorporate(0);
        aux.incorporate(0);
        aux.incorporate(1);
        assert_eq!(aux.n(), 3);
        assert_eq!(aux.count(0), 2);
        assert_eq!(aux.tables(), vec![0, 1]);
        assert_eq!(aux.fresh_table(), 2);
        // EPPF equals the telescoped predictive chain
        let chain = (alpha / alpha).ln()
            + (1.0 / (1.0 + alpha)).ln()
            + (alpha / (2.0 + alpha)).ln();
        assert!((aux.seating_logp(alpha) - chain).abs() < 1e-12);
        aux.unincorporate(1);
        assert_eq!(aux.tables(), vec![0]);
        // freed id is never reissued
        assert_eq!(aux.fresh_table(), 2);
    }

    #[test]
    fn crp_sample_matches_predictive() {
        let mut aux = CrpAux::new();
        for _ in 0..6 {
            aux.incorporate(0);
        }
        for _ in 0..2 {
            aux.incorporate(1);
        }
        let alpha = 2.0;
        let mut rng = Pcg64::seeded(7);
        let mut counts = std::collections::HashMap::new();
        let trials = 50_000;
        for _ in 0..trials {
            *counts.entry(aux.sample(&mut rng, alpha)).or_insert(0usize) += 1;
        }
        let frac0 = counts[&0] as f64 / trials as f64;
        let fresh = counts.get(&aux.fresh_table()).copied().unwrap_or(0) as f64 / trials as f64;
        assert!((frac0 - 0.6).abs() < 0.01, "{frac0}");
        assert!((fresh - 0.2).abs() < 0.01, "{fresh}");
    }

    #[test]
    fn mvn_logpdf_matches_scalar_product() {
        let mvn = MvNormal::isotropic(vec![1.0, -2.0], 4.0);
        let x = [0.0, 0.0];
        let want = normal_logpdf(0.0, 1.0, 2.0) + normal_logpdf(0.0, -2.0, 2.0);
        assert!((mvn.logpdf(&x) - want).abs() < 1e-12);
        // full covariance agrees with diagonal when off-diagonals are 0
        let full = MvNormal::full(
            vec![1.0, -2.0],
            &[vec![4.0, 0.0], vec![0.0, 4.0]],
        )
        .unwrap();
        assert!((full.logpdf(&x) - want).abs() < 1e-12);
        // non-PD covariance is rejected
        assert!(MvNormal::full(vec![0.0, 0.0], &[vec![1.0, 2.0], vec![2.0, 1.0]]).is_none());
        // invalid variance scores -inf
        assert_eq!(MvNormal::isotropic(vec![0.0], -1.0).logpdf(&[0.0]), f64::NEG_INFINITY);
    }

    #[test]
    fn mvn_correlated_logpdf_known() {
        // cov [[2, 1], [1, 2]]: det 3, inv = [[2,-1],[-1,2]]/3
        let mvn = MvNormal::full(vec![0.0, 0.0], &[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let x = [1.0, -1.0];
        // det = 3, x' cov^-1 x = 2
        let want = -0.5 * 2.0 - 0.5 * 3f64.ln() - LN_2PI;
        assert!((mvn.logpdf(&x) - want).abs() < 1e-12, "{}", mvn.logpdf(&x));
    }

    #[test]
    fn mvn_sample_moments() {
        let mvn = MvNormal::full(vec![1.0, 2.0], &[vec![2.0, 1.0], vec![1.0, 2.0]]).unwrap();
        let mut rng = Pcg64::seeded(3);
        let n = 60_000;
        let (mut m0, mut m1, mut c01) = (0.0, 0.0, 0.0);
        let samples: Vec<Vec<f64>> = (0..n).map(|_| mvn.sample(&mut rng)).collect();
        for s in &samples {
            m0 += s[0];
            m1 += s[1];
        }
        m0 /= n as f64;
        m1 /= n as f64;
        for s in &samples {
            c01 += (s[0] - m0) * (s[1] - m1);
        }
        c01 /= n as f64;
        assert!((m0 - 1.0).abs() < 0.03, "{m0}");
        assert!((m1 - 2.0).abs() < 0.03, "{m1}");
        assert!((c01 - 1.0).abs() < 0.06, "{c01}");
    }

    #[test]
    fn niw_chain_equals_marginal() {
        // sum of predictives along any insertion order = marginal loglik
        let mut niw = CollapsedNiw::new(
            vec![0.0, 0.0],
            1.0,
            4.0,
            vec![vec![1.0, 0.0], vec![0.0, 1.0]],
        );
        let xs = [[0.3, -0.1], [1.2, 0.4], [-0.7, 0.9], [0.05, 0.0]];
        let mut chain = 0.0;
        for x in &xs {
            chain += niw.predictive_logpdf(x);
            niw.incorporate(x);
        }
        let marginal = niw.marginal_loglik();
        assert!((chain - marginal).abs() < 1e-9, "{chain} vs {marginal}");
        // remove/re-add identity
        niw.unincorporate(&xs[1]);
        let pred = niw.predictive_logpdf(&xs[1]);
        niw.incorporate(&xs[1]);
        assert!((niw.marginal_loglik() - marginal).abs() < 1e-9);
        assert!(pred.is_finite());
    }

    #[test]
    fn niw_predictive_is_normalized_1d_check() {
        // d=1 collapses to a scalar Student-t; compare against it
        let niw = CollapsedNiw::new(vec![0.5], 2.0, 3.0, vec![vec![1.5]]);
        let (kn, vn, mn, sn) = (2.0, 3.0, vec![0.5], vec![vec![1.5]]);
        let nu = vn - 1.0 + 1.0;
        let scale = (sn[0][0] * (kn + 1.0) / (kn * nu)).sqrt();
        for &x in &[-1.0, 0.0, 0.5, 2.0] {
            let want = student_t_logpdf(x, nu, mn[0], scale);
            let got = niw.predictive_logpdf(&[x]);
            assert!((got - want).abs() < 1e-10, "x={x}: {got} vs {want}");
        }
    }
}
