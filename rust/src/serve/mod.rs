//! Inference as a service: the `subppl serve` daemon.
//!
//! Zero-dependency TCP + newline-delimited JSON-RPC.  Three layers:
//!
//! - [`protocol`] — typed request/response/error frames over a
//!   hand-rolled JSON value tree.
//! - [`session`] — one inference session: a `Trace` + per-session PCG
//!   stream (`session_rng(seed, id)`), stepped at draw granularity,
//!   with deadlines/cancellation observed at draw boundaries, per-draw
//!   in-memory checkpoints, and panic-restart recovery.
//! - [`server`] — session registry with admission control, bounded
//!   per-session command queues, request dispatch, subscriber
//!   streaming, and graceful drain.
//!
//! See the README "Serving inference" section for the wire protocol
//! and semantics.

pub mod protocol;
pub mod server;
pub mod session;

pub use protocol::{CreateParams, ErrCode, Fault, Json, Method, Request};
pub use server::{serve, serve_with, DrainReport, ServeCfg, Server, SessionCmd};
pub use session::{session_rng, Session, SessionCfg, StepReport, StopReason, SESSION_STREAM_BASE};
