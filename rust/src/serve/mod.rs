//! Inference as a service: the `subppl serve` daemon.
//!
//! Zero-dependency TCP + newline-delimited JSON-RPC.  Three layers:
//!
//! - [`protocol`] — typed request/response/error frames over a
//!   hand-rolled JSON value tree.
//! - [`session`] — one inference session: a `Trace` + per-session PCG
//!   stream (`session_rng(seed, id)`), stepped at draw granularity,
//!   with deadlines/cancellation observed at draw boundaries, per-draw
//!   in-memory checkpoints, and panic-restart recovery.
//! - [`server`] — session registry with admission control, bounded
//!   per-session command queues, request dispatch, subscriber
//!   streaming, and graceful drain.
//! - [`journal`] — per-session write-ahead journal under `--state-dir`:
//!   acknowledged creates/appends/checkpoints are durable before the
//!   reply, and `serve --recover` rebuilds sessions bitwise-identically
//!   after a crash (torn tails detected and dropped).
//!
//! See the README "Serving inference" and "Crash recovery & durability"
//! sections for the wire protocol and semantics.

pub mod journal;
pub mod protocol;
pub mod server;
pub mod session;

pub use journal::{journal_path, read_journal, scan_state_dir, Journal, JournalState};
pub use protocol::{CreateParams, ErrCode, Fault, Json, Method, Request};
pub use server::{serve, serve_with, DrainReport, ServeCfg, Server, SessionCmd};
pub use session::{
    session_rng, AppendErr, Session, SessionCfg, StepReport, StopReason, SESSION_STREAM_BASE,
};
