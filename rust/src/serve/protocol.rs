//! Newline-delimited JSON-RPC frames for `subppl serve`.
//!
//! One request per line, one response per line, plus unsolicited
//! `event` lines on subscribed connections.  The JSON is hand-rolled
//! (parser + encoder below) to keep the repo's no-dependency
//! discipline — the value model is the minimal six-kind tree, numbers
//! are f64, and object key order is preserved so frames are
//! deterministic.
//!
//! Frames:
//!
//! ```text
//! → {"id":1,"method":"create","params":{"program":"...","infer":"...","watch":["mu"]}}
//! ← {"id":1,"ok":{"session":1}}
//! → {"id":2,"method":"step","params":{"session":1,"n":100,"deadline_ms":500}}
//! ← {"id":2,"ok":{"requested":100,"done":100,"total":100,"restarts":0,"sections":12345}}
//! ← {"id":7,"error":{"code":"Overloaded","message":"...","retry_after_ms":100}}
//! ← {"event":"monitor","session":1,"line":"[monitor] n=200/chain ..."}
//! ```
//!
//! Error codes are a closed set ([`ErrCode`]) so clients can switch on
//! them: `Overloaded` / `Draining` (and queue-budget `BudgetExceeded`)
//! carry `retry_after_ms`, the rest are terminal for the request
//! (`BadRequest`, `NotFound`, `Deadline`) or the session (`Expired`,
//! `Failed`, trace/journal `BudgetExceeded`, `Internal`).  A step that makes
//! partial progress before a deadline/cancel lands is NOT an error: it
//! replies with an ok frame whose `stopped` field names the reason
//! (`"deadline"` / `"cancelled"` / `"expired"`); the error codes cover
//! the zero-progress terminal cases — `Deadline` when the request's
//! deadline lapsed (queue wait included) before any draw, `Expired` for
//! every step after a session's lifetime deadline was first observed.

use std::fmt::Write as _;

/// The minimal JSON value tree.  Objects are ordered key/value pairs —
/// frames stay byte-deterministic and duplicate keys are a parse error
/// nobody tripped yet.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative whole number (the id/count fields).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= (1u64 << 53) as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Encode to a single-line JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.is_finite() {
                    let _ = write!(out, "{n}");
                } else {
                    // JSON has no Inf/NaN; null round-trips as "absent"
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_json_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_json_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse one JSON document (trailing garbage is an error — frames
    /// are one value per line).
    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }
}

fn write_json_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            )),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if matches!(b, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).map_err(|e| e.to_string())?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number {text:?} at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape {hex:?}"))?;
                            // surrogate pairs are not reassembled —
                            // frames never carry astral-plane text, and
                            // a lone surrogate maps to the replacement
                            // char rather than failing the request
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => {
                            return Err(format!("bad escape {:?}", other.map(|c| c as char)))
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar (the input is &str, so
                    // slicing at char boundaries is safe)
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut fields: Vec<(String, Json)> = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            if fields.iter().any(|(k, _)| *k == key) {
                return Err(format!("duplicate key {key:?}"));
            }
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.pos,
                        other.map(|c| c as char)
                    ))
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// typed frames
// ---------------------------------------------------------------------

/// Closed set of error codes.  `Overloaded`/`Draining` are retryable
/// and carry `retry_after_ms`; the rest are terminal for the request or
/// the session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrCode {
    /// Admission control refused (registry or step queue full).
    Overloaded,
    /// The server is draining; no new sessions or steps.
    Draining,
    /// No such session (never created, cancelled, or reaped).
    NotFound,
    /// The session outlived its lifetime deadline; every step after
    /// the one that first observed expiry fails with this code.
    Expired,
    /// The per-request deadline lapsed (time queued behind other steps
    /// counts) before any draw completed.  Partial progress replies
    /// with an ok frame carrying `stopped:"deadline"` instead.
    Deadline,
    /// Malformed frame or parameters.
    BadRequest,
    /// The session's model errored or exhausted its restart budget.
    Failed,
    /// The session hit one of its resource budgets (trace nodes,
    /// journal bytes, or queued commands).  Queue-budget rejections are
    /// retryable and carry `retry_after_ms`; trace/journal ceilings are
    /// permanent for the session but degrade only that session.
    BudgetExceeded,
    /// Server-side invariant violation (session thread gone, etc).
    Internal,
}

impl ErrCode {
    pub fn name(self) -> &'static str {
        match self {
            ErrCode::Overloaded => "Overloaded",
            ErrCode::Draining => "Draining",
            ErrCode::NotFound => "NotFound",
            ErrCode::Expired => "Expired",
            ErrCode::Deadline => "Deadline",
            ErrCode::BadRequest => "BadRequest",
            ErrCode::Failed => "Failed",
            ErrCode::BudgetExceeded => "BudgetExceeded",
            ErrCode::Internal => "Internal",
        }
    }
}

/// A typed request error (becomes one `error` frame).
#[derive(Clone, Debug)]
pub struct Fault {
    pub code: ErrCode,
    pub message: String,
    /// Backpressure hint, only on `Overloaded`/`Draining`.
    pub retry_after_ms: Option<u64>,
}

impl Fault {
    pub fn new(code: ErrCode, message: impl Into<String>) -> Fault {
        Fault {
            code,
            message: message.into(),
            retry_after_ms: None,
        }
    }

    pub fn overloaded(message: impl Into<String>, retry_after_ms: u64) -> Fault {
        Fault {
            code: ErrCode::Overloaded,
            message: message.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }
}

/// Session parameters of a `create` request (everything but `program`
/// optional).
#[derive(Clone, Debug, Default)]
pub struct CreateParams {
    pub program: String,
    pub infer: Option<String>,
    pub watch: Vec<String>,
    /// Per-session seed override (default: the server's seed; the
    /// session id always picks the PCG stream, so two sessions with the
    /// same seed still draw independently).
    pub seed: Option<u64>,
    pub target_risk: Option<f64>,
    /// Per-session shard-watchdog deadline (0 = server/process default).
    pub shard_timeout_ms: u64,
    /// Per-session column-store verify mode override ("off" /
    /// "refreshed" / "full"; `None` = server/env default).
    pub store_verify: Option<crate::trace::colstore::VerifyMode>,
    /// Per-session lifetime deadline override in ms (0 = server
    /// default; capped by the server's `--session-deadline-ms`).
    pub deadline_ms: u64,
    /// Cross-draw convergence snapshot cadence (0 = no monitor).
    pub monitor_every: usize,
    /// Fair-scheduling weight on the shared shard pool (deficit
    /// round-robin quanta per visit; 0 is normalized to 1).
    pub weight: u32,
    /// Trace-size budget: appends that would grow the trace past this
    /// many live nodes are refused with `BudgetExceeded` (0 = server
    /// default / uncapped).
    pub max_trace_nodes: u64,
    /// Journal-byte budget: once the session's *compacted* write-ahead
    /// journal exceeds this, the session stops with `"budget"` and
    /// further steps fail with `BudgetExceeded` (0 = server default /
    /// uncapped).
    pub max_journal_bytes: u64,
    /// Per-session command-queue depth override (0 = server default).
    /// A full queue on a session with its own cap answers
    /// `BudgetExceeded` instead of `Overloaded` — the tenant, not the
    /// server, is over its ceiling.
    pub queue_cap: u64,
}

/// One parsed request frame.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub method: Method,
}

#[derive(Clone, Debug)]
pub enum Method {
    Ping,
    Create(CreateParams),
    Step {
        session: u64,
        n: usize,
        /// Per-request deadline (0 = none): the step stops at the first
        /// draw boundary past the deadline and reports what it did.
        deadline_ms: u64,
    },
    /// Append new observations to a live session's model at the next
    /// draw boundary ("ticks in, posterior out").  `program` is one or
    /// more `[observe ...]` (or `[assume ...]`) directives in the same
    /// surface syntax as `create`'s program.
    Append {
        session: u64,
        program: String,
    },
    Snapshot {
        session: u64,
    },
    Subscribe {
        session: u64,
    },
    Cancel {
        session: u64,
    },
    Shutdown,
}

impl Request {
    /// Parse one request line.  Errors name the offending field — they
    /// become `BadRequest` frames with `id` 0 when the id itself is
    /// unreadable.
    pub fn parse(line: &str) -> Result<Request, Fault> {
        let bad = |msg: String| Fault::new(ErrCode::BadRequest, msg);
        let v = Json::parse(line).map_err(|e| bad(format!("bad JSON: {e}")))?;
        let id = v
            .get("id")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad("missing numeric \"id\"".into()))?;
        let method = v
            .get("method")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("missing \"method\"".into()))?;
        let p = v.get("params");
        let session = || -> Result<u64, Fault> {
            p.and_then(|p| p.get("session"))
                .and_then(Json::as_u64)
                .ok_or_else(|| bad("missing \"params.session\"".into()))
        };
        let u64_field = |name: &str, default: u64| -> u64 {
            p.and_then(|p| p.get(name))
                .and_then(Json::as_u64)
                .unwrap_or(default)
        };
        let method = match method {
            "ping" => Method::Ping,
            "create" => {
                let program = p
                    .and_then(|p| p.get("program"))
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("create: missing \"params.program\"".into()))?
                    .to_string();
                let watch = p
                    .and_then(|p| p.get("watch"))
                    .and_then(Json::as_arr)
                    .map(|a| {
                        a.iter()
                            .filter_map(|v| v.as_str().map(str::to_string))
                            .collect()
                    })
                    .unwrap_or_default();
                Method::Create(CreateParams {
                    program,
                    infer: p
                        .and_then(|p| p.get("infer"))
                        .and_then(Json::as_str)
                        .map(str::to_string),
                    watch,
                    seed: p.and_then(|p| p.get("seed")).and_then(Json::as_u64),
                    target_risk: p.and_then(|p| p.get("target_risk")).and_then(Json::as_f64),
                    shard_timeout_ms: u64_field("shard_timeout_ms", 0),
                    store_verify: match p.and_then(|p| p.get("store_verify")).and_then(Json::as_str)
                    {
                        Some(s) => Some(
                            crate::trace::colstore::VerifyMode::parse(s).ok_or_else(|| {
                                bad(format!("create: bad \"params.store_verify\" {s:?}"))
                            })?,
                        ),
                        None => None,
                    },
                    deadline_ms: u64_field("deadline_ms", 0),
                    monitor_every: u64_field("monitor_every", 0) as usize,
                    weight: u64_field("weight", 1).clamp(1, u32::MAX as u64) as u32,
                    max_trace_nodes: u64_field("max_trace_nodes", 0),
                    max_journal_bytes: u64_field("max_journal_bytes", 0),
                    queue_cap: u64_field("queue_cap", 0),
                })
            }
            "step" => Method::Step {
                session: session()?,
                n: u64_field("n", 1) as usize,
                deadline_ms: u64_field("deadline_ms", 0),
            },
            "append" => Method::Append {
                session: session()?,
                program: p
                    .and_then(|p| p.get("program"))
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("append: missing \"params.program\"".into()))?
                    .to_string(),
            },
            "snapshot" => Method::Snapshot { session: session()? },
            "subscribe" => Method::Subscribe { session: session()? },
            "cancel" => Method::Cancel { session: session()? },
            "shutdown" => Method::Shutdown,
            other => return Err(bad(format!("unknown method {other:?}"))),
        };
        Ok(Request { id, method })
    }
}

/// Encode a success frame.
pub fn ok_frame(id: u64, body: Json) -> String {
    Json::Obj(vec![
        ("id".into(), Json::Num(id as f64)),
        ("ok".into(), body),
    ])
    .encode()
}

/// Encode an error frame.
pub fn err_frame(id: u64, f: &Fault) -> String {
    let mut err = vec![
        ("code".into(), Json::Str(f.code.name().into())),
        ("message".into(), Json::Str(f.message.clone())),
    ];
    if let Some(ms) = f.retry_after_ms {
        err.push(("retry_after_ms".into(), Json::Num(ms as f64)));
    }
    Json::Obj(vec![
        ("id".into(), Json::Num(id as f64)),
        ("error".into(), Json::Obj(err)),
    ])
    .encode()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_values() {
        for src in [
            "null",
            "true",
            "[1,2.5,-3]",
            r#"{"a":[{"b":"c\n\"d\""}],"e":null}"#,
            r#""\u0041\t""#,
        ] {
            let v = Json::parse(src).unwrap();
            let enc = v.encode();
            assert_eq!(Json::parse(&enc).unwrap(), v, "src={src}");
        }
    }

    #[test]
    fn rejects_malformed() {
        for src in ["", "{", "[1,]", "{\"a\":1,\"a\":2}", "1 2", "\"\\x\""] {
            assert!(Json::parse(src).is_err(), "src={src:?}");
        }
    }

    #[test]
    fn parses_request_frames() {
        let r = Request::parse(
            r#"{"id":3,"method":"step","params":{"session":7,"n":50,"deadline_ms":100}}"#,
        )
        .unwrap();
        assert_eq!(r.id, 3);
        match r.method {
            Method::Step {
                session,
                n,
                deadline_ms,
            } => {
                assert_eq!((session, n, deadline_ms), (7, 50, 100));
            }
            m => panic!("{m:?}"),
        }
        let r = Request::parse(
            r#"{"id":1,"method":"create","params":{"program":"[assume x (normal 0 1)]","watch":["x"],"monitor_every":10}}"#,
        )
        .unwrap();
        match r.method {
            Method::Create(c) => {
                assert_eq!(c.watch, vec!["x"]);
                assert_eq!(c.monitor_every, 10);
                assert!(c.infer.is_none());
                assert_eq!(c.weight, 1, "weight defaults to 1");
                assert_eq!(c.max_trace_nodes, 0);
                assert_eq!(c.max_journal_bytes, 0);
                assert_eq!(c.queue_cap, 0);
            }
            m => panic!("{m:?}"),
        }
        let r = Request::parse(
            r#"{"id":2,"method":"create","params":{"program":"x","weight":8,"max_trace_nodes":5000,"max_journal_bytes":65536,"queue_cap":2}}"#,
        )
        .unwrap();
        match r.method {
            Method::Create(c) => {
                assert_eq!(c.weight, 8);
                assert_eq!(c.max_trace_nodes, 5000);
                assert_eq!(c.max_journal_bytes, 65536);
                assert_eq!(c.queue_cap, 2);
            }
            m => panic!("{m:?}"),
        }
        let r = Request::parse(r#"{"id":2,"method":"create","params":{"program":"x","weight":0}}"#)
            .unwrap();
        match r.method {
            Method::Create(c) => assert_eq!(c.weight, 1, "weight 0 is normalized to 1"),
            m => panic!("{m:?}"),
        }
        let r = Request::parse(
            r#"{"id":4,"method":"append","params":{"session":2,"program":"[observe (f 1) 0.5]"}}"#,
        )
        .unwrap();
        match r.method {
            Method::Append { session, program } => {
                assert_eq!(session, 2);
                assert_eq!(program, "[observe (f 1) 0.5]");
            }
            m => panic!("{m:?}"),
        }
        assert!(
            Request::parse(r#"{"id":4,"method":"append","params":{"session":2}}"#).is_err(),
            "append requires a program"
        );
        assert!(
            Request::parse(
                r#"{"id":1,"method":"create","params":{"program":"x","store_verify":"sometimes"}}"#
            )
            .is_err(),
            "unknown store_verify mode is a BadRequest"
        );
        assert!(Request::parse(r#"{"id":1,"method":"warp"}"#).is_err());
        assert!(Request::parse("not json").is_err());
        assert!(Request::parse(r#"{"method":"ping"}"#).is_err(), "id required");
    }

    #[test]
    fn frames_are_single_lines() {
        let ok = ok_frame(5, Json::Obj(vec![("session".into(), Json::Num(1.0))]));
        assert_eq!(ok, r#"{"id":5,"ok":{"session":1}}"#);
        let err = err_frame(9, &Fault::overloaded("registry full", 250));
        assert_eq!(
            err,
            r#"{"id":9,"error":{"code":"Overloaded","message":"registry full","retry_after_ms":250}}"#
        );
        assert!(!ok.contains('\n') && !err.contains('\n'));
        let budget = err_frame(2, &Fault::new(ErrCode::BudgetExceeded, "journal over cap"));
        assert!(budget.contains(r#""code":"BudgetExceeded""#));
    }
}
