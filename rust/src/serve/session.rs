//! One inference session: a `Trace` + PCG stream owned by a single
//! thread, stepped on demand, streaming draws and `[monitor]` snapshots
//! over the existing `ChainSink`/`ChainEvent` lane.
//!
//! Determinism contract: a session's draw sequence is a pure function
//! of `(seed, session id)` — the RNG stream is
//! `Pcg64::new(seed, SESSION_STREAM_BASE + id)`, mirroring the
//! per-chain streams of `coordinator/multichain.rs`, and the evaluator
//! tiers are bitwise identical sequential vs sharded.  Concurrent
//! sessions therefore cannot perturb each other's draws no matter how
//! the shared `WorkerPool` interleaves their shards — the isolation
//! property `tests/serve.rs` pins under injected faults.
//!
//! Robustness contract: deadlines (per-step and per-session) and
//! cancellation are observed at *draw boundaries* — a transition either
//! commits or rejects atomically (`subsampled_mh_transition` mutates
//! the trace only in its final commit), so a stopped session's trace is
//! always pre- or post-transition, never torn.  A panicking draw is
//! caught, the trace is rebuilt from source, and the session resumes
//! from its last per-draw in-memory [`ChainCheckpoint`] — bitwise
//! identical to the draw sequence that would have happened without the
//! panic, up to `max_restarts` per session.
//!
//! Durability contract (when `state_dir` is set): every acknowledged
//! operation is on disk *before* its reply — the create record lands
//! before the session is born, each append record (source + post-append
//! checkpoint, one atomic record) lands before the append reply, and a
//! checkpoint record lands at the end of every completed step before
//! the step reply (plus every `journal_every` draws mid-step, bounding
//! replay after a crash mid-step).  [`Session::recover`] rebuilds from
//! the journal with exactly the panic-`rebuild()` discipline — replay
//! program + appends for node ids, restore checkpoint for values + RNG
//! position — so the recovered draw sequence is bitwise identical to
//! the uninterrupted run.  A journal write failure is terminal
//! (`Failed`): the op is never acknowledged, so recovery serves the
//! last *acknowledged* state.  Convergence-monitor state and evaluator
//! counters are not journaled: after recovery the monitor starts fresh
//! and counters restart from zero (draw values are unaffected).

use crate::coordinator::checkpoint::ChainCheckpoint;
use crate::coordinator::monitor::{ConvergenceMonitor, DiagSnapshot};
use crate::coordinator::multichain::{chain_lane, ChainLane, ChainSink};
use crate::infer::planned::{EvalStats, PlannedEval};
use crate::infer::program::{parse_infer, run_command, InfCmd};
use crate::math::Pcg64;
use crate::runtime::faults;
use crate::runtime::pool::{resolve_threads, WorkerPool};
use crate::serve::journal::{journal_path, Journal, KIND_APPEND, KIND_CKPT};
use crate::serve::protocol::Json;
use crate::trace::Trace;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serve sessions draw from their own PCG stream family, disjoint from
/// the multichain `CHAIN_STREAM_BASE` ("ch") family — a session and a
/// CLI chain with the same index never share a stream.
pub const SESSION_STREAM_BASE: u64 = 0x7365_0000; // "se"

/// The session's RNG: deterministic in `(seed, session id)` only.
pub fn session_rng(seed: u64, id: u64) -> Pcg64 {
    Pcg64::new(seed, SESSION_STREAM_BASE + id)
}

/// Everything a session needs to build itself inside its own thread.
#[derive(Clone, Debug)]
pub struct SessionCfg {
    pub id: u64,
    pub seed: u64,
    /// Model program source (`[assume ...]` / `[observe ...]` forms).
    pub program: String,
    /// Inference program (`(cycle ...)` surface syntax); `None` = the
    /// session only holds the prior trace (snapshot-only sessions).
    pub infer: Option<String>,
    /// Watched parameter names: one row per draw on the event lane.
    pub watch: Vec<String>,
    pub target_risk: Option<f64>,
    /// Per-session shard-watchdog deadline (0 = process default).
    pub shard_timeout_ms: u64,
    /// Per-session column-store verify mode (`None` = the
    /// `SUBPPL_STORE_VERIFY` env default).
    pub store_verify: Option<crate::trace::colstore::VerifyMode>,
    /// Session lifetime budget from creation (None = unbounded).
    pub deadline: Option<Duration>,
    /// Panic restarts granted before the session is declared Failed.
    pub max_restarts: usize,
    /// Shard intra-draw scoring across the shared pool (false = the
    /// sequential evaluator; results are bitwise identical either way).
    pub use_pool: bool,
    /// Parallel-dispatch cutoff override (0 = default 256; tests force
    /// the sharded path on small models with 1).
    pub min_parallel: usize,
    /// Convergence snapshot cadence in draws (0 = no monitor).
    pub monitor_every: usize,
    /// Where drain writes the session's final checkpoint (None = the
    /// session's state dies with it).
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Fair-scheduling weight on the shared shard pool (deficit
    /// round-robin quanta per visit; normalized to ≥ 1).
    pub weight: u32,
    /// Where the write-ahead journal lives (None = no durability; the
    /// session's state dies with the process).
    pub state_dir: Option<std::path::PathBuf>,
    /// Mid-step journal checkpoint cadence in draws (0 = default 64).
    /// A checkpoint record also always lands at the end of every
    /// completed step, so this only bounds replay after a crash
    /// mid-step.
    pub journal_every: usize,
    /// Trace-size budget: appends that would grow the trace past this
    /// many live nodes are refused (0 = uncapped).
    pub max_trace_nodes: usize,
    /// Journal-byte budget: when the *compacted* journal still exceeds
    /// this, the session is over budget (0 = uncapped; the journal is
    /// still compacted past [`COMPACT_THRESHOLD`] to bound growth).
    pub max_journal_bytes: u64,
    /// Per-session command-queue depth (0 = server default).  Lives in
    /// the server's registry; journaled here only so recovery restores
    /// the same cap.
    pub queue_cap: usize,
}

/// Uncapped sessions still compact their journal past this size — the
/// per-draw `ckpt` records accrete and compaction is cheap (one
/// temp-then-rename of create + appends + latest checkpoint).
pub const COMPACT_THRESHOLD: u64 = 1 << 20;

/// Default mid-step journal checkpoint cadence (`journal_every` 0).
pub const DEFAULT_JOURNAL_EVERY: usize = 64;

impl Default for SessionCfg {
    fn default() -> SessionCfg {
        SessionCfg {
            id: 0,
            seed: 0,
            program: String::new(),
            infer: None,
            watch: Vec::new(),
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
            deadline: None,
            max_restarts: 2,
            use_pool: false,
            min_parallel: 0,
            monitor_every: 0,
            checkpoint_dir: None,
            weight: 1,
            state_dir: None,
            journal_every: 0,
            max_trace_nodes: 0,
            max_journal_bytes: 0,
            queue_cap: 0,
        }
    }
}

/// The `create` journal record's payload: every field of the resolved
/// session config that recovery must reproduce to rebuild the same
/// draw stream.  Server-local policy (restart budget, pool usage,
/// checkpoint dir, deadline) is *not* journaled — recovery applies the
/// recovering server's settings, and a recovered session gets a fresh
/// lifetime window.
pub fn journal_payload(cfg: &SessionCfg) -> Json {
    let verify = cfg.store_verify.map(|v| match v {
        crate::trace::colstore::VerifyMode::Off => "off",
        crate::trace::colstore::VerifyMode::Refreshed => "refreshed",
        crate::trace::colstore::VerifyMode::Full => "full",
    });
    Json::Obj(vec![
        ("seed".into(), Json::Num(cfg.seed as f64)),
        ("program".into(), Json::Str(cfg.program.clone())),
        (
            "infer".into(),
            match &cfg.infer {
                Some(s) => Json::Str(s.clone()),
                None => Json::Null,
            },
        ),
        (
            "watch".into(),
            Json::Arr(cfg.watch.iter().map(|w| Json::Str(w.clone())).collect()),
        ),
        (
            "target_risk".into(),
            match cfg.target_risk {
                Some(r) => Json::Num(r),
                None => Json::Null,
            },
        ),
        (
            "shard_timeout_ms".into(),
            Json::Num(cfg.shard_timeout_ms as f64),
        ),
        (
            "store_verify".into(),
            match verify {
                Some(v) => Json::Str(v.into()),
                None => Json::Null,
            },
        ),
        (
            "monitor_every".into(),
            Json::Num(cfg.monitor_every as f64),
        ),
        ("weight".into(), Json::Num(cfg.weight as f64)),
        (
            "max_trace_nodes".into(),
            Json::Num(cfg.max_trace_nodes as f64),
        ),
        (
            "max_journal_bytes".into(),
            Json::Num(cfg.max_journal_bytes as f64),
        ),
        ("queue_cap".into(), Json::Num(cfg.queue_cap as f64)),
    ])
}

/// Invert [`journal_payload`]: a `SessionCfg` for [`Session::recover`].
/// Server-local fields (deadline, max_restarts, use_pool, min_parallel,
/// checkpoint_dir, state_dir, journal_every) start at their defaults —
/// the recovering server fills them in from its own config.
pub fn cfg_from_journal(id: u64, payload: &Json) -> Result<SessionCfg, String> {
    let bad = |f: &str| format!("journal: session {id} create record missing {f:?}");
    let u = |f: &str| payload.get(f).and_then(Json::as_u64).unwrap_or(0);
    Ok(SessionCfg {
        id,
        seed: payload
            .get("seed")
            .and_then(Json::as_u64)
            .ok_or_else(|| bad("seed"))?,
        program: payload
            .get("program")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("program"))?
            .to_string(),
        infer: payload
            .get("infer")
            .and_then(Json::as_str)
            .map(str::to_string),
        watch: payload
            .get("watch")
            .and_then(Json::as_arr)
            .map(|a| {
                a.iter()
                    .filter_map(|v| v.as_str().map(str::to_string))
                    .collect()
            })
            .unwrap_or_default(),
        target_risk: payload.get("target_risk").and_then(Json::as_f64),
        shard_timeout_ms: u("shard_timeout_ms"),
        store_verify: match payload.get("store_verify").and_then(Json::as_str) {
            Some(s) => Some(
                crate::trace::colstore::VerifyMode::parse(s)
                    .ok_or_else(|| format!("journal: session {id} bad store_verify {s:?}"))?,
            ),
            None => None,
        },
        monitor_every: u("monitor_every") as usize,
        weight: u("weight").clamp(1, u32::MAX as u64) as u32,
        max_trace_nodes: u("max_trace_nodes") as usize,
        max_journal_bytes: u("max_journal_bytes"),
        queue_cap: u("queue_cap") as usize,
        ..SessionCfg::default()
    })
}

/// Why a step returned before completing its requested draws.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The per-request deadline fired at a draw boundary.
    Deadline,
    /// The session's stop flag was raised (cancel RPC, drain, or the
    /// `cancel@k` fault) and observed at a draw boundary.
    Cancelled,
    /// The session outlived its lifetime deadline; it will accept no
    /// further steps.
    Expired,
    /// The session hit its journal-byte budget; like expiry, this is
    /// permanent — further steps fail with `BudgetExceeded`.
    Budget,
}

impl StopReason {
    pub fn name(self) -> &'static str {
        match self {
            StopReason::Deadline => "deadline",
            StopReason::Cancelled => "cancelled",
            StopReason::Expired => "expired",
            StopReason::Budget => "budget",
        }
    }
}

/// Why an `append` was refused.  Parse and budget refusals mutate
/// nothing — the session stays live; `Failed` is terminal.
#[derive(Clone, Debug)]
pub enum AppendErr {
    /// The appended source did not parse (nothing was applied).
    Parse(String),
    /// The append would exceed the session's trace-node budget
    /// (nothing was applied; the session stays live for steps and
    /// snapshots).
    Budget(String),
    /// The session is terminally failed — either it already was, or a
    /// directive failed mid-batch / the journal write failed.
    Failed(String),
}

impl AppendErr {
    pub fn message(&self) -> &str {
        match self {
            AppendErr::Parse(m) | AppendErr::Budget(m) | AppendErr::Failed(m) => m,
        }
    }
}

/// What one `step(n)` actually did.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub requested: usize,
    pub done: usize,
    /// Completed draws over the session's lifetime.
    pub total: usize,
    pub stopped: Option<StopReason>,
    pub restarts: usize,
    /// Cumulative evaluator counters (survives evaluator rebuilds
    /// after a panic restart).
    pub eval: EvalStats,
}

/// A session that can be driven directly (tests) or by the server's
/// per-session thread.  Owns non-`Send` state (`Trace` is `Rc`-based),
/// so it must be built and driven on one thread.
pub struct Session {
    pub cfg: SessionCfg,
    trace: Trace,
    rng: Pcg64,
    cmd: Option<InfCmd>,
    ev: PlannedEval,
    sink: ChainSink,
    lane: ChainLane,
    stop: Arc<AtomicBool>,
    mon: Option<ConvergenceMonitor>,
    /// Completed draws (checkpoint granularity: every draw).
    draws: usize,
    restarts: usize,
    /// Terminal model error (restart budget exhausted or a
    /// non-panic evaluation error).
    failed: Option<String>,
    expired: bool,
    created: Instant,
    last_ck: Option<ChainCheckpoint>,
    last_snap: Option<DiagSnapshot>,
    last_row: Vec<f64>,
    /// Counters accumulated by evaluator incarnations that a panic
    /// restart already tore down.
    eval_base: EvalStats,
    /// Journal of appended program sources (the `append` RPC), in
    /// arrival order: a panic rebuild replays these after
    /// `cfg.program` so the rebuilt trace allocates the same node ids
    /// as the live one before the checkpoint restore overwrites state.
    appended: Vec<String>,
    /// Subscribed streams: bounded senders of encoded event lines.  A
    /// full or closed channel drops the subscriber (slowloris
    /// protection) — the session never blocks on a slow client.
    subs: Vec<SyncSender<String>>,
    /// Write-ahead journal (None = no `state_dir`, no durability).
    journal: Option<Journal>,
    /// Draws since the last journaled checkpoint record.
    since_journal_ckpt: usize,
    /// Permanent journal-byte budget violation (set when even the
    /// compacted journal exceeds `max_journal_bytes`).  Mirrors
    /// `expired`: the first observing step reports `stopped:"budget"`,
    /// later steps map to the `BudgetExceeded` error code.
    over_budget: bool,
    budget_observed: bool,
}

impl Session {
    /// Build the session: run the model program under the session RNG,
    /// parse the inference program, capture the draw-0 checkpoint.
    pub fn new(cfg: SessionCfg) -> Result<Session, String> {
        let stop = Arc::new(AtomicBool::new(false));
        // the cancel@k fault needs to find this session's flag
        faults::register_cancel_flag(&stop);
        let mut rng = session_rng(cfg.seed, cfg.id);
        let mut trace = Trace::new();
        trace.run_program(&cfg.program, &mut rng)?;
        let mut cmd = match &cfg.infer {
            Some(src) => Some(parse_infer(src)?),
            None => None,
        };
        if let Some(c) = cmd.as_mut() {
            if let Some(tr) = cfg.target_risk {
                c.set_target_risk(tr);
            }
            if cfg.shard_timeout_ms > 0 {
                c.set_shard_timeout_ms(cfg.shard_timeout_ms);
            }
            if let Some(v) = cfg.store_verify {
                c.set_store_verify(v);
            }
        }
        let ev = Self::fresh_eval(&cfg);
        // lane chain index 0: the per-session monitor folds exactly one
        // chain (the session id lives in the checkpoint and the frames)
        let (sink, lane) = chain_lane(0, stop.clone());
        let mon = (cfg.monitor_every > 0 && !cfg.watch.is_empty())
            .then(|| ConvergenceMonitor::new(1, &cfg.watch, cfg.monitor_every));
        let last_ck = Some(ChainCheckpoint::capture(
            cfg.seed,
            cfg.id as usize,
            0,
            &trace,
            &rng,
        ));
        // durability: the create record must be on disk before this
        // constructor returns (the server acknowledges after)
        let journal = match &cfg.state_dir {
            Some(dir) => Some(Journal::create(dir, cfg.id, &journal_payload(&cfg))?),
            None => None,
        };
        Ok(Session {
            trace,
            rng,
            cmd,
            ev,
            sink,
            lane,
            stop,
            mon,
            draws: 0,
            restarts: 0,
            failed: None,
            expired: false,
            created: Instant::now(),
            last_ck,
            last_snap: None,
            last_row: vec![f64::NAN; cfg.watch.len()],
            eval_base: EvalStats::default(),
            appended: Vec::new(),
            subs: Vec::new(),
            journal,
            since_journal_ckpt: 0,
            over_budget: false,
            budget_observed: false,
            cfg,
        })
    }

    /// Rebuild a session from its recovered journal state: replay the
    /// program and every acknowledged append under the session RNG (so
    /// the trace allocates the same node ids as the dead process's
    /// did), then restore committed values + RNG position from the last
    /// journaled checkpoint — exactly the panic-`rebuild()` discipline,
    /// so subsequent draws are bitwise identical to the uninterrupted
    /// run.  `ckpt_text` of `None` means no draw or append was ever
    /// acknowledged: the draw-0 replay state is already correct.
    ///
    /// The journal itself is reopened for appending; `cfg.state_dir`
    /// must be set and [`read_journal`](crate::serve::journal::read_journal)
    /// must already have truncated any torn tail.
    pub fn recover(
        cfg: SessionCfg,
        appends: &[String],
        ckpt_text: Option<&str>,
    ) -> Result<Session, String> {
        let dir = cfg
            .state_dir
            .clone()
            .ok_or_else(|| format!("session {}: recover needs a state_dir", cfg.id))?;
        let stop = Arc::new(AtomicBool::new(false));
        faults::register_cancel_flag(&stop);
        let mut rng = session_rng(cfg.seed, cfg.id);
        let mut trace = Trace::new();
        trace
            .run_program(&cfg.program, &mut rng)
            .map_err(|e| format!("session {}: recovery replay failed: {e}", cfg.id))?;
        for src in appends {
            trace
                .append_program(src, &mut rng)
                .map_err(|e| format!("session {}: recovery append replay failed: {e}", cfg.id))?;
        }
        let (draws, last_ck) = match ckpt_text {
            Some(text) => {
                let ck = ChainCheckpoint::decode(text)
                    .map_err(|e| format!("session {}: journaled checkpoint: {e}", cfg.id))?;
                rng = ck
                    .restore(&mut trace)
                    .map_err(|e| format!("session {}: recovery restore failed: {e}", cfg.id))?;
                (ck.draw, Some(ck))
            }
            None => (
                0,
                Some(ChainCheckpoint::capture(
                    cfg.seed,
                    cfg.id as usize,
                    0,
                    &trace,
                    &rng,
                )),
            ),
        };
        let mut cmd = match &cfg.infer {
            Some(src) => Some(parse_infer(src)?),
            None => None,
        };
        if let Some(c) = cmd.as_mut() {
            if let Some(tr) = cfg.target_risk {
                c.set_target_risk(tr);
            }
            if cfg.shard_timeout_ms > 0 {
                c.set_shard_timeout_ms(cfg.shard_timeout_ms);
            }
            if let Some(v) = cfg.store_verify {
                c.set_store_verify(v);
            }
        }
        let ev = Self::fresh_eval(&cfg);
        let (sink, lane) = chain_lane(0, stop.clone());
        let mon = (cfg.monitor_every > 0 && !cfg.watch.is_empty())
            .then(|| ConvergenceMonitor::new(1, &cfg.watch, cfg.monitor_every));
        let mut last_row = vec![f64::NAN; cfg.watch.len()];
        if draws > 0 {
            for (i, n) in cfg.watch.iter().enumerate() {
                last_row[i] = trace
                    .lookup_value(n)
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN);
            }
        }
        let journal = Journal::open_append(&journal_path(&dir, cfg.id))?;
        Ok(Session {
            trace,
            rng,
            cmd,
            ev,
            sink,
            lane,
            stop,
            mon,
            draws,
            restarts: 0,
            failed: None,
            expired: false,
            // recovery grants a fresh lifetime window: wall-clock spent
            // dead should not count against the tenant
            created: Instant::now(),
            last_ck,
            last_snap: None,
            last_row,
            eval_base: EvalStats::default(),
            appended: appends.to_vec(),
            subs: Vec::new(),
            journal: Some(journal),
            since_journal_ckpt: 0,
            over_budget: false,
            budget_observed: false,
            cfg,
        })
    }

    fn fresh_eval(cfg: &SessionCfg) -> PlannedEval {
        let mut ev = if cfg.use_pool && resolve_threads(0) > 1 {
            PlannedEval::with_pool(WorkerPool::global().clone())
                .with_shard_timeout(cfg.shard_timeout_ms)
                // fair scheduling: this session's shards queue on their
                // own DRR lane, weighted by the create param
                .with_session(cfg.id, cfg.weight)
        } else {
            PlannedEval::new()
        };
        ev = ev.with_store_verify(cfg.store_verify);
        if cfg.min_parallel > 0 {
            ev = ev.with_min_parallel(cfg.min_parallel);
        }
        ev
    }

    /// The shared stop flag (the server's cancel/drain handle).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    pub fn total_draws(&self) -> usize {
        self.draws
    }

    pub fn restarts(&self) -> usize {
        self.restarts
    }

    pub fn failed(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    /// Whether a step already observed the session's lifetime deadline
    /// (expiry is permanent; the server maps further steps to the
    /// `Expired` error code).
    pub fn expired(&self) -> bool {
        self.expired
    }

    /// Whether a step already observed the session's permanent journal
    /// budget violation (the server maps further steps to the
    /// `BudgetExceeded` error code, mirroring expiry).
    pub fn budget_exceeded(&self) -> bool {
        self.over_budget && self.budget_observed
    }

    /// Current journal size (0 without durability).
    pub fn journal_bytes(&self) -> u64 {
        self.journal.as_ref().map_or(0, Journal::bytes)
    }

    /// Delete the session's journal file.  Cancel only: a *discarded*
    /// session must not resurrect on the next `--recover`.  Drain and
    /// crash teardown keep the journal — that state is exactly what
    /// recovery replays.
    pub fn retire_journal(&mut self) {
        self.journal = None;
        if let Some(dir) = &self.cfg.state_dir {
            let _ = std::fs::remove_file(crate::serve::journal::journal_path(dir, self.cfg.id));
        }
    }

    /// Cumulative evaluator counters across restarts.
    pub fn eval_stats(&self) -> EvalStats {
        self.eval_base.add(&self.ev.stats())
    }

    fn past_session_deadline(&self) -> bool {
        self.cfg
            .deadline
            .is_some_and(|d| self.created.elapsed() >= d)
    }

    /// Run up to `n` draws, stopping early at a draw boundary on
    /// cancellation, per-request deadline, or session expiry.  `Err` is
    /// terminal: the model itself failed (bad program, restart budget
    /// exhausted) and the session accepts no further steps.
    pub fn step(&mut self, n: usize, deadline: Option<Duration>) -> Result<StepReport, String> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        let t0 = Instant::now();
        let mut done = 0usize;
        let mut stopped = None;
        while done < n {
            // permanent expiry outranks the stop flag: expiry raises
            // that same shared flag below, so checking cancelled()
            // first would turn every post-expiry step into Cancelled
            if self.expired || self.past_session_deadline() {
                // expiry is permanent: raise the stop flag so any
                // in-flight transition machinery also winds down
                self.expired = true;
                self.stop.store(true, Ordering::SeqCst);
                stopped = Some(StopReason::Expired);
                break;
            }
            if self.over_budget {
                // like expiry: permanent, observed at a draw boundary;
                // the step that first observes it reports partial
                // progress, later steps map to BudgetExceeded
                self.budget_observed = true;
                stopped = Some(StopReason::Budget);
                break;
            }
            if self.sink.cancelled() {
                stopped = Some(StopReason::Cancelled);
                break;
            }
            if deadline.is_some_and(|d| t0.elapsed() >= d) {
                stopped = Some(StopReason::Deadline);
                break;
            }
            match self.one_draw() {
                Ok(()) => done += 1,
                Err(DrawErr::Panic(msg)) => {
                    self.restarts += 1;
                    if self.restarts > self.cfg.max_restarts {
                        let e = format!(
                            "session {}: draw panicked ({msg}) and restart budget ({}) \
                             is exhausted",
                            self.cfg.id, self.cfg.max_restarts
                        );
                        self.failed = Some(e.clone());
                        self.pump_events();
                        return Err(e);
                    }
                    self.sink.set_restarts(self.restarts);
                    if let Err(e) = self.rebuild() {
                        self.failed = Some(e.clone());
                        self.pump_events();
                        return Err(e);
                    }
                    // the draw that panicked has not been counted: the
                    // rebuilt state re-runs it from the checkpointed
                    // RNG position, so the sequence stays bitwise
                    // identical to an uninjected run
                }
                Err(DrawErr::Model(e)) => {
                    self.failed = Some(e.clone());
                    self.pump_events();
                    return Err(e);
                }
            }
        }
        // durability: a checkpoint covering every draw this step
        // committed must land before the reply — the acked draw count
        // is then always recoverable
        if self.since_journal_ckpt > 0 {
            if let Err(e) = self.journal_ckpt() {
                let e = format!("session {}: journal write failed: {e}", self.cfg.id);
                self.failed = Some(e.clone());
                self.pump_events();
                return Err(e);
            }
        }
        self.pump_events();
        Ok(StepReport {
            requested: n,
            done,
            total: self.draws,
            stopped,
            restarts: self.restarts,
            eval: self.eval_stats(),
        })
    }

    /// Append new directives (typically `[observe ...]` ticks) to the
    /// live model.  The server routes this through the session thread,
    /// so it always lands at a draw boundary: the trace is never
    /// mid-transition.  Appends take the O(|append|) fast path — plans,
    /// batch groups, and column-store panels for the existing data stay
    /// cached (`append_version` bumps, `structure_version` does not).
    ///
    /// Parse and budget errors are non-terminal (nothing was mutated;
    /// the client gets a `BadRequest` / `BudgetExceeded` and the
    /// session stays live).  A directive that parses but fails to
    /// *execute* may leave earlier directives of the same batch applied,
    /// so that error is terminal: the session is marked Failed rather
    /// than serve a half-applied model.  On success the appended source
    /// is retained (panic rebuilds replay it after `cfg.program`), a
    /// fresh checkpoint is captured so a restart resumes post-append,
    /// and — when durable — one atomic journal record carrying both the
    /// source and the post-append checkpoint lands before the reply.
    ///
    /// Returns the number of directives appended.
    pub fn append(&mut self, src: &str) -> Result<usize, AppendErr> {
        if let Some(e) = &self.failed {
            return Err(AppendErr::Failed(e.clone()));
        }
        // budget before mutation: a refused append leaves the trace
        // exactly as it was (steps and snapshots keep working)
        if self.cfg.max_trace_nodes > 0 && self.trace.num_live_nodes() >= self.cfg.max_trace_nodes {
            return Err(AppendErr::Budget(format!(
                "session {}: trace holds {} live nodes, at its {}-node budget; append refused",
                self.cfg.id,
                self.trace.num_live_nodes(),
                self.cfg.max_trace_nodes
            )));
        }
        let prog = crate::ppl::parser::parse_program(src).map_err(AppendErr::Parse)?;
        let n = prog.len();
        for d in &prog {
            if let Err(e) = self.trace.append_directive(d, &mut self.rng) {
                let e = format!("session {}: append failed mid-batch: {e}", self.cfg.id);
                self.failed = Some(e.clone());
                return Err(AppendErr::Failed(e));
            }
        }
        self.appended.push(src.to_string());
        self.last_ck = Some(ChainCheckpoint::capture(
            self.cfg.seed,
            self.cfg.id as usize,
            self.draws,
            &self.trace,
            &self.rng,
        ));
        // durability: the append record must land before the ack; a
        // failed write is terminal (the op is never acknowledged, so
        // recovery serves the pre-append state)
        if let Err(e) = self.journal_append_record(src) {
            let e = format!("session {}: journal write failed: {e}", self.cfg.id);
            self.failed = Some(e.clone());
            return Err(AppendErr::Failed(e));
        }
        Ok(n)
    }

    /// Write the atomic append record (`{src, ckpt}`) and run the
    /// compaction check.  No-op without a journal.
    fn journal_append_record(&mut self, src: &str) -> Result<(), String> {
        if self.journal.is_none() {
            return Ok(());
        }
        let ck_text = self
            .last_ck
            .as_ref()
            .ok_or_else(|| "no checkpoint to journal".to_string())?
            .encode()?;
        let payload = Json::Obj(vec![
            ("src".into(), Json::Str(src.to_string())),
            ("ckpt".into(), Json::Str(ck_text)),
        ]);
        self.journal
            .as_mut()
            .expect("checked above")
            .append_record(KIND_APPEND, payload.encode().as_bytes())?;
        // the append record carries a checkpoint at the current draw
        // count, so nothing since it needs re-journaling
        self.since_journal_ckpt = 0;
        self.maybe_compact()
    }

    /// Journal the latest checkpoint and run the compaction check.
    /// No-op without a journal.
    fn journal_ckpt(&mut self) -> Result<(), String> {
        if self.journal.is_none() {
            return Ok(());
        }
        let text = self
            .last_ck
            .as_ref()
            .ok_or_else(|| "no checkpoint to journal".to_string())?
            .encode()?;
        self.journal
            .as_mut()
            .expect("checked above")
            .append_record(KIND_CKPT, text.as_bytes())?;
        self.since_journal_ckpt = 0;
        self.maybe_compact()
    }

    /// Compact the journal when it outgrows its cap (the session's
    /// `max_journal_bytes`, or [`COMPACT_THRESHOLD`] when uncapped).
    /// A session whose *compacted* journal still exceeds its budget is
    /// permanently over budget: the next draw boundary reports
    /// `stopped:"budget"` and later steps get `BudgetExceeded`.
    fn maybe_compact(&mut self) -> Result<(), String> {
        let cap = if self.cfg.max_journal_bytes > 0 {
            self.cfg.max_journal_bytes
        } else {
            COMPACT_THRESHOLD
        };
        let over = match self.journal.as_ref() {
            Some(j) => j.bytes() > cap,
            None => false,
        };
        if !over {
            return Ok(());
        }
        let payload = journal_payload(&self.cfg);
        let ck_text = match self.last_ck.as_ref() {
            Some(ck) => Some(ck.encode()?),
            None => None,
        };
        let j = self.journal.as_mut().expect("checked above");
        j.compact(&payload, &self.appended, ck_text.as_deref())?;
        if self.cfg.max_journal_bytes > 0 && j.bytes() > self.cfg.max_journal_bytes {
            self.over_budget = true;
        }
        Ok(())
    }

    /// One committed draw: run the inference program once, record the
    /// watched row on the event lane, checkpoint.
    fn one_draw(&mut self) -> Result<(), DrawErr> {
        let trace = &mut self.trace;
        let rng = &mut self.rng;
        let ev = &mut self.ev;
        let cmd = self.cmd.as_ref();
        let res = catch_unwind(AssertUnwindSafe(|| {
            if faults::session_panic_now() {
                panic!("injected: session fault");
            }
            match cmd {
                Some(c) => run_command(trace, rng, c, ev).map(|_| ()),
                None => Ok(()),
            }
        }));
        match res {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(DrawErr::Model(e)),
            Err(p) => {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic".into());
                return Err(DrawErr::Panic(msg));
            }
        }
        self.draws += 1;
        let mut row = Vec::with_capacity(self.cfg.watch.len());
        for n in &self.cfg.watch {
            row.push(
                self.trace
                    .lookup_value(n)
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN),
            );
        }
        self.last_row = row.clone();
        if !row.is_empty() {
            self.sink
                .send_with_stats(vec![row], Some(self.eval_base.add(&self.ev.stats())));
        }
        // per-draw in-memory checkpoint: the panic-restart granularity
        self.last_ck = Some(ChainCheckpoint::capture(
            self.cfg.seed,
            self.cfg.id as usize,
            self.draws,
            &self.trace,
            &self.rng,
        ));
        // mid-step journal cadence: bounds replay after a crash
        // mid-step (the end-of-step flush covers the acked count)
        if self.journal.is_some() {
            self.since_journal_ckpt += 1;
            let every = if self.cfg.journal_every == 0 {
                DEFAULT_JOURNAL_EVERY
            } else {
                self.cfg.journal_every
            };
            if self.since_journal_ckpt >= every {
                if let Err(e) = self.journal_ckpt() {
                    // terminal Model error: the draw happened in memory
                    // but can no longer be made durable, so it must
                    // never be acknowledged
                    return Err(DrawErr::Model(format!(
                        "session {}: journal write failed: {e}",
                        self.cfg.id
                    )));
                }
            }
        }
        Ok(())
    }

    /// Post-panic recovery: fold the dead evaluator's counters into the
    /// base, rebuild trace + evaluator from scratch, restore committed
    /// values + RNG position from the last per-draw checkpoint.
    fn rebuild(&mut self) -> Result<(), String> {
        self.eval_base = self.eval_base.add(&self.ev.stats());
        self.ev = Self::fresh_eval(&self.cfg);
        let mut rng = session_rng(self.cfg.seed, self.cfg.id);
        let mut trace = Trace::new();
        trace
            .run_program(&self.cfg.program, &mut rng)
            .map_err(|e| format!("session {}: rebuild failed: {e}", self.cfg.id))?;
        // replay journaled appends so the rebuilt trace has the same
        // node ids as the live one had at the last checkpoint (the
        // values drawn here are scratch — restore overwrites them, and
        // the RNG is swapped to the checkpointed position)
        for src in &self.appended {
            trace
                .append_program(src, &mut rng)
                .map_err(|e| format!("session {}: append replay failed: {e}", self.cfg.id))?;
        }
        let ck = self
            .last_ck
            .as_ref()
            .ok_or_else(|| format!("session {}: no checkpoint to restore", self.cfg.id))?;
        let rng = ck
            .restore(&mut trace)
            .map_err(|e| format!("session {}: restore failed: {e}", self.cfg.id))?;
        self.trace = trace;
        self.rng = rng;
        Ok(())
    }

    /// Drain the event lane: fold draws into the convergence monitor
    /// and broadcast draw batches + ready `[monitor]` snapshots to
    /// subscribers.  Runs at step boundaries — the lane is written and
    /// read by this same thread, so nothing accumulates unbounded.
    fn pump_events(&mut self) {
        for ev in self.lane.drain() {
            self.broadcast(&draws_event(self.cfg.id, &ev.draws));
            if let Some(m) = self.mon.as_mut() {
                m.absorb(ev);
                for snap in m.ready_snapshots() {
                    self.broadcast(&monitor_event(self.cfg.id, &snap));
                    self.last_snap = Some(snap);
                }
            }
        }
    }

    /// Attach a subscriber stream.  The sender must be bounded; the
    /// session drops subscribers whose channel is full or closed.
    pub fn subscribe(&mut self, tx: SyncSender<String>) {
        self.subs.push(tx);
    }

    fn broadcast(&mut self, line: &str) {
        self.subs.retain(|tx| match tx.try_send(line.to_string()) {
            Ok(()) => true,
            // Full = wedged/slow client: drop it rather than buffer
            // unboundedly or block the session (slowloris defense)
            Err(TrySendError::Full(_)) => false,
            Err(TrySendError::Disconnected(_)) => false,
        });
    }

    /// Current state as a JSON body (the `snapshot` RPC).
    pub fn snapshot_json(&self) -> Json {
        let values = Json::Obj(
            self.cfg
                .watch
                .iter()
                .zip(&self.last_row)
                .map(|(n, v)| (n.clone(), Json::Num(*v)))
                .collect(),
        );
        let e = self.eval_stats();
        Json::Obj(vec![
            ("session".into(), Json::Num(self.cfg.id as f64)),
            ("draws".into(), Json::Num(self.draws as f64)),
            ("restarts".into(), Json::Num(self.restarts as f64)),
            (
                "failed".into(),
                match &self.failed {
                    Some(m) => Json::Str(m.clone()),
                    None => Json::Null,
                },
            ),
            ("values".into(), values),
            (
                "sections".into(),
                Json::Num((e.planned + e.fallback) as f64),
            ),
            ("journal_bytes".into(), Json::Num(self.journal_bytes() as f64)),
            (
                "monitor".into(),
                match &self.last_snap {
                    Some(s) => Json::Str(s.render()),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Write the last per-draw checkpoint to the session's checkpoint
    /// dir (drain path).  `Ok(false)` when the session has no dir.
    pub fn checkpoint_to_disk(&self) -> Result<bool, String> {
        let (dir, ck) = match (&self.cfg.checkpoint_dir, &self.last_ck) {
            (Some(d), Some(c)) => (d, c),
            _ => return Ok(false),
        };
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        ck.save(dir)?;
        Ok(true)
    }
}

enum DrawErr {
    /// Caught panic: recoverable via checkpoint restart.
    Panic(String),
    /// Model-level error: terminal.
    Model(String),
}

fn draws_event(id: u64, draws: &[Vec<f64>]) -> String {
    Json::Obj(vec![
        ("event".into(), Json::Str("draws".into())),
        ("session".into(), Json::Num(id as f64)),
        (
            "draws".into(),
            Json::Arr(
                draws
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|v| Json::Num(*v)).collect()))
                    .collect(),
            ),
        ),
    ])
    .encode()
}

fn monitor_event(id: u64, snap: &DiagSnapshot) -> String {
    Json::Obj(vec![
        ("event".into(), Json::Str("monitor".into())),
        ("session".into(), Json::Num(id as f64)),
        ("draws".into(), Json::Num(snap.draws_per_chain as f64)),
        ("max_rhat".into(), Json::Num(snap.max_rhat())),
        ("sections".into(), Json::Num(snap.sections_scored() as f64)),
        ("line".into(), Json::Str(snap.render())),
    ])
    .encode()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MODEL: &str = r#"
        [assume mu (scope_include 'mu 0 (normal 0 1))]
        [observe (normal mu 0.5) 1.2]
        [observe (normal mu 0.5) 0.8]
    "#;

    fn cfg(id: u64) -> SessionCfg {
        SessionCfg {
            id,
            seed: 42,
            program: MODEL.into(),
            infer: Some("(mh mu one drift 0.5 1)".into()),
            watch: vec!["mu".into()],
            ..SessionCfg::default()
        }
    }

    #[test]
    fn draws_are_deterministic_in_seed_and_id() {
        let run = |id: u64, chunks: &[usize]| -> Vec<f64> {
            let mut s = Session::new(cfg(id)).unwrap();
            let mut out = Vec::new();
            for &n in chunks {
                s.step(n, None).unwrap();
                out.push(s.last_row[0]);
            }
            assert_eq!(s.total_draws(), chunks.iter().sum::<usize>());
            out
        };
        // same (seed, id): identical regardless of step chunking
        let a = run(1, &[30]);
        let b = run(1, &[7, 13, 10]);
        assert_eq!(a[a.len() - 1].to_bits(), b[b.len() - 1].to_bits());
        // different id: a different stream entirely
        let c = run(2, &[30]);
        assert_ne!(a[a.len() - 1].to_bits(), c[c.len() - 1].to_bits());
    }

    #[test]
    fn cancellation_stops_at_a_draw_boundary() {
        let mut s = Session::new(cfg(3)).unwrap();
        s.step(5, None).unwrap();
        s.stop_flag().store(true, Ordering::SeqCst);
        let rep = s.step(10, None).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Cancelled));
        assert_eq!(rep.total, 5, "no draw committed after the stop");
    }

    #[test]
    fn session_deadline_expires_and_is_permanent() {
        let mut c = cfg(4);
        c.deadline = Some(Duration::from_millis(0));
        let mut s = Session::new(c).unwrap();
        let rep = s.step(10, None).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Expired));
        let rep = s.step(1, None).unwrap();
        assert_eq!(rep.stopped, Some(StopReason::Expired));
    }

    #[test]
    fn appends_land_between_steps_deterministically() {
        // same (seed, id) and same append schedule → bitwise identical
        // draws regardless of how the steps around the append are
        // chunked; the appended observation visibly shifts the
        // posterior relative to a no-append run
        let run = |pre: &[usize], post: &[usize], append: bool| -> f64 {
            let mut s = Session::new(cfg(6)).unwrap();
            for &n in pre {
                s.step(n, None).unwrap();
            }
            if append {
                assert_eq!(s.append("[observe (normal mu 0.5) -3.0]").unwrap(), 1);
            }
            for &n in post {
                s.step(n, None).unwrap();
            }
            s.last_row[0]
        };
        let a = run(&[10], &[10], true);
        let b = run(&[3, 7], &[4, 6], true);
        assert_eq!(a.to_bits(), b.to_bits());
        let c = run(&[10], &[10], false);
        assert_ne!(a.to_bits(), c.to_bits(), "append must change the chain");
    }

    #[test]
    fn append_parse_error_is_not_terminal() {
        let mut s = Session::new(cfg(7)).unwrap();
        s.step(2, None).unwrap();
        assert!(s.append("[observe (normal mu").is_err());
        assert!(s.failed().is_none(), "parse errors leave the session live");
        s.step(2, None).unwrap();
        assert_eq!(s.total_draws(), 4);
    }

    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("subppl-sess-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn journal_recovery_is_bitwise() {
        let dir = scratch_dir("rec");
        // "interrupted" run: steps, an acknowledged append, more steps,
        // then the process dies (drop = nothing further is flushed; the
        // journal already holds everything acknowledged)
        let mut c = cfg(11);
        c.state_dir = Some(dir.clone());
        c.journal_every = 4;
        let mut s = Session::new(c).unwrap();
        s.step(10, None).unwrap();
        s.append("[observe (normal mu 0.5) -3.0]").unwrap();
        s.step(5, None).unwrap();
        drop(s);

        let st = crate::serve::journal::read_journal(&journal_path(&dir, 11)).unwrap();
        assert!(!st.torn);
        assert_eq!(st.appends.len(), 1);
        let mut rc = cfg_from_journal(11, &st.create).unwrap();
        assert_eq!(rc.seed, 42, "create record round-trips the seed");
        rc.state_dir = Some(dir.clone());
        let mut r = Session::recover(rc, &st.appends, st.ckpt.as_deref()).unwrap();
        assert_eq!(r.total_draws(), 15, "every acked draw was recovered");
        r.step(10, None).unwrap();

        // control: same (seed, id, append schedule), never interrupted
        let mut u = Session::new(cfg(11)).unwrap();
        u.step(10, None).unwrap();
        u.append("[observe (normal mu 0.5) -3.0]").unwrap();
        u.step(15, None).unwrap();
        assert_eq!(
            r.last_row[0].to_bits(),
            u.last_row[0].to_bits(),
            "recovered draws must be bitwise identical to the uninterrupted run"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_budget_refuses_append_but_session_lives() {
        let mut c = cfg(12);
        c.max_trace_nodes = 1;
        let mut s = Session::new(c).unwrap();
        s.step(2, None).unwrap();
        match s.append("[observe (normal mu 0.5) 0.1]") {
            Err(AppendErr::Budget(_)) => {}
            other => panic!("expected a budget refusal, got {other:?}"),
        }
        assert!(s.failed().is_none(), "budget refusals are not terminal");
        s.step(2, None).unwrap();
        assert_eq!(s.total_draws(), 4);
    }

    #[test]
    fn journal_budget_is_permanent_and_observed_at_a_draw_boundary() {
        let dir = scratch_dir("budget");
        let mut c = cfg(13);
        c.state_dir = Some(dir.clone());
        c.journal_every = 1;
        // even a compacted journal exceeds one byte
        c.max_journal_bytes = 1;
        let mut s = Session::new(c).unwrap();
        let rep = s.step(5, None).unwrap();
        assert_eq!(rep.done, 1, "the violating draw boundary still reports");
        assert_eq!(rep.stopped, Some(StopReason::Budget));
        assert!(s.budget_exceeded());
        let rep = s.step(5, None).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Budget));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_names_watched_values() {
        let mut s = Session::new(cfg(5)).unwrap();
        s.step(3, None).unwrap();
        let js = s.snapshot_json();
        assert_eq!(js.get("draws").and_then(Json::as_u64), Some(3));
        assert!(js.get("values").unwrap().get("mu").is_some());
    }
}
