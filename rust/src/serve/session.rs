//! One inference session: a `Trace` + PCG stream owned by a single
//! thread, stepped on demand, streaming draws and `[monitor]` snapshots
//! over the existing `ChainSink`/`ChainEvent` lane.
//!
//! Determinism contract: a session's draw sequence is a pure function
//! of `(seed, session id)` — the RNG stream is
//! `Pcg64::new(seed, SESSION_STREAM_BASE + id)`, mirroring the
//! per-chain streams of `coordinator/multichain.rs`, and the evaluator
//! tiers are bitwise identical sequential vs sharded.  Concurrent
//! sessions therefore cannot perturb each other's draws no matter how
//! the shared `WorkerPool` interleaves their shards — the isolation
//! property `tests/serve.rs` pins under injected faults.
//!
//! Robustness contract: deadlines (per-step and per-session) and
//! cancellation are observed at *draw boundaries* — a transition either
//! commits or rejects atomically (`subsampled_mh_transition` mutates
//! the trace only in its final commit), so a stopped session's trace is
//! always pre- or post-transition, never torn.  A panicking draw is
//! caught, the trace is rebuilt from source, and the session resumes
//! from its last per-draw in-memory [`ChainCheckpoint`] — bitwise
//! identical to the draw sequence that would have happened without the
//! panic, up to `max_restarts` per session.

use crate::coordinator::checkpoint::ChainCheckpoint;
use crate::coordinator::monitor::{ConvergenceMonitor, DiagSnapshot};
use crate::coordinator::multichain::{chain_lane, ChainLane, ChainSink};
use crate::infer::planned::{EvalStats, PlannedEval};
use crate::infer::program::{parse_infer, run_command, InfCmd};
use crate::math::Pcg64;
use crate::runtime::faults;
use crate::runtime::pool::{resolve_threads, WorkerPool};
use crate::serve::protocol::Json;
use crate::trace::Trace;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{SyncSender, TrySendError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Serve sessions draw from their own PCG stream family, disjoint from
/// the multichain `CHAIN_STREAM_BASE` ("ch") family — a session and a
/// CLI chain with the same index never share a stream.
pub const SESSION_STREAM_BASE: u64 = 0x7365_0000; // "se"

/// The session's RNG: deterministic in `(seed, session id)` only.
pub fn session_rng(seed: u64, id: u64) -> Pcg64 {
    Pcg64::new(seed, SESSION_STREAM_BASE + id)
}

/// Everything a session needs to build itself inside its own thread.
#[derive(Clone, Debug)]
pub struct SessionCfg {
    pub id: u64,
    pub seed: u64,
    /// Model program source (`[assume ...]` / `[observe ...]` forms).
    pub program: String,
    /// Inference program (`(cycle ...)` surface syntax); `None` = the
    /// session only holds the prior trace (snapshot-only sessions).
    pub infer: Option<String>,
    /// Watched parameter names: one row per draw on the event lane.
    pub watch: Vec<String>,
    pub target_risk: Option<f64>,
    /// Per-session shard-watchdog deadline (0 = process default).
    pub shard_timeout_ms: u64,
    /// Per-session column-store verify mode (`None` = the
    /// `SUBPPL_STORE_VERIFY` env default).
    pub store_verify: Option<crate::trace::colstore::VerifyMode>,
    /// Session lifetime budget from creation (None = unbounded).
    pub deadline: Option<Duration>,
    /// Panic restarts granted before the session is declared Failed.
    pub max_restarts: usize,
    /// Shard intra-draw scoring across the shared pool (false = the
    /// sequential evaluator; results are bitwise identical either way).
    pub use_pool: bool,
    /// Parallel-dispatch cutoff override (0 = default 256; tests force
    /// the sharded path on small models with 1).
    pub min_parallel: usize,
    /// Convergence snapshot cadence in draws (0 = no monitor).
    pub monitor_every: usize,
    /// Where drain writes the session's final checkpoint (None = the
    /// session's state dies with it).
    pub checkpoint_dir: Option<std::path::PathBuf>,
}

impl Default for SessionCfg {
    fn default() -> SessionCfg {
        SessionCfg {
            id: 0,
            seed: 0,
            program: String::new(),
            infer: None,
            watch: Vec::new(),
            target_risk: None,
            shard_timeout_ms: 0,
            store_verify: None,
            deadline: None,
            max_restarts: 2,
            use_pool: false,
            min_parallel: 0,
            monitor_every: 0,
            checkpoint_dir: None,
        }
    }
}

/// Why a step returned before completing its requested draws.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StopReason {
    /// The per-request deadline fired at a draw boundary.
    Deadline,
    /// The session's stop flag was raised (cancel RPC, drain, or the
    /// `cancel@k` fault) and observed at a draw boundary.
    Cancelled,
    /// The session outlived its lifetime deadline; it will accept no
    /// further steps.
    Expired,
}

impl StopReason {
    pub fn name(self) -> &'static str {
        match self {
            StopReason::Deadline => "deadline",
            StopReason::Cancelled => "cancelled",
            StopReason::Expired => "expired",
        }
    }
}

/// What one `step(n)` actually did.
#[derive(Clone, Debug)]
pub struct StepReport {
    pub requested: usize,
    pub done: usize,
    /// Completed draws over the session's lifetime.
    pub total: usize,
    pub stopped: Option<StopReason>,
    pub restarts: usize,
    /// Cumulative evaluator counters (survives evaluator rebuilds
    /// after a panic restart).
    pub eval: EvalStats,
}

/// A session that can be driven directly (tests) or by the server's
/// per-session thread.  Owns non-`Send` state (`Trace` is `Rc`-based),
/// so it must be built and driven on one thread.
pub struct Session {
    pub cfg: SessionCfg,
    trace: Trace,
    rng: Pcg64,
    cmd: Option<InfCmd>,
    ev: PlannedEval,
    sink: ChainSink,
    lane: ChainLane,
    stop: Arc<AtomicBool>,
    mon: Option<ConvergenceMonitor>,
    /// Completed draws (checkpoint granularity: every draw).
    draws: usize,
    restarts: usize,
    /// Terminal model error (restart budget exhausted or a
    /// non-panic evaluation error).
    failed: Option<String>,
    expired: bool,
    created: Instant,
    last_ck: Option<ChainCheckpoint>,
    last_snap: Option<DiagSnapshot>,
    last_row: Vec<f64>,
    /// Counters accumulated by evaluator incarnations that a panic
    /// restart already tore down.
    eval_base: EvalStats,
    /// Journal of appended program sources (the `append` RPC), in
    /// arrival order: a panic rebuild replays these after
    /// `cfg.program` so the rebuilt trace allocates the same node ids
    /// as the live one before the checkpoint restore overwrites state.
    appended: Vec<String>,
    /// Subscribed streams: bounded senders of encoded event lines.  A
    /// full or closed channel drops the subscriber (slowloris
    /// protection) — the session never blocks on a slow client.
    subs: Vec<SyncSender<String>>,
}

impl Session {
    /// Build the session: run the model program under the session RNG,
    /// parse the inference program, capture the draw-0 checkpoint.
    pub fn new(cfg: SessionCfg) -> Result<Session, String> {
        let stop = Arc::new(AtomicBool::new(false));
        // the cancel@k fault needs to find this session's flag
        faults::register_cancel_flag(&stop);
        let mut rng = session_rng(cfg.seed, cfg.id);
        let mut trace = Trace::new();
        trace.run_program(&cfg.program, &mut rng)?;
        let mut cmd = match &cfg.infer {
            Some(src) => Some(parse_infer(src)?),
            None => None,
        };
        if let Some(c) = cmd.as_mut() {
            if let Some(tr) = cfg.target_risk {
                c.set_target_risk(tr);
            }
            if cfg.shard_timeout_ms > 0 {
                c.set_shard_timeout_ms(cfg.shard_timeout_ms);
            }
            if let Some(v) = cfg.store_verify {
                c.set_store_verify(v);
            }
        }
        let ev = Self::fresh_eval(&cfg);
        // lane chain index 0: the per-session monitor folds exactly one
        // chain (the session id lives in the checkpoint and the frames)
        let (sink, lane) = chain_lane(0, stop.clone());
        let mon = (cfg.monitor_every > 0 && !cfg.watch.is_empty())
            .then(|| ConvergenceMonitor::new(1, &cfg.watch, cfg.monitor_every));
        let last_ck = Some(ChainCheckpoint::capture(
            cfg.seed,
            cfg.id as usize,
            0,
            &trace,
            &rng,
        ));
        Ok(Session {
            trace,
            rng,
            cmd,
            ev,
            sink,
            lane,
            stop,
            mon,
            draws: 0,
            restarts: 0,
            failed: None,
            expired: false,
            created: Instant::now(),
            last_ck,
            last_snap: None,
            last_row: vec![f64::NAN; cfg.watch.len()],
            eval_base: EvalStats::default(),
            appended: Vec::new(),
            subs: Vec::new(),
            cfg,
        })
    }

    fn fresh_eval(cfg: &SessionCfg) -> PlannedEval {
        let mut ev = if cfg.use_pool && resolve_threads(0) > 1 {
            PlannedEval::with_pool(WorkerPool::global().clone())
                .with_shard_timeout(cfg.shard_timeout_ms)
        } else {
            PlannedEval::new()
        };
        ev = ev.with_store_verify(cfg.store_verify);
        if cfg.min_parallel > 0 {
            ev = ev.with_min_parallel(cfg.min_parallel);
        }
        ev
    }

    /// The shared stop flag (the server's cancel/drain handle).
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        self.stop.clone()
    }

    pub fn total_draws(&self) -> usize {
        self.draws
    }

    pub fn restarts(&self) -> usize {
        self.restarts
    }

    pub fn failed(&self) -> Option<&str> {
        self.failed.as_deref()
    }

    /// Whether a step already observed the session's lifetime deadline
    /// (expiry is permanent; the server maps further steps to the
    /// `Expired` error code).
    pub fn expired(&self) -> bool {
        self.expired
    }

    /// Cumulative evaluator counters across restarts.
    pub fn eval_stats(&self) -> EvalStats {
        self.eval_base.add(&self.ev.stats())
    }

    fn past_session_deadline(&self) -> bool {
        self.cfg
            .deadline
            .is_some_and(|d| self.created.elapsed() >= d)
    }

    /// Run up to `n` draws, stopping early at a draw boundary on
    /// cancellation, per-request deadline, or session expiry.  `Err` is
    /// terminal: the model itself failed (bad program, restart budget
    /// exhausted) and the session accepts no further steps.
    pub fn step(&mut self, n: usize, deadline: Option<Duration>) -> Result<StepReport, String> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        let t0 = Instant::now();
        let mut done = 0usize;
        let mut stopped = None;
        while done < n {
            // permanent expiry outranks the stop flag: expiry raises
            // that same shared flag below, so checking cancelled()
            // first would turn every post-expiry step into Cancelled
            if self.expired || self.past_session_deadline() {
                // expiry is permanent: raise the stop flag so any
                // in-flight transition machinery also winds down
                self.expired = true;
                self.stop.store(true, Ordering::SeqCst);
                stopped = Some(StopReason::Expired);
                break;
            }
            if self.sink.cancelled() {
                stopped = Some(StopReason::Cancelled);
                break;
            }
            if deadline.is_some_and(|d| t0.elapsed() >= d) {
                stopped = Some(StopReason::Deadline);
                break;
            }
            match self.one_draw() {
                Ok(()) => done += 1,
                Err(DrawErr::Panic(msg)) => {
                    self.restarts += 1;
                    if self.restarts > self.cfg.max_restarts {
                        let e = format!(
                            "session {}: draw panicked ({msg}) and restart budget ({}) \
                             is exhausted",
                            self.cfg.id, self.cfg.max_restarts
                        );
                        self.failed = Some(e.clone());
                        self.pump_events();
                        return Err(e);
                    }
                    self.sink.set_restarts(self.restarts);
                    if let Err(e) = self.rebuild() {
                        self.failed = Some(e.clone());
                        self.pump_events();
                        return Err(e);
                    }
                    // the draw that panicked has not been counted: the
                    // rebuilt state re-runs it from the checkpointed
                    // RNG position, so the sequence stays bitwise
                    // identical to an uninjected run
                }
                Err(DrawErr::Model(e)) => {
                    self.failed = Some(e.clone());
                    self.pump_events();
                    return Err(e);
                }
            }
        }
        self.pump_events();
        Ok(StepReport {
            requested: n,
            done,
            total: self.draws,
            stopped,
            restarts: self.restarts,
            eval: self.eval_stats(),
        })
    }

    /// Append new directives (typically `[observe ...]` ticks) to the
    /// live model.  The server routes this through the session thread,
    /// so it always lands at a draw boundary: the trace is never
    /// mid-transition.  Appends take the O(|append|) fast path — plans,
    /// batch groups, and column-store panels for the existing data stay
    /// cached (`append_version` bumps, `structure_version` does not).
    ///
    /// Parse errors are non-terminal (nothing was mutated; the client
    /// just gets a `BadRequest`).  A directive that parses but fails to
    /// *execute* may leave earlier directives of the same batch applied,
    /// so that error is terminal: the session is marked Failed rather
    /// than serve a half-applied model.  On success the appended source
    /// is journaled (panic rebuilds replay it after `cfg.program`) and a
    /// fresh checkpoint is captured so a restart resumes post-append.
    ///
    /// Returns the number of directives appended.
    pub fn append(&mut self, src: &str) -> Result<usize, String> {
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        let prog = crate::ppl::parser::parse_program(src)?;
        let n = prog.len();
        for d in &prog {
            if let Err(e) = self.trace.append_directive(d, &mut self.rng) {
                let e = format!("session {}: append failed mid-batch: {e}", self.cfg.id);
                self.failed = Some(e.clone());
                return Err(e);
            }
        }
        self.appended.push(src.to_string());
        self.last_ck = Some(ChainCheckpoint::capture(
            self.cfg.seed,
            self.cfg.id as usize,
            self.draws,
            &self.trace,
            &self.rng,
        ));
        Ok(n)
    }

    /// One committed draw: run the inference program once, record the
    /// watched row on the event lane, checkpoint.
    fn one_draw(&mut self) -> Result<(), DrawErr> {
        let trace = &mut self.trace;
        let rng = &mut self.rng;
        let ev = &mut self.ev;
        let cmd = self.cmd.as_ref();
        let res = catch_unwind(AssertUnwindSafe(|| {
            if faults::session_panic_now() {
                panic!("injected: session fault");
            }
            match cmd {
                Some(c) => run_command(trace, rng, c, ev).map(|_| ()),
                None => Ok(()),
            }
        }));
        match res {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(DrawErr::Model(e)),
            Err(p) => {
                let msg = p
                    .downcast_ref::<&str>()
                    .map(|s| s.to_string())
                    .or_else(|| p.downcast_ref::<String>().cloned())
                    .unwrap_or_else(|| "non-string panic".into());
                return Err(DrawErr::Panic(msg));
            }
        }
        self.draws += 1;
        let mut row = Vec::with_capacity(self.cfg.watch.len());
        for n in &self.cfg.watch {
            row.push(
                self.trace
                    .lookup_value(n)
                    .and_then(|v| v.as_f64())
                    .unwrap_or(f64::NAN),
            );
        }
        self.last_row = row.clone();
        if !row.is_empty() {
            self.sink
                .send_with_stats(vec![row], Some(self.eval_base.add(&self.ev.stats())));
        }
        // per-draw in-memory checkpoint: the panic-restart granularity
        self.last_ck = Some(ChainCheckpoint::capture(
            self.cfg.seed,
            self.cfg.id as usize,
            self.draws,
            &self.trace,
            &self.rng,
        ));
        Ok(())
    }

    /// Post-panic recovery: fold the dead evaluator's counters into the
    /// base, rebuild trace + evaluator from scratch, restore committed
    /// values + RNG position from the last per-draw checkpoint.
    fn rebuild(&mut self) -> Result<(), String> {
        self.eval_base = self.eval_base.add(&self.ev.stats());
        self.ev = Self::fresh_eval(&self.cfg);
        let mut rng = session_rng(self.cfg.seed, self.cfg.id);
        let mut trace = Trace::new();
        trace
            .run_program(&self.cfg.program, &mut rng)
            .map_err(|e| format!("session {}: rebuild failed: {e}", self.cfg.id))?;
        // replay journaled appends so the rebuilt trace has the same
        // node ids as the live one had at the last checkpoint (the
        // values drawn here are scratch — restore overwrites them, and
        // the RNG is swapped to the checkpointed position)
        for src in &self.appended {
            trace
                .append_program(src, &mut rng)
                .map_err(|e| format!("session {}: append replay failed: {e}", self.cfg.id))?;
        }
        let ck = self
            .last_ck
            .as_ref()
            .ok_or_else(|| format!("session {}: no checkpoint to restore", self.cfg.id))?;
        let rng = ck
            .restore(&mut trace)
            .map_err(|e| format!("session {}: restore failed: {e}", self.cfg.id))?;
        self.trace = trace;
        self.rng = rng;
        Ok(())
    }

    /// Drain the event lane: fold draws into the convergence monitor
    /// and broadcast draw batches + ready `[monitor]` snapshots to
    /// subscribers.  Runs at step boundaries — the lane is written and
    /// read by this same thread, so nothing accumulates unbounded.
    fn pump_events(&mut self) {
        for ev in self.lane.drain() {
            self.broadcast(&draws_event(self.cfg.id, &ev.draws));
            if let Some(m) = self.mon.as_mut() {
                m.absorb(ev);
                for snap in m.ready_snapshots() {
                    self.broadcast(&monitor_event(self.cfg.id, &snap));
                    self.last_snap = Some(snap);
                }
            }
        }
    }

    /// Attach a subscriber stream.  The sender must be bounded; the
    /// session drops subscribers whose channel is full or closed.
    pub fn subscribe(&mut self, tx: SyncSender<String>) {
        self.subs.push(tx);
    }

    fn broadcast(&mut self, line: &str) {
        self.subs.retain(|tx| match tx.try_send(line.to_string()) {
            Ok(()) => true,
            // Full = wedged/slow client: drop it rather than buffer
            // unboundedly or block the session (slowloris defense)
            Err(TrySendError::Full(_)) => false,
            Err(TrySendError::Disconnected(_)) => false,
        });
    }

    /// Current state as a JSON body (the `snapshot` RPC).
    pub fn snapshot_json(&self) -> Json {
        let values = Json::Obj(
            self.cfg
                .watch
                .iter()
                .zip(&self.last_row)
                .map(|(n, v)| (n.clone(), Json::Num(*v)))
                .collect(),
        );
        let e = self.eval_stats();
        Json::Obj(vec![
            ("session".into(), Json::Num(self.cfg.id as f64)),
            ("draws".into(), Json::Num(self.draws as f64)),
            ("restarts".into(), Json::Num(self.restarts as f64)),
            (
                "failed".into(),
                match &self.failed {
                    Some(m) => Json::Str(m.clone()),
                    None => Json::Null,
                },
            ),
            ("values".into(), values),
            (
                "sections".into(),
                Json::Num((e.planned + e.fallback) as f64),
            ),
            (
                "monitor".into(),
                match &self.last_snap {
                    Some(s) => Json::Str(s.render()),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Write the last per-draw checkpoint to the session's checkpoint
    /// dir (drain path).  `Ok(false)` when the session has no dir.
    pub fn checkpoint_to_disk(&self) -> Result<bool, String> {
        let (dir, ck) = match (&self.cfg.checkpoint_dir, &self.last_ck) {
            (Some(d), Some(c)) => (d, c),
            _ => return Ok(false),
        };
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        ck.save(dir)?;
        Ok(true)
    }
}

enum DrawErr {
    /// Caught panic: recoverable via checkpoint restart.
    Panic(String),
    /// Model-level error: terminal.
    Model(String),
}

fn draws_event(id: u64, draws: &[Vec<f64>]) -> String {
    Json::Obj(vec![
        ("event".into(), Json::Str("draws".into())),
        ("session".into(), Json::Num(id as f64)),
        (
            "draws".into(),
            Json::Arr(
                draws
                    .iter()
                    .map(|row| Json::Arr(row.iter().map(|v| Json::Num(*v)).collect()))
                    .collect(),
            ),
        ),
    ])
    .encode()
}

fn monitor_event(id: u64, snap: &DiagSnapshot) -> String {
    Json::Obj(vec![
        ("event".into(), Json::Str("monitor".into())),
        ("session".into(), Json::Num(id as f64)),
        ("draws".into(), Json::Num(snap.draws_per_chain as f64)),
        ("max_rhat".into(), Json::Num(snap.max_rhat())),
        ("sections".into(), Json::Num(snap.sections_scored() as f64)),
        ("line".into(), Json::Str(snap.render())),
    ])
    .encode()
}

#[cfg(test)]
mod tests {
    use super::*;

    const MODEL: &str = r#"
        [assume mu (scope_include 'mu 0 (normal 0 1))]
        [observe (normal mu 0.5) 1.2]
        [observe (normal mu 0.5) 0.8]
    "#;

    fn cfg(id: u64) -> SessionCfg {
        SessionCfg {
            id,
            seed: 42,
            program: MODEL.into(),
            infer: Some("(mh mu one drift 0.5 1)".into()),
            watch: vec!["mu".into()],
            ..SessionCfg::default()
        }
    }

    #[test]
    fn draws_are_deterministic_in_seed_and_id() {
        let run = |id: u64, chunks: &[usize]| -> Vec<f64> {
            let mut s = Session::new(cfg(id)).unwrap();
            let mut out = Vec::new();
            for &n in chunks {
                s.step(n, None).unwrap();
                out.push(s.last_row[0]);
            }
            assert_eq!(s.total_draws(), chunks.iter().sum::<usize>());
            out
        };
        // same (seed, id): identical regardless of step chunking
        let a = run(1, &[30]);
        let b = run(1, &[7, 13, 10]);
        assert_eq!(a[a.len() - 1].to_bits(), b[b.len() - 1].to_bits());
        // different id: a different stream entirely
        let c = run(2, &[30]);
        assert_ne!(a[a.len() - 1].to_bits(), c[c.len() - 1].to_bits());
    }

    #[test]
    fn cancellation_stops_at_a_draw_boundary() {
        let mut s = Session::new(cfg(3)).unwrap();
        s.step(5, None).unwrap();
        s.stop_flag().store(true, Ordering::SeqCst);
        let rep = s.step(10, None).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Cancelled));
        assert_eq!(rep.total, 5, "no draw committed after the stop");
    }

    #[test]
    fn session_deadline_expires_and_is_permanent() {
        let mut c = cfg(4);
        c.deadline = Some(Duration::from_millis(0));
        let mut s = Session::new(c).unwrap();
        let rep = s.step(10, None).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Expired));
        let rep = s.step(1, None).unwrap();
        assert_eq!(rep.stopped, Some(StopReason::Expired));
    }

    #[test]
    fn appends_land_between_steps_deterministically() {
        // same (seed, id) and same append schedule → bitwise identical
        // draws regardless of how the steps around the append are
        // chunked; the appended observation visibly shifts the
        // posterior relative to a no-append run
        let run = |pre: &[usize], post: &[usize], append: bool| -> f64 {
            let mut s = Session::new(cfg(6)).unwrap();
            for &n in pre {
                s.step(n, None).unwrap();
            }
            if append {
                assert_eq!(s.append("[observe (normal mu 0.5) -3.0]").unwrap(), 1);
            }
            for &n in post {
                s.step(n, None).unwrap();
            }
            s.last_row[0]
        };
        let a = run(&[10], &[10], true);
        let b = run(&[3, 7], &[4, 6], true);
        assert_eq!(a.to_bits(), b.to_bits());
        let c = run(&[10], &[10], false);
        assert_ne!(a.to_bits(), c.to_bits(), "append must change the chain");
    }

    #[test]
    fn append_parse_error_is_not_terminal() {
        let mut s = Session::new(cfg(7)).unwrap();
        s.step(2, None).unwrap();
        assert!(s.append("[observe (normal mu").is_err());
        assert!(s.failed().is_none(), "parse errors leave the session live");
        s.step(2, None).unwrap();
        assert_eq!(s.total_draws(), 4);
    }

    #[test]
    fn snapshot_names_watched_values() {
        let mut s = Session::new(cfg(5)).unwrap();
        s.step(3, None).unwrap();
        let js = s.snapshot_json();
        assert_eq!(js.get("draws").and_then(Json::as_u64), Some(3));
        assert!(js.get("values").unwrap().get("mu").is_some());
    }
}
