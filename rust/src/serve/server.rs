//! The serve daemon: accept loop, session registry, dispatch, drain.
//!
//! Threading model: `Trace` is `Rc`-based (deliberately single-
//! threaded), so every session lives on its **own dedicated thread**
//! that builds and owns the `Session`; the registry holds only `Send`
//! handles (command sender + stop flag + join handle).  Intra-draw
//! parallelism still goes through the shared global `WorkerPool` —
//! its FIFO queue interleaves shards from concurrent sessions fairly,
//! and shard results are bitwise independent of placement, so sessions
//! cannot perturb each other's draws.
//!
//! Robustness ladder, outermost first:
//! - **admission control**: at most `max_sessions` live sessions; a
//!   `create` past the limit gets `Overloaded` + `retry_after_ms`
//!   instead of queueing.  Finished/expired sessions are reaped first,
//!   so the limit counts *live* sessions.
//! - **backpressure**: each session's command queue is a bounded
//!   `sync_channel`; a `step` against a busy session gets `Overloaded`
//!   rather than queueing unboundedly.
//! - **deadlines**: per-request (`deadline_ms` on `step`) and
//!   per-session (`--session-deadline-ms`), both observed at draw
//!   boundaries inside the session.
//! - **panic isolation**: a panicking draw is caught inside the
//!   session (checkpoint restart, `restarts` surfaced in every step
//!   report); a session that exhausts its budget turns `Failed`
//!   without touching its neighbors.
//! - **graceful drain**: `shutdown` stops admission, raises every stop
//!   flag, closes every command queue, and joins session threads
//!   within `drain_timeout`; each session writes a final checkpoint on
//!   the way out when a checkpoint dir is configured.

use crate::serve::protocol::{
    err_frame, ok_frame, CreateParams, ErrCode, Fault, Json, Method, Request,
};
use crate::serve::session::{Session, SessionCfg, StepReport};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Retry hint handed out with `Overloaded`/`Draining` frames.
const RETRY_AFTER_MS: u64 = 100;

/// Subscriber stream buffer: events queued for one client before the
/// session declares it wedged and drops it.
const SUBSCRIBER_BUFFER: usize = 64;

/// Server knobs (the `subppl serve` flags).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub addr: String,
    pub max_sessions: usize,
    /// Default + cap for per-session lifetime deadlines (None =
    /// unbounded sessions allowed).
    pub session_deadline: Option<Duration>,
    pub drain_timeout: Duration,
    /// Base seed: a session draws from `(seed, session id)`.
    pub seed: u64,
    /// Bound on each session's queued-but-unserved commands.
    pub queue_cap: usize,
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Default shard-watchdog deadline for sessions that don't set one.
    pub shard_timeout_ms: u64,
    /// Default column-store verify mode for sessions that don't set one
    /// (`None` = the `SUBPPL_STORE_VERIFY` env default).
    pub store_verify: Option<crate::trace::colstore::VerifyMode>,
    /// Let sessions shard scoring across the shared pool.
    pub use_pool: bool,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:7777".into(),
            max_sessions: 64,
            session_deadline: None,
            drain_timeout: Duration::from_millis(5000),
            seed: 0,
            queue_cap: 4,
            checkpoint_dir: None,
            shard_timeout_ms: 0,
            store_verify: None,
            use_pool: true,
        }
    }
}

/// Commands a session thread serves, in arrival order.
pub enum SessionCmd {
    Step {
        n: usize,
        /// Absolute per-request deadline, stamped at request arrival so
        /// time spent waiting in the session's queue counts against it.
        deadline_at: Option<Instant>,
        reply: Sender<Result<StepReport, Fault>>,
    },
    /// Append directives to the live model.  Served by the session
    /// thread between steps, so the append always lands at a draw
    /// boundary.
    Append {
        program: String,
        reply: Sender<Result<usize, Fault>>,
    },
    Snapshot {
        reply: Sender<Json>,
    },
    Subscribe {
        tx: SyncSender<String>,
    },
}

struct SessionHandle {
    tx: SyncSender<SessionCmd>,
    stop: Arc<AtomicBool>,
    thread: JoinHandle<()>,
    /// Lifetime deadline for the reaper (the session enforces its own
    /// copy at draw boundaries).
    expires_at: Option<Instant>,
}

/// The session registry plus in-flight `create` reservations, guarded
/// by one mutex so the admission check and the insert are atomic:
/// concurrent creates each reserve a slot under the lock before
/// spawning, and can never overshoot `max_sessions` together.
#[derive(Default)]
struct Registry {
    map: HashMap<u64, SessionHandle>,
    /// Slots held by `create` calls between the admission check and
    /// the insert (or release, on a failed build).
    reserved: usize,
}

/// What a drain actually did.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainReport {
    /// Sessions whose thread exited within the drain timeout.
    pub drained: usize,
    /// Sessions still running when the timeout fired (their threads
    /// are left detached; the process is about to exit anyway).
    pub forced: usize,
    /// Final checkpoints written during the drain.
    pub checkpointed: usize,
}

/// The registry + dispatch core, TCP-independent so tests can drive it
/// directly.
pub struct Server {
    pub cfg: ServeCfg,
    sessions: Mutex<Registry>,
    next_id: AtomicU64,
    draining: AtomicBool,
    /// Set by the `shutdown` RPC; the accept loop polls it.
    shutdown_requested: AtomicBool,
    /// Checkpoints written by session threads on their way out.
    checkpoints_written: AtomicU64,
}

impl Server {
    pub fn new(cfg: ServeCfg) -> Arc<Server> {
        Arc::new(Server {
            cfg,
            sessions: Mutex::new(Registry::default()),
            next_id: AtomicU64::new(1),
            draining: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            checkpoints_written: AtomicU64::new(0),
        })
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Live session count (after reaping finished threads).
    pub fn live_sessions(&self) -> usize {
        let mut reg = self.sessions.lock().unwrap();
        Self::reap(&mut reg.map);
        reg.map.len()
    }

    /// Drop registry entries whose thread already exited (failed
    /// models, expired sessions that wound down) and raise the stop
    /// flag on expired-but-idle sessions so they exit too.  Called with
    /// the registry lock held.
    fn reap(reg: &mut HashMap<u64, SessionHandle>) {
        let now = Instant::now();
        reg.retain(|_, h| {
            if h.thread.is_finished() {
                return false;
            }
            if h.expires_at.is_some_and(|t| now >= t) {
                // idle-expired: the session only notices expiry while
                // stepping, so kick it via the stop flag and close its
                // queue by dropping the handle
                h.stop.store(true, Ordering::SeqCst);
                return false;
            }
            true
        });
    }

    /// Admit one session: reserve a registry slot under the lock (so
    /// concurrent creates cannot overshoot `max_sessions` together),
    /// spawn its thread, wait for the build result (a parse error must
    /// come back on the create response, not a later step), then
    /// register — re-checking for a drain that raced in meanwhile.
    pub fn create(self: &Arc<Self>, p: CreateParams) -> Result<u64, Fault> {
        if self.draining() {
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        {
            let mut reg = self.sessions.lock().unwrap();
            Self::reap(&mut reg.map);
            if reg.map.len() + reg.reserved >= self.cfg.max_sessions {
                return Err(Fault::overloaded(
                    format!(
                        "session registry full ({} live)",
                        reg.map.len() + reg.reserved
                    ),
                    RETRY_AFTER_MS,
                ));
            }
            reg.reserved += 1;
        }
        let res = self.spawn_session(p);
        let mut reg = self.sessions.lock().unwrap();
        reg.reserved -= 1;
        // a failed spawn/build releases the reservation and reports
        let (id, handle) = res?;
        if self.draining() {
            // a drain raced in while this session was being built: it
            // already emptied the registry, so don't register behind it
            // — stop the newborn (dropping its handle closes the queue;
            // the idle thread winds down on its own) and refuse
            handle.stop.store(true, Ordering::SeqCst);
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        reg.map.insert(id, handle);
        Ok(id)
    }

    /// Spawn one session thread and wait for its birth report (the
    /// caller holds a reserved registry slot).
    fn spawn_session(self: &Arc<Self>, p: CreateParams) -> Result<(u64, SessionHandle), Fault> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        // per-session deadline: the requested one, capped by the
        // server default; no request → the server default
        let deadline = match (p.deadline_ms, self.cfg.session_deadline) {
            (0, d) => d,
            (ms, None) => Some(Duration::from_millis(ms)),
            (ms, Some(cap)) => Some(Duration::from_millis(ms).min(cap)),
        };
        let scfg = SessionCfg {
            id,
            seed: p.seed.unwrap_or(self.cfg.seed),
            program: p.program,
            infer: p.infer,
            watch: p.watch,
            target_risk: p.target_risk,
            shard_timeout_ms: if p.shard_timeout_ms > 0 {
                p.shard_timeout_ms
            } else {
                self.cfg.shard_timeout_ms
            },
            store_verify: p.store_verify.or(self.cfg.store_verify),
            deadline,
            max_restarts: 2,
            use_pool: self.cfg.use_pool,
            min_parallel: 0,
            monitor_every: p.monitor_every,
            checkpoint_dir: self.cfg.checkpoint_dir.clone(),
        };
        let (tx, rx) = sync_channel::<SessionCmd>(self.cfg.queue_cap.max(1));
        let (born_tx, born_rx) = sync_channel::<Result<Arc<AtomicBool>, String>>(1);
        let server = Arc::downgrade(self);
        let thread = std::thread::Builder::new()
            .name(format!("subppl-session-{id}"))
            .spawn(move || session_thread(scfg, rx, born_tx, server))
            .map_err(|e| Fault::new(ErrCode::Internal, format!("spawn: {e}")))?;
        let stop = match born_rx.recv() {
            Ok(Ok(stop)) => stop,
            Ok(Err(e)) => {
                let _ = thread.join();
                return Err(Fault::new(ErrCode::BadRequest, e));
            }
            Err(_) => {
                let _ = thread.join();
                return Err(Fault::new(ErrCode::Internal, "session thread died".into()));
            }
        };
        let expires_at = deadline.map(|d| Instant::now() + d);
        Ok((
            id,
            SessionHandle {
                tx,
                stop,
                thread,
                expires_at,
            },
        ))
    }

    /// Enqueue one command on a session's bounded queue.
    fn send(&self, session: u64, cmd: SessionCmd) -> Result<(), Fault> {
        let reg = self.sessions.lock().unwrap();
        let h = reg
            .map
            .get(&session)
            .ok_or_else(|| Fault::new(ErrCode::NotFound, format!("no session {session}")))?;
        match h.tx.try_send(cmd) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => Err(Fault::overloaded(
                format!("session {session} step queue full"),
                RETRY_AFTER_MS,
            )),
            Err(TrySendError::Disconnected(_)) => Err(Fault::new(
                ErrCode::Failed,
                format!("session {session} wound down"),
            )),
        }
    }

    pub fn step(&self, session: u64, n: usize, deadline_ms: u64) -> Result<StepReport, Fault> {
        if self.draining() {
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        let (reply, done) = std::sync::mpsc::channel();
        let deadline_at =
            (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));
        self.send(
            session,
            SessionCmd::Step {
                n,
                deadline_at,
                reply,
            },
        )?;
        done.recv()
            .map_err(|_| Fault::new(ErrCode::Internal, "session dropped the reply".into()))?
    }

    /// Append directives to a live session ("ticks in, posterior
    /// out").  Queued like a step, so it lands at a draw boundary in
    /// arrival order relative to surrounding steps.  Returns the number
    /// of directives appended.
    pub fn append(&self, session: u64, program: String) -> Result<usize, Fault> {
        if self.draining() {
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        let (reply, done) = std::sync::mpsc::channel();
        self.send(session, SessionCmd::Append { program, reply })?;
        done.recv()
            .map_err(|_| Fault::new(ErrCode::Internal, "session dropped the reply".into()))?
    }

    pub fn snapshot(&self, session: u64) -> Result<Json, Fault> {
        let (reply, done) = std::sync::mpsc::channel();
        self.send(session, SessionCmd::Snapshot { reply })?;
        done.recv()
            .map_err(|_| Fault::new(ErrCode::Internal, "session dropped the reply".into()))
    }

    /// Attach a bounded event-line sender to a session's stream.
    pub fn subscribe(&self, session: u64, tx: SyncSender<String>) -> Result<(), Fault> {
        self.send(session, SessionCmd::Subscribe { tx })
    }

    /// Cancel = raise the stop flag (an in-flight step stops at its
    /// next draw boundary) and retire the session: its queue closes,
    /// its thread exits (writing a final checkpoint if configured).
    pub fn cancel(&self, session: u64) -> Result<(), Fault> {
        let mut reg = self.sessions.lock().unwrap();
        let h = reg
            .map
            .remove(&session)
            .ok_or_else(|| Fault::new(ErrCode::NotFound, format!("no session {session}")))?;
        h.stop.store(true, Ordering::SeqCst);
        // dropping h.tx closes the queue; the thread winds down on its
        // own — drain (or process exit) picks up the join
        Ok(())
    }

    /// Graceful drain: stop admitting, cancel everything in flight,
    /// join session threads within the drain budget.
    pub fn drain(&self) -> DrainReport {
        self.draining.store(true, Ordering::SeqCst);
        self.shutdown_requested.store(true, Ordering::SeqCst);
        let handles: Vec<(u64, SessionHandle)> =
            self.sessions.lock().unwrap().map.drain().collect();
        for (_, h) in &handles {
            h.stop.store(true, Ordering::SeqCst);
        }
        let before = self.checkpoints_written.load(Ordering::SeqCst);
        let deadline = Instant::now() + self.cfg.drain_timeout;
        let mut rep = DrainReport::default();
        for (_, h) in handles {
            // dropping the sender closes the queue → the session loop
            // exits after its current (cancelled) command
            let SessionHandle { tx, thread, .. } = h;
            drop(tx);
            let mut finished = thread.is_finished();
            while !finished && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
                finished = thread.is_finished();
            }
            if finished {
                let _ = thread.join();
                rep.drained += 1;
            } else {
                rep.forced += 1;
            }
        }
        rep.checkpointed =
            (self.checkpoints_written.load(Ordering::SeqCst) - before) as usize;
        rep
    }

    /// Dispatch one parsed request to a response frame.  `Subscribe`
    /// is handled by the connection layer (it needs the socket) — this
    /// returns its error frames only.
    pub fn handle(self: &Arc<Self>, req: Request) -> String {
        let id = req.id;
        let res: Result<Json, Fault> = match req.method {
            Method::Ping => Ok(Json::Obj(vec![("pong".into(), Json::Bool(true))])),
            Method::Create(p) => self.create(p).map(|sid| {
                Json::Obj(vec![("session".into(), Json::Num(sid as f64))])
            }),
            Method::Step {
                session,
                n,
                deadline_ms,
            } => self.step(session, n, deadline_ms).map(step_json),
            Method::Append { session, program } => {
                self.append(session, program).map(|n| {
                    Json::Obj(vec![
                        ("session".into(), Json::Num(session as f64)),
                        ("appended".into(), Json::Num(n as f64)),
                    ])
                })
            }
            Method::Snapshot { session } => self.snapshot(session),
            Method::Cancel { session } => self.cancel(session).map(|()| {
                Json::Obj(vec![("cancelled".into(), Json::Num(session as f64))])
            }),
            Method::Shutdown => {
                let rep = self.drain();
                Ok(Json::Obj(vec![
                    ("drained".into(), Json::Num(rep.drained as f64)),
                    ("forced".into(), Json::Num(rep.forced as f64)),
                    ("checkpointed".into(), Json::Num(rep.checkpointed as f64)),
                ]))
            }
            Method::Subscribe { .. } => Err(Fault::new(
                ErrCode::Internal,
                "subscribe must be handled by the connection layer".into(),
            )),
        };
        match res {
            Ok(body) => ok_frame(id, body),
            Err(f) => err_frame(id, &f),
        }
    }
}

fn step_json(r: StepReport) -> Json {
    let mut fields = vec![
        ("requested".into(), Json::Num(r.requested as f64)),
        ("done".into(), Json::Num(r.done as f64)),
        ("total".into(), Json::Num(r.total as f64)),
        ("restarts".into(), Json::Num(r.restarts as f64)),
        (
            "sections".into(),
            Json::Num((r.eval.planned + r.eval.fallback) as f64),
        ),
    ];
    if let Some(s) = r.stopped {
        fields.push(("stopped".into(), Json::Str(s.name().into())));
    }
    Json::Obj(fields)
}

/// The session thread body: build, report birth, serve commands until
/// the queue closes, checkpoint on the way out.
fn session_thread(
    cfg: SessionCfg,
    rx: Receiver<SessionCmd>,
    born: SyncSender<Result<Arc<AtomicBool>, String>>,
    server: std::sync::Weak<Server>,
) {
    let mut sess = match Session::new(cfg) {
        Ok(s) => {
            let _ = born.send(Ok(s.stop_flag()));
            s
        }
        Err(e) => {
            let _ = born.send(Err(e));
            return;
        }
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            SessionCmd::Step {
                n,
                deadline_at,
                reply,
            } => {
                let _ = reply.send(step_reply(&mut sess, n, deadline_at));
            }
            SessionCmd::Append { program, reply } => {
                let res = sess.append(&program).map_err(|e| {
                    // a parse error leaves the session live (BadRequest);
                    // a mid-batch execute failure marked it Failed
                    if sess.failed().is_some() {
                        Fault::new(ErrCode::Failed, e)
                    } else {
                        Fault::new(ErrCode::BadRequest, e)
                    }
                });
                let _ = reply.send(res);
            }
            SessionCmd::Snapshot { reply } => {
                let _ = reply.send(sess.snapshot_json());
            }
            SessionCmd::Subscribe { tx } => sess.subscribe(tx),
        }
    }
    // queue closed: cancel/drain/reap — write the final checkpoint
    if let Ok(true) = sess.checkpoint_to_disk() {
        if let Some(srv) = server.upgrade() {
            srv.checkpoints_written.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Serve-layer step semantics, emitting the documented terminal codes:
/// a step against an already-expired session fails with `Expired`
/// (expiry is permanent), and a request whose deadline lapsed while it
/// waited in the queue fails with `Deadline` before any draw runs.
/// Partial progress stays an ok report with the `stopped` field set
/// (the first step to *observe* expiry reports `stopped:"expired"`).
fn step_reply(
    sess: &mut Session,
    n: usize,
    deadline_at: Option<Instant>,
) -> Result<StepReport, Fault> {
    if sess.expired() {
        return Err(Fault::new(
            ErrCode::Expired,
            format!("session {} outlived its deadline", sess.cfg.id),
        ));
    }
    let deadline = match deadline_at {
        Some(at) => match at.checked_duration_since(Instant::now()) {
            Some(left) if left > Duration::ZERO => Some(left),
            _ => {
                return Err(Fault::new(
                    ErrCode::Deadline,
                    "request deadline lapsed before any draw".to_string(),
                ))
            }
        },
        None => None,
    };
    sess.step(n, deadline)
        .map_err(|e| Fault::new(ErrCode::Failed, e))
}

// ---------------------------------------------------------------------
// TCP layer
// ---------------------------------------------------------------------

/// Run the daemon until a `shutdown` request drains it.  Returns the
/// bound address via `on_ready` (port 0 in `cfg.addr` picks a free
/// port — the bench harness uses this).
pub fn serve_with(cfg: ServeCfg, on_ready: impl FnOnce(String)) -> Result<DrainReport, String> {
    let listener = TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| e.to_string())?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    let server = Server::new(cfg);
    on_ready(local.to_string());
    loop {
        if server.shutdown_requested() {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let server = server.clone();
                let _ = std::thread::Builder::new()
                    .name("subppl-conn".into())
                    .spawn(move || handle_connection(server, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                // transient accept failures (ECONNABORTED, EMFILE under
                // fd pressure, ...) must not kill the daemon and strand
                // its sessions undrained: log, back off, keep serving
                eprintln!("[serve] accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    // the shutdown RPC already drained the registry; drain() again is
    // idempotent (empty registry) and covers the no-RPC exit path
    Ok(server.drain())
}

/// `subppl serve` entry point: prints the bound address, serves until
/// drained.
pub fn serve(cfg: ServeCfg) -> Result<(), String> {
    let rep = serve_with(cfg, |addr| {
        println!("[serve] listening on {addr}");
    })?;
    println!(
        "[serve] drained: {} sessions ({} forced, {} checkpointed)",
        rep.drained + rep.forced,
        rep.forced,
        rep.checkpointed
    );
    Ok(())
}

/// One client connection: newline-delimited request frames in,
/// response frames out, plus an event-writer thread per `subscribe`.
fn handle_connection(server: Arc<Server>, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = BufReader::new(match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    });
    // writes go through a mutex so response frames and streamed event
    // lines never interleave mid-line
    let out = Arc::new(Mutex::new(stream));
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // read_line may have appended a partial frame before the
                // timeout fired: keep `line` accumulating — the next
                // successful read completes it (slow-writer safety)
                if server.shutdown_requested() {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let text = line.trim();
        if !text.is_empty() {
            let frame = match Request::parse(text) {
                Ok(req) => match req.method {
                    Method::Subscribe { session } => {
                        subscribe_frame(&server, &out, req.id, session)
                    }
                    _ => server.handle(req),
                },
                Err(f) => err_frame(0, &f),
            };
            if write_line(&out, &frame).is_err() {
                return;
            }
        }
        // only a fully-read line is consumed
        line.clear();
    }
}

fn write_line(out: &Arc<Mutex<TcpStream>>, line: &str) -> std::io::Result<()> {
    let mut s = out.lock().unwrap();
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")?;
    s.flush()
}

/// Wire a subscription: a bounded channel into the session, a writer
/// thread pumping event lines onto this connection.  The serve-scoped
/// faults hook here: `slowloris@k` wedges the writer (the channel
/// fills, the session drops the subscriber), `disconnect@k` drops the
/// connection mid-stream.
fn subscribe_frame(
    server: &Arc<Server>,
    out: &Arc<Mutex<TcpStream>>,
    id: u64,
    session: u64,
) -> String {
    let (tx, rx) = sync_channel::<String>(SUBSCRIBER_BUFFER);
    if let Err(f) = server.subscribe(session, tx) {
        return err_frame(id, &f);
    }
    let out = out.clone();
    let _ = std::thread::Builder::new()
        .name("subppl-sub-writer".into())
        .spawn(move || {
            while let Ok(line) = rx.recv() {
                if crate::runtime::faults::slowloris_write_now() {
                    // a client that stopped reading: stop draining the
                    // channel; the session's try_send fills it and
                    // drops this subscriber, then recv() errors out.
                    // bounded nap so the thread can't outlive the test
                    for _ in 0..200 {
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    return;
                }
                if crate::runtime::faults::disconnect_write_now() {
                    if let Ok(s) = out.lock() {
                        let _ = s.shutdown(std::net::Shutdown::Both);
                    }
                    return;
                }
                if write_line(&out, &line).is_err() {
                    return;
                }
            }
        });
    ok_frame(
        id,
        Json::Obj(vec![("subscribed".into(), Json::Num(session as f64))]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::session::StopReason;

    const MODEL: &str = r#"
        [assume mu (scope_include 'mu 0 (normal 0 1))]
        [observe (normal mu 0.5) 1.2]
    "#;

    fn params() -> CreateParams {
        CreateParams {
            program: MODEL.into(),
            infer: Some("(mh mu one drift 0.5 1)".into()),
            watch: vec!["mu".into()],
            ..CreateParams::default()
        }
    }

    fn tiny_server(max_sessions: usize) -> Arc<Server> {
        Server::new(ServeCfg {
            max_sessions,
            use_pool: false,
            ..ServeCfg::default()
        })
    }

    #[test]
    fn create_step_snapshot_cancel_lifecycle() {
        let srv = tiny_server(4);
        let id = srv.create(params()).unwrap();
        let rep = srv.step(id, 10, 0).unwrap();
        assert_eq!(rep.done, 10);
        assert_eq!(rep.total, 10);
        let snap = srv.snapshot(id).unwrap();
        assert_eq!(snap.get("draws").and_then(Json::as_u64), Some(10));
        srv.cancel(id).unwrap();
        // retired: further RPCs are NotFound
        assert_eq!(
            srv.step(id, 1, 0).unwrap_err().code,
            ErrCode::NotFound
        );
    }

    #[test]
    fn admission_control_rejects_over_limit() {
        let srv = tiny_server(2);
        let a = srv.create(params()).unwrap();
        let _b = srv.create(params()).unwrap();
        let err = srv.create(params()).unwrap_err();
        assert_eq!(err.code, ErrCode::Overloaded);
        assert!(err.retry_after_ms.is_some());
        // cancelling frees a slot
        srv.cancel(a).unwrap();
        // the cancelled session's thread needs a beat to exit; create
        // reaps finished threads, so retry briefly
        let mut ok = false;
        for _ in 0..100 {
            if srv.create(params()).is_ok() {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(ok, "slot never freed after cancel");
    }

    #[test]
    fn expired_sessions_fail_with_the_expired_code() {
        let srv = tiny_server(4);
        let mut p = params();
        p.deadline_ms = 1;
        let id = srv.create(p).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        // the first step observes expiry at a draw boundary and
        // reports it on an ok frame (partial-progress convention)
        let rep = srv.step(id, 5, 0).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Expired));
        // expiry is permanent: further steps get the documented code
        assert_eq!(srv.step(id, 1, 0).unwrap_err().code, ErrCode::Expired);
    }

    #[test]
    fn bad_programs_fail_the_create_not_the_server() {
        let srv = tiny_server(4);
        let err = srv
            .create(CreateParams {
                program: "[assume x (this_is_not_a_distribution)]".into(),
                ..CreateParams::default()
            })
            .unwrap_err();
        assert_eq!(err.code, ErrCode::BadRequest);
        // the server still admits good sessions
        assert!(srv.create(params()).is_ok());
    }

    #[test]
    fn drain_joins_all_sessions() {
        let srv = tiny_server(8);
        for _ in 0..4 {
            srv.create(params()).unwrap();
        }
        let rep = srv.drain();
        assert_eq!(rep.drained, 4);
        assert_eq!(rep.forced, 0);
        // post-drain: no admission
        assert_eq!(
            srv.create(params()).unwrap_err().code,
            ErrCode::Draining
        );
    }

    #[test]
    fn append_lifecycle_between_steps() {
        let srv = tiny_server(4);
        let id = srv.create(params()).unwrap();
        srv.step(id, 5, 0).unwrap();
        assert_eq!(
            srv.append(id, "[observe (normal mu 0.5) 0.9]".into()).unwrap(),
            1
        );
        let rep = srv.step(id, 5, 0).unwrap();
        assert_eq!(rep.total, 10, "appends are not draws");
        // a parse error is BadRequest and leaves the session stepping
        let err = srv.append(id, "[observe (normal mu".into()).unwrap_err();
        assert_eq!(err.code, ErrCode::BadRequest);
        assert_eq!(srv.step(id, 1, 0).unwrap().done, 1);
        // unknown session is NotFound, same as step
        assert_eq!(
            srv.append(99, "[observe (normal mu 0.5) 0.9]".into())
                .unwrap_err()
                .code,
            ErrCode::NotFound
        );
    }

    #[test]
    fn dispatch_encodes_frames() {
        let srv = tiny_server(4);
        let resp = srv.handle(Request::parse(r#"{"id":1,"method":"ping"}"#).unwrap());
        assert_eq!(resp, r#"{"id":1,"ok":{"pong":true}}"#);
        let resp =
            srv.handle(Request::parse(r#"{"id":2,"method":"step","params":{"session":99}}"#).unwrap());
        assert!(resp.contains("\"NotFound\""), "{resp}");
    }
}
