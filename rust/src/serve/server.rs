//! The serve daemon: accept loop, session registry, dispatch, drain.
//!
//! Threading model: `Trace` is `Rc`-based (deliberately single-
//! threaded), so every session lives on its **own dedicated thread**
//! that builds and owns the `Session`; the registry holds only `Send`
//! handles (command sender + stop flag + join handle).  Intra-draw
//! parallelism still goes through the shared global `WorkerPool` —
//! its FIFO queue interleaves shards from concurrent sessions fairly,
//! and shard results are bitwise independent of placement, so sessions
//! cannot perturb each other's draws.
//!
//! Robustness ladder, outermost first:
//! - **admission control**: at most `max_sessions` live sessions; a
//!   `create` past the limit gets `Overloaded` + `retry_after_ms`
//!   instead of queueing.  Finished/expired sessions are reaped first,
//!   so the limit counts *live* sessions.
//! - **backpressure**: each session's command queue is a bounded
//!   `sync_channel`; a `step` against a busy session gets `Overloaded`
//!   rather than queueing unboundedly.
//! - **deadlines**: per-request (`deadline_ms` on `step`) and
//!   per-session (`--session-deadline-ms`), both observed at draw
//!   boundaries inside the session.
//! - **panic isolation**: a panicking draw is caught inside the
//!   session (checkpoint restart, `restarts` surfaced in every step
//!   report); a session that exhausts its budget turns `Failed`
//!   without touching its neighbors.
//! - **graceful drain**: `shutdown` stops admission, raises every stop
//!   flag, closes every command queue, and joins session threads
//!   within `drain_timeout`; each session writes a final checkpoint on
//!   the way out when a checkpoint dir is configured.
//! - **frame cap**: a request line longer than `--max-frame-bytes`
//!   gets one `BadRequest` frame and a closed connection, before the
//!   bytes are buffered without bound.
//! - **resource budgets**: per-session ceilings from the create params
//!   (`max_trace_nodes`, `max_journal_bytes`, `queue_cap`) surface as
//!   `BudgetExceeded` on exactly that session; neighbors are untouched.
//! - **durability**: with `--state-dir`, every acknowledged create /
//!   append / step is journaled before the reply, and `--recover`
//!   rebuilds the registry bitwise-identically on restart (see the
//!   [`journal`](crate::serve::journal) module).

use crate::serve::journal::{read_journal, scan_state_dir};
use crate::serve::protocol::{
    err_frame, ok_frame, CreateParams, ErrCode, Fault, Json, Method, Request,
};
use crate::serve::session::{cfg_from_journal, AppendErr, Session, SessionCfg, StepReport};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Retry hint handed out with `Overloaded`/`Draining` frames.
const RETRY_AFTER_MS: u64 = 100;

/// Subscriber stream buffer: events queued for one client before the
/// session declares it wedged and drops it.
const SUBSCRIBER_BUFFER: usize = 64;

/// Server knobs (the `subppl serve` flags).
#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub addr: String,
    pub max_sessions: usize,
    /// Default + cap for per-session lifetime deadlines (None =
    /// unbounded sessions allowed).
    pub session_deadline: Option<Duration>,
    pub drain_timeout: Duration,
    /// Base seed: a session draws from `(seed, session id)`.
    pub seed: u64,
    /// Bound on each session's queued-but-unserved commands.
    pub queue_cap: usize,
    pub checkpoint_dir: Option<std::path::PathBuf>,
    /// Default shard-watchdog deadline for sessions that don't set one.
    pub shard_timeout_ms: u64,
    /// Default column-store verify mode for sessions that don't set one
    /// (`None` = the `SUBPPL_STORE_VERIFY` env default).
    pub store_verify: Option<crate::trace::colstore::VerifyMode>,
    /// Let sessions shard scoring across the shared pool.
    pub use_pool: bool,
    /// Per-session write-ahead journal root (None = no durability).
    pub state_dir: Option<std::path::PathBuf>,
    /// Rebuild sessions from `state_dir` journals before accepting.
    pub recover: bool,
    /// Hard cap on one request frame (bytes, newline included).
    /// Oversized frames get `BadRequest` and a closed connection.
    pub max_frame_bytes: usize,
    /// Mid-step journal checkpoint cadence (0 = the session default,
    /// [`DEFAULT_JOURNAL_EVERY`](crate::serve::session::DEFAULT_JOURNAL_EVERY)).
    pub journal_every: usize,
    /// Server-wide default trace-node budget for sessions that don't
    /// set `max_trace_nodes` on create (0 = unbounded).
    pub max_trace_nodes: usize,
    /// Server-wide default journal-bytes budget for sessions that
    /// don't set `max_journal_bytes` on create (0 = compact-only).
    pub max_journal_bytes: u64,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            addr: "127.0.0.1:7777".into(),
            max_sessions: 64,
            session_deadline: None,
            drain_timeout: Duration::from_millis(5000),
            seed: 0,
            queue_cap: 4,
            checkpoint_dir: None,
            shard_timeout_ms: 0,
            store_verify: None,
            use_pool: true,
            state_dir: None,
            recover: false,
            max_frame_bytes: 1 << 20,
            journal_every: 0,
            max_trace_nodes: 0,
            max_journal_bytes: 0,
        }
    }
}

/// How a session comes to life on its thread: `Session::new` for a
/// fresh create, `Session::recover` for a journal replay.  Boxed so
/// both paths share one thread body (and one birth-report protocol).
type SessionBuilder = Box<dyn FnOnce() -> Result<Session, String> + Send>;

/// Commands a session thread serves, in arrival order.
pub enum SessionCmd {
    Step {
        n: usize,
        /// Absolute per-request deadline, stamped at request arrival so
        /// time spent waiting in the session's queue counts against it.
        deadline_at: Option<Instant>,
        reply: Sender<Result<StepReport, Fault>>,
    },
    /// Append directives to the live model.  Served by the session
    /// thread between steps, so the append always lands at a draw
    /// boundary.
    Append {
        program: String,
        reply: Sender<Result<usize, Fault>>,
    },
    Snapshot {
        reply: Sender<Json>,
    },
    Subscribe {
        tx: SyncSender<String>,
    },
}

struct SessionHandle {
    tx: SyncSender<SessionCmd>,
    stop: Arc<AtomicBool>,
    thread: JoinHandle<()>,
    /// Lifetime deadline for the reaper (the session enforces its own
    /// copy at draw boundaries).
    expires_at: Option<Instant>,
    /// The session chose its own `queue_cap` on create, so a full
    /// queue is *its* budget (`BudgetExceeded`), not server pressure
    /// (`Overloaded`).
    own_queue: bool,
}

/// The session registry plus in-flight `create` reservations, guarded
/// by one mutex so the admission check and the insert are atomic:
/// concurrent creates each reserve a slot under the lock before
/// spawning, and can never overshoot `max_sessions` together.
#[derive(Default)]
struct Registry {
    map: HashMap<u64, SessionHandle>,
    /// Slots held by `create` calls between the admission check and
    /// the insert (or release, on a failed build).
    reserved: usize,
}

/// What a drain actually did.
#[derive(Clone, Copy, Debug, Default)]
pub struct DrainReport {
    /// Sessions whose thread exited within the drain timeout.
    pub drained: usize,
    /// Sessions still running when the timeout fired (their threads
    /// are left detached; the process is about to exit anyway).
    pub forced: usize,
    /// Final checkpoints written during the drain.
    pub checkpointed: usize,
}

/// The registry + dispatch core, TCP-independent so tests can drive it
/// directly.
pub struct Server {
    pub cfg: ServeCfg,
    sessions: Mutex<Registry>,
    next_id: AtomicU64,
    draining: AtomicBool,
    /// Set by the `shutdown` RPC; the accept loop polls it.
    shutdown_requested: AtomicBool,
    /// Checkpoints written by session threads on their way out.
    checkpoints_written: AtomicU64,
}

impl Server {
    pub fn new(cfg: ServeCfg) -> Arc<Server> {
        Arc::new(Server {
            cfg,
            sessions: Mutex::new(Registry::default()),
            next_id: AtomicU64::new(1),
            draining: AtomicBool::new(false),
            shutdown_requested: AtomicBool::new(false),
            checkpoints_written: AtomicU64::new(0),
        })
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_requested.load(Ordering::SeqCst)
    }

    /// Live session count (after reaping finished threads).
    pub fn live_sessions(&self) -> usize {
        let mut reg = self.sessions.lock().unwrap();
        Self::reap(&mut reg.map);
        reg.map.len()
    }

    /// Drop registry entries whose thread already exited (failed
    /// models, expired sessions that wound down) and raise the stop
    /// flag on expired-but-idle sessions so they exit too.  Called with
    /// the registry lock held.
    fn reap(reg: &mut HashMap<u64, SessionHandle>) {
        let now = Instant::now();
        reg.retain(|_, h| {
            if h.thread.is_finished() {
                return false;
            }
            if h.expires_at.is_some_and(|t| now >= t) {
                // idle-expired: the session only notices expiry while
                // stepping, so kick it via the stop flag and close its
                // queue by dropping the handle
                h.stop.store(true, Ordering::SeqCst);
                return false;
            }
            true
        });
    }

    /// Admit one session: reserve a registry slot under the lock (so
    /// concurrent creates cannot overshoot `max_sessions` together),
    /// spawn its thread, wait for the build result (a parse error must
    /// come back on the create response, not a later step), then
    /// register — re-checking for a drain that raced in meanwhile.
    pub fn create(self: &Arc<Self>, p: CreateParams) -> Result<u64, Fault> {
        if self.draining() {
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        {
            let mut reg = self.sessions.lock().unwrap();
            Self::reap(&mut reg.map);
            if reg.map.len() + reg.reserved >= self.cfg.max_sessions {
                return Err(Fault::overloaded(
                    format!(
                        "session registry full ({} live)",
                        reg.map.len() + reg.reserved
                    ),
                    RETRY_AFTER_MS,
                ));
            }
            reg.reserved += 1;
        }
        let res = self.spawn_session(p);
        let mut reg = self.sessions.lock().unwrap();
        reg.reserved -= 1;
        // a failed spawn/build releases the reservation and reports
        let (id, handle) = res?;
        if self.draining() {
            // a drain raced in while this session was being built: it
            // already emptied the registry, so don't register behind it
            // — stop the newborn (dropping its handle closes the queue;
            // the idle thread winds down on its own) and refuse
            handle.stop.store(true, Ordering::SeqCst);
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        reg.map.insert(id, handle);
        Ok(id)
    }

    /// Spawn one session thread and wait for its birth report (the
    /// caller holds a reserved registry slot).
    fn spawn_session(self: &Arc<Self>, p: CreateParams) -> Result<(u64, SessionHandle), Fault> {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        // per-session deadline: the requested one, capped by the
        // server default; no request → the server default
        let deadline = match (p.deadline_ms, self.cfg.session_deadline) {
            (0, d) => d,
            (ms, None) => Some(Duration::from_millis(ms)),
            (ms, Some(cap)) => Some(Duration::from_millis(ms).min(cap)),
        };
        let scfg = SessionCfg {
            id,
            seed: p.seed.unwrap_or(self.cfg.seed),
            program: p.program,
            infer: p.infer,
            watch: p.watch,
            target_risk: p.target_risk,
            shard_timeout_ms: if p.shard_timeout_ms > 0 {
                p.shard_timeout_ms
            } else {
                self.cfg.shard_timeout_ms
            },
            store_verify: p.store_verify.or(self.cfg.store_verify),
            deadline,
            max_restarts: 2,
            use_pool: self.cfg.use_pool,
            min_parallel: 0,
            monitor_every: p.monitor_every,
            checkpoint_dir: self.cfg.checkpoint_dir.clone(),
            weight: p.weight,
            state_dir: self.cfg.state_dir.clone(),
            journal_every: self.cfg.journal_every,
            max_trace_nodes: if p.max_trace_nodes > 0 {
                p.max_trace_nodes as usize
            } else {
                self.cfg.max_trace_nodes
            },
            max_journal_bytes: if p.max_journal_bytes > 0 {
                p.max_journal_bytes
            } else {
                self.cfg.max_journal_bytes
            },
            queue_cap: p.queue_cap as usize,
        };
        let own_queue = scfg.queue_cap > 0;
        let depth = if own_queue {
            scfg.queue_cap
        } else {
            self.cfg.queue_cap
        };
        self.spawn_thread(id, depth, deadline, own_queue, Box::new(move || Session::new(scfg)))
    }

    /// Thread mechanics shared by fresh creates and journal recovery:
    /// bounded command queue, named thread running the builder, birth
    /// report waited on so build errors come back on *this* call.
    fn spawn_thread(
        self: &Arc<Self>,
        id: u64,
        queue_depth: usize,
        deadline: Option<Duration>,
        own_queue: bool,
        build: SessionBuilder,
    ) -> Result<(u64, SessionHandle), Fault> {
        let (tx, rx) = sync_channel::<SessionCmd>(queue_depth.max(1));
        let (born_tx, born_rx) = sync_channel::<Result<Arc<AtomicBool>, String>>(1);
        let server = Arc::downgrade(self);
        let thread = std::thread::Builder::new()
            .name(format!("subppl-session-{id}"))
            .spawn(move || session_thread(build, rx, born_tx, server))
            .map_err(|e| Fault::new(ErrCode::Internal, format!("spawn: {e}")))?;
        let stop = match born_rx.recv() {
            Ok(Ok(stop)) => stop,
            Ok(Err(e)) => {
                let _ = thread.join();
                return Err(Fault::new(ErrCode::BadRequest, e));
            }
            Err(_) => {
                let _ = thread.join();
                return Err(Fault::new(ErrCode::Internal, "session thread died".into()));
            }
        };
        let expires_at = deadline.map(|d| Instant::now() + d);
        Ok((
            id,
            SessionHandle {
                tx,
                stop,
                thread,
                expires_at,
                own_queue,
            },
        ))
    }

    /// Rebuild every journaled session from `cfg.state_dir` (the
    /// `--recover` path), bitwise-identical to the uninterrupted run:
    /// same `(seed, id)` RNG stream, journaled appends replayed in
    /// order, the last durable checkpoint restored.  Torn journal
    /// tails were already truncated by `read_journal`; a journal that
    /// is corrupt *before* its last valid record fails the whole
    /// recovery rather than silently dropping a tenant.  Returns the
    /// number of sessions brought back; `next_id` is bumped past the
    /// highest recovered id so new creates never collide.
    pub fn recover_sessions(self: &Arc<Self>) -> Result<usize, String> {
        let dir = self
            .cfg
            .state_dir
            .clone()
            .ok_or_else(|| "recovery requires --state-dir".to_string())?;
        let ids = scan_state_dir(&dir)?;
        let mut recovered = 0usize;
        for (id, path) in ids {
            let state = read_journal(&path)
                .map_err(|e| format!("session {id} ({}): {e}", path.display()))?;
            let mut scfg = cfg_from_journal(id, &state.create)?;
            // server-local policy is not journaled: fill it from this
            // server's flags.  Recovery grants a fresh lifetime window
            // (the original create time did not survive the crash).
            scfg.state_dir = Some(dir.clone());
            scfg.journal_every = self.cfg.journal_every;
            scfg.deadline = self.cfg.session_deadline;
            scfg.max_restarts = 2;
            scfg.use_pool = self.cfg.use_pool;
            scfg.checkpoint_dir = self.cfg.checkpoint_dir.clone();
            if scfg.shard_timeout_ms == 0 {
                scfg.shard_timeout_ms = self.cfg.shard_timeout_ms;
            }
            if scfg.store_verify.is_none() {
                scfg.store_verify = self.cfg.store_verify;
            }
            let own_queue = scfg.queue_cap > 0;
            let depth = if own_queue {
                scfg.queue_cap
            } else {
                self.cfg.queue_cap
            };
            let deadline = scfg.deadline;
            let appends = state.appends.clone();
            let ckpt = state.ckpt.clone();
            let build: SessionBuilder =
                Box::new(move || Session::recover(scfg, &appends, ckpt.as_deref()));
            let (sid, handle) = self
                .spawn_thread(id, depth, deadline, own_queue, build)
                .map_err(|f| format!("session {id}: {}", f.message))?;
            self.sessions.lock().unwrap().map.insert(sid, handle);
            self.next_id.fetch_max(id + 1, Ordering::SeqCst);
            recovered += 1;
        }
        Ok(recovered)
    }

    /// Enqueue one command on a session's bounded queue.
    fn send(&self, session: u64, cmd: SessionCmd) -> Result<(), Fault> {
        let reg = self.sessions.lock().unwrap();
        let h = reg
            .map
            .get(&session)
            .ok_or_else(|| Fault::new(ErrCode::NotFound, format!("no session {session}")))?;
        match h.tx.try_send(cmd) {
            Ok(()) => Ok(()),
            // a full queue the session sized itself (create param
            // `queue_cap`) is that tenant's own budget; a full
            // server-default queue is ordinary backpressure
            Err(TrySendError::Full(_)) if h.own_queue => Err(Fault {
                code: ErrCode::BudgetExceeded,
                message: format!("session {session} queued-command budget exhausted"),
                retry_after_ms: Some(RETRY_AFTER_MS),
            }),
            Err(TrySendError::Full(_)) => Err(Fault::overloaded(
                format!("session {session} step queue full"),
                RETRY_AFTER_MS,
            )),
            Err(TrySendError::Disconnected(_)) => Err(Fault::new(
                ErrCode::Failed,
                format!("session {session} wound down"),
            )),
        }
    }

    pub fn step(&self, session: u64, n: usize, deadline_ms: u64) -> Result<StepReport, Fault> {
        if self.draining() {
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        let (reply, done) = std::sync::mpsc::channel();
        let deadline_at =
            (deadline_ms > 0).then(|| Instant::now() + Duration::from_millis(deadline_ms));
        self.send(
            session,
            SessionCmd::Step {
                n,
                deadline_at,
                reply,
            },
        )?;
        done.recv()
            .map_err(|_| Fault::new(ErrCode::Internal, "session dropped the reply".into()))?
    }

    /// Append directives to a live session ("ticks in, posterior
    /// out").  Queued like a step, so it lands at a draw boundary in
    /// arrival order relative to surrounding steps.  Returns the number
    /// of directives appended.
    pub fn append(&self, session: u64, program: String) -> Result<usize, Fault> {
        if self.draining() {
            return Err(Fault {
                code: ErrCode::Draining,
                message: "server is draining".into(),
                retry_after_ms: Some(RETRY_AFTER_MS),
            });
        }
        let (reply, done) = std::sync::mpsc::channel();
        self.send(session, SessionCmd::Append { program, reply })?;
        done.recv()
            .map_err(|_| Fault::new(ErrCode::Internal, "session dropped the reply".into()))?
    }

    pub fn snapshot(&self, session: u64) -> Result<Json, Fault> {
        let (reply, done) = std::sync::mpsc::channel();
        self.send(session, SessionCmd::Snapshot { reply })?;
        done.recv()
            .map_err(|_| Fault::new(ErrCode::Internal, "session dropped the reply".into()))
    }

    /// Attach a bounded event-line sender to a session's stream.
    pub fn subscribe(&self, session: u64, tx: SyncSender<String>) -> Result<(), Fault> {
        self.send(session, SessionCmd::Subscribe { tx })
    }

    /// Cancel = raise the stop flag (an in-flight step stops at its
    /// next draw boundary) and retire the session: its queue closes,
    /// its thread exits (writing a final checkpoint if configured).
    pub fn cancel(&self, session: u64) -> Result<(), Fault> {
        let mut reg = self.sessions.lock().unwrap();
        let h = reg
            .map
            .remove(&session)
            .ok_or_else(|| Fault::new(ErrCode::NotFound, format!("no session {session}")))?;
        h.stop.store(true, Ordering::SeqCst);
        // dropping h.tx closes the queue; the thread winds down on its
        // own — drain (or process exit) picks up the join
        Ok(())
    }

    /// Graceful drain: stop admitting, cancel everything in flight,
    /// join session threads within the drain budget.
    pub fn drain(&self) -> DrainReport {
        self.draining.store(true, Ordering::SeqCst);
        self.shutdown_requested.store(true, Ordering::SeqCst);
        let handles: Vec<(u64, SessionHandle)> =
            self.sessions.lock().unwrap().map.drain().collect();
        for (_, h) in &handles {
            h.stop.store(true, Ordering::SeqCst);
        }
        let before = self.checkpoints_written.load(Ordering::SeqCst);
        let deadline = Instant::now() + self.cfg.drain_timeout;
        let mut rep = DrainReport::default();
        for (_, h) in handles {
            // dropping the sender closes the queue → the session loop
            // exits after its current (cancelled) command
            let SessionHandle { tx, thread, .. } = h;
            drop(tx);
            let mut finished = thread.is_finished();
            while !finished && Instant::now() < deadline {
                std::thread::sleep(Duration::from_millis(2));
                finished = thread.is_finished();
            }
            if finished {
                let _ = thread.join();
                rep.drained += 1;
            } else {
                rep.forced += 1;
            }
        }
        rep.checkpointed =
            (self.checkpoints_written.load(Ordering::SeqCst) - before) as usize;
        rep
    }

    /// Dispatch one parsed request to a response frame.  `Subscribe`
    /// is handled by the connection layer (it needs the socket) — this
    /// returns its error frames only.
    pub fn handle(self: &Arc<Self>, req: Request) -> String {
        let id = req.id;
        let res: Result<Json, Fault> = match req.method {
            Method::Ping => Ok(Json::Obj(vec![("pong".into(), Json::Bool(true))])),
            Method::Create(p) => self.create(p).map(|sid| {
                Json::Obj(vec![("session".into(), Json::Num(sid as f64))])
            }),
            Method::Step {
                session,
                n,
                deadline_ms,
            } => self.step(session, n, deadline_ms).map(step_json),
            Method::Append { session, program } => {
                self.append(session, program).map(|n| {
                    Json::Obj(vec![
                        ("session".into(), Json::Num(session as f64)),
                        ("appended".into(), Json::Num(n as f64)),
                    ])
                })
            }
            Method::Snapshot { session } => self.snapshot(session),
            Method::Cancel { session } => self.cancel(session).map(|()| {
                Json::Obj(vec![("cancelled".into(), Json::Num(session as f64))])
            }),
            Method::Shutdown => {
                let rep = self.drain();
                Ok(Json::Obj(vec![
                    ("drained".into(), Json::Num(rep.drained as f64)),
                    ("forced".into(), Json::Num(rep.forced as f64)),
                    ("checkpointed".into(), Json::Num(rep.checkpointed as f64)),
                ]))
            }
            Method::Subscribe { .. } => Err(Fault::new(
                ErrCode::Internal,
                "subscribe must be handled by the connection layer".into(),
            )),
        };
        match res {
            Ok(body) => ok_frame(id, body),
            Err(f) => err_frame(id, &f),
        }
    }
}

fn step_json(r: StepReport) -> Json {
    let mut fields = vec![
        ("requested".into(), Json::Num(r.requested as f64)),
        ("done".into(), Json::Num(r.done as f64)),
        ("total".into(), Json::Num(r.total as f64)),
        ("restarts".into(), Json::Num(r.restarts as f64)),
        (
            "sections".into(),
            Json::Num((r.eval.planned + r.eval.fallback) as f64),
        ),
    ];
    if let Some(s) = r.stopped {
        fields.push(("stopped".into(), Json::Str(s.name().into())));
    }
    Json::Obj(fields)
}

/// The session thread body: build (fresh or recovered), report birth,
/// serve commands until the queue closes, checkpoint on the way out.
fn session_thread(
    build: SessionBuilder,
    rx: Receiver<SessionCmd>,
    born: SyncSender<Result<Arc<AtomicBool>, String>>,
    server: std::sync::Weak<Server>,
) {
    let mut sess = match build() {
        Ok(s) => {
            let _ = born.send(Ok(s.stop_flag()));
            s
        }
        Err(e) => {
            let _ = born.send(Err(e));
            return;
        }
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            SessionCmd::Step {
                n,
                deadline_at,
                reply,
            } => {
                let _ = reply.send(step_reply(&mut sess, n, deadline_at));
            }
            SessionCmd::Append { program, reply } => {
                let res = sess.append(&program).map_err(|e| match e {
                    // parse and budget refusals leave the session live
                    AppendErr::Parse(m) => Fault::new(ErrCode::BadRequest, m),
                    AppendErr::Budget(m) => Fault::new(ErrCode::BudgetExceeded, m),
                    // mid-batch execute / journal-write failure is terminal
                    AppendErr::Failed(m) => Fault::new(ErrCode::Failed, m),
                });
                let _ = reply.send(res);
            }
            SessionCmd::Snapshot { reply } => {
                let _ = reply.send(sess.snapshot_json());
            }
            SessionCmd::Subscribe { tx } => sess.subscribe(tx),
        }
    }
    // queue closed: cancel/drain/reap — write the final checkpoint
    if let Ok(true) = sess.checkpoint_to_disk() {
        if let Some(srv) = server.upgrade() {
            srv.checkpoints_written.fetch_add(1, Ordering::SeqCst);
        }
    }
    // a cancel *discards* the session, so its journal must not
    // resurrect it on the next --recover.  Drain (`draining` set) and
    // teardown-without-drain (the upgrade fails — the crash path) both
    // keep the journal: that state is exactly what recovery replays.
    if let Some(srv) = server.upgrade() {
        if !srv.draining() {
            sess.retire_journal();
        }
    }
}

/// Serve-layer step semantics, emitting the documented terminal codes:
/// a step against an already-expired session fails with `Expired`
/// (expiry is permanent), and a request whose deadline lapsed while it
/// waited in the queue fails with `Deadline` before any draw runs.
/// Partial progress stays an ok report with the `stopped` field set
/// (the first step to *observe* expiry reports `stopped:"expired"`).
fn step_reply(
    sess: &mut Session,
    n: usize,
    deadline_at: Option<Instant>,
) -> Result<StepReport, Fault> {
    if sess.expired() {
        return Err(Fault::new(
            ErrCode::Expired,
            format!("session {} outlived its deadline", sess.cfg.id),
        ));
    }
    // like expiry, a journal-bytes budget breach is permanent and the
    // first step to *observe* it reports `stopped:"budget"` on an ok
    // frame; every later step gets the typed error
    if sess.budget_exceeded() {
        return Err(Fault::new(
            ErrCode::BudgetExceeded,
            format!("session {} exceeded its journal-bytes budget", sess.cfg.id),
        ));
    }
    let deadline = match deadline_at {
        Some(at) => match at.checked_duration_since(Instant::now()) {
            Some(left) if left > Duration::ZERO => Some(left),
            _ => {
                return Err(Fault::new(
                    ErrCode::Deadline,
                    "request deadline lapsed before any draw".to_string(),
                ))
            }
        },
        None => None,
    };
    sess.step(n, deadline)
        .map_err(|e| Fault::new(ErrCode::Failed, e))
}

// ---------------------------------------------------------------------
// TCP layer
// ---------------------------------------------------------------------

/// Run the daemon until a `shutdown` request drains it.  Returns the
/// bound address via `on_ready` (port 0 in `cfg.addr` picks a free
/// port — the bench harness uses this).
pub fn serve_with(cfg: ServeCfg, on_ready: impl FnOnce(String)) -> Result<DrainReport, String> {
    let listener = TcpListener::bind(&cfg.addr).map_err(|e| format!("bind {}: {e}", cfg.addr))?;
    listener
        .set_nonblocking(true)
        .map_err(|e| e.to_string())?;
    let local = listener.local_addr().map_err(|e| e.to_string())?;
    let server = Server::new(cfg);
    if server.cfg.recover {
        // rebuild journaled sessions before announcing readiness, so a
        // client that reconnects on `on_ready` already sees them
        let n = server
            .recover_sessions()
            .map_err(|e| format!("recover: {e}"))?;
        println!("[serve] recovered {n} session(s) from the journal");
    }
    on_ready(local.to_string());
    loop {
        if server.shutdown_requested() {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let server = server.clone();
                let _ = std::thread::Builder::new()
                    .name("subppl-conn".into())
                    .spawn(move || handle_connection(server, stream));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                // transient accept failures (ECONNABORTED, EMFILE under
                // fd pressure, ...) must not kill the daemon and strand
                // its sessions undrained: log, back off, keep serving
                eprintln!("[serve] accept error (continuing): {e}");
                std::thread::sleep(Duration::from_millis(10));
            }
        }
    }
    // the shutdown RPC already drained the registry; drain() again is
    // idempotent (empty registry) and covers the no-RPC exit path
    Ok(server.drain())
}

/// `subppl serve` entry point: prints the bound address, serves until
/// drained.
pub fn serve(cfg: ServeCfg) -> Result<(), String> {
    let rep = serve_with(cfg, |addr| {
        println!("[serve] listening on {addr}");
    })?;
    println!(
        "[serve] drained: {} sessions ({} forced, {} checkpointed)",
        rep.drained + rep.forced,
        rep.forced,
        rep.checkpointed
    );
    Ok(())
}

/// One client connection: newline-delimited request frames in,
/// response frames out, plus an event-writer thread per `subscribe`.
///
/// Frames are read in raw chunks into a byte accumulator (not
/// `read_line`) so the `--max-frame-bytes` cap applies to the bytes
/// *buffered*, not just to completed lines: a client streaming an
/// endless newline-free frame is cut off at the cap instead of growing
/// the buffer without bound.  Non-UTF-8 garbage on a line becomes an
/// ordinary parse error (`BadRequest`, connection stays open);
/// oversized frames get one `BadRequest` and a closed connection.
fn handle_connection(server: Arc<Server>, stream: TcpStream) {
    let max_frame = server.cfg.max_frame_bytes.max(1);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    // writes go through a mutex so response frames and streamed event
    // lines never interleave mid-line
    let out = Arc::new(Mutex::new(stream));
    let mut pending: Vec<u8> = Vec::new();
    let mut buf = [0u8; 8192];
    loop {
        let n = match reader.read(&mut buf) {
            Ok(0) => return, // EOF
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // a partial frame keeps accumulating across timeouts —
                // the next read completes it (slow-writer safety)
                if server.shutdown_requested() {
                    return;
                }
                continue;
            }
            Err(_) => return,
        };
        pending.extend_from_slice(&buf[..n]);
        // serve every complete line in the accumulator
        while let Some(pos) = pending.iter().position(|&b| b == b'\n') {
            let line: Vec<u8> = pending.drain(..=pos).collect();
            if line.len() > max_frame {
                oversized_frame(&out, max_frame);
                return;
            }
            let owned = String::from_utf8_lossy(&line);
            let text = owned.trim();
            if text.is_empty() {
                continue; // zero-length / whitespace lines are keepalives
            }
            let frame = match Request::parse(text) {
                Ok(req) => match req.method {
                    Method::Subscribe { session } => {
                        subscribe_frame(&server, &out, req.id, session)
                    }
                    _ => server.handle(req),
                },
                Err(f) => err_frame(0, &f),
            };
            if write_line(&out, &frame).is_err() {
                return;
            }
        }
        // no newline yet and already past the cap: this frame can only
        // get bigger — refuse it now instead of buffering forever
        if pending.len() > max_frame {
            oversized_frame(&out, max_frame);
            return;
        }
    }
}

/// One `BadRequest` frame for an over-cap request line; the caller
/// closes the connection (the frame boundary is lost, so resyncing on
/// the same stream would mis-parse the tail of the oversized frame).
fn oversized_frame(out: &Arc<Mutex<TcpStream>>, max_frame: usize) {
    let f = Fault::new(
        ErrCode::BadRequest,
        format!("frame exceeds max_frame_bytes ({max_frame})"),
    );
    let _ = write_line(out, &err_frame(0, &f));
}

fn write_line(out: &Arc<Mutex<TcpStream>>, line: &str) -> std::io::Result<()> {
    let mut s = out.lock().unwrap();
    s.write_all(line.as_bytes())?;
    s.write_all(b"\n")?;
    s.flush()
}

/// Wire a subscription: a bounded channel into the session, a writer
/// thread pumping event lines onto this connection.  The serve-scoped
/// faults hook here: `slowloris@k` wedges the writer (the channel
/// fills, the session drops the subscriber), `disconnect@k` drops the
/// connection mid-stream.
fn subscribe_frame(
    server: &Arc<Server>,
    out: &Arc<Mutex<TcpStream>>,
    id: u64,
    session: u64,
) -> String {
    let (tx, rx) = sync_channel::<String>(SUBSCRIBER_BUFFER);
    if let Err(f) = server.subscribe(session, tx) {
        return err_frame(id, &f);
    }
    let out = out.clone();
    let _ = std::thread::Builder::new()
        .name("subppl-sub-writer".into())
        .spawn(move || {
            while let Ok(line) = rx.recv() {
                if crate::runtime::faults::slowloris_write_now() {
                    // a client that stopped reading: stop draining the
                    // channel; the session's try_send fills it and
                    // drops this subscriber, then recv() errors out.
                    // bounded nap so the thread can't outlive the test
                    for _ in 0..200 {
                        std::thread::sleep(Duration::from_millis(25));
                    }
                    return;
                }
                if crate::runtime::faults::disconnect_write_now() {
                    if let Ok(s) = out.lock() {
                        let _ = s.shutdown(std::net::Shutdown::Both);
                    }
                    return;
                }
                if write_line(&out, &line).is_err() {
                    return;
                }
            }
        });
    ok_frame(
        id,
        Json::Obj(vec![("subscribed".into(), Json::Num(session as f64))]),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::session::StopReason;

    const MODEL: &str = r#"
        [assume mu (scope_include 'mu 0 (normal 0 1))]
        [observe (normal mu 0.5) 1.2]
    "#;

    fn params() -> CreateParams {
        CreateParams {
            program: MODEL.into(),
            infer: Some("(mh mu one drift 0.5 1)".into()),
            watch: vec!["mu".into()],
            ..CreateParams::default()
        }
    }

    fn tiny_server(max_sessions: usize) -> Arc<Server> {
        Server::new(ServeCfg {
            max_sessions,
            use_pool: false,
            ..ServeCfg::default()
        })
    }

    #[test]
    fn create_step_snapshot_cancel_lifecycle() {
        let srv = tiny_server(4);
        let id = srv.create(params()).unwrap();
        let rep = srv.step(id, 10, 0).unwrap();
        assert_eq!(rep.done, 10);
        assert_eq!(rep.total, 10);
        let snap = srv.snapshot(id).unwrap();
        assert_eq!(snap.get("draws").and_then(Json::as_u64), Some(10));
        srv.cancel(id).unwrap();
        // retired: further RPCs are NotFound
        assert_eq!(
            srv.step(id, 1, 0).unwrap_err().code,
            ErrCode::NotFound
        );
    }

    #[test]
    fn admission_control_rejects_over_limit() {
        let srv = tiny_server(2);
        let a = srv.create(params()).unwrap();
        let _b = srv.create(params()).unwrap();
        let err = srv.create(params()).unwrap_err();
        assert_eq!(err.code, ErrCode::Overloaded);
        assert!(err.retry_after_ms.is_some());
        // cancelling frees a slot
        srv.cancel(a).unwrap();
        // the cancelled session's thread needs a beat to exit; create
        // reaps finished threads, so retry briefly
        let mut ok = false;
        for _ in 0..100 {
            if srv.create(params()).is_ok() {
                ok = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(ok, "slot never freed after cancel");
    }

    #[test]
    fn expired_sessions_fail_with_the_expired_code() {
        let srv = tiny_server(4);
        let mut p = params();
        p.deadline_ms = 1;
        let id = srv.create(p).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        // the first step observes expiry at a draw boundary and
        // reports it on an ok frame (partial-progress convention)
        let rep = srv.step(id, 5, 0).unwrap();
        assert_eq!(rep.done, 0);
        assert_eq!(rep.stopped, Some(StopReason::Expired));
        // expiry is permanent: further steps get the documented code
        assert_eq!(srv.step(id, 1, 0).unwrap_err().code, ErrCode::Expired);
    }

    #[test]
    fn bad_programs_fail_the_create_not_the_server() {
        let srv = tiny_server(4);
        let err = srv
            .create(CreateParams {
                program: "[assume x (this_is_not_a_distribution)]".into(),
                ..CreateParams::default()
            })
            .unwrap_err();
        assert_eq!(err.code, ErrCode::BadRequest);
        // the server still admits good sessions
        assert!(srv.create(params()).is_ok());
    }

    #[test]
    fn drain_joins_all_sessions() {
        let srv = tiny_server(8);
        for _ in 0..4 {
            srv.create(params()).unwrap();
        }
        let rep = srv.drain();
        assert_eq!(rep.drained, 4);
        assert_eq!(rep.forced, 0);
        // post-drain: no admission
        assert_eq!(
            srv.create(params()).unwrap_err().code,
            ErrCode::Draining
        );
    }

    #[test]
    fn append_lifecycle_between_steps() {
        let srv = tiny_server(4);
        let id = srv.create(params()).unwrap();
        srv.step(id, 5, 0).unwrap();
        assert_eq!(
            srv.append(id, "[observe (normal mu 0.5) 0.9]".into()).unwrap(),
            1
        );
        let rep = srv.step(id, 5, 0).unwrap();
        assert_eq!(rep.total, 10, "appends are not draws");
        // a parse error is BadRequest and leaves the session stepping
        let err = srv.append(id, "[observe (normal mu".into()).unwrap_err();
        assert_eq!(err.code, ErrCode::BadRequest);
        assert_eq!(srv.step(id, 1, 0).unwrap().done, 1);
        // unknown session is NotFound, same as step
        assert_eq!(
            srv.append(99, "[observe (normal mu 0.5) 0.9]".into())
                .unwrap_err()
                .code,
            ErrCode::NotFound
        );
    }

    fn scratch_dir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "subppl-server-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn watched_mu(snap: &Json) -> f64 {
        match snap.get("values").and_then(|v| v.get("mu")) {
            Some(Json::Num(x)) => *x,
            other => panic!("no watched mu in snapshot: {other:?}"),
        }
    }

    #[test]
    fn own_queue_full_is_budget_exceeded_not_overloaded() {
        let srv = tiny_server(4);
        let mut p = params();
        p.queue_cap = 1;
        let id = srv.create(p).unwrap();
        // occupy the session with a long step, then flood its 1-slot
        // queue: among the next two sends at least one must bounce off
        // the full queue (the session is busy for the whole test), and
        // the bounce carries the session's own budget code
        let srv2 = srv.clone();
        let long = std::thread::spawn(move || {
            let _ = srv2.step(id, 5_000_000, 0);
        });
        // let the long step get dequeued before flooding, so the flood
        // can't race it out of the queue
        std::thread::sleep(Duration::from_millis(20));
        let mut saw_budget = None;
        for _ in 0..50 {
            let (reply, _done) = std::sync::mpsc::channel();
            if let Err(f) = srv.send(
                id,
                SessionCmd::Step {
                    n: 1,
                    deadline_at: None,
                    reply,
                },
            ) {
                saw_budget = Some(f);
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        let f = saw_budget.expect("1-slot queue never filled");
        assert_eq!(f.code, ErrCode::BudgetExceeded);
        assert!(f.retry_after_ms.is_some(), "queue budget is retryable");
        // a server-default queue under the same pressure says Overloaded
        let other = srv.create(params()).unwrap();
        let srv3 = srv.clone();
        let long2 = std::thread::spawn(move || {
            let _ = srv3.step(other, 5_000_000, 0);
        });
        std::thread::sleep(Duration::from_millis(20));
        let mut saw_overload = None;
        for _ in 0..200 {
            let (reply, _done) = std::sync::mpsc::channel();
            if let Err(f) = srv.send(
                other,
                SessionCmd::Step {
                    n: 1,
                    deadline_at: None,
                    reply,
                },
            ) {
                saw_overload = Some(f);
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert_eq!(saw_overload.expect("queue never filled").code, ErrCode::Overloaded);
        // cancel stops the long steps at a draw boundary
        srv.cancel(id).unwrap();
        srv.cancel(other).unwrap();
        long.join().unwrap();
        long2.join().unwrap();
        srv.drain();
    }

    #[test]
    fn trace_budget_append_maps_to_budget_exceeded() {
        let srv = tiny_server(4);
        let mut p = params();
        p.max_trace_nodes = 1; // any append would exceed it
        let id = srv.create(p).unwrap();
        srv.step(id, 3, 0).unwrap();
        let err = srv
            .append(id, "[observe (normal mu 0.5) 0.9]".into())
            .unwrap_err();
        assert_eq!(err.code, ErrCode::BudgetExceeded);
        // the refusal mutated nothing: the session still steps
        assert_eq!(srv.step(id, 2, 0).unwrap().total, 5);
    }

    #[test]
    fn cancel_retires_the_journal_but_drain_keeps_it() {
        let dir = scratch_dir("cancel-retire");
        let cfg = ServeCfg {
            max_sessions: 4,
            use_pool: false,
            state_dir: Some(dir.clone()),
            ..ServeCfg::default()
        };
        let srv = Server::new(cfg);
        let kept = srv.create(params()).unwrap();
        let discarded = srv.create(params()).unwrap();
        srv.step(kept, 3, 0).unwrap();
        srv.step(discarded, 3, 0).unwrap();
        let kept_path = crate::serve::journal::journal_path(&dir, kept);
        let discarded_path = crate::serve::journal::journal_path(&dir, discarded);
        assert!(kept_path.exists() && discarded_path.exists());
        srv.cancel(discarded).unwrap();
        // the session thread deletes the journal as it winds down
        let deadline = Instant::now() + Duration::from_secs(5);
        while discarded_path.exists() && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert!(
            !discarded_path.exists(),
            "a cancelled session must not resurrect on --recover"
        );
        srv.drain();
        assert!(
            kept_path.exists(),
            "drain keeps the journal — that state is what recovery replays"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recover_rebuilds_sessions_bitwise_and_bumps_next_id() {
        let dir = scratch_dir("recover");
        let cfg = ServeCfg {
            max_sessions: 4,
            use_pool: false,
            state_dir: Some(dir.clone()),
            ..ServeCfg::default()
        };
        let srv = Server::new(cfg.clone());
        let id = srv.create(params()).unwrap();
        srv.step(id, 8, 0).unwrap();
        srv.append(id, "[observe (normal mu 0.5) -3.0]".into())
            .unwrap();
        srv.step(id, 4, 0).unwrap();
        srv.drain();
        drop(srv);
        // restart: same state dir, recover before serving
        let srv2 = Server::new(ServeCfg {
            recover: true,
            ..cfg
        });
        assert_eq!(srv2.recover_sessions().unwrap(), 1);
        let rep = srv2.step(id, 8, 0).unwrap();
        assert_eq!(rep.total, 20, "recovered draw count continues");
        let recovered_mu = watched_mu(&srv2.snapshot(id).unwrap());
        // a fresh create must not collide with the recovered id
        assert_eq!(srv2.create(params()).unwrap(), id + 1);
        srv2.drain();
        // control: the same schedule uninterrupted (same seed, id 1)
        let ctl = tiny_server(4);
        let c = ctl.create(params()).unwrap();
        assert_eq!(c, id);
        ctl.step(c, 8, 0).unwrap();
        ctl.append(c, "[observe (normal mu 0.5) -3.0]".into())
            .unwrap();
        ctl.step(c, 12, 0).unwrap();
        let control_mu = watched_mu(&ctl.snapshot(c).unwrap());
        assert_eq!(
            recovered_mu.to_bits(),
            control_mu.to_bits(),
            "recovery must be bitwise-identical to the uninterrupted run"
        );
        ctl.drain();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dispatch_encodes_frames() {
        let srv = tiny_server(4);
        let resp = srv.handle(Request::parse(r#"{"id":1,"method":"ping"}"#).unwrap());
        assert_eq!(resp, r#"{"id":1,"ok":{"pong":true}}"#);
        let resp =
            srv.handle(Request::parse(r#"{"id":2,"method":"step","params":{"session":99}}"#).unwrap());
        assert!(resp.contains("\"NotFound\""), "{resp}");
    }
}
