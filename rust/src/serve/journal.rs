//! Per-session write-ahead journal: the serve daemon's durability
//! layer.
//!
//! PR 8/9 sessions kept their state in memory — durable only on
//! graceful drain — so a daemon crash or SIGKILL silently discarded
//! every live session's trace, PCG position, and appended
//! observations, pushing an O(full-history) replay cost onto clients.
//! The journal closes that hole: everything a session acknowledges is
//! on disk *before* the acknowledgement, and `serve --recover` rebuilds
//! every session from its journal so the recovered session's
//! subsequent draw sequence is **bitwise identical** to the
//! uninterrupted run — the same contract `tests/checkpoint.rs` pins
//! for chains, now pinned across process death.
//!
//! # What is journaled, and when
//!
//! One file per session, `session<id>.journal` under `--state-dir`,
//! holding three record kinds:
//!
//! - `create` — the session's fully-resolved creation parameters
//!   (seed, program, inference program, watch list, budgets, weight),
//!   written via temp-then-rename *before* the `create` RPC is
//!   acknowledged;
//! - `append` — one atomic record per acknowledged `append` RPC
//!   carrying **both** the appended source and the fresh post-append
//!   [`ChainCheckpoint`](crate::coordinator::checkpoint::ChainCheckpoint)
//!   text, so no cross-record invariant exists: either the whole
//!   append is durable or none of it;
//! - `ckpt` — a checkpoint of the session's stochastic state + RNG
//!   position, written every `--journal-every` draws *and* at the end
//!   of every completed `step` before its reply, so the last
//!   acknowledged draw count is always covered by a durable
//!   checkpoint.
//!
//! # Record framing and torn tails
//!
//! Appends cannot use temp-then-rename (rewriting the file per draw
//! would be O(history)), so each record carries its own checksum:
//!
//! ```text
//! rec <kind> <payload-byte-len>\n
//! <payload bytes>\n
//! sum <fnv1a:16-hex>\n
//! ```
//!
//! The checksum covers the header line and the payload (the same
//! FNV-1a the checkpoint format uses).  A crash mid-append leaves a
//! *torn tail*: a final frame that is truncated or fails its checksum.
//! [`read_journal`] detects it, reports the state of the valid prefix,
//! and physically truncates the file at the last valid record boundary
//! — the torn operation was never acknowledged, so dropping it
//! restores exactly the last acknowledged state.  A checksum-valid
//! record with an unparsable payload is *corruption*, not a torn tail,
//! and is a hard error: never silently start over on a file that
//! should have parsed.
//!
//! # Compaction
//!
//! `ckpt` records accrete, so the journal is rewritten (temp, then
//! rename — the same atomic discipline as `chain<k>.ckpt`) down to
//! `create` + append sources + the latest checkpoint whenever it
//! outgrows its session's journal-byte budget; a session whose
//! *compacted* journal still exceeds the budget is out of budget for
//! real and gets `BudgetExceeded`.

use crate::coordinator::checkpoint::fnv1a;
use crate::runtime::faults;
use crate::serve::protocol::Json;
use std::fs::{File, OpenOptions};
use std::io::Write as _;
use std::path::{Path, PathBuf};

/// Record kinds (the `<kind>` token of a frame header).
pub const KIND_CREATE: &str = "create";
pub const KIND_APPEND: &str = "append";
pub const KIND_CKPT: &str = "ckpt";

/// One session's open journal handle.  All writes go through
/// [`append_record`](Self::append_record); a write failure (real IO
/// error or an injected `torn-write`/`kill-recover` fault) marks the
/// handle dead — the caller must treat the session as failed, because
/// durability can no longer be guaranteed.
pub struct Journal {
    path: PathBuf,
    file: Option<File>,
    bytes: u64,
    dead: bool,
}

impl std::fmt::Debug for Journal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Journal")
            .field("path", &self.path)
            .field("bytes", &self.bytes)
            .field("dead", &self.dead)
            .finish()
    }
}

/// Canonical journal location for session `id` under `dir`.
pub fn journal_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("session{id}.journal"))
}

/// Encode one framed record (header + payload + checksum line).
fn encode_record(kind: &str, payload: &[u8]) -> Vec<u8> {
    let header = format!("rec {kind} {}\n", payload.len());
    let mut sum_input = Vec::with_capacity(header.len() + payload.len());
    sum_input.extend_from_slice(header.as_bytes());
    sum_input.extend_from_slice(payload);
    let sum = fnv1a(&sum_input);
    let mut out = sum_input;
    out.extend_from_slice(format!("\nsum {sum:016x}\n").as_bytes());
    out
}

impl Journal {
    /// Create session `id`'s journal under `dir` with its `create`
    /// record already durable: the full file (one record) is written to
    /// a temp name and renamed into place, so a crash at any point
    /// leaves either no journal (the create was never acknowledged) or
    /// a complete one — never a torn create.
    pub fn create(dir: &Path, id: u64, create_payload: &Json) -> Result<Journal, String> {
        std::fs::create_dir_all(dir)
            .map_err(|e| format!("journal: create_dir {}: {e}", dir.display()))?;
        let path = journal_path(dir, id);
        let rec = encode_record(KIND_CREATE, create_payload.encode().as_bytes());
        let tmp = path.with_extension("journal.tmp");
        std::fs::write(&tmp, &rec).map_err(|e| format!("journal: write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &path)
            .map_err(|e| format!("journal: rename {}: {e}", path.display()))?;
        let file = OpenOptions::new()
            .append(true)
            .open(&path)
            .map_err(|e| format!("journal: open {}: {e}", path.display()))?;
        Ok(Journal {
            path,
            file: Some(file),
            bytes: rec.len() as u64,
            dead: false,
        })
    }

    /// Reopen an existing journal for appending (the recovery path:
    /// [`read_journal`] already truncated any torn tail away).
    pub fn open_append(path: &Path) -> Result<Journal, String> {
        let bytes = std::fs::metadata(path)
            .map_err(|e| format!("journal: stat {}: {e}", path.display()))?
            .len();
        let file = OpenOptions::new()
            .append(true)
            .open(path)
            .map_err(|e| format!("journal: open {}: {e}", path.display()))?;
        Ok(Journal {
            path: path.to_path_buf(),
            file: Some(file),
            bytes,
            dead: false,
        })
    }

    /// Current on-disk size in bytes (the journal-byte budget's meter).
    pub fn bytes(&self) -> u64 {
        self.bytes
    }

    /// Whether a write failure already killed this handle.
    pub fn dead(&self) -> bool {
        self.dead
    }

    /// Append one framed record and flush it.  The record is durable
    /// when this returns `Ok` — callers acknowledge the corresponding
    /// operation only after that.  On failure (IO error, or the
    /// `torn-write@k` / `kill-recover@k` faults simulating process
    /// death mid-write / just-before-write) the handle goes dead and
    /// the operation must not be acknowledged.
    pub fn append_record(&mut self, kind: &str, payload: &[u8]) -> Result<(), String> {
        if self.dead {
            return Err("journal: handle is dead after a failed write".into());
        }
        let rec = encode_record(kind, payload);
        if faults::journal_kill_now() {
            // SIGKILL between the state change and the journal append:
            // nothing lands; the journal is clean but stale
            self.dead = true;
            self.file = None;
            return Err("journal: injected kill before record write".into());
        }
        if faults::journal_torn_write_now() {
            // death mid-write(2): a prefix of the frame lands, then the
            // handle dies — recovery must drop this tail
            let half = &rec[..rec.len() / 2];
            if let Some(f) = self.file.as_mut() {
                let _ = f.write_all(half);
                let _ = f.flush();
            }
            self.bytes += (rec.len() / 2) as u64;
            self.dead = true;
            self.file = None;
            return Err("journal: injected torn write".into());
        }
        let f = self
            .file
            .as_mut()
            .ok_or_else(|| "journal: no open file".to_string())?;
        if let Err(e) = f.write_all(&rec).and_then(|()| f.flush()) {
            self.dead = true;
            self.file = None;
            return Err(format!("journal: write {}: {e}", self.path.display()));
        }
        self.bytes += rec.len() as u64;
        Ok(())
    }

    /// Rewrite the journal down to `create` + append sources + the
    /// latest checkpoint, atomically (temp, then rename).  State is
    /// unchanged — a recovery from the compacted journal rebuilds the
    /// same session — only the accreted per-draw `ckpt` records are
    /// dropped.
    pub fn compact(
        &mut self,
        create_payload: &Json,
        appends: &[String],
        ckpt: Option<&str>,
    ) -> Result<(), String> {
        if self.dead {
            return Err("journal: handle is dead after a failed write".into());
        }
        let mut out = encode_record(KIND_CREATE, create_payload.encode().as_bytes());
        for src in appends {
            let payload = Json::Obj(vec![
                ("src".into(), Json::Str(src.clone())),
                // the checkpoint that rode along with this append is
                // superseded by the final ckpt record below
                ("ckpt".into(), Json::Str(String::new())),
            ]);
            out.extend_from_slice(&encode_record(KIND_APPEND, payload.encode().as_bytes()));
        }
        if let Some(ck) = ckpt {
            out.extend_from_slice(&encode_record(KIND_CKPT, ck.as_bytes()));
        }
        let tmp = self.path.with_extension("journal.tmp");
        std::fs::write(&tmp, &out).map_err(|e| format!("journal: write {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, &self.path)
            .map_err(|e| format!("journal: rename {}: {e}", self.path.display()))?;
        let file = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .map_err(|e| format!("journal: open {}: {e}", self.path.display()))?;
        self.file = Some(file);
        self.bytes = out.len() as u64;
        Ok(())
    }
}

/// Everything a journal pins about its session: the recovery input.
#[derive(Debug)]
pub struct JournalState {
    /// The `create` record's parameter object.
    pub create: Json,
    /// Appended program sources, in acknowledgement order.
    pub appends: Vec<String>,
    /// The latest checkpoint text (from an `append` or `ckpt` record);
    /// `None` when no draw/append was ever acknowledged — the draw-0
    /// program replay is already the correct state.
    pub ckpt: Option<String>,
    /// Whether a torn tail was detected (and truncated away).
    pub torn: bool,
    /// Size of the valid prefix — the file's size after truncation.
    pub valid_bytes: u64,
}

/// Read (and repair) one session journal.  Scans records in order,
/// verifying each frame's checksum; the first truncated or
/// checksum-failing frame marks a torn tail, which is dropped by
/// physically truncating the file at the last valid record boundary.
/// A checksum-valid record whose payload fails to parse, or a journal
/// with no `create` record, is corruption — a hard error.
pub fn read_journal(path: &Path) -> Result<JournalState, String> {
    let data =
        std::fs::read(path).map_err(|e| format!("journal: read {}: {e}", path.display()))?;
    let mut pos = 0usize;
    let mut valid = 0usize;
    let mut torn = false;
    let mut create: Option<Json> = None;
    let mut appends: Vec<String> = Vec::new();
    let mut ckpt: Option<String> = None;
    while pos < data.len() {
        let Some((kind, payload, end)) = next_record(&data, pos) else {
            torn = true;
            break;
        };
        match kind.as_str() {
            KIND_CREATE => {
                let js = Json::parse(
                    std::str::from_utf8(payload)
                        .map_err(|_| corrupt(path, "create payload is not UTF-8"))?,
                )
                .map_err(|e| corrupt(path, &format!("create payload: {e}")))?;
                create = Some(js);
            }
            KIND_APPEND => {
                let js = Json::parse(
                    std::str::from_utf8(payload)
                        .map_err(|_| corrupt(path, "append payload is not UTF-8"))?,
                )
                .map_err(|e| corrupt(path, &format!("append payload: {e}")))?;
                let src = js
                    .get("src")
                    .and_then(Json::as_str)
                    .ok_or_else(|| corrupt(path, "append payload missing src"))?;
                appends.push(src.to_string());
                if let Some(ck) = js.get("ckpt").and_then(Json::as_str) {
                    if !ck.is_empty() {
                        ckpt = Some(ck.to_string());
                    }
                }
            }
            KIND_CKPT => {
                ckpt = Some(
                    std::str::from_utf8(payload)
                        .map_err(|_| corrupt(path, "ckpt payload is not UTF-8"))?
                        .to_string(),
                );
            }
            other => return Err(corrupt(path, &format!("unknown record kind {other:?}"))),
        }
        pos = end;
        valid = end;
    }
    if torn && valid < data.len() {
        // drop the torn tail at the last valid record boundary — the
        // torn operation was never acknowledged, so the truncated
        // journal is exactly the last acknowledged state
        let f = OpenOptions::new()
            .write(true)
            .open(path)
            .map_err(|e| format!("journal: open {}: {e}", path.display()))?;
        f.set_len(valid as u64)
            .map_err(|e| format!("journal: truncate {}: {e}", path.display()))?;
        eprintln!(
            "[journal] {}: torn tail ({} byte(s)) dropped at the last valid record",
            path.display(),
            data.len() - valid
        );
    }
    let create = create.ok_or_else(|| corrupt(path, "no create record"))?;
    Ok(JournalState {
        create,
        appends,
        ckpt,
        torn,
        valid_bytes: valid as u64,
    })
}

fn corrupt(path: &Path, what: &str) -> String {
    format!("journal: {} is corrupt ({what})", path.display())
}

/// Parse one frame at `pos`.  `None` = torn (truncated frame, bad
/// header syntax, or checksum mismatch — everything a death mid-write
/// can produce); `Some((kind, payload, end))` on a valid frame.
#[allow(clippy::type_complexity)]
fn next_record(data: &[u8], pos: usize) -> Option<(String, &[u8], usize)> {
    let header_end = data[pos..].iter().position(|&b| b == b'\n')? + pos;
    let header = std::str::from_utf8(&data[pos..header_end]).ok()?;
    let mut parts = header.split(' ');
    if parts.next() != Some("rec") {
        return None;
    }
    let kind = parts.next()?.to_string();
    let len: usize = parts.next()?.parse().ok()?;
    if parts.next().is_some() {
        return None;
    }
    let payload_start = header_end + 1;
    let payload_end = payload_start.checked_add(len)?;
    // payload + '\n' + "sum " + 16 hex + '\n'
    let sum_line_start = payload_end.checked_add(1)?;
    let end = sum_line_start.checked_add(4 + 16 + 1)?;
    if end > data.len() {
        return None;
    }
    if data[payload_end] != b'\n' || data[end - 1] != b'\n' {
        return None;
    }
    let sum_line = std::str::from_utf8(&data[sum_line_start..end - 1]).ok()?;
    let want = u64::from_str_radix(sum_line.strip_prefix("sum ")?, 16).ok()?;
    let got = fnv1a(&data[pos..payload_end]);
    if got != want {
        return None;
    }
    Some((kind, &data[payload_start..payload_end], end))
}

/// Enumerate the session journals under a state dir as
/// `(session id, path)` pairs, in ascending id order.  Non-journal
/// files are ignored (the state dir may share space with temp files).
pub fn scan_state_dir(dir: &Path) -> Result<Vec<(u64, PathBuf)>, String> {
    let rd = match std::fs::read_dir(dir) {
        Ok(rd) => rd,
        // nothing to recover is not an error
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
        Err(e) => return Err(format!("journal: read_dir {}: {e}", dir.display())),
    };
    let mut out = Vec::new();
    for entry in rd {
        let entry = entry.map_err(|e| format!("journal: read_dir {}: {e}", dir.display()))?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(id) = name
            .strip_prefix("session")
            .and_then(|r| r.strip_suffix(".journal"))
            .and_then(|id| id.parse::<u64>().ok())
        else {
            continue;
        };
        out.push((id, entry.path()));
    }
    out.sort_by_key(|(id, _)| *id);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "subppl-journal-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn params() -> Json {
        Json::Obj(vec![
            ("seed".into(), Json::Num(7.0)),
            ("program".into(), Json::Str("[assume x (normal 0 1)]".into())),
        ])
    }

    #[test]
    fn journal_roundtrips_records() {
        let dir = tmp_dir("roundtrip");
        let mut j = Journal::create(&dir, 3, &params()).unwrap();
        assert!(!j.dead());
        let append = Json::Obj(vec![
            ("src".into(), Json::Str("[observe (normal x 1) 0.5]".into())),
            ("ckpt".into(), Json::Str("ck-after-append\nline2".into())),
        ]);
        j.append_record(KIND_APPEND, append.encode().as_bytes())
            .unwrap();
        j.append_record(KIND_CKPT, b"ck-draw-10\nline2").unwrap();
        j.append_record(KIND_CKPT, b"ck-draw-20\nline2").unwrap();
        let expect_bytes = j.bytes();

        let st = read_journal(&journal_path(&dir, 3)).unwrap();
        assert!(!st.torn);
        assert_eq!(st.valid_bytes, expect_bytes);
        assert_eq!(
            st.create.get("seed").and_then(Json::as_u64),
            Some(7),
            "create params survive"
        );
        assert_eq!(st.appends, vec!["[observe (normal x 1) 0.5]".to_string()]);
        assert_eq!(st.ckpt.as_deref(), Some("ck-draw-20\nline2"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_detected_and_truncated() {
        let dir = tmp_dir("torn");
        let mut j = Journal::create(&dir, 1, &params()).unwrap();
        j.append_record(KIND_CKPT, b"ck-draw-5").unwrap();
        let good = j.bytes();
        drop(j);
        let path = journal_path(&dir, 1);
        // simulate death mid-write: a prefix of a would-be record
        let torn = &encode_record(KIND_CKPT, b"ck-draw-6-never-acked")[..17];
        let mut f = OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(torn).unwrap();
        drop(f);

        let st = read_journal(&path).unwrap();
        assert!(st.torn, "torn tail must be flagged");
        assert_eq!(st.ckpt.as_deref(), Some("ck-draw-5"));
        assert_eq!(st.valid_bytes, good);
        assert_eq!(
            std::fs::metadata(&path).unwrap().len(),
            good,
            "file physically truncated at the last valid record"
        );
        // after repair the journal reads clean and is appendable again
        let st2 = read_journal(&path).unwrap();
        assert!(!st2.torn);
        let mut j2 = Journal::open_append(&path).unwrap();
        j2.append_record(KIND_CKPT, b"ck-draw-6-retry").unwrap();
        assert_eq!(
            read_journal(&path).unwrap().ckpt.as_deref(),
            Some("ck-draw-6-retry")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupted_checksum_tail_is_dropped_not_loaded() {
        let dir = tmp_dir("sum");
        let mut j = Journal::create(&dir, 2, &params()).unwrap();
        j.append_record(KIND_CKPT, b"ck-good").unwrap();
        let good = j.bytes();
        j.append_record(KIND_CKPT, b"ck-to-corrupt").unwrap();
        drop(j);
        let path = journal_path(&dir, 2);
        // flip one payload byte of the final record: its checksum fails,
        // so the scan treats it as a torn tail
        let mut data = std::fs::read(&path).unwrap();
        let at = good as usize + 20;
        data[at] ^= 0x01;
        std::fs::write(&path, &data).unwrap();
        let st = read_journal(&path).unwrap();
        assert!(st.torn);
        assert_eq!(st.ckpt.as_deref(), Some("ck-good"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn compaction_preserves_state_and_shrinks() {
        let dir = tmp_dir("compact");
        let mut j = Journal::create(&dir, 9, &params()).unwrap();
        let append = Json::Obj(vec![
            ("src".into(), Json::Str("[observe (normal x 1) 2]".into())),
            ("ckpt".into(), Json::Str("ck-append".into())),
        ]);
        j.append_record(KIND_APPEND, append.encode().as_bytes())
            .unwrap();
        for i in 0..50 {
            j.append_record(KIND_CKPT, format!("ck-draw-{i}").as_bytes())
                .unwrap();
        }
        let fat = j.bytes();
        j.compact(
            &params(),
            &["[observe (normal x 1) 2]".to_string()],
            Some("ck-draw-49"),
        )
        .unwrap();
        assert!(j.bytes() < fat, "compaction must shrink the journal");
        let st = read_journal(&journal_path(&dir, 9)).unwrap();
        assert!(!st.torn);
        assert_eq!(st.appends, vec!["[observe (normal x 1) 2]".to_string()]);
        assert_eq!(st.ckpt.as_deref(), Some("ck-draw-49"));
        // and the compacted journal is still appendable
        j.append_record(KIND_CKPT, b"ck-draw-50").unwrap();
        assert_eq!(
            read_journal(&journal_path(&dir, 9)).unwrap().ckpt.as_deref(),
            Some("ck-draw-50")
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scan_finds_session_journals_in_id_order() {
        let dir = tmp_dir("scan");
        for id in [12u64, 3, 7] {
            Journal::create(&dir, id, &params()).unwrap();
        }
        std::fs::write(dir.join("not-a-journal.txt"), b"x").unwrap();
        std::fs::write(dir.join("sessionX.journal"), b"x").unwrap();
        let found = scan_state_dir(&dir).unwrap();
        let ids: Vec<u64> = found.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![3, 7, 12]);
        // a missing dir is an empty recovery set, not an error
        assert!(scan_state_dir(&dir.join("nope")).unwrap().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_create_record_is_corruption() {
        let dir = tmp_dir("nocreate");
        std::fs::create_dir_all(&dir).unwrap();
        let path = journal_path(&dir, 4);
        std::fs::write(&path, encode_record(KIND_CKPT, b"ck")).unwrap();
        assert!(read_journal(&path).unwrap_err().contains("no create record"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
