//! PET nodes.
//!
//! A node is one executed computation (Def. 1).  Statistical parents
//! (`E_s`) are implied by the node's kind + argument references; children
//! lists are maintained explicitly as the reverse edges, because both
//! scaffold construction (Defs. 2–5) and border detection (Def. 6) walk
//! the trace downstream.

use crate::ppl::ast::Expr;
use crate::ppl::env::EnvRef;
use crate::ppl::prim::Prim;
use crate::ppl::sp::{MakerFamily, SpFamily};
use crate::ppl::value::{KeyVec, MemId, SpId, Value};
use std::rc::Rc;

/// Index into the trace's node arena.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub u32);

impl NodeId {
    pub fn idx(self) -> usize {
        self.0 as usize
    }
}

/// An argument position: either a compile-time constant (no node is
/// materialized — this is what keeps per-observation node counts low) or
/// a reference to a parent node.
#[derive(Clone, Debug)]
pub enum ArgRef {
    Const(Value),
    Node(NodeId),
}

impl ArgRef {
    pub fn node(&self) -> Option<NodeId> {
        match self {
            ArgRef::Node(id) => Some(*id),
            ArgRef::Const(_) => None,
        }
    }
}

/// Result of evaluating an expression: a constant-folded value or a node.
#[derive(Clone, Debug)]
pub enum EvalResult {
    Static(Value),
    Node(NodeId),
}

impl EvalResult {
    pub fn as_argref(&self) -> ArgRef {
        match self {
            EvalResult::Static(v) => ArgRef::Const(v.clone()),
            EvalResult::Node(id) => ArgRef::Node(*id),
        }
    }

    pub fn node(&self) -> Option<NodeId> {
        match self {
            EvalResult::Node(id) => Some(*id),
            EvalResult::Static(_) => None,
        }
    }
}

/// What kind of computation a node represents.
#[derive(Clone, Debug)]
pub enum NodeKind {
    /// Deterministic primitive application; value = prim(args).
    Det(Prim),
    /// Stochastic application of a stateless family; args are params.
    StochFam(SpFamily),
    /// Stochastic application whose operator is the value of node `op`
    /// (must be `Value::Sp`); e.g. `((c (z i)))` in the JointDPM program.
    StochDyn { op: NodeId },
    /// Stochastic application of a fixed SP instance (operator was a
    /// static `Value::Sp`, e.g. a maker with constant args).
    StochInst { sp: SpId },
    /// Maker application: creates/owns SP instance `sp`; value = Sp(sp).
    /// Recomputation updates the instance's params in place (AAA).
    Maker { family: MakerFamily, sp: SpId },
    /// Memoized application: `key` computed from args routes to a cache
    /// entry of `mem`; value mirrors the target's value.
    MemApp {
        mem: MemId,
        key: KeyVec,
        target: EvalResult,
    },
    /// `if` with a dynamic predicate (args[0]); the chosen branch's nodes
    /// are existential children (`E_e`), owned by this node.
    If {
        expr: Rc<Expr>, // the full If expression, for branch re-eval
        env: EnvRef,
        take_conseq: bool,
        branch: EvalResult,
        owned: Vec<NodeId>,
    },
    /// Closure-application passthrough: value mirrors `inner`.
    Inner { inner: NodeId },
}

/// One executed computation in the PET.
#[derive(Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub value: Value,
    /// Semantic arguments (operands; If predicate at position 0).
    pub args: Vec<ArgRef>,
    /// Reverse statistical edges.
    pub children: Vec<NodeId>,
    /// Observation constraint?
    pub observed: bool,
    /// Slot liveness (false after unevaluation).
    pub alive: bool,
}

impl Node {
    pub fn new(kind: NodeKind, value: Value, args: Vec<ArgRef>) -> Node {
        Node {
            kind,
            value,
            args,
            children: Vec::new(),
            observed: false,
            alive: true,
        }
    }

    /// Visit every dynamic (node-backed) parent implied by kind + args,
    /// possibly with duplicates — the allocation-free core of
    /// `dyn_parents`, and the single definition of the parent set (hot
    /// paths like `freshen_section` iterate through this instead of
    /// duplicating the kind dispatch).
    pub fn for_each_dyn_parent(&self, mut f: impl FnMut(NodeId)) {
        for a in &self.args {
            if let ArgRef::Node(id) = a {
                f(*id);
            }
        }
        match &self.kind {
            NodeKind::StochDyn { op } => f(*op),
            NodeKind::MemApp { target, .. } => {
                if let Some(t) = target.node() {
                    f(t);
                }
            }
            NodeKind::If { branch, .. } => {
                if let Some(b) = branch.node() {
                    f(b);
                }
            }
            NodeKind::Inner { inner } => f(*inner),
            _ => {}
        }
    }

    /// Dynamic (node-backed) parents implied by kind + args, sorted and
    /// deduplicated.
    pub fn dyn_parents(&self) -> Vec<NodeId> {
        let mut ps: Vec<NodeId> = Vec::new();
        self.for_each_dyn_parent(|p| ps.push(p));
        ps.sort_unstable();
        ps.dedup();
        ps
    }

    /// Is this node a stochastic computation (has a log density)?
    pub fn is_stochastic(&self) -> bool {
        matches!(
            self.kind,
            NodeKind::StochFam(_) | NodeKind::StochDyn { .. } | NodeKind::StochInst { .. }
        )
    }

    /// Is this node deterministic given its parents (value propagates)?
    pub fn is_deterministic(&self) -> bool {
        !self.is_stochastic()
    }
}
