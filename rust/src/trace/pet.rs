//! The probabilistic execution trace (PET, Def. 1): node arena, SP/mem
//! tables, scope registry, directives, lazy staleness (§3.5), and joint
//! density (Eq. 1).

use crate::math::Pcg64;
use crate::ppl::ast::Directive;
use crate::ppl::env::{Binding, Env, EnvRef};
use crate::ppl::sp::SpState;
use crate::ppl::value::{Closure, KeyVec, MemId, SpId, Value};
use crate::trace::node::{ArgRef, EvalResult, Node, NodeId, NodeKind};
use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

/// A memoized procedure: body closure + cache of evaluated applications.
#[derive(Debug)]
pub struct MemState {
    pub closure: Rc<Closure>,
    pub cache: HashMap<KeyVec, CacheEntry>,
}

/// One mem-cache entry; `owned` are the nodes created when the body was
/// evaluated for this key (freed when the entry is purged).
#[derive(Debug)]
pub struct CacheEntry {
    pub target: EvalResult,
    pub refcount: u32,
    pub owned: Vec<NodeId>,
}

/// Scope registry: `(scope_include 'name block expr)` tags principal
/// nodes so inference programs can address them.
#[derive(Debug, Default)]
pub struct Scope {
    pub blocks: Vec<(Value, Vec<NodeId>)>,
    index: HashMap<KeyVec, usize>,
}

impl Scope {
    fn register(&mut self, block: Value, node: NodeId) {
        let key = KeyVec(vec![block.clone()]);
        let idx = *self.index.entry(key).or_insert_with(|| {
            self.blocks.push((block, Vec::new()));
            self.blocks.len() - 1
        });
        self.blocks[idx].1.push(node);
    }

    fn deregister(&mut self, block: &Value, node: NodeId) {
        if let Some(&idx) = self.index.get(&KeyVec(vec![block.clone()])) {
            self.blocks[idx].1.retain(|&n| n != node);
        }
    }

    pub fn block_nodes(&self, block: &Value) -> &[NodeId] {
        match self.index.get(&KeyVec(vec![block.clone()])) {
            Some(&idx) => &self.blocks[idx].1,
            None => &[],
        }
    }

    /// Non-empty blocks.
    pub fn live_blocks(&self) -> Vec<&Value> {
        self.blocks
            .iter()
            .filter(|(_, ns)| !ns.is_empty())
            .map(|(b, _)| b)
            .collect()
    }
}

/// Shared handle on a cached column store (rows mutate between
/// structural rebuilds, hence the inner `RefCell`).
pub type ColStoreHandle = Rc<RefCell<crate::trace::colstore::ColumnStoreSet>>;

/// Record of an executed top-level directive.
#[derive(Debug)]
pub struct DirectiveRecord {
    pub directive: Directive,
    pub result: EvalResult,
    pub owned: Vec<NodeId>,
}

/// The trace.
pub struct Trace {
    pub(crate) nodes: Vec<Node>,
    free: Vec<u32>,
    pub(crate) sps: Vec<SpState>,
    pub(crate) mems: Vec<MemState>,
    pub global_env: EnvRef,
    pub(crate) scopes: HashMap<Rc<str>, Scope>,
    node_scope: HashMap<NodeId, (Rc<str>, Value)>,
    /// Staleness epoch (§3.5): a deterministic node is fresh iff its
    /// epoch equals this.
    pub(crate) epoch: u64,
    /// Node epochs live out-of-line so `fresh_value` can run with `&self`
    /// node borrows (u64 per slot, index-aligned with `nodes`).
    pub(crate) epochs: Vec<u64>,
    /// Bumped on any structural change (node alloc/free, child-edge
    /// rewiring from rekeys/branch swaps).  Caches keyed on structure
    /// (border partitions, section plans) revalidate against this.
    /// Invariant: rejected transitions restore this to its
    /// pre-journal value after `rollback` (the structure is exactly
    /// restored), which is sound only because cache entries are never
    /// created while a journal is open — do not call
    /// `cached_partition`/`cached_section_plan` from inside
    /// detach/regen/rollback.
    pub structure_version: u64,
    /// Bumped on every *committed-value* write (`set_value`): accepted
    /// subsampled proposals (`commit_global`), journaled transitions
    /// (detach/regen/rollback all write through `set_value`),
    /// particle-gibbs state commits, and observation rewrites.  The
    /// persistent column store (`trace/colstore.rs`) stamps each cached
    /// member row with this and lazily re-reads rows whose stamp is
    /// stale.  Lazy freshening (`freshen`) deliberately does NOT bump
    /// it: a freshen under unchanged committed inputs recomputes
    /// bit-identical values, so store rows stay valid across epoch
    /// bumps until some committed input actually moves.
    pub value_version: u64,
    /// Bumped on *append-only* growth (node allocs and child-edge
    /// additions made under [`append_directive`](Self::append_directive)).
    /// Structure-keyed caches treat the two versions asymmetrically: a
    /// `structure_version` mismatch invalidates wholesale (re-keys,
    /// branch swaps, retirement), while an `append_version` mismatch
    /// with a matching `structure_version` means the trace only *grew*
    /// at the ends of existing children lists — cached partitions,
    /// batch-plan sets and column stores extend in place in
    /// O(|append|) instead of rebuilding in O(N).
    pub append_version: u64,
    /// True while a directive executes in append mode (growth bumps
    /// `append_version`; any shrinking mutation still bumps
    /// `structure_version`, degrading the append to a full rebuild).
    appending: bool,
    pub(crate) records: Vec<DirectiveRecord>,
    pub(crate) observations: Vec<NodeId>,
    /// Border-partition cache (Defs. 6-8), keyed by principal node and
    /// validated against `structure_version` — rebuilding the partition
    /// clones the border's N-child list, which would otherwise make
    /// every subsampled transition O(N).
    partition_cache: RefCell<HashMap<NodeId, Rc<crate::trace::partition::Partition>>>,
    /// Section-plan cache (trace/plan.rs), keyed by (principal, border
    /// child) and validated against `structure_version` exactly like the
    /// partition cache — re-lowering a section per mini-batch would put
    /// the graph walk back on the hot path the plans exist to remove.
    /// The principal is part of the key because lowering is
    /// partition-relative (`PlanArg::Global` indices): two principals
    /// whose partitions share border children need distinct plans.
    plan_cache: RefCell<HashMap<(NodeId, NodeId), Rc<crate::trace::plan::SectionPlan>>>,
    /// Shape-keyed batch-plan cache (trace/batch.rs), keyed by principal
    /// and validated against `structure_version` like the other two:
    /// groups hold per-section slot tables whose *node ids* would dangle
    /// across structural changes, so a stale set is rebuilt wholesale,
    /// never patched.
    batch_cache: RefCell<HashMap<NodeId, Rc<crate::trace::batch::BatchPlanSet>>>,
    /// Persistent column-store cache (trace/colstore.rs), keyed by
    /// principal and aligned group-for-group with the cached
    /// `BatchPlanSet`.  The set's *layout* (group membership, column
    /// offsets) is structure-keyed like the other caches; its *rows*
    /// carry per-member `value_version` stamps and refresh lazily, so
    /// it lives behind its own `RefCell` (rows mutate between
    /// structural rebuilds).
    colstore_cache: RefCell<HashMap<NodeId, ColStoreHandle>>,
    /// Running count of column stores evicted from `colstore_cache`
    /// because a structural rebuild left them behind (their principal
    /// stopped being sampled — DPM cluster churn creates and abandons
    /// such principals constantly, and without the sweep their
    /// full-width panels would accumulate for the life of the trace).
    /// Evaluators sample deltas of this around
    /// [`cached_colstore`](Self::cached_colstore) for their stats.
    store_evicted: Cell<u64>,
    /// Process-unique id of this trace (evaluators that carry per-trace
    /// caches validate against it — `structure_version` alone is not
    /// unique across traces).
    pub instance_id: u64,
}

static TRACE_IDS: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

impl Default for Trace {
    fn default() -> Self {
        Self::new()
    }
}

impl Trace {
    pub fn new() -> Trace {
        Trace {
            nodes: Vec::new(),
            free: Vec::new(),
            sps: Vec::new(),
            mems: Vec::new(),
            global_env: Env::root(),
            scopes: HashMap::new(),
            node_scope: HashMap::new(),
            epoch: 0,
            epochs: Vec::new(),
            structure_version: 0,
            value_version: 1,
            append_version: 1,
            appending: false,
            records: Vec::new(),
            observations: Vec::new(),
            partition_cache: RefCell::new(HashMap::new()),
            plan_cache: RefCell::new(HashMap::new()),
            batch_cache: RefCell::new(HashMap::new()),
            colstore_cache: RefCell::new(HashMap::new()),
            store_evicted: Cell::new(0),
            instance_id: TRACE_IDS.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
        }
    }

    /// Cached global/local partition for a principal node (None if the
    /// variable has no border).  Rebuilt when the trace structure has
    /// changed since the cached copy was built; *extended in place*
    /// (O(|append|)) when the only changes since were append-mode
    /// growth — appends push new border children at the end of the
    /// children list, so the cached locals are a prefix of the current
    /// list and only the suffix needs adopting.
    pub fn cached_partition(
        &self,
        v: NodeId,
    ) -> Option<Rc<crate::trace::partition::Partition>> {
        if let Some(p) = self.partition_cache.borrow_mut().get_mut(&v) {
            if p.built_at == self.structure_version {
                if p.appended_at == self.append_version {
                    return Some(p.clone());
                }
                // grown by appends: extend in place when we hold the
                // only reference (draw boundaries do); otherwise fall
                // through to a full rebuild
                if let Some(pm) = Rc::get_mut(p) {
                    if crate::trace::partition::extend_partition(self, pm) {
                        return Some(p.clone());
                    }
                }
            }
        }
        let p = Rc::new(crate::trace::partition::build_partition(self, v)?);
        self.partition_cache.borrow_mut().insert(v, p.clone());
        Some(p)
    }

    /// Cached replayable plan for the local section rooted at border
    /// child `root` of partition `p`.  Stale plans (any structural
    /// change since lowering) are rebuilt, never reused; value-only
    /// changes keep plans valid because plans store value *sources*,
    /// not values.  Errors propagate for section shapes the planned
    /// path does not support (callers fall back to the interpreter).
    pub fn cached_section_plan(
        &self,
        p: &crate::trace::partition::Partition,
        root: NodeId,
    ) -> Result<Rc<crate::trace::plan::SectionPlan>, String> {
        let key = (p.v, root);
        if let Some(pl) = self.plan_cache.borrow().get(&key) {
            if pl.built_at == self.structure_version {
                return Ok(pl.clone());
            }
        }
        let pl = Rc::new(crate::trace::plan::lower_section(self, p, root)?);
        self.plan_cache.borrow_mut().insert(key, pl.clone());
        Ok(pl)
    }

    /// Cached shape-keyed batch plans for partition `p` (trace/batch.rs):
    /// every local section grouped by structural shape, each group
    /// carrying one f64 column program plus per-section slot tables.
    /// Built eagerly over the whole partition on first use and rebuilt —
    /// not patched — whenever the trace structure has changed since, the
    /// same discipline as `cached_partition`/`cached_section_plan`
    /// (value-only changes keep sets valid: slot tables store where to
    /// read values, never values).  Append-mode growth is the one
    /// sanctioned patch: new border children join existing shape groups
    /// (or found new ones at the end) without touching any existing
    /// member's indices — see `trace/batch.rs::extend_batch_plans`.
    ///
    /// `p` must be current (obtained from
    /// [`cached_partition`](Self::cached_partition) this draw), so its
    /// locals already cover the appended suffix.
    pub fn cached_batch_plans(
        &self,
        p: &crate::trace::partition::Partition,
    ) -> Rc<crate::trace::batch::BatchPlanSet> {
        if let Some(s) = self.batch_cache.borrow_mut().get_mut(&p.v) {
            if s.built_at == self.structure_version {
                if s.appended_at == self.append_version {
                    return s.clone();
                }
                if let Some(sm) = Rc::get_mut(s) {
                    crate::trace::batch::extend_batch_plans(self, p, sm);
                    return s.clone();
                }
            }
        }
        let s = Rc::new(crate::trace::batch::build_batch_plans(self, p));
        self.batch_cache.borrow_mut().insert(p.v, s.clone());
        s
    }

    /// Cached persistent column store for partition `p`, aligned
    /// group-for-group with `set` (the *current* cached batch-plan set —
    /// callers obtain it from [`cached_batch_plans`](Self::cached_batch_plans)
    /// first, which guarantees `set.built_at == structure_version`).
    /// Returns `(store, freshly_built)`; a fresh build allocates the
    /// full-width panels with every member row stale, so rows fill
    /// lazily as members are sampled (see `trace/colstore.rs`).
    ///
    /// A fresh build also sweeps the cache: stores whose layout
    /// predates the current structure *and* whose principal is not the
    /// one being rebuilt are evicted (counted in
    /// [`store_evictions`](Self::store_evictions)).  Such stores belong
    /// to principals abandoned by the structural change — on DPM runs
    /// with many short-lived clusters they would otherwise pin dead
    /// full-width panels for the life of the trace.  Stores still
    /// current (other principals rebuilt since the change) are kept.
    pub fn cached_colstore(
        &self,
        p: &crate::trace::partition::Partition,
        set: &crate::trace::batch::BatchPlanSet,
    ) -> (ColStoreHandle, bool) {
        debug_assert_eq!(set.built_at, self.structure_version);
        debug_assert_eq!(set.appended_at, self.append_version);
        if let Some(s) = self.colstore_cache.borrow().get(&p.v) {
            let mut sb = s.borrow_mut();
            if sb.built_at == self.structure_version {
                if sb.appended_at != self.append_version {
                    // grown by appends: extend panels in place — new
                    // member rows are born stale and fill on first
                    // gather, existing rows keep their stamps
                    sb.extend(set);
                }
                drop(sb);
                return (s.clone(), false);
            }
        }
        let mut cache = self.colstore_cache.borrow_mut();
        let before = cache.len();
        // the rebuilding principal's own stale entry is a replacement,
        // not an abandonment — exclude it from the eviction count
        cache.retain(|&k, s| k == p.v || s.borrow().built_at == self.structure_version);
        let swept = before - cache.len();
        if swept > 0 {
            self.store_evicted.set(self.store_evicted.get() + swept as u64);
        }
        let s = Rc::new(RefCell::new(crate::trace::colstore::ColumnStoreSet::new(set)));
        cache.insert(p.v, s.clone());
        (s, true)
    }

    /// Stores evicted from the column-store cache so far (see
    /// [`cached_colstore`](Self::cached_colstore)).
    pub fn store_evictions(&self) -> u64 {
        self.store_evicted.get()
    }

    /// Column stores currently cached (footprint observability: on
    /// cluster-churn workloads this must stay bounded by the number of
    /// live principals, not grow with churn — `tests` pin this).
    pub fn colstore_cache_len(&self) -> usize {
        self.colstore_cache.borrow().len()
    }

    // ---------------- arena ----------------

    pub fn node(&self, id: NodeId) -> &Node {
        let n = &self.nodes[id.idx()];
        debug_assert!(n.alive, "access to dead node {id:?}");
        n
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        let n = &mut self.nodes[id.idx()];
        debug_assert!(n.alive, "access to dead node {id:?}");
        n
    }

    pub fn num_live_nodes(&self) -> usize {
        self.nodes.len() - self.free.len()
    }

    /// Allocate a node and wire child edges into its dynamic parents.
    pub fn alloc(&mut self, node: Node) -> NodeId {
        let parents = node.dyn_parents();
        let id = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot as usize] = node;
                self.epochs[slot as usize] = self.epoch;
                NodeId(slot)
            }
            None => {
                self.nodes.push(node);
                self.epochs.push(self.epoch);
                NodeId((self.nodes.len() - 1) as u32)
            }
        };
        for p in parents {
            self.nodes[p.idx()].children.push(id);
        }
        self.bump_structural();
        id
    }

    /// Record a growing structural change: appends land on
    /// `append_version` (caches extend in place), everything else on
    /// `structure_version` (caches rebuild wholesale).  Shrinking
    /// changes (`free_slot`, edge removal) never come through here —
    /// they bump `structure_version` unconditionally, which makes a
    /// mid-append re-key or purge auto-degrade to a full rebuild.
    #[inline]
    fn bump_structural(&mut self) {
        if self.appending {
            self.append_version += 1;
        } else {
            self.structure_version += 1;
        }
    }

    /// Free a node slot.  Caller is responsible for having removed child
    /// edges / aux incorporation first (see regen::unevaluate).
    pub(crate) fn free_slot(&mut self, id: NodeId) {
        let n = &mut self.nodes[id.idx()];
        debug_assert!(n.alive, "double free of {id:?}");
        n.alive = false;
        n.children.clear();
        n.args.clear();
        n.value = Value::Bool(false);
        self.free.push(id.0);
        self.structure_version += 1;
    }

    /// Child-edge rewiring is structural: a mem re-key between two
    /// *existing* cache entries (or a branch swap between node-backed
    /// branches) changes border children without allocating or freeing
    /// a node, so these must bump `structure_version` themselves or the
    /// partition/plan caches would serve stale children lists.
    pub(crate) fn add_child_edge(&mut self, parent: NodeId, child: NodeId) {
        self.nodes[parent.idx()].children.push(child);
        self.bump_structural();
    }

    pub(crate) fn remove_child_edge(&mut self, parent: NodeId, child: NodeId) {
        let ch = &mut self.nodes[parent.idx()].children;
        if let Some(pos) = ch.iter().rposition(|&c| c == child) {
            ch.swap_remove(pos);
        }
        self.structure_version += 1;
    }

    /// Order-preserving edge removal for observation retirement:
    /// surviving children keep arrival order, so a rebuilt partition
    /// lists border children oldest-first and subsequent appends keep
    /// extending caches in place.
    pub(crate) fn remove_child_edge_ordered(&mut self, parent: NodeId, child: NodeId) {
        let ch = &mut self.nodes[parent.idx()].children;
        if let Some(pos) = ch.iter().position(|&c| c == child) {
            ch.remove(pos);
        }
        self.structure_version += 1;
    }

    // ---------------- SP / mem tables ----------------

    pub fn push_sp(&mut self, sp: SpState) -> SpId {
        self.sps.push(sp);
        SpId((self.sps.len() - 1) as u32)
    }

    pub fn sp(&self, id: SpId) -> &SpState {
        &self.sps[id.0 as usize]
    }

    pub fn sp_mut(&mut self, id: SpId) -> &mut SpState {
        &mut self.sps[id.0 as usize]
    }

    pub fn push_mem(&mut self, closure: Rc<Closure>) -> MemId {
        self.mems.push(MemState {
            closure,
            cache: HashMap::new(),
        });
        MemId((self.mems.len() - 1) as u32)
    }

    pub fn mem(&self, id: MemId) -> &MemState {
        &self.mems[id.0 as usize]
    }

    pub fn mem_mut(&mut self, id: MemId) -> &mut MemState {
        &mut self.mems[id.0 as usize]
    }

    /// The SP instance a stochastic node currently scores against, if it
    /// is an instance application.
    pub fn stoch_sp(&self, id: NodeId) -> Option<SpId> {
        match &self.node(id).kind {
            NodeKind::StochDyn { op } => match &self.node(*op).value {
                Value::Sp(sp) => Some(*sp),
                v => panic!("StochDyn operator is {} not an SP", v.type_name()),
            },
            NodeKind::StochInst { sp } => Some(*sp),
            _ => None,
        }
    }

    /// Whether a stochastic node is exchangeably coupled (instance SP).
    pub fn is_exchangeable(&self, id: NodeId) -> bool {
        matches!(
            self.node(id).kind,
            NodeKind::StochDyn { .. } | NodeKind::StochInst { .. }
        )
    }

    // ---------------- values ----------------

    pub fn value(&self, id: NodeId) -> &Value {
        &self.node(id).value
    }

    pub fn arg_value<'a>(&'a self, a: &'a ArgRef) -> &'a Value {
        match a {
            ArgRef::Const(v) => v,
            ArgRef::Node(id) => self.value(*id),
        }
    }

    pub fn arg_values(&self, args: &[ArgRef]) -> Vec<Value> {
        args.iter().map(|a| self.arg_value(a).clone()).collect()
    }

    pub fn result_value(&self, r: &EvalResult) -> Value {
        match r {
            EvalResult::Static(v) => v.clone(),
            EvalResult::Node(id) => self.value(*id).clone(),
        }
    }

    /// Set a node's value directly and stamp it fresh.  This is the
    /// committed-value write path (commits, rollbacks, observation
    /// rewrites), so it bumps `value_version` — the column store's
    /// per-member staleness key.  Lazy recomputation (`freshen`) writes
    /// values directly instead: it cannot change a value unless some
    /// committed input already moved through here.
    pub fn set_value(&mut self, id: NodeId, v: Value) {
        self.nodes[id.idx()].value = v;
        self.epochs[id.idx()] = self.epoch;
        self.value_version += 1;
    }

    /// Re-stamp a node as fresh under the current epoch without cloning
    /// or replacing its value (commit_global re-marks the global section
    /// after an epoch bump; the values were just written).
    pub fn touch(&mut self, id: NodeId) {
        self.epochs[id.idx()] = self.epoch;
    }

    // ---------------- staleness (§3.5) ----------------

    /// Invalidate every deterministic node's cached value; they will be
    /// recomputed lazily on first access.  Called after an accepted
    /// subsampled transition, whose unvisited local sections are stale.
    pub fn bump_epoch(&mut self) {
        self.epoch += 1;
    }

    pub fn is_fresh(&self, id: NodeId) -> bool {
        self.epochs[id.idx()] == self.epoch
    }

    /// Value with lazy recomputation: deterministic nodes stale since the
    /// last epoch bump are recomputed from (recursively freshened)
    /// parents.  Stochastic nodes are never stale — their values are
    /// samples, not functions.
    pub fn fresh_value(&mut self, id: NodeId) -> Value {
        if self.epochs[id.idx()] == self.epoch {
            return self.node(id).value.clone();
        }
        self.freshen(id);
        self.node(id).value.clone()
    }

    /// Freshen a node (and, recursively, its parents) without cloning
    /// its value — the no-copy variant of `fresh_value` for callers that
    /// only need the committed value to be current in the trace.
    #[inline]
    pub fn ensure_fresh(&mut self, id: NodeId) {
        if self.epochs[id.idx()] != self.epoch {
            self.freshen(id);
        }
    }

    fn freshen(&mut self, id: NodeId) {
        if self.epochs[id.idx()] == self.epoch {
            return;
        }
        // mark first to cut cycles (there are none in a DAG, but keeps
        // repeated visits O(1))
        self.epochs[id.idx()] = self.epoch;
        if self.node(id).is_stochastic() {
            return;
        }
        // freshen dynamic parents, then recompute
        for p in self.node(id).dyn_parents() {
            self.freshen(p);
        }
        let new_val = self.compute_det_value(id);
        if let Some(v) = new_val {
            self.nodes[id.idx()].value = v;
        }
    }

    /// Pure recomputation of a deterministic node's value from current
    /// parent values.  Returns None for kinds whose value cannot change
    /// without a structural transition (Maker) — those keep their value.
    /// Panics if a lazy recompute would require a structural change
    /// (stale If branch flip / MemApp re-key), which subsampled
    /// transitions are prohibited from introducing (paper §3.1).
    pub fn compute_det_value(&self, id: NodeId) -> Option<Value> {
        let node = self.node(id);
        match &node.kind {
            NodeKind::Det(prim) => {
                let args = self.arg_values(&node.args);
                Some(prim.apply(&args).unwrap_or_else(|e| {
                    panic!("recompute of {prim:?} failed: {e}")
                }))
            }
            NodeKind::MemApp { key, target, .. } => {
                let new_key = KeyVec(self.arg_values(&node.args));
                assert!(
                    new_key == *key,
                    "lazy recompute changed a mem key (structural change)"
                );
                Some(self.result_value(target))
            }
            NodeKind::If {
                take_conseq,
                branch,
                ..
            } => {
                let pred = self
                    .arg_value(&node.args[0])
                    .as_bool()
                    .expect("if predicate must be bool");
                assert_eq!(
                    pred, *take_conseq,
                    "lazy recompute flipped an if branch (structural change)"
                );
                Some(self.result_value(branch))
            }
            NodeKind::Inner { inner } => Some(self.value(*inner).clone()),
            NodeKind::Maker { .. } => None,
            NodeKind::StochFam(_) | NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => None,
        }
    }

    /// Eagerly recompute deterministic descendants of `id` (used after
    /// constraining an observation at construction time).
    pub fn propagate_det(&mut self, id: NodeId) {
        let children = self.node(id).children.clone();
        for c in children {
            if self.node(c).is_deterministic() {
                if let Some(v) = self.compute_det_value(c) {
                    self.set_value(c, v);
                }
                self.propagate_det(c);
            }
        }
    }

    // ---------------- scoring ----------------

    /// Log density of a stochastic node's current value given its current
    /// (fresh) argument values.  For exchangeable nodes this is the
    /// predictive *with the node's own value still incorporated* — use
    /// the detach/regen discipline (regen.rs) for correct ratios.
    pub fn logpdf_current(&mut self, id: NodeId) -> f64 {
        for p in self.node(id).dyn_parents() {
            self.freshen(p);
        }
        let node = self.node(id);
        match &node.kind {
            NodeKind::StochFam(f) => {
                let args = self.arg_values(&node.args);
                f.logpdf(&node.value, &args)
            }
            NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
                let sp = self.stoch_sp(id).unwrap();
                let args = self.arg_values(&self.node(id).args);
                self.sp(sp).logpdf(&self.node(id).value, &args)
            }
            k => panic!("logpdf of non-stochastic node {k:?}"),
        }
    }

    /// Joint log density of the trace (Eq. 1).  Exchangeable families are
    /// scored by rebuilding their predictive chain in node-id order,
    /// which equals the joint by exchangeability.
    pub fn log_joint(&mut self) -> f64 {
        let ids: Vec<NodeId> = (0..self.nodes.len() as u32)
            .map(NodeId)
            .filter(|id| self.nodes[id.idx()].alive)
            .collect();
        for &id in &ids {
            self.freshen(id);
        }
        // rebuild aux chains
        let mut temp_sps: HashMap<SpId, SpState> = HashMap::new();
        let mut total = 0.0;
        for &id in &ids {
            let node = &self.nodes[id.idx()];
            match &node.kind {
                NodeKind::StochFam(f) => {
                    let args = self.arg_values(&node.args);
                    total += f.logpdf(&node.value, &args);
                }
                NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
                    let sp_id = self.stoch_sp(id).unwrap();
                    let fresh = temp_sps.entry(sp_id).or_insert_with(|| {
                        // clone hyperparams, reset aux by unmaking
                        let mut clone = self.sps[sp_id.0 as usize].clone();
                        reset_aux(&mut clone);
                        clone
                    });
                    let node = &self.nodes[id.idx()];
                    let args = node
                        .args
                        .iter()
                        .map(|a| match a {
                            ArgRef::Const(v) => v.clone(),
                            ArgRef::Node(n) => self.nodes[n.idx()].value.clone(),
                        })
                        .collect::<Vec<_>>();
                    total += fresh.logpdf(&node.value, &args);
                    fresh.incorporate(&node.value);
                }
                _ => {}
            }
        }
        total
    }

    // ---------------- scopes ----------------

    pub fn register_scope(&mut self, scope: Rc<str>, block: Value, node: NodeId) {
        self.scopes
            .entry(scope.clone())
            .or_default()
            .register(block.clone(), node);
        self.node_scope.insert(node, (scope, block));
    }

    pub(crate) fn deregister_scope(&mut self, node: NodeId) -> Option<(Rc<str>, Value)> {
        if let Some((scope, block)) = self.node_scope.remove(&node) {
            if let Some(s) = self.scopes.get_mut(&scope) {
                s.deregister(&block, node);
            }
            Some((scope, block))
        } else {
            None
        }
    }

    pub fn scope(&self, name: &str) -> Option<&Scope> {
        self.scopes.get(name)
    }

    /// All principal nodes in a scope, across blocks.
    pub fn scope_nodes(&self, name: &str) -> Vec<NodeId> {
        self.scopes
            .get(name)
            .map(|s| s.blocks.iter().flat_map(|(_, ns)| ns.iter().copied()).collect())
            .unwrap_or_default()
    }

    // ---------------- directives ----------------

    /// Execute one directive (delegates to the evaluator).
    pub fn execute(&mut self, d: &Directive, rng: &mut Pcg64) -> Result<EvalResult, String> {
        crate::trace::eval::execute_directive(self, d, rng)
    }

    /// Parse and execute a whole program.
    pub fn run_program(&mut self, src: &str, rng: &mut Pcg64) -> Result<(), String> {
        let prog = crate::ppl::parser::parse_program(src)?;
        for d in &prog {
            self.execute(d, rng)?;
        }
        Ok(())
    }

    // ---------------- streaming appends / retirement ----------------

    /// Execute one directive in *append mode*: node allocations and
    /// child-edge additions bump `append_version` instead of
    /// `structure_version`, so structure-keyed caches extend in place
    /// (O(|append|)) instead of rebuilding (O(N)) on next use.  The
    /// trace produced is identical to executing the directive through
    /// [`execute`](Self::execute) — only the version bookkeeping (and
    /// therefore cache reuse) differs, which is what the
    /// append-vs-fresh-build differential tests pin bitwise.
    ///
    /// Shrinking mutations reached from inside the directive (a mem
    /// re-key releasing its last route, a branch swap) still bump
    /// `structure_version`, auto-degrading that append to a full
    /// rebuild; correctness is unaffected.
    pub fn append_directive(&mut self, d: &Directive, rng: &mut Pcg64) -> Result<EvalResult, String> {
        self.appending = true;
        let r = crate::trace::eval::execute_directive(self, d, rng);
        self.appending = false;
        r
    }

    /// Parse and execute a whole program in append mode (see
    /// [`append_directive`](Self::append_directive)).
    pub fn append_program(&mut self, src: &str, rng: &mut Pcg64) -> Result<(), String> {
        let prog = crate::ppl::parser::parse_program(src)?;
        for d in &prog {
            self.append_directive(d, rng)?;
        }
        Ok(())
    }

    /// Retire the `k` oldest observations — the append machinery run in
    /// reverse, for windowed/decaying streaming workloads.  Each
    /// retired observe directive's owned nodes are disconnected with
    /// the same discipline as a structural transition (SP
    /// unincorporation, mem-route release, scope deregistration) and
    /// freed; edges into retained parents are removed
    /// order-preservingly so surviving border children keep arrival
    /// order.  Latent state shared with retained structure (memoized
    /// SV states referenced by successor states) stays allocated —
    /// only nodes owned exclusively by the retired directives go.
    ///
    /// Retirement is a *batched structural* change: it bumps
    /// `structure_version`, so every structure-keyed cache rebuilds
    /// wholesale on next use.  Windowed workloads retire in batches
    /// and amortize that rebuild; appends stay O(|append|).
    ///
    /// Returns the number of observations actually retired (fewer than
    /// `k` when the trace holds fewer observe records).
    pub fn retire_observations(&mut self, k: usize) -> Result<usize, String> {
        let mut retired = 0;
        let mut i = 0;
        while retired < k && i < self.records.len() {
            if matches!(self.records[i].directive, Directive::Observe(..)) {
                let rec = self.records.remove(i);
                self.retire_record(&rec)?;
                retired += 1;
            } else {
                i += 1;
            }
        }
        if retired > 0 {
            self.structure_version += 1;
        }
        Ok(retired)
    }

    fn retire_record(&mut self, rec: &DirectiveRecord) -> Result<(), String> {
        let target = self.principal_node(&rec.result);
        if let Some(t) = target {
            self.observations.retain(|&o| o != t);
        }
        self.retire_owned(&rec.owned)?;
        // a target owned by a surviving mem entry outlives the record:
        // it reverts to an unobserved latent pinned at the observed
        // value (still incorporated, the unobserved-exchangeable norm)
        if let Some(t) = target {
            if self.nodes[t.idx()].alive {
                self.nodes[t.idx()].observed = false;
            }
        }
        Ok(())
    }

    /// Free an owned subtree immediately, reverse creation order
    /// (children before parents, mirroring rollback's NodeCreated
    /// discipline — no retained node still points at a slot when it is
    /// freed).
    fn retire_owned(&mut self, owned: &[NodeId]) -> Result<(), String> {
        for &id in owned.iter().rev() {
            if !self.nodes[id.idx()].alive {
                continue; // already freed via a purged mem entry
            }
            match self.nodes[id.idx()].kind.clone() {
                NodeKind::If { owned: inner, .. } => {
                    self.retire_owned(&inner)?;
                }
                NodeKind::MemApp { mem, key, .. } => {
                    let entry = self
                        .mems[mem.0 as usize]
                        .cache
                        .get_mut(&key)
                        .ok_or("retire: mem route missing from cache")?;
                    entry.refcount -= 1;
                    if entry.refcount == 0 {
                        let entry = self.mems[mem.0 as usize].cache.remove(&key).unwrap();
                        self.retire_owned(&entry.owned)?;
                    }
                }
                NodeKind::StochFam(_)
                | NodeKind::StochDyn { .. }
                | NodeKind::StochInst { .. } => {
                    if let Some(sp) = self.stoch_sp(id) {
                        let value = self.nodes[id.idx()].value.clone();
                        self.sp_mut(sp).unincorporate(&value);
                    }
                }
                _ => {}
            }
            for p in self.nodes[id.idx()].dyn_parents() {
                if self.nodes[p.idx()].alive {
                    self.remove_child_edge_ordered(p, id);
                }
            }
            self.deregister_scope(id);
            if !self.nodes[id.idx()].children.is_empty() {
                return Err(format!(
                    "retire: node {id:?} still referenced by retained structure"
                ));
            }
            self.free_slot(id);
        }
        Ok(())
    }

    /// Value bound to an assumed name (freshened).
    pub fn lookup_value(&mut self, name: &str) -> Option<Value> {
        match self.global_env.lookup(name)? {
            Binding::Static(v) => Some(v),
            Binding::Node(id) => Some(self.fresh_value(id)),
        }
    }

    /// Node bound to an assumed name (if node-backed).
    pub fn lookup_node(&self, name: &str) -> Option<NodeId> {
        match self.global_env.lookup(name)? {
            Binding::Node(id) => Some(id),
            Binding::Static(_) => None,
        }
    }

    pub fn observations(&self) -> &[NodeId] {
        &self.observations
    }

    /// Follow the value-source chain down to the stochastic node that
    /// ultimately produced a result (for observe / scope registration).
    pub fn principal_node(&self, r: &EvalResult) -> Option<NodeId> {
        let mut id = r.node()?;
        loop {
            match &self.node(id).kind {
                NodeKind::StochFam(_)
                | NodeKind::StochDyn { .. }
                | NodeKind::StochInst { .. } => return Some(id),
                NodeKind::Inner { inner } => id = *inner,
                NodeKind::MemApp { target, .. } => match target {
                    EvalResult::Node(t) => id = *t,
                    EvalResult::Static(_) => return None,
                },
                NodeKind::If { branch, .. } => match branch {
                    EvalResult::Node(b) => id = *b,
                    EvalResult::Static(_) => return None,
                },
                NodeKind::Det(_) | NodeKind::Maker { .. } => return None,
            }
        }
    }

    /// Constrain the stochastic source of `r` to the observed value.
    pub fn constrain(&mut self, r: &EvalResult, obs: Value) -> Result<NodeId, String> {
        let target = self
            .principal_node(r)
            .ok_or("observe: expression has no stochastic source")?;
        if self.node(target).observed {
            return Err("observe: node already observed".into());
        }
        // exchangeable values move between aux states
        if let Some(sp) = self.stoch_sp(target) {
            let old = self.node(target).value.clone();
            self.sp_mut(sp).unincorporate(&old);
            self.sp_mut(sp).incorporate(&obs);
        }
        self.node_mut(target).observed = true;
        self.set_value(target, obs.clone());
        // propagate through the passthrough chain up to r and any det children
        let mut id = r.node();
        while let Some(cur) = id {
            if cur == target {
                break;
            }
            self.set_value(cur, obs.clone());
            id = match &self.node(cur).kind {
                NodeKind::Inner { inner } => Some(*inner),
                NodeKind::MemApp { target: t, .. } => t.node(),
                NodeKind::If { branch, .. } => branch.node(),
                _ => None,
            };
        }
        self.propagate_det(target);
        self.observations.push(target);
        Ok(target)
    }

    // ---------------- checkpoint support ----------------

    /// Snapshot every unobserved stochastic node's committed value, in
    /// node-id order.  Given a fixed structure this is the chain's
    /// entire mutable trace state: observed values are pinned by the
    /// program and deterministic nodes are functions of these.  The
    /// checkpoint writer (`coordinator/checkpoint.rs`) serializes this
    /// together with the RNG stream position.
    pub fn stoch_state(&self) -> Vec<(u32, Value)> {
        (0..self.nodes.len() as u32)
            .map(NodeId)
            .filter(|id| {
                let n = &self.nodes[id.idx()];
                n.alive && n.is_stochastic() && !n.observed
            })
            .map(|id| (id.0, self.nodes[id.idx()].value.clone()))
            .collect()
    }

    /// Restore a [`Trace::stoch_state`] snapshot onto a structurally
    /// identical trace (the same program replayed from source produces
    /// the same node ids regardless of what the RNG sampled).
    /// Exchangeable values move between aux states with the same
    /// unincorporate/incorporate discipline as `constrain`;
    /// bitwise-equal values are skipped outright so aux sufficient
    /// statistics are not perturbed by a remove/re-add round trip
    /// (floating-point sums are not exactly reversible).  Ends with an
    /// epoch bump: deterministic nodes refreshen lazily from the
    /// restored values.
    pub fn restore_stoch_state(&mut self, state: &[(u32, Value)]) -> Result<(), String> {
        for &(raw, ref v) in state {
            let idx = raw as usize;
            if idx >= self.nodes.len() || !self.nodes[idx].alive {
                return Err(format!(
                    "checkpoint: node {raw} does not exist in the rebuilt trace \
                     (structure changed since the checkpoint was taken?)"
                ));
            }
            let n = &self.nodes[idx];
            if !n.is_stochastic() || n.observed {
                return Err(format!(
                    "checkpoint: node {raw} is not an unobserved stochastic node"
                ));
            }
            if value_bits_eq(&n.value, v) {
                continue;
            }
            let id = NodeId(raw);
            if let Some(sp) = self.stoch_sp(id) {
                let old = self.nodes[idx].value.clone();
                self.sp_mut(sp).unincorporate(&old);
                self.sp_mut(sp).incorporate(v);
            }
            self.set_value(id, v.clone());
        }
        self.bump_epoch();
        Ok(())
    }
}

/// Bitwise value equality (f64 compared by bit pattern, so NaN == NaN
/// and 0.0 != -0.0): the restore path must not churn SP aux state for
/// values that are already in place.
fn value_bits_eq(a: &Value, b: &Value) -> bool {
    match (a, b) {
        (Value::Bool(x), Value::Bool(y)) => x == y,
        (Value::Int(x), Value::Int(y)) => x == y,
        (Value::Real(x), Value::Real(y)) => x.to_bits() == y.to_bits(),
        (Value::Vector(x), Value::Vector(y)) => {
            x.len() == y.len() && x.iter().zip(y.iter()).all(|(p, q)| p.to_bits() == q.to_bits())
        }
        _ => false,
    }
}

/// Reset an SP instance's aux to empty (for log_joint's rebuild).
fn reset_aux(sp: &mut SpState) {
    match sp {
        SpState::Crp { aux, .. } => *aux = crate::dist::CrpAux::new(),
        SpState::CollapsedMvn { niw } => {
            *niw = crate::dist::CollapsedNiw::new(
                niw.m0.clone(),
                niw.k0,
                niw.v0,
                niw.s0.clone(),
            )
        }
    }
}
