//! Global/local partition of a scaffold (paper §3.1, Defs. 6–8) and
//! non-destructive override scoring.
//!
//! Subsampled transitions never detach local sections: each sampled
//! section's contribution l_i (Eq. 6) is computed by *override
//! evaluation* — recomputing the section's deterministic nodes against a
//! candidate value of the global section without mutating the trace.
//! Committing an accepted proposal writes only the global section and
//! bumps the staleness epoch; unvisited sections are refreshed lazily
//! (§3.5).

use crate::trace::node::{ArgRef, NodeId, NodeKind};
use crate::trace::pet::Trace;
use crate::trace::scaffold::{build_scaffold, find_border};
use crate::ppl::value::Value;
use std::collections::HashMap;

/// The partitioned scaffold of a global variable.
#[derive(Clone, Debug)]
pub struct Partition {
    pub v: NodeId,
    /// Border node b(s, v) (Def. 6).
    pub border: NodeId,
    /// D ∩ global: the single-link path v..=border (topological order).
    pub global_drg: Vec<NodeId>,
    /// Children of the border: the roots of the N local sections.
    pub locals: Vec<NodeId>,
    /// structure_version at build time (for cache revalidation).
    pub built_at: u64,
    /// append_version as of the last build/extension: when `built_at`
    /// is current but this lags, the trace grew by append-mode
    /// directives and the partition extends in place
    /// ([`extend_partition`]).
    pub appended_at: u64,
}

impl Partition {
    pub fn n(&self) -> usize {
        self.locals.len()
    }
}

/// One local section (Def. 8), discovered lazily from a border child.
#[derive(Clone, Debug, Default)]
pub struct Section {
    /// Deterministic members (D ∩ local_i), topological order.
    pub dets: Vec<NodeId>,
    /// Absorbing members (A ∩ local_i).
    pub absorbing: Vec<NodeId>,
}

/// Build the partition for `v`, or None if its scaffold has no border
/// (fewer than 2 dependents) — callers fall back to exact MH.
pub fn build_partition(trace: &Trace, v: NodeId) -> Option<Partition> {
    let scaffold = build_scaffold(trace, v);
    let border = find_border(trace, &scaffold)?;
    // global D = path v -> border (all deterministic but v)
    let mut global_drg = vec![v];
    let mut cur = v;
    while cur != border {
        let kids: Vec<NodeId> = trace.node(cur).children.clone();
        debug_assert_eq!(kids.len(), 1, "pre-border path must be a single link");
        cur = kids[0];
        global_drg.push(cur);
    }
    let locals = trace.node(border).children.clone();
    Some(Partition {
        v,
        border,
        global_drg,
        locals,
        built_at: trace.structure_version,
        appended_at: trace.append_version,
    })
}

/// Extend a cached partition in place after append-only growth
/// (`built_at` current, `appended_at` behind): verify the pre-border
/// path is still a single link (O(|global path|), guards against an
/// append that grew the global section itself), then adopt the
/// border's new children.  Appends only ever *push* onto children
/// lists — any removal bumps `structure_version` and disqualifies the
/// partition before this runs — so the cached locals are necessarily a
/// prefix of the current list and only the suffix is cloned:
/// O(|append|), independent of N.  Returns false when the partition
/// cannot be extended (caller falls back to a full rebuild).
pub fn extend_partition(trace: &Trace, p: &mut Partition) -> bool {
    debug_assert_eq!(p.built_at, trace.structure_version);
    for (i, &n) in p.global_drg.iter().enumerate() {
        if n == p.border {
            break;
        }
        let kids = &trace.node(n).children;
        if kids.len() != 1 || kids[0] != p.global_drg[i + 1] {
            return false;
        }
    }
    let cur = &trace.node(p.border).children;
    if cur.len() < p.locals.len() {
        return false;
    }
    debug_assert!(
        p.locals.iter().zip(cur.iter()).all(|(a, b)| a == b),
        "append-only growth must preserve the locals prefix"
    );
    p.locals.extend_from_slice(&cur[p.locals.len()..]);
    p.appended_at = trace.append_version;
    true
}

/// Discover the local section rooted at border child `root`.
pub fn discover_section(trace: &Trace, root: NodeId) -> Section {
    let mut sec = Section::default();
    let mut stack = vec![root];
    while let Some(n) = stack.pop() {
        if trace.node(n).is_stochastic() {
            sec.absorbing.push(n);
        } else {
            sec.dets.push(n);
            for &c in &trace.node(n).children {
                stack.push(c);
            }
        }
    }
    // dets discovered root-first is already parent-before-child for the
    // single-chain sections our models produce; general DAGs are small
    // enough to sort by a second pass if ever needed.
    sec
}

/// Non-destructive override evaluation context.
///
/// `overrides` pins candidate values for nodes (the proposed global
/// section); `candidate_value` computes what any node's value *would be*
/// under those pins, recursing through deterministic parents and memoizing.
pub struct OverrideCtx<'t> {
    pub trace: &'t Trace,
    overrides: HashMap<NodeId, Value>,
    memo: HashMap<NodeId, Value>,
}

impl<'t> OverrideCtx<'t> {
    pub fn new(trace: &'t Trace) -> Self {
        OverrideCtx {
            trace,
            overrides: HashMap::new(),
            memo: HashMap::new(),
        }
    }

    pub fn pin(&mut self, node: NodeId, value: Value) {
        self.overrides.insert(node, value);
        self.memo.clear();
    }

    /// Value of `id` under the pins (committed values elsewhere).
    /// The caller must have freshened the relevant region (lazy §3.5
    /// updates) before constructing the ctx.
    pub fn candidate_value(&mut self, id: NodeId) -> Value {
        if let Some(v) = self.overrides.get(&id) {
            return v.clone();
        }
        if let Some(v) = self.memo.get(&id) {
            return v.clone();
        }
        let node = self.trace.node(id);
        let v = if node.is_stochastic() {
            node.value.clone()
        } else {
            // recompute iff some ancestor is pinned; otherwise committed
            // value is already correct
            if !self.any_pinned_ancestor(id) {
                node.value.clone()
            } else {
                match &node.kind {
                    NodeKind::Det(prim) => {
                        let args: Vec<Value> =
                            node.args.iter().map(|a| self.arg_candidate(a)).collect();
                        prim.apply(&args).expect("override recompute failed")
                    }
                    NodeKind::MemApp { target, .. } => match target {
                        crate::trace::node::EvalResult::Node(t) => self.candidate_value(*t),
                        crate::trace::node::EvalResult::Static(v) => v.clone(),
                    },
                    NodeKind::If { branch, .. } => match branch {
                        crate::trace::node::EvalResult::Node(b) => self.candidate_value(*b),
                        crate::trace::node::EvalResult::Static(v) => v.clone(),
                    },
                    NodeKind::Inner { inner } => self.candidate_value(*inner),
                    NodeKind::Maker { .. } => node.value.clone(),
                    _ => unreachable!(),
                }
            }
        };
        self.memo.insert(id, v.clone());
        v
    }

    pub fn arg_candidate(&mut self, a: &ArgRef) -> Value {
        match a {
            ArgRef::Const(v) => v.clone(),
            ArgRef::Node(id) => self.candidate_value(*id),
        }
    }

    fn any_pinned_ancestor(&mut self, id: NodeId) -> bool {
        // cheap DFS; sections are tiny.  memoized values imply resolved.
        if self.overrides.contains_key(&id) {
            return true;
        }
        self.trace.node(id).dyn_parents().iter().any(|&p| {
            self.overrides.contains_key(&p)
                || (!self.trace.node(p).is_stochastic() && self.any_pinned_ancestor(p))
        })
    }

    /// log p(value(n) | candidate parent values) for a stochastic node.
    pub fn logpdf_candidate(&mut self, n: NodeId) -> f64 {
        let node = self.trace.node(n);
        let value = node.value.clone();
        let args: Vec<Value> = node.args.iter().map(|a| self.arg_candidate(a)).collect();
        match &node.kind {
            NodeKind::StochFam(f) => f.logpdf(&value, &args),
            NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
                let sp = self.trace.stoch_sp(n).expect("instance sp");
                self.trace.sp(sp).logpdf(&value, &args)
            }
            k => panic!("logpdf_candidate on {k:?}"),
        }
    }

    /// log p(value(n) | committed parent values).
    pub fn logpdf_committed(&self, n: NodeId) -> f64 {
        let node = self.trace.node(n);
        let args: Vec<Value> = node
            .args
            .iter()
            .map(|a| self.trace.arg_value(a).clone())
            .collect();
        match &node.kind {
            NodeKind::StochFam(f) => f.logpdf(&node.value, &args),
            NodeKind::StochDyn { .. } | NodeKind::StochInst { .. } => {
                let sp = self.trace.stoch_sp(n).expect("instance sp");
                self.trace.sp(sp).logpdf(&node.value, &args)
            }
            k => panic!("logpdf_committed on {k:?}"),
        }
    }

    /// l_i for a local section: sum over its absorbing nodes of
    /// log p(x | new global) - log p(x | old global).
    ///
    /// Exchangeable absorbing nodes are rejected: a subsampled transition
    /// cannot maintain their sufficient statistics consistently (the
    /// paper's experiments never require this — logistic and Gaussian
    /// sections only).
    pub fn section_ratio(&mut self, sec: &Section) -> f64 {
        let mut l = 0.0;
        for &a in &sec.absorbing {
            assert!(
                self.trace.stoch_sp(a).is_none(),
                "subsampled transitions over exchangeable local sections are unsupported"
            );
            l += self.logpdf_candidate(a) - self.logpdf_committed(a);
        }
        l
    }
}

/// Freshen everything a partition's global section + a set of local
/// sections read (call before constructing an OverrideCtx).
pub fn freshen_partition(trace: &mut Trace, p: &Partition) {
    for &n in &p.global_drg {
        for q in trace.node(n).dyn_parents() {
            trace.fresh_value(q);
        }
        trace.fresh_value(n);
    }
}

/// Commit an accepted subsampled proposal: write the global section's
/// new values, then bump the epoch so unvisited local sections are
/// refreshed lazily on next touch (§3.5, Fig. 2d).
pub fn commit_global(trace: &mut Trace, p: &Partition, new_principal: Value) {
    trace.set_value(p.v, new_principal);
    // recompute the (short) global path eagerly
    for &n in &p.global_drg[1..] {
        if let Some(v) = trace.compute_det_value(n) {
            trace.set_value(n, v);
        }
    }
    trace.bump_epoch();
    // re-stamp the global section as fresh under the new epoch — its
    // values were just written, so only the epoch stamp moves
    for &n in &p.global_drg {
        trace.touch(n);
    }
}

/// Validate a cached partition against the current trace structure.
pub fn partition_valid(trace: &Trace, p: &Partition) -> bool {
    p.built_at == trace.structure_version
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::Pcg64;

    fn lr_trace(n: usize, seed: u64) -> Trace {
        let mut src = String::from(
            "[assume w (scope_include 'w 0 (multivariate_normal (vector 0 0 0) 0.1))]\n\
             [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n",
        );
        let mut rng = Pcg64::seeded(seed ^ 0xabc);
        for _ in 0..n {
            let (a, b) = (rng.normal(), rng.normal());
            let lab = if rng.bernoulli(0.5) { "true" } else { "false" };
            src.push_str(&format!("[observe (f (vector {a} {b} 1.0)) {lab}]\n"));
        }
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(&src, &mut rng).unwrap();
        t
    }

    #[test]
    fn lr_partition_shape() {
        let t = lr_trace(20, 0);
        let w = t.lookup_node("w").unwrap();
        let p = build_partition(&t, w).unwrap();
        assert_eq!(p.border, w);
        assert_eq!(p.global_drg, vec![w]);
        assert_eq!(p.n(), 20);
        for &root in &p.locals {
            let sec = discover_section(&t, root);
            assert_eq!(sec.dets.len(), 1); // linlog
            assert_eq!(sec.absorbing.len(), 1); // bernoulli
        }
    }

    #[test]
    fn section_ratio_matches_manual_logistic() {
        let mut t = lr_trace(5, 1);
        let w = t.lookup_node("w").unwrap();
        let p = build_partition(&t, w).unwrap();
        freshen_partition(&mut t, &p);
        let w_old = t.value(w).as_vector().unwrap().as_ref().clone();
        let w_new: Vec<f64> = w_old.iter().map(|x| x + 0.3).collect();
        let mut ctx = OverrideCtx::new(&t);
        ctx.pin(w, Value::vector(w_new.clone()));
        for &root in &p.locals.clone() {
            let sec = discover_section(&t, root);
            let l = ctx.section_ratio(&sec);
            // manual: bernoulli(linear_logistic(w, x))
            let y_node = sec.absorbing[0];
            let lin = sec.dets[0];
            let x = match &t.node(lin).args[1] {
                ArgRef::Const(Value::Vector(v)) => v.clone(),
                a => panic!("{a:?}"),
            };
            let yv = t.node(y_node).value.as_bool().unwrap();
            let dot = |wv: &[f64]| -> f64 { wv.iter().zip(x.iter()).map(|(a, b)| a * b).sum() };
            let lp = |z: f64| crate::dist::bernoulli_logit_logpmf(yv, z);
            let want = lp(dot(&w_new)) - lp(dot(&w_old));
            assert!((l - want).abs() < 1e-9, "{l} vs {want}");
        }
    }

    #[test]
    fn commit_global_leaves_stale_then_lazy_refresh() {
        let mut t = lr_trace(8, 2);
        let w = t.lookup_node("w").unwrap();
        let p = build_partition(&t, w).unwrap();
        let w_new = Value::vector(vec![0.5, -0.5, 0.1]);
        commit_global(&mut t, &p, w_new.clone());
        // local linlog nodes are stale now
        let sec = discover_section(&t, p.locals[0]);
        let lin = sec.dets[0];
        assert!(!t.is_fresh(lin));
        // lazy refresh computes the value under the new w
        let v = t.fresh_value(lin).as_f64().unwrap();
        let x = match &t.node(lin).args[1] {
            ArgRef::Const(Value::Vector(v)) => v.clone(),
            a => panic!("{a:?}"),
        };
        let wv = w_new.as_vector().unwrap();
        let dot: f64 = wv.iter().zip(x.iter()).map(|(a, b)| a * b).sum();
        let want = 1.0 / (1.0 + (-dot).exp());
        assert!((v - want).abs() < 1e-12);
        assert!(t.is_fresh(lin));
    }

    #[test]
    fn sv_partition_for_sig_has_stoch_roots() {
        let src = r#"
            [assume sig (sqrt (inv_gamma 5 0.05))]
            [assume phi (beta 5 1)]
            [assume h (mem (lambda (t) (if (<= t 0) 0.0 (normal (* phi (h (- t 1))) sig))))]
            [assume x (lambda (t) (normal 0 (exp (/ (h t) 2))))]
            [observe (x 1) 0.1]
            [observe (x 2) -0.2]
            [observe (x 3) 0.05]
            [observe (x 4) 0.3]
        "#;
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(3);
        t.run_program(src, &mut rng).unwrap();
        // `sig` is the sqrt det node; the sampled variable is its
        // inv_gamma argument.  Border must be the sqrt node.
        let sqrt_node = t.lookup_node("sig").unwrap();
        let v = t.node(sqrt_node).args[0].node().unwrap();
        assert!(t.node(v).is_stochastic());
        let p = build_partition(&t, v).unwrap();
        assert_eq!(p.border, sqrt_node);
        assert_eq!(p.n(), 4);
        // local sections: each h_t is directly absorbing (size-1 section)
        for &root in &p.locals {
            let sec = discover_section(&t, root);
            assert_eq!(sec.dets.len(), 0);
            assert_eq!(sec.absorbing.len(), 1);
        }
    }
}
