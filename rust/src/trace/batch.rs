//! Shape-keyed batch plans: one op list scores a whole mini-batch of
//! same-shaped local sections through an f64 register file.
//!
//! # Why
//!
//! PR 1's [`SectionPlan`]s made each section cheap individually, but the
//! subsampled-MH inner loop still replayed them one at a time: one plan
//! lookup, one `Value`-typed arena pass, and one absorber dispatch per
//! section.  The paper's workloads score *hundreds of structurally
//! identical sections per mini-batch* (every LR observation lowers to
//! the same `linear_logistic` + `bernoulli` op pair; every SV step to
//! the same `mul` + `normal` pair) — exactly the "minibatch MH as a
//! vectorizable inner loop" framing of Angelino et al. (2016).  This
//! module groups sections by a structural [`ShapeKey`] and lowers each
//! group once into a [`BatchGroup`]: a single op list plus per-section
//! *slot tables* (constants, trace reads, absorber nodes).  Replay walks
//! the op list once, executing each op column-wise over all sampled
//! sections through a [`RegFile`] of plain `f64` registers — no `Value`
//! enum dispatch, no per-section hashing, and the memory access pattern
//! XLA kernels want (the slot tables are the kernel inputs; see
//! `coordinator/fused.rs`).
//!
//! # Bitwise-identity contract
//!
//! The columnar replay performs, for every section, the *same scalar
//! f64 operations in the same order* as `Prim::apply` and
//! `SpFamily::logpdf` do on the interpreter/`ScorerArena` path, so its
//! `l_i` values are bit-for-bit identical (enforced by the unit tests
//! here, `infer/planned.rs`, and `tests/differential.rs`).  Anything
//! that could break that contract is rejected at lowering or replay
//! time and falls back to the scalar per-section path:
//!
//! * non-f64 slots or bindings (`Value::Sp` committed reads,
//!   matrices/lists) — with one deliberate widening: int/bool operands
//!   *are* admitted, through coercing (`as_f64`) bindings, exactly at
//!   positions where `Prim::apply`/`SpFamily::logpdf` provably apply
//!   the same coercion (always-float prims, logpdf args, and
//!   `Add`/`Mul`/`Sub` with a guaranteed-`Real` sibling).  All-int
//!   `Add`/`Mul`/`Sub` still refuses — the interpreter's
//!   int-preserving branch could fire and diverge from a float
//!   register;
//! * prims outside the scalar whitelist (comparisons, vector
//!   constructors, lookups);
//! * exchangeable or multivariate absorbers;
//! * type changes discovered at pack time (a trace read that no longer
//!   fits its binding) — the whole batch returns `Err` and the caller
//!   re-scores it per section.
//!
//! # Lifecycle
//!
//! Groups are built per partition by [`build_batch_plans`] (cached as
//! `Trace::cached_batch_plans`), stamped with `structure_version`, and
//! rebuilt — never patched — after any structural change, exactly like
//! the partition and section-plan caches.  Value-only changes (accepted
//! proposals, epoch bumps, observation rewrites) keep groups valid:
//! slot tables store *where* to read values, never values themselves.
//!
//! # Pack/replay split (the parallel rung)
//!
//! Replay is two stages.  **Pack** ([`PackedBatch::pack_into`]) performs
//! every trace read — binding columns, batch-shared globals, absorber
//! values and committed arguments — single-threaded, into flat `f64`
//! buffers; anything that would have made the old replay `Err` (a
//! binding whose type changed, a non-numeric absorber value) errors
//! here instead, with the same scalar-path fallback.  **Replay**
//! ([`PackedBatch::replay_range`]) is then pure arithmetic over those
//! buffers: no `Trace`, no `Rc`, no allocation — which makes
//! `PackedBatch` `Send + Sync` and lets `runtime::pool::ShardScorer`
//! run contiguous section ranges on worker threads.  Every section's
//! `l_i` depends only on its own column `j`, so the sharded replay is
//! bitwise identical to the sequential one *by construction*: both run
//! the same kernel over the same columns.

use crate::ppl::prim::Prim;
use crate::ppl::sp::SpFamily;
use crate::ppl::value::Value;
use crate::trace::memread::{
    prim_always_coerces, BatchOp, ColumnProgram, MemberReader, MemberSink, ScalOperand, VecOperand,
};
use crate::trace::node::NodeId;
use crate::trace::partition::Partition;
use crate::trace::pet::Trace;
use crate::trace::plan::{PlanArg, PlanOp, SectionPlan};
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::rc::Rc;

/// Structural fingerprint of a lowered section: the op list modulo its
/// per-section bindings (constant *values*, trace node *ids*, absorber
/// node *ids* are excluded; constant type classes and vector arities are
/// included, because a shared op list must agree on them).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShapeKey(pub u64);

/// Type class of a value for shape purposes.  `Vec(len)` carries the
/// arity: two dot products over different dimensions are different
/// shapes (they cannot share a kernel or an op list).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Cls {
    Real,
    Int,
    Bool,
    Vec(usize),
    Other,
}

fn value_class(v: &Value) -> Cls {
    match v {
        Value::Real(_) => Cls::Real,
        Value::Int(_) => Cls::Int,
        Value::Bool(_) => Cls::Bool,
        Value::Vector(x) => Cls::Vec(x.len()),
        _ => Cls::Other,
    }
}

fn hash_value_class(v: &Value, h: &mut DefaultHasher) {
    match value_class(v) {
        Cls::Real => 0u8.hash(h),
        Cls::Int => 1u8.hash(h),
        Cls::Bool => 2u8.hash(h),
        Cls::Vec(n) => {
            3u8.hash(h);
            n.hash(h);
        }
        Cls::Other => 4u8.hash(h),
    }
}

fn hash_arg(a: &PlanArg, h: &mut DefaultHasher) {
    match a {
        PlanArg::Const(v) => {
            0u8.hash(h);
            hash_value_class(v, h);
        }
        PlanArg::Slot(i) => {
            1u8.hash(h);
            i.hash(h);
        }
        PlanArg::Global(k) => {
            2u8.hash(h);
            k.hash(h);
        }
        // the node id is a binding, not structure
        PlanArg::Trace(_) => 3u8.hash(h),
    }
}

fn hash_args(args: &[PlanArg], h: &mut DefaultHasher) {
    args.len().hash(h);
    for a in args {
        hash_arg(a, h);
    }
}

impl ShapeKey {
    /// Structural hash of a lowered section plan.
    pub fn of(plan: &SectionPlan) -> ShapeKey {
        let mut h = DefaultHasher::new();
        plan.n_slots.hash(&mut h);
        plan.ops.len().hash(&mut h);
        for op in &plan.ops {
            match op {
                PlanOp::Prim { prim, out, args } => {
                    0u8.hash(&mut h);
                    prim.hash(&mut h);
                    out.hash(&mut h);
                    hash_args(args, &mut h);
                }
                PlanOp::Copy { out, from } => {
                    1u8.hash(&mut h);
                    out.hash(&mut h);
                    hash_arg(from, &mut h);
                }
                PlanOp::Committed { out, .. } => {
                    2u8.hash(&mut h);
                    out.hash(&mut h);
                }
            }
        }
        plan.absorbers.len().hash(&mut h);
        for ab in &plan.absorbers {
            ab.fam.hash(&mut h);
            hash_args(&ab.args, &mut h);
        }
        ShapeKey(h.finish())
    }
}

fn arg_matches(t: &PlanArg, m: &PlanArg) -> bool {
    match (t, m) {
        (PlanArg::Const(a), PlanArg::Const(b)) => value_class(a) == value_class(b),
        (PlanArg::Slot(a), PlanArg::Slot(b)) => a == b,
        (PlanArg::Global(a), PlanArg::Global(b)) => a == b,
        (PlanArg::Trace(_), PlanArg::Trace(_)) => true,
        _ => false,
    }
}

fn args_match(t: &[PlanArg], m: &[PlanArg]) -> bool {
    t.len() == m.len() && t.iter().zip(m).all(|(a, b)| arg_matches(a, b))
}

/// Full structural comparison — the authoritative check behind the
/// [`ShapeKey`] hash, run per member when a group forms, so a hash
/// collision can never mix shapes into one op list.
pub fn same_shape(t: &SectionPlan, m: &SectionPlan) -> bool {
    if t.n_slots != m.n_slots
        || t.ops.len() != m.ops.len()
        || t.absorbers.len() != m.absorbers.len()
    {
        return false;
    }
    for (x, y) in t.ops.iter().zip(&m.ops) {
        let ok = match (x, y) {
            (
                PlanOp::Prim { prim: p1, out: o1, args: a1 },
                PlanOp::Prim { prim: p2, out: o2, args: a2 },
            ) => p1 == p2 && o1 == o2 && args_match(a1, a2),
            (PlanOp::Copy { out: o1, from: f1 }, PlanOp::Copy { out: o2, from: f2 }) => {
                o1 == o2 && arg_matches(f1, f2)
            }
            (PlanOp::Committed { out: o1, .. }, PlanOp::Committed { out: o2, .. }) => o1 == o2,
            _ => false,
        };
        if !ok {
            return false;
        }
    }
    t.absorbers
        .iter()
        .zip(&m.absorbers)
        .all(|(a, b)| a.fam == b.fam && args_match(&a.args, &b.args))
}

// ---------------------------------------------------------------------
// f64 lowering: the shared column program
// ---------------------------------------------------------------------

/// Scalar (f64) operand of a column op.
#[derive(Clone, Copy, Debug)]
pub enum ColS {
    /// f64 register (column) written by an earlier op.
    Slot(u32),
    /// Candidate value of the k-th global-section node (batch-shared),
    /// required to be `Value::Real` at pack time.
    Global(u32),
    /// Like `Global`, but coerced through `as_f64` at pack time — only
    /// emitted for operand positions the interpreter provably coerces
    /// the same way (see the int-widening rules in `lower_cols`).
    GlobalNum(u32),
    /// Per-section scalar binding column (constant or trace read).
    Bind(u32),
}

/// Vector operand of a column op.
#[derive(Clone, Copy, Debug)]
pub enum ColV {
    /// Vector register written by an earlier `CopyV`.
    Slot(u32),
    /// Candidate value of the k-th global-section node (batch-shared).
    Global(u32),
    /// Per-section vector binding (constant or trace read).
    Bind(u32),
}

/// One column op, executed over every selected section before the next
/// op runs (column-wise replay).
#[derive(Clone, Debug)]
pub enum ColOp {
    /// `s[out][j] = prim(args[j]...)` — scalar whitelist prims only.
    Map { prim: Prim, out: u32, args: Vec<ColS> },
    /// `s[out][j] = dot(a[j], b[j])`, optionally through the logistic
    /// link — the lowering of `Prim::Dot` / `Prim::LinearLogistic`.
    Dot { sigmoid: bool, out: u32, a: ColV, b: ColV },
    CopyS { out: u32, from: ColS },
    CopyV { out: u32, from: ColV },
}

/// One absorbing score: `l[j] += logpdf(value_j | cand args) -
/// logpdf(value_j | committed args)` for a scalar SP family.
#[derive(Clone, Debug)]
pub struct ColAbsorb {
    pub fam: SpFamily,
    /// Candidate-side argument sources, in `node.args` order.
    pub cand: Vec<ColS>,
}

/// Where one per-section binding lives inside a member's `SectionPlan`
/// (used to extract the member's slot-table row in the same canonical
/// order the lowering assigned binding indices).
#[derive(Clone, Copy, Debug)]
enum ArgPath {
    OpArg(u32, u32),
    CopyFrom(u32),
    AbsorbArg(u32, u32),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BindKind {
    /// Strict `f64` binding: only `Value::Real` reads are admitted, so a
    /// runtime type change makes the pack `Err` into the scalar path.
    Scalar,
    /// Coercing numeric binding (`as_f64`): admitted only at operand
    /// positions where the interpreter itself coerces through `as_f64`,
    /// so int/bool values replay bitwise-identically.
    ScalarNum,
    /// Vector binding with the template's arity: `ShapeKey` does not
    /// hash trace-read arities (the node id is a binding), so member
    /// extraction must enforce the template's length or a single
    /// mixed-arity member would `Err` every replay of its group.
    Vector(u32),
}

/// The f64-lowered column program shared by every member of a group.
#[derive(Debug)]
pub struct ColShape {
    pub n_sregs: u32,
    pub n_vregs: u32,
    pub n_sbind: u32,
    pub n_vbind: u32,
    /// Arity of each vector-binding column (template arity, enforced per
    /// member at extraction).
    pub varities: Vec<u32>,
    pub ops: Vec<ColOp>,
    pub absorbers: Vec<ColAbsorb>,
    bind_plan: Vec<(ArgPath, BindKind)>,
}

/// One entry of a per-section scalar slot table.
#[derive(Clone, Debug)]
pub enum SBind {
    /// Constant, pre-narrowed to f64 at group build — from `Value::Real`
    /// directly, or from `Value::Int` at a coercing operand position
    /// (`i as f64` is exactly the interpreter's `as_f64`, so no
    /// int-preservation divergence is possible).
    Const(f64),
    /// Committed trace value, read (strictly as `Value::Real`) at
    /// pack time after freshening.
    Node(NodeId),
    /// Committed trace value at a coercing operand position, read
    /// through `as_f64` at pack time — exactly the coercion
    /// `Prim::apply`'s float fold and `SpFamily::logpdf` apply.
    NodeNum(NodeId),
}

/// One entry of a per-section vector slot table.
#[derive(Clone, Debug)]
pub enum VBind {
    Const(Rc<Vec<f64>>),
    Node(NodeId),
}

#[derive(Clone, Copy, PartialEq, Eq)]
enum Ty {
    S,
    V,
}

/// Lowering state: slot -> typed register mapping + binding allocation.
struct Low {
    slot_map: Vec<Option<(Ty, u32)>>,
    n_s: u32,
    n_v: u32,
    n_sb: u32,
    n_vb: u32,
    varities: Vec<u32>,
    bind_plan: Vec<(ArgPath, BindKind)>,
}

impl Low {
    fn alloc_s(&mut self, slot: u32) -> u32 {
        let r = self.n_s;
        self.n_s += 1;
        self.slot_map[slot as usize] = Some((Ty::S, r));
        r
    }

    fn alloc_v(&mut self, slot: u32) -> u32 {
        let r = self.n_v;
        self.n_v += 1;
        self.slot_map[slot as usize] = Some((Ty::V, r));
        r
    }

    fn sbind(&mut self, path: ArgPath, kind: BindKind) -> ColS {
        let i = self.n_sb;
        self.n_sb += 1;
        self.bind_plan.push((path, kind));
        ColS::Bind(i)
    }

    fn vbind(&mut self, path: ArgPath, arity: u32) -> ColV {
        let i = self.n_vb;
        self.n_vb += 1;
        self.varities.push(arity);
        self.bind_plan.push((path, BindKind::Vector(arity)));
        ColV::Bind(i)
    }

    /// Whether an argument is guaranteed to read as `Value::Real` in any
    /// *successful* batch replay: constants are checked here, f64
    /// registers hold interpreter-`Real` results by induction, and
    /// global/trace reads verified `Real` here are re-checked strictly
    /// at pack time (a runtime type change falls back to the scalar
    /// path).  Such an argument witnesses that `Prim::apply`'s all-int
    /// branch cannot fire, so sibling int operands may be coerced.
    fn guaranteed_real(&self, trace: &Trace, p: &Partition, a: &PlanArg) -> bool {
        match a {
            PlanArg::Const(v) => matches!(v, Value::Real(_)),
            PlanArg::Slot(s) => matches!(self.slot_map[*s as usize], Some((Ty::S, _))),
            PlanArg::Global(k) => {
                value_class(trace.value(p.global_drg[*k as usize])) == Cls::Real
            }
            PlanArg::Trace(id) => value_class(trace.value(*id)) == Cls::Real,
        }
    }

    /// Lower one argument as a scalar operand; `None` when the argument
    /// is not provably f64-safe (caller abandons the f64 lowering).
    ///
    /// `coerce` marks operand positions where the interpreter itself
    /// applies `as_f64` (always-float prims, `SpFamily::logpdf` args,
    /// or an `Add`/`Mul`/`Sub` with a guaranteed-`Real` sibling): there,
    /// int constants and int/bool-classed reads are admitted through
    /// coercing bindings and stay bitwise-identical by construction.
    fn scalar_arg(
        &mut self,
        trace: &Trace,
        p: &Partition,
        a: &PlanArg,
        path: ArgPath,
        coerce: bool,
    ) -> Option<ColS> {
        match a {
            PlanArg::Const(Value::Real(_)) => Some(self.sbind(path, BindKind::Scalar)),
            PlanArg::Const(Value::Int(_)) if coerce => {
                Some(self.sbind(path, BindKind::ScalarNum))
            }
            PlanArg::Const(_) => None,
            PlanArg::Slot(s) => match self.slot_map[*s as usize] {
                Some((Ty::S, r)) => Some(ColS::Slot(r)),
                _ => None,
            },
            PlanArg::Global(k) => {
                match value_class(trace.value(p.global_drg[*k as usize])) {
                    Cls::Real => Some(ColS::Global(*k)),
                    Cls::Int | Cls::Bool if coerce => Some(ColS::GlobalNum(*k)),
                    _ => None,
                }
            }
            PlanArg::Trace(id) => match value_class(trace.value(*id)) {
                Cls::Real => Some(self.sbind(path, BindKind::Scalar)),
                Cls::Int | Cls::Bool if coerce => {
                    Some(self.sbind(path, BindKind::ScalarNum))
                }
                _ => None,
            },
        }
    }

    /// Lower one argument as a vector operand.
    fn vec_arg(
        &mut self,
        trace: &Trace,
        p: &Partition,
        a: &PlanArg,
        path: ArgPath,
    ) -> Option<ColV> {
        match a {
            PlanArg::Const(Value::Vector(v)) => Some(self.vbind(path, v.len() as u32)),
            PlanArg::Const(_) => None,
            PlanArg::Slot(s) => match self.slot_map[*s as usize] {
                Some((Ty::V, r)) => Some(ColV::Slot(r)),
                _ => None,
            },
            PlanArg::Global(k) => {
                match value_class(trace.value(p.global_drg[*k as usize])) {
                    Cls::Vec(_) => Some(ColV::Global(*k)),
                    _ => None,
                }
            }
            PlanArg::Trace(id) => match value_class(trace.value(*id)) {
                Cls::Vec(n) => Some(self.vbind(path, n as u32)),
                _ => None,
            },
        }
    }

    /// Class of a copy source (decides scalar vs vector register).
    fn copy_class(&self, trace: &Trace, p: &Partition, a: &PlanArg) -> Cls {
        match a {
            PlanArg::Const(v) => value_class(v),
            PlanArg::Slot(s) => match self.slot_map[*s as usize] {
                Some((Ty::S, _)) => Cls::Real,
                Some((Ty::V, _)) => Cls::Vec(0),
                None => Cls::Other,
            },
            PlanArg::Global(k) => value_class(trace.value(p.global_drg[*k as usize])),
            PlanArg::Trace(id) => value_class(trace.value(*id)),
        }
    }
}

/// Arity accepted by the scalar whitelist, mirroring `Prim::apply`.
fn scalar_prim_arity_ok(prim: Prim, n: usize) -> bool {
    use Prim::*;
    match prim {
        Add | Mul | Min | Max => n >= 1,
        Sub => n == 1 || n == 2,
        Div | Pow => n == 2,
        Neg | Exp | Log | Sqrt | Abs | Sigmoid => n == 1,
        _ => false,
    }
}

/// Lower a template plan to the shared f64 column program, or `None`
/// when the shape is not (provably) f64-clean — the group then scores
/// per section through the scalar `ScorerArena` path.
pub fn lower_cols(trace: &Trace, p: &Partition, plan: &SectionPlan) -> Option<ColShape> {
    let mut low = Low {
        slot_map: vec![None; plan.n_slots as usize],
        n_s: 0,
        n_v: 0,
        n_sb: 0,
        n_vb: 0,
        varities: Vec::new(),
        bind_plan: Vec::new(),
    };
    let mut ops: Vec<ColOp> = Vec::with_capacity(plan.ops.len());
    for (oi, op) in plan.ops.iter().enumerate() {
        let oi = oi as u32;
        match op {
            PlanOp::Prim { prim, out, args } => match prim {
                Prim::LinearLogistic | Prim::Dot => {
                    if args.len() != 2 {
                        return None;
                    }
                    let a = low.vec_arg(trace, p, &args[0], ArgPath::OpArg(oi, 0))?;
                    let b = low.vec_arg(trace, p, &args[1], ArgPath::OpArg(oi, 1))?;
                    let r = low.alloc_s(*out);
                    ops.push(ColOp::Dot {
                        sigmoid: matches!(prim, Prim::LinearLogistic),
                        out: r,
                        a,
                        b,
                    });
                }
                _ if scalar_prim_arity_ok(*prim, args.len()) => {
                    // int widening: every operand of an always-coercing
                    // prim goes through as_f64 in Prim::apply; for
                    // Add/Mul/Sub a guaranteed-Real sibling forces the
                    // float fold, which coerces the remaining operands
                    // the same way.  Without a witness the all-int
                    // branch could fire, so the shape stays scalar.
                    let coerce = prim_always_coerces(*prim)
                        || args.iter().any(|a| low.guaranteed_real(trace, p, a));
                    let mut cargs = Vec::with_capacity(args.len());
                    for (ai, a) in args.iter().enumerate() {
                        cargs.push(low.scalar_arg(
                            trace,
                            p,
                            a,
                            ArgPath::OpArg(oi, ai as u32),
                            coerce,
                        )?);
                    }
                    let r = low.alloc_s(*out);
                    ops.push(ColOp::Map {
                        prim: *prim,
                        out: r,
                        args: cargs,
                    });
                }
                _ => return None,
            },
            PlanOp::Copy { out, from } => match low.copy_class(trace, p, from) {
                Cls::Real => {
                    let f = low.scalar_arg(trace, p, from, ArgPath::CopyFrom(oi), false)?;
                    let r = low.alloc_s(*out);
                    ops.push(ColOp::CopyS { out: r, from: f });
                }
                Cls::Vec(_) => {
                    let f = low.vec_arg(trace, p, from, ArgPath::CopyFrom(oi))?;
                    let r = low.alloc_v(*out);
                    ops.push(ColOp::CopyV { out: r, from: f });
                }
                _ => return None,
            },
            // Maker values (Value::Sp) are never f64-representable.
            PlanOp::Committed { .. } => return None,
        }
    }
    let mut absorbers = Vec::with_capacity(plan.absorbers.len());
    for (bi, ab) in plan.absorbers.iter().enumerate() {
        if matches!(ab.fam, SpFamily::MvNormal) {
            return None;
        }
        let mut cand = Vec::with_capacity(ab.args.len());
        for (ai, a) in ab.args.iter().enumerate() {
            // SpFamily::logpdf coerces every argument through as_f64
            // (`num`), so absorber operands always admit int widening
            cand.push(low.scalar_arg(
                trace,
                p,
                a,
                ArgPath::AbsorbArg(bi as u32, ai as u32),
                true,
            )?);
        }
        absorbers.push(ColAbsorb { fam: ab.fam, cand });
    }
    Some(ColShape {
        n_sregs: low.n_s,
        n_vregs: low.n_v,
        n_sbind: low.n_sb,
        n_vbind: low.n_vb,
        varities: low.varities,
        ops,
        absorbers,
        bind_plan: low.bind_plan,
    })
}

/// Extract one member's slot-table row by following the template's
/// binding paths through the member's plan.  `None` on any kind
/// mismatch — including a *trace-read* binding whose current value
/// class does not fit the column type (`ShapeKey` hashes `Trace` args
/// as a bare tag, so an Int-valued read can share a key with a
/// Real-valued template; admitting it would make every replay of the
/// whole group `Err` into the scalar path).  A rejected member stays
/// scalar alone; the rest of the group keeps vectorizing.
fn extract_binds(
    trace: &Trace,
    shape: &ColShape,
    plan: &SectionPlan,
) -> Option<(Vec<SBind>, Vec<VBind>)> {
    let mut sb = Vec::with_capacity(shape.n_sbind as usize);
    let mut vb = Vec::with_capacity(shape.n_vbind as usize);
    for &(path, kind) in &shape.bind_plan {
        let arg: &PlanArg = match path {
            ArgPath::OpArg(oi, ai) => match plan.ops.get(oi as usize)? {
                PlanOp::Prim { args, .. } => args.get(ai as usize)?,
                _ => return None,
            },
            ArgPath::CopyFrom(oi) => match plan.ops.get(oi as usize)? {
                PlanOp::Copy { from, .. } => from,
                _ => return None,
            },
            ArgPath::AbsorbArg(bi, ai) => plan.absorbers.get(bi as usize)?.args.get(ai as usize)?,
        };
        match (kind, arg) {
            (BindKind::Scalar, PlanArg::Const(Value::Real(x))) => sb.push(SBind::Const(*x)),
            (BindKind::Scalar, PlanArg::Trace(id)) => {
                if value_class(trace.value(*id)) != Cls::Real {
                    return None;
                }
                sb.push(SBind::Node(*id));
            }
            // coercing positions: the const class matches the template
            // (ShapeKey/same_shape), so ScalarNum consts are ints; trace
            // reads may be any as_f64-able class (the interpreter
            // coerces them identically at these positions)
            (BindKind::ScalarNum, PlanArg::Const(Value::Int(i))) => {
                sb.push(SBind::Const(*i as f64))
            }
            (BindKind::ScalarNum, PlanArg::Trace(id)) => {
                match value_class(trace.value(*id)) {
                    Cls::Real | Cls::Int | Cls::Bool => sb.push(SBind::NodeNum(*id)),
                    _ => return None,
                }
            }
            // const arities are already part of the ShapeKey/same_shape
            // structure; the check is defense in depth
            (BindKind::Vector(arity), PlanArg::Const(Value::Vector(v))) => {
                if v.len() as u32 != arity {
                    return None;
                }
                vb.push(VBind::Const(v.clone()));
            }
            (BindKind::Vector(arity), PlanArg::Trace(id)) => match trace.value(*id) {
                Value::Vector(v) if v.len() as u32 == arity => vb.push(VBind::Node(*id)),
                _ => return None,
            },
            _ => return None,
        }
    }
    Some((sb, vb))
}

// ---------------------------------------------------------------------
// Groups and the per-partition set
// ---------------------------------------------------------------------

/// A batched group: the shared column program plus flat per-section
/// slot tables (SoA layout; strides are the shape's binding counts).
#[derive(Debug)]
pub struct BatchGroup {
    pub key: ShapeKey,
    /// The structural template every member was verified against.
    pub template: Rc<SectionPlan>,
    /// The shared f64 column program (groups only exist for shapes
    /// that lowered; shapes that fail to lower stay unbatched).
    pub cols: ColShape,
    /// Border-child root of each member, in membership order.
    pub roots: Vec<NodeId>,
    /// Scalar slot tables, stride `cols.n_sbind`.
    pub sbinds: Vec<SBind>,
    /// Vector slot tables, stride `cols.n_vbind`.
    pub vbinds: Vec<VBind>,
    /// Absorber nodes, stride `template.absorbers.len()`.
    pub absorbers: Vec<NodeId>,
    /// Concatenated freshen-before-replay node lists; member `m` owns
    /// `touch[touch_off[m]..touch_off[m+1]]`.
    pub touch: Vec<NodeId>,
    pub touch_off: Vec<u32>,
}

impl BatchGroup {
    pub fn len(&self) -> usize {
        self.roots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.roots.is_empty()
    }

    /// The freshen list of member `m`.
    pub fn touch_of(&self, m: usize) -> &[NodeId] {
        &self.touch[self.touch_off[m] as usize..self.touch_off[m + 1] as usize]
    }

    /// The absorbing node of member `m` at absorber position `bi`.
    pub fn absorber_of(&self, m: usize, bi: usize) -> NodeId {
        self.absorbers[m * self.template.absorbers.len() + bi]
    }

    /// Columnar f32 narrowing of vector-binding column `col` for the
    /// listed members, appended row-major (`members.len() x arity`) into
    /// `out` — the XLA kernels' input layout, read straight off the slot
    /// table with no per-row node-structure walk.  Returns the column
    /// arity; `None` if any member's current value no longer fits the
    /// column (callers fall back to the structural walk).  Trace-read
    /// members must be freshened first (the evaluators already do).
    pub fn narrow_vbind_into(
        &self,
        trace: &Trace,
        col: u32,
        members: &[u32],
        out: &mut Vec<f32>,
    ) -> Option<usize> {
        let nvb = self.cols.n_vbind as usize;
        let d = self.cols.varities[col as usize] as usize;
        out.reserve(members.len() * d);
        for &m in members {
            match &self.vbinds[m as usize * nvb + col as usize] {
                VBind::Const(v) => out.extend(v.iter().map(|&x| x as f32)),
                VBind::Node(id) => match trace.value(*id) {
                    Value::Vector(v) if v.len() == d => {
                        out.extend(v.iter().map(|&x| x as f32))
                    }
                    _ => return None,
                },
            }
        }
        Some(d)
    }

    /// Columnar f32 narrowing of scalar-binding column `col` for the
    /// listed members, appended into `out`.  `None` if any member's
    /// current value is non-numeric.
    pub fn narrow_sbind_into(
        &self,
        trace: &Trace,
        col: u32,
        members: &[u32],
        out: &mut Vec<f32>,
    ) -> Option<()> {
        let nsb = self.cols.n_sbind as usize;
        out.reserve(members.len());
        for &m in members {
            let x = match &self.sbinds[m as usize * nsb + col as usize] {
                SBind::Const(x) => *x,
                SBind::Node(id) | SBind::NodeNum(id) => trace.value(*id).as_f64()?,
            };
            out.push(x as f32);
        }
        Some(())
    }
}

/// All batchable sections of one partition, grouped by shape.
#[derive(Debug)]
pub struct BatchPlanSet {
    pub groups: Vec<BatchGroup>,
    /// root -> (group index, member index).  Roots absent from the map
    /// (unlowerable sections, shape mismatches, non-f64 shapes) are
    /// scored per section by the caller.
    pub of_root: HashMap<NodeId, (u32, u32)>,
    /// `Trace::structure_version` at build time (cache validation).
    pub built_at: u64,
    /// `Trace::append_version` as of the last build/extension: when
    /// `built_at` is current but this lags, the partition grew by
    /// appends and the set extends in place ([`extend_batch_plans`]).
    pub appended_at: u64,
    /// Partition locals processed so far (batched *or* deliberately
    /// left scalar) — `of_root.len()` undercounts because unlowerable
    /// roots are skipped, so extension starts at `locals[covers..]`.
    pub covers: usize,
}

impl BatchPlanSet {
    /// Sections covered by a batched group.
    pub fn batched_roots(&self) -> usize {
        self.of_root.len()
    }
}

/// Group every local section of partition `p` by shape and lower each
/// group's column program.  Sections that cannot be planned, cannot be
/// f64-lowered, or structurally mismatch their group's template are
/// simply left out of `of_root` (scalar fallback), never mis-grouped.
pub fn build_batch_plans(trace: &Trace, p: &Partition) -> BatchPlanSet {
    let mut by_key: HashMap<ShapeKey, u32> = HashMap::new();
    let mut groups: Vec<BatchGroup> = Vec::new();
    let mut of_root: HashMap<NodeId, (u32, u32)> = HashMap::new();
    for &root in &p.locals {
        let Ok(plan) = trace.cached_section_plan(p, root) else {
            continue;
        };
        let key = ShapeKey::of(&plan);
        let gi = match by_key.get(&key) {
            Some(&gi) => gi,
            None => {
                // a member whose lowering fails stays scalar, but does
                // NOT ban the key: a later same-shaped member with
                // f64-clean trace reads may still found the group
                // (lowering is O(ops), and this runs once per rebuild)
                let Some(cols) = lower_cols(trace, p, &plan) else {
                    continue;
                };
                groups.push(BatchGroup {
                    key,
                    template: plan.clone(),
                    cols,
                    roots: Vec::new(),
                    sbinds: Vec::new(),
                    vbinds: Vec::new(),
                    absorbers: Vec::new(),
                    touch: Vec::new(),
                    touch_off: vec![0],
                });
                let gi = (groups.len() - 1) as u32;
                by_key.insert(key, gi);
                gi
            }
        };
        let g = &mut groups[gi as usize];
        if !Rc::ptr_eq(&plan, &g.template) && !same_shape(&g.template, &plan) {
            continue; // hash collision: keep the member on the scalar path
        }
        let Some((sb, vb)) = extract_binds(trace, &g.cols, &plan) else {
            continue;
        };
        let mi = g.roots.len() as u32;
        g.roots.push(root);
        g.sbinds.extend(sb);
        g.vbinds.extend(vb);
        g.absorbers.extend(plan.absorbers.iter().map(|a| a.node));
        g.touch.extend_from_slice(&plan.touch);
        g.touch_off.push(g.touch.len() as u32);
        of_root.insert(root, (gi, mi));
    }
    BatchPlanSet {
        groups,
        of_root,
        built_at: trace.structure_version,
        appended_at: trace.append_version,
        covers: p.locals.len(),
    }
}

/// Extend a cached set in place over a partition grown by appends:
/// process only `p.locals[set.covers..]`, replicating the build loop
/// per new root.  A new root either joins an existing shape group
/// (membership indices of existing members never move — groups are
/// append-only), founds a new group at the end of `groups` (the
/// column store extends index-aligned), or stays scalar.  O(|append|)
/// section lowerings, independent of N.
pub fn extend_batch_plans(trace: &Trace, p: &Partition, set: &mut BatchPlanSet) {
    debug_assert_eq!(set.built_at, trace.structure_version);
    for &root in &p.locals[set.covers..] {
        set.covers += 1;
        let Ok(plan) = trace.cached_section_plan(p, root) else {
            continue;
        };
        let key = ShapeKey::of(&plan);
        // groups are few (one per shape); a linear scan matches the
        // build map's first-group-per-key semantics without storing it
        let gi = match set.groups.iter().position(|g| g.key == key) {
            Some(gi) => gi as u32,
            None => {
                let Some(cols) = lower_cols(trace, p, &plan) else {
                    continue;
                };
                set.groups.push(BatchGroup {
                    key,
                    template: plan.clone(),
                    cols,
                    roots: Vec::new(),
                    sbinds: Vec::new(),
                    vbinds: Vec::new(),
                    absorbers: Vec::new(),
                    touch: Vec::new(),
                    touch_off: vec![0],
                });
                (set.groups.len() - 1) as u32
            }
        };
        let g = &mut set.groups[gi as usize];
        if !Rc::ptr_eq(&plan, &g.template) && !same_shape(&g.template, &plan) {
            continue;
        }
        let Some((sb, vb)) = extract_binds(trace, &g.cols, &plan) else {
            continue;
        };
        let mi = g.roots.len() as u32;
        g.roots.push(root);
        g.sbinds.extend(sb);
        g.vbinds.extend(vb);
        g.absorbers.extend(plan.absorbers.iter().map(|a| a.node));
        g.touch.extend_from_slice(&plan.touch);
        g.touch_off.push(g.touch.len() as u32);
        set.of_root.insert(root, (gi, mi));
    }
    set.appended_at = trace.append_version;
}

// ---------------------------------------------------------------------
// The packed batch: pack (trace reads) + replay (pure f64 kernel)
// ---------------------------------------------------------------------

#[derive(Clone, Debug)]
struct PAbsorb {
    fam: SpFamily,
    /// Candidate-side args at `(offset, len)` in the program's pool.
    args: (u32, u32),
    /// Offset of the committed-arg block in `ab_cargs` (`len * w`
    /// floats, arg-major).
    cargs: u32,
}

/// Sel-order destination for the shared member reader: member `m`'s row
/// lands in column `j` of a `w = |sel|`-wide batch.  Buffers are
/// pre-sized, so placement is pure positioned writes.
struct PackSink<'a> {
    j: usize,
    w: usize,
    sbind: &'a mut [f64],
    vbind: &'a mut [f64],
    vcols: &'a [(u32, u32)],
    ab_vals: &'a mut [f64],
    ab_cargs: &'a mut [f64],
    absorbers: &'a [PAbsorb],
}

impl MemberSink for PackSink<'_> {
    fn scalar(&mut self, b: usize, x: f64) {
        self.sbind[b * self.w + self.j] = x;
    }
    fn vector(&mut self, b: usize, ar: usize, xs: &[f64]) {
        let off = self.vcols[b].0 as usize + self.j * ar;
        self.vbind[off..off + ar].copy_from_slice(xs);
    }
    fn absorb_val(&mut self, bi: usize, x: f64) {
        self.ab_vals[bi * self.w + self.j] = x;
    }
    fn absorb_carg(&mut self, bi: usize, ai: usize, x: f64) {
        let coff = self.absorbers[bi].cargs as usize;
        self.ab_cargs[coff + ai * self.w + self.j] = x;
    }
}

/// A fully packed mini-batch: every trace/global read resolved into
/// flat `f64` buffers, plus the op list to run over them.  Plain data
/// throughout — `Send + Sync` — so [`replay_range`](Self::replay_range)
/// can run on worker threads over disjoint section ranges with no locks
/// and no `Trace` access.  Buffers are cleared, not freed, between
/// packs, so the sequential path stays allocation-free in steady state.
#[derive(Default, Debug)]
pub struct PackedBatch {
    w: usize,
    /// The candidate-resolved column program (ops, operand pool, shared
    /// vectors) — built by the shared resolution core in `memread`.
    prog: ColumnProgram,
    absorbers: Vec<PAbsorb>,
    /// Scalar binding columns, column-major (`b * w + j`).
    sbind: Vec<f64>,
    /// Flattened vector binding columns; column `b` holds `w` vectors of
    /// arity `vcols[b].1` starting at `vcols[b].0`.
    vbind: Vec<f64>,
    vcols: Vec<(u32, u32)>,
    /// Absorber values, column-major (`bi * w + j`); Bernoulli values
    /// encoded 1.0/0.0.
    ab_vals: Vec<f64>,
    /// Committed absorber args, per-absorber arg-major blocks.
    ab_cargs: Vec<f64>,
}

impl PackedBatch {
    /// Number of selected sections (the batch width).
    pub fn width(&self) -> usize {
        self.w
    }

    /// Pack into a fresh batch (the parallel path, which hands the
    /// result to the worker pool behind an `Arc`).
    pub fn pack(
        trace: &Trace,
        group: &BatchGroup,
        sel: &[(u32, u32)],
        globals: &[Value],
    ) -> Result<PackedBatch, String> {
        let mut pb = PackedBatch::default();
        pb.pack_into(trace, group, sel, globals)?;
        Ok(pb)
    }

    /// Perform every trace read the replay needs, single-threaded, into
    /// this batch's flat buffers.  `sel` holds `(member index, caller
    /// tag)` pairs; only the member index is read here.  The caller must
    /// have freshened each member's touch list and filled `globals`
    /// (via `plan::candidate_globals`) first.
    ///
    /// On `Err`, the batch is not replayable and the caller must
    /// re-score the selection per section (the scalar path reproduces
    /// the interpreter oracle exactly, including its error/`-inf`
    /// behavior).
    ///
    /// Member reads and operand resolution both go through the shared
    /// core in `trace/memread` — the column store's row refresh calls
    /// the *same* [`MemberReader`], so the pack/store bitwise-twin
    /// contract holds by construction, not by mirrored edits.
    pub fn pack_into(
        &mut self,
        trace: &Trace,
        group: &BatchGroup,
        sel: &[(u32, u32)],
        globals: &[Value],
    ) -> Result<(), String> {
        let cols = &group.cols;
        let w = sel.len();
        self.w = w;
        self.absorbers.clear();
        self.sbind.clear();
        self.vbind.clear();
        self.vcols.clear();
        self.ab_vals.clear();
        self.ab_cargs.clear();
        if w == 0 {
            // nothing to replay; the program is left unresolved on
            // purpose (the old path skipped op resolution too)
            self.prog = ColumnProgram::default();
            return Ok(());
        }

        // --- candidate side: the shared op/operand resolution ---
        self.prog.resolve("batch pack", cols, globals)?;

        // --- pre-size the committed-side panels (sel-width columns) ---
        self.sbind.resize(cols.n_sbind as usize * w, 0.0);
        let mut voff = 0u32;
        for &ar in &cols.varities {
            self.vcols.push((voff, ar));
            voff += ar * w as u32;
        }
        self.vbind.resize(voff as usize, 0.0);
        self.ab_vals.resize(cols.absorbers.len() * w, 0.0);
        let mut coff = 0u32;
        for &(fam, args) in &self.prog.absorbers {
            self.absorbers.push(PAbsorb { fam, args, cargs: coff });
            coff += args.1 * w as u32;
        }
        self.ab_cargs.resize(coff as usize, 0.0);

        // --- committed side: every member through the shared reader ---
        let reader = MemberReader::new(trace, "batch pack");
        for (j, &(m, _)) in sel.iter().enumerate() {
            let mut sink = PackSink {
                j,
                w,
                sbind: &mut self.sbind,
                vbind: &mut self.vbind,
                vcols: &self.vcols,
                ab_vals: &mut self.ab_vals,
                ab_cargs: &mut self.ab_cargs,
                absorbers: &self.absorbers,
            };
            reader.read_member(group, m as usize, &mut sink)?;
        }
        Ok(())
    }

    #[inline]
    fn scal(&self, a: ScalOperand, sregs: &[f64], ws: usize, jj: usize, j: usize) -> f64 {
        match a {
            ScalOperand::Slot(r) => sregs[r as usize * ws + jj],
            ScalOperand::Bind(b) => self.sbind[b as usize * self.w + j],
            ScalOperand::Const(c) => c,
        }
    }

    #[inline]
    fn vec_at(&self, a: VecOperand, j: usize) -> &[f64] {
        match a {
            VecOperand::Bind(b) => {
                let (off, ar) = self.vcols[b as usize];
                let (off, ar) = (off as usize, ar as usize);
                &self.vbind[off + j * ar..off + (j + 1) * ar]
            }
            VecOperand::Shared(s) => {
                let (off, len) = self.prog.scols[s as usize];
                &self.prog.shared[off as usize..(off + len) as usize]
            }
        }
    }

    /// Replay sections `lo..hi` of the packed batch into `out` (length
    /// `hi - lo`), using `sregs` as register scratch.  Pure arithmetic
    /// over the packed buffers: infallible, `Trace`-free, and per-`j`
    /// independent — the computation for section `j` is the *same
    /// scalar f64 operations in the same order* no matter how the range
    /// is sharded, which is the whole bitwise-identity argument for the
    /// parallel path.
    pub fn replay_range(&self, lo: usize, hi: usize, sregs: &mut Vec<f64>, out: &mut [f64]) {
        debug_assert!(lo <= hi && hi <= self.w);
        debug_assert_eq!(out.len(), hi - lo);
        let w = self.w;
        let ws = hi - lo;
        out.fill(0.0);
        if ws == 0 {
            return;
        }
        sregs.clear();
        sregs.resize(self.prog.n_sregs as usize * ws, 0.0);
        for op in &self.prog.ops {
            match op {
                BatchOp::Map { prim, out: o, args } => {
                    use Prim::*;
                    let argv = &self.prog.args[args.0 as usize..(args.0 + args.1) as usize];
                    for j in lo..hi {
                        let jj = j - lo;
                        let a0 = self.scal(argv[0], sregs, ws, jj, j);
                        let r = match prim {
                            // identical fold order to Prim::apply
                            Add | Mul | Min | Max => {
                                let mut acc = a0;
                                for &a in &argv[1..] {
                                    let x = self.scal(a, sregs, ws, jj, j);
                                    acc = match prim {
                                        Add => acc + x,
                                        Mul => acc * x,
                                        Min => acc.min(x),
                                        Max => acc.max(x),
                                        _ => unreachable!(),
                                    };
                                }
                                acc
                            }
                            Sub => {
                                if argv.len() == 1 {
                                    -a0
                                } else {
                                    a0 - self.scal(argv[1], sregs, ws, jj, j)
                                }
                            }
                            Div => a0 / self.scal(argv[1], sregs, ws, jj, j),
                            Pow => a0.powf(self.scal(argv[1], sregs, ws, jj, j)),
                            Neg => -a0,
                            Exp => a0.exp(),
                            Log => a0.ln(),
                            Sqrt => a0.sqrt(),
                            Abs => a0.abs(),
                            Sigmoid => 1.0 / (1.0 + (-a0).exp()),
                            // lower_cols admits only the scalar whitelist
                            _ => unreachable!("non-columnar prim in packed batch"),
                        };
                        sregs[*o as usize * ws + jj] = r;
                    }
                }
                BatchOp::Dot { sigmoid, out: o, a, b } => {
                    for j in lo..hi {
                        let av = self.vec_at(*a, j);
                        let bv = self.vec_at(*b, j);
                        // same accumulation order as Prim::apply's
                        // zip/map/sum (fold from 0.0 in index order)
                        let mut d = 0.0f64;
                        for (x, y) in av.iter().zip(bv.iter()) {
                            d += x * y;
                        }
                        sregs[*o as usize * ws + (j - lo)] =
                            if *sigmoid { 1.0 / (1.0 + (-d).exp()) } else { d };
                    }
                }
                BatchOp::CopyS { out: o, from } => {
                    for j in lo..hi {
                        let jj = j - lo;
                        let x = self.scal(*from, sregs, ws, jj, j);
                        sregs[*o as usize * ws + jj] = x;
                    }
                }
            }
        }

        // --- absorbers: l[j] += cand - committed, in absorber order ---
        let sr: &[f64] = sregs;
        for (bi, ab) in self.absorbers.iter().enumerate() {
            let argv = &self.prog.args[ab.args.0 as usize..(ab.args.0 + ab.args.1) as usize];
            let n_args = argv.len();
            let coff = ab.cargs as usize;
            for j in lo..hi {
                let jj = j - lo;
                let val = self.ab_vals[bi * w + j];
                let cand =
                    packed_fam_logpdf(ab.fam, val, |i| self.scal(argv[i], sr, ws, jj, j), n_args);
                let committed =
                    packed_fam_logpdf(ab.fam, val, |i| self.ab_cargs[coff + i * w + j], n_args);
                out[jj] += cand - committed;
            }
        }
    }
}

/// `logpdf(value | args)` for a scalar SP family over packed f64 data,
/// matching `SpFamily::logpdf`'s coercions bit-for-bit (values and args
/// were coerced identically — `as_f64`, NaN for out-of-class — at pack
/// time).
pub(crate) fn packed_fam_logpdf(
    fam: SpFamily,
    val: f64,
    arg: impl Fn(usize) -> f64,
    n_args: usize,
) -> f64 {
    use crate::dist;
    match fam {
        SpFamily::Bernoulli => {
            let p = if n_args == 0 { 0.5 } else { arg(0) };
            dist::bernoulli_logpmf(val != 0.0, p)
        }
        SpFamily::Normal => dist::normal_logpdf(val, arg(0), arg(1)),
        SpFamily::Gamma => dist::gamma_logpdf(val, arg(0), arg(1)),
        SpFamily::InvGamma => dist::inv_gamma_logpdf(val, arg(0), arg(1)),
        SpFamily::Beta => dist::beta_logpdf(val, arg(0), arg(1)),
        SpFamily::UniformContinuous => dist::uniform_logpdf(val, arg(0), arg(1)),
        SpFamily::StudentT => dist::student_t_logpdf(val, arg(0), arg(1), arg(2)),
        // lower_cols rejects multivariate absorbers
        SpFamily::MvNormal => unreachable!("multivariate absorber in packed batch"),
    }
}

/// Reusable sequential replay state: one [`PackedBatch`] plus the
/// scalar-register scratch, cleared — not freed — between batches.  The
/// pool workers own the same storage privately on the parallel path
/// (`runtime::pool`), so no state is shared across threads except the
/// immutable packed batch itself.
#[derive(Default)]
pub struct RegFile {
    packed: PackedBatch,
    sregs: Vec<f64>,
}

impl RegFile {
    pub fn new() -> RegFile {
        RegFile::default()
    }

    /// Sequential columnar replay of `group` over the selected members
    /// (outputs land in `out` in `sel` order): pack, then run the
    /// kernel over the full range.  The parallel path
    /// (`runtime::pool::ShardScorer`) runs the *same* kernel over
    /// contiguous shards of the same packed batch, so the two are
    /// bitwise identical by construction.
    ///
    /// On `Err`, no output is valid and the caller must re-score the
    /// batch per section.
    pub fn replay(
        &mut self,
        trace: &Trace,
        group: &BatchGroup,
        sel: &[(u32, u32)],
        globals: &[Value],
        out: &mut Vec<f64>,
    ) -> Result<(), String> {
        self.packed.pack_into(trace, group, sel, globals)?;
        out.clear();
        out.resize(sel.len(), 0.0);
        self.packed.replay_range(0, sel.len(), &mut self.sregs, out);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::subsampled_mh::{InterpreterEval, LocalEvaluator};
    use crate::math::Pcg64;
    use crate::trace::plan::candidate_globals;

    fn lr_trace(n: usize, seed: u64) -> Trace {
        let mut src = String::from(
            "[assume w (scope_include 'w 0 (multivariate_normal (vector 0 0 0) 0.1))]\n\
             [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n",
        );
        let mut rng = Pcg64::seeded(seed ^ 0xbeef);
        for _ in 0..n {
            let (a, b) = (rng.normal(), rng.normal());
            let lab = if rng.bernoulli(0.5) { "true" } else { "false" };
            src.push_str(&format!("[observe (f (vector {a} {b} 1.0)) {lab}]\n"));
        }
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(&src, &mut rng).unwrap();
        t
    }

    #[test]
    fn lr_sections_form_one_group_and_replay_bitwise() {
        let mut t = lr_trace(24, 0);
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let set = t.cached_batch_plans(&p);
        assert_eq!(set.groups.len(), 1, "LR sections must share one shape");
        assert_eq!(set.batched_roots(), 24);
        let g = &set.groups[0];
        assert_eq!(g.len(), 24);
        assert_eq!(g.cols.n_vbind, 1); // the per-observation x vector
        assert_eq!(g.cols.absorbers.len(), 1);

        let new_w = Value::vector(vec![0.3, -0.1, 0.2]);
        let mut globals = Vec::new();
        candidate_globals(&t, &p, &new_w, &mut globals).unwrap();
        let sel: Vec<(u32, u32)> = (0..g.len() as u32).map(|m| (m, m)).collect();
        let mut rf = RegFile::new();
        let mut out = Vec::new();
        rf.replay(&t, g, &sel, &globals, &mut out).unwrap();

        let roots = g.roots.clone();
        let mut interp = InterpreterEval;
        let p2 = t.cached_partition(w).unwrap();
        let want = interp.eval_sections(&mut t, &p2, &roots, &new_w).unwrap();
        for (i, (a, b)) in out.iter().zip(&want).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "l[{i}]: batched {a} vs interpreter {b}"
            );
        }
    }

    #[test]
    fn subset_selection_matches_full_replay() {
        let t = lr_trace(16, 1);
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let set = t.cached_batch_plans(&p);
        let g = &set.groups[0];
        let new_w = Value::vector(vec![-0.2, 0.4, 0.05]);
        let mut globals = Vec::new();
        candidate_globals(&t, &p, &new_w, &mut globals).unwrap();
        let mut rf = RegFile::new();
        let all: Vec<(u32, u32)> = (0..g.len() as u32).map(|m| (m, m)).collect();
        let mut full = Vec::new();
        rf.replay(&t, g, &all, &globals, &mut full).unwrap();
        // a scattered subset must read the same slot-table rows
        let sub: Vec<(u32, u32)> = vec![(3, 0), (11, 1), (0, 2), (7, 3)];
        let mut part = Vec::new();
        rf.replay(&t, g, &sub, &globals, &mut part).unwrap();
        for (k, &(m, _)) in sub.iter().enumerate() {
            assert_eq!(part[k].to_bits(), full[m as usize].to_bits());
        }
    }

    #[test]
    fn shape_keys_separate_det_chains_and_arities() {
        // three shapes over the same principal: logistic, gaussian dot,
        // gaussian exp(dot); plus logistic at a different dimension on a
        // second principal
        let src = "\
            [assume w (scope_include 'w 0 (multivariate_normal (vector 0 0) 0.5))]\n\
            [assume w2 (scope_include 'w2 0 (multivariate_normal (vector 0 0 0) 0.5))]\n\
            [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n\
            [assume gn (lambda (x s) (normal (dot w x) s))]\n\
            [assume ge (lambda (x s) (normal (exp (dot w x)) s))]\n\
            [observe (f (vector 1.0 0.5)) true]\n\
            [observe (f (vector -0.3 0.8)) false]\n\
            [observe (gn (vector 0.2 0.1) 0.7) 0.4]\n\
            [observe (gn (vector 0.9 -0.4) 1.2) -0.1]\n\
            [observe (ge (vector 0.5 0.5) 0.9) 1.3]\n\
            [observe (ge (vector -0.2 0.6) 0.8) 0.7]\n\
            [assume f2 (lambda (x) (bernoulli (linear_logistic w2 x)))]\n\
            [observe (f2 (vector 1.0 0.5 0.2)) true]\n\
            [observe (f2 (vector -1.0 0.25 0.1)) false]\n";
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(2);
        t.run_program(src, &mut rng).unwrap();
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        assert_eq!(p.n(), 6);
        let mut keys = Vec::new();
        for &root in &p.locals {
            let plan = t.cached_section_plan(&p, root).unwrap();
            keys.push(ShapeKey::of(&plan));
        }
        // obs order: f f gn gn ge ge
        assert_eq!(keys[0], keys[1]);
        assert_eq!(keys[2], keys[3]);
        assert_eq!(keys[4], keys[5]);
        assert_ne!(keys[0], keys[2]);
        assert_ne!(keys[0], keys[4]);
        assert_ne!(keys[2], keys[4]);
        let set = t.cached_batch_plans(&p);
        assert_eq!(set.groups.len(), 3);
        assert_eq!(set.batched_roots(), 6);
        // same op pattern at a different vector arity is a different shape
        let w2 = t.lookup_node("w2").unwrap();
        let p2 = t.cached_partition(w2).unwrap();
        let plan2 = t.cached_section_plan(&p2, p2.locals[0]).unwrap();
        assert_ne!(ShapeKey::of(&plan2), keys[0]);
    }

    #[test]
    fn mixed_shape_groups_replay_bitwise() {
        let src = "\
            [assume w (scope_include 'w 0 (multivariate_normal (vector 0 0) 0.5))]\n\
            [assume f (lambda (x) (bernoulli (linear_logistic w x)))]\n\
            [assume gn (lambda (x s) (normal (dot w x) s))]\n\
            [observe (f (vector 1.0 0.5)) true]\n\
            [observe (gn (vector 0.2 0.1) 0.7) 0.4]\n\
            [observe (f (vector -0.3 0.8)) false]\n\
            [observe (gn (vector 0.9 -0.4) 1.2) -0.1]\n";
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(3);
        t.run_program(src, &mut rng).unwrap();
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let set = t.cached_batch_plans(&p);
        assert_eq!(set.groups.len(), 2);
        let new_w = Value::vector(vec![0.15, -0.35]);
        let mut globals = Vec::new();
        candidate_globals(&t, &p, &new_w, &mut globals).unwrap();
        let mut interp = InterpreterEval;
        let mut rf = RegFile::new();
        for g in &set.groups {
            let sel: Vec<(u32, u32)> = (0..g.len() as u32).map(|m| (m, m)).collect();
            let mut out = Vec::new();
            rf.replay(&t, g, &sel, &globals, &mut out).unwrap();
            let roots = g.roots.clone();
            let p2 = t.cached_partition(w).unwrap();
            let want = interp
                .eval_sections(&mut t, &p2, &roots, &new_w)
                .unwrap();
            for (a, b) in out.iter().zip(&want) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    /// Cache identity discipline: reuse while the structure is
    /// unchanged, wholesale rebuild on any structural change.  The
    /// child-edge-rewiring (mem re-key) variant of this regression —
    /// with a bitwise post-rekey oracle check — lives in
    /// `tests/shapekey.rs::batch_plans_rebuild_after_mem_rekey`.
    #[test]
    fn batch_set_cached_until_structure_changes() {
        let mut t = lr_trace(10, 7);
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let set_a = t.cached_batch_plans(&p);
        let set_b = t.cached_batch_plans(&p);
        assert!(Rc::ptr_eq(&set_a, &set_b), "unchanged structure must reuse");
        assert_eq!(set_a.built_at, t.structure_version);
        assert_eq!(set_a.batched_roots(), 10);
        // a structural change (node allocation from a new observation)
        // must rebuild the set, never patch it
        let mut rng = Pcg64::seeded(8);
        t.run_program("[observe (f (vector 0.3 0.4 1.0)) true]", &mut rng)
            .unwrap();
        let p2 = t.cached_partition(w).unwrap();
        let set_c = t.cached_batch_plans(&p2);
        assert!(!Rc::ptr_eq(&set_a, &set_c), "stale set must rebuild");
        assert_eq!(set_c.built_at, t.structure_version);
        assert_ne!(set_c.built_at, set_a.built_at);
        assert_eq!(set_c.batched_roots(), 11);
    }

    #[test]
    fn int_constants_batch_when_a_real_sibling_forces_the_float_fold() {
        // (+ (dot w x) 1): the dot result is guaranteed Real, so
        // Prim::apply takes the float fold and coerces the int constant
        // through as_f64 — the f64 lowering may admit it and must stay
        // bitwise identical to the interpreter
        let src = "\
            [assume w (scope_include 'w 0 (multivariate_normal (vector 0 0) 0.5))]\n\
            [assume g (lambda (x) (normal (+ (dot w x) 1) 0.8))]\n\
            [observe (g (vector 1.0 0.5)) 0.4]\n\
            [observe (g (vector 0.3 -0.2)) 1.1]\n";
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(4);
        t.run_program(src, &mut rng).unwrap();
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let set = t.cached_batch_plans(&p);
        assert_eq!(set.batched_roots(), 2, "int-const widened shape must batch");
        let g = &set.groups[0];
        let new_w = Value::vector(vec![0.2, -0.4]);
        let mut globals = Vec::new();
        candidate_globals(&t, &p, &new_w, &mut globals).unwrap();
        let sel: Vec<(u32, u32)> = (0..g.len() as u32).map(|m| (m, m)).collect();
        let mut rf = RegFile::new();
        let mut out = Vec::new();
        rf.replay(&t, g, &sel, &globals, &mut out).unwrap();
        let roots = g.roots.clone();
        let mut interp = InterpreterEval;
        let mut t2 = t;
        let p2 = t2.cached_partition(w).unwrap();
        let want = interp.eval_sections(&mut t2, &p2, &roots, &new_w).unwrap();
        for (a, b) in out.iter().zip(&want) {
            assert_eq!(a.to_bits(), b.to_bits(), "widened int shape diverged");
        }
    }

    /// The witness rule, tested straight on `lower_cols`: an
    /// `Add`/`Mul`/`Sub` whose operands are *all* possibly-int must
    /// refuse the f64 lowering (`Prim::apply`'s int-preserving branch
    /// could fire), while one guaranteed-`Real` sibling admits the int
    /// constant through the coercing binding.
    #[test]
    fn all_int_arithmetic_refuses_to_lower() {
        use crate::trace::plan::AbsorbOp;
        let t = lr_trace(2, 14);
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let absorber = {
            let real_plan = t.cached_section_plan(&p, p.locals[0]).unwrap();
            real_plan.absorbers[0].node
        };
        let mk = |args: Vec<PlanArg>| SectionPlan {
            root: p.locals[0],
            n_slots: 1,
            ops: vec![PlanOp::Prim {
                prim: Prim::Add,
                out: 0,
                args,
            }],
            absorbers: vec![AbsorbOp {
                node: absorber,
                fam: SpFamily::Normal,
                args: vec![PlanArg::Slot(0), PlanArg::Const(Value::Real(1.0))],
            }],
            touch: vec![],
            built_at: t.structure_version,
        };
        // all-int operands: no witness, must refuse
        let all_int = mk(vec![
            PlanArg::Const(Value::Int(1)),
            PlanArg::Const(Value::Int(2)),
        ]);
        assert!(lower_cols(&t, &p, &all_int).is_none());
        // a Real sibling forces the float fold: the int is admitted
        let widened = mk(vec![
            PlanArg::Const(Value::Real(0.5)),
            PlanArg::Const(Value::Int(2)),
        ]);
        let cols = lower_cols(&t, &p, &widened).expect("witnessed int must lower");
        // two op binds (Real + widened Int) plus the absorber's Real arg
        assert_eq!(cols.n_sbind, 3);
    }

    /// The sharded kernel is the sequential kernel: any split of the
    /// packed range must reproduce the full-range replay bit-for-bit.
    #[test]
    fn packed_range_splits_are_bitwise_identical() {
        let t = lr_trace(33, 21);
        let w = t.lookup_node("w").unwrap();
        let p = t.cached_partition(w).unwrap();
        let set = t.cached_batch_plans(&p);
        let g = &set.groups[0];
        let new_w = Value::vector(vec![0.1, -0.25, 0.3]);
        let mut globals = Vec::new();
        candidate_globals(&t, &p, &new_w, &mut globals).unwrap();
        let sel: Vec<(u32, u32)> = (0..g.len() as u32).map(|m| (m, m)).collect();
        let pb = PackedBatch::pack(&t, g, &sel, &globals).unwrap();
        let n = pb.width();
        let mut sregs = Vec::new();
        let mut full = vec![0.0; n];
        pb.replay_range(0, n, &mut sregs, &mut full);
        for &shards in &[2usize, 3, 5, 7] {
            let chunk = n.div_ceil(shards);
            let mut pieced = vec![0.0; n];
            let mut lo = 0;
            while lo < n {
                let hi = (lo + chunk).min(n);
                pb.replay_range(lo, hi, &mut sregs, &mut pieced[lo..hi]);
                lo = hi;
            }
            for (i, (a, b)) in pieced.iter().zip(&full).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "shards={shards}: l[{i}] diverged"
                );
            }
        }
    }
}
