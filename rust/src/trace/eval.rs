//! The evaluator: executes expressions, building the PET.
//!
//! Pure sub-expressions are constant-folded (no node is materialized),
//! which keeps per-observation node counts at 2–4 for the paper's
//! models and lets traces with 10^6 observations fit comfortably in
//! memory.  Any expression whose value depends on a random choice gets a
//! node, so the statistical dependency graph (`E_s`) is exact.

use crate::math::Pcg64;
use crate::ppl::ast::{Directive, Expr};
use crate::ppl::env::{Binding, Env, EnvRef};
use crate::ppl::prim::Prim;
use crate::ppl::sp::{family_from_name, maker_from_name, SpState};
use crate::ppl::value::{Closure, KeyVec, Value};
use crate::trace::node::{ArgRef, EvalResult, Node, NodeId, NodeKind};
use crate::trace::pet::{CacheEntry, DirectiveRecord, Trace};
use std::rc::Rc;

/// Evaluation context: the trace being extended, the RNG driving fresh
/// stochastic choices, and the creation log used for ownership tracking
/// (if-branches, mem entries, directives each own the nodes created
/// while evaluating them).
pub struct Evaluator<'a> {
    pub trace: &'a mut Trace,
    pub rng: &'a mut Pcg64,
    /// Scoped creation log: drained into owner lists (if-branches, mem
    /// entries, directives) as evaluation unwinds.
    pub created: Vec<NodeId>,
    /// Full creation log in creation order (never drained) — the regen
    /// transaction journals these for rollback.
    pub all_created: Vec<NodeId>,
    /// Mem cache entries inserted during this evaluation.
    pub inserted_cache: Vec<(crate::ppl::value::MemId, KeyVec)>,
    /// Mem cache refcount increments made during this evaluation.
    pub ref_incs: Vec<(crate::ppl::value::MemId, KeyVec)>,
    /// When regenerating structure deterministically (gibbs final pass),
    /// stochastic draws are consumed from here instead of sampled.
    pub replay: Option<std::collections::VecDeque<Value>>,
}

impl<'a> Evaluator<'a> {
    pub fn new(trace: &'a mut Trace, rng: &'a mut Pcg64) -> Self {
        Evaluator {
            trace,
            rng,
            created: Vec::new(),
            all_created: Vec::new(),
            inserted_cache: Vec::new(),
            ref_incs: Vec::new(),
            replay: None,
        }
    }

    fn alloc(&mut self, node: Node) -> NodeId {
        let id = self.trace.alloc(node);
        self.created.push(id);
        self.all_created.push(id);
        id
    }

    /// Creation-log checkpoint; nodes created after it can be drained
    /// into an owner list with `drain_since`.
    pub fn mark(&self) -> usize {
        self.created.len()
    }

    pub fn drain_since(&mut self, mark: usize) -> Vec<NodeId> {
        self.created.split_off(mark)
    }

    /// Evaluate an expression.
    pub fn eval(&mut self, expr: &Rc<Expr>, env: &EnvRef) -> Result<EvalResult, String> {
        match &**expr {
            Expr::Const(v) => Ok(EvalResult::Static(v.clone())),
            Expr::Sym(name) => self.eval_sym(name, env),
            Expr::Lambda(params, body) => Ok(EvalResult::Static(Value::Closure(Rc::new(
                Closure {
                    params: params.clone(),
                    body: body.clone(),
                    env: env.clone(),
                },
            )))),
            Expr::Let(binds, body) => {
                let child = Env::child(env);
                for (name, e) in binds {
                    let r = self.eval(e, &child)?;
                    child.define(name.clone(), result_binding(&r));
                }
                self.eval(body, &child)
            }
            Expr::Mem(inner) => {
                let r = self.eval(inner, env)?;
                match r {
                    EvalResult::Static(Value::Closure(c)) => {
                        let id = self.trace.push_mem(c);
                        Ok(EvalResult::Static(Value::Mem(id)))
                    }
                    _ => Err("mem: operand must be a (static) lambda".into()),
                }
            }
            Expr::ScopeInclude(scope_e, block_e, body) => {
                let scope = match self.eval(scope_e, env)? {
                    EvalResult::Static(Value::Sym(s)) => s,
                    r => return Err(format!("scope_include: scope must be a symbol, got {r:?}")),
                };
                let block = match self.eval(block_e, env)? {
                    EvalResult::Static(v) => v,
                    EvalResult::Node(id) => self.trace.value(id).clone(),
                };
                let r = self.eval(body, env)?;
                if let Some(principal) = self.trace.principal_node(&r) {
                    self.trace.register_scope(scope, block, principal);
                }
                Ok(r)
            }
            Expr::If(pred_e, conseq, alt) => {
                let pred = self.eval(pred_e, env)?;
                match pred {
                    EvalResult::Static(v) => {
                        let b = v.as_bool().ok_or("if: predicate must be bool")?;
                        self.eval(if b { conseq } else { alt }, env)
                    }
                    EvalResult::Node(pred_id) => {
                        let b = self
                            .trace
                            .value(pred_id)
                            .as_bool()
                            .ok_or("if: predicate must be bool")?;
                        let mark = self.mark();
                        let branch = self.eval(if b { conseq } else { alt }, env)?;
                        let owned = self.drain_since(mark);
                        let value = self.trace.result_value(&branch);
                        let id = self.alloc(Node::new(
                            NodeKind::If {
                                expr: expr.clone(),
                                env: env.clone(),
                                take_conseq: b,
                                branch,
                                owned,
                            },
                            value,
                            vec![ArgRef::Node(pred_id)],
                        ));
                        Ok(EvalResult::Node(id))
                    }
                }
            }
            Expr::App(parts) => self.eval_app(parts, env),
        }
    }

    fn eval_sym(&mut self, name: &str, env: &EnvRef) -> Result<EvalResult, String> {
        if let Some(b) = env.lookup(name) {
            return Ok(binding_result(b));
        }
        builtin(name)
            .map(EvalResult::Static)
            .ok_or_else(|| format!("unbound symbol: {name}"))
    }

    fn eval_app(&mut self, parts: &[Rc<Expr>], env: &EnvRef) -> Result<EvalResult, String> {
        // evaluate operator; locals shadow globals, so check env first
        let op = match &*parts[0] {
            Expr::Sym(name) => match env.lookup(name) {
                Some(b) => binding_result(b),
                None => builtin(name)
                    .map(EvalResult::Static)
                    .ok_or_else(|| format!("unbound operator: {name}"))?,
            },
            _ => self.eval_expr_in(&parts[0], env)?,
        };
        // evaluate operands
        let mut args: Vec<EvalResult> = Vec::with_capacity(parts.len() - 1);
        for p in &parts[1..] {
            args.push(self.eval_expr_in(p, env)?);
        }
        self.apply(op, args)
    }

    /// Evaluate an operand (symbols resolve through the *local* env).
    fn eval_expr_in(&mut self, expr: &Rc<Expr>, env: &EnvRef) -> Result<EvalResult, String> {
        if let Expr::Sym(name) = &**expr {
            if let Some(b) = env.lookup(name) {
                return Ok(binding_result(b));
            }
            return builtin(name)
                .map(EvalResult::Static)
                .ok_or_else(|| format!("unbound symbol: {name}"));
        }
        self.eval(expr, env)
    }

    /// Apply an operator result to operand results.
    pub fn apply(
        &mut self,
        op: EvalResult,
        args: Vec<EvalResult>,
    ) -> Result<EvalResult, String> {
        match op {
            EvalResult::Static(Value::Prim(p)) => self.apply_prim(p, args),
            EvalResult::Static(Value::Closure(c)) => self.apply_closure(&c, args),
            EvalResult::Static(Value::SpFam(f)) => {
                let arg_refs: Vec<ArgRef> = args.iter().map(|a| a.as_argref()).collect();
                let vals = self.trace.arg_values(&arg_refs);
                let value = self.draw(|ev| f.sample(ev.rng, &vals))?;
                let id = self.alloc(Node::new(NodeKind::StochFam(f), value, arg_refs));
                Ok(EvalResult::Node(id))
            }
            EvalResult::Static(Value::MakerFam(mf)) => {
                let arg_refs: Vec<ArgRef> = args.iter().map(|a| a.as_argref()).collect();
                let vals = self.trace.arg_values(&arg_refs);
                let sp = self.trace.push_sp(SpState::make(mf, &vals)?);
                if arg_refs.iter().all(|a| matches!(a, ArgRef::Const(_))) {
                    // params can never change: no node needed
                    return Ok(EvalResult::Static(Value::Sp(sp)));
                }
                let id = self.alloc(Node::new(
                    NodeKind::Maker { family: mf, sp },
                    Value::Sp(sp),
                    arg_refs,
                ));
                Ok(EvalResult::Node(id))
            }
            EvalResult::Static(Value::Sp(sp)) => {
                let arg_refs: Vec<ArgRef> = args.iter().map(|a| a.as_argref()).collect();
                let vals = self.trace.arg_values(&arg_refs);
                let value = self.draw(|ev| ev.trace.sp(sp).sample(ev.rng, &vals))?;
                self.trace.sp_mut(sp).incorporate(&value);
                let id = self.alloc(Node::new(NodeKind::StochInst { sp }, value, arg_refs));
                Ok(EvalResult::Node(id))
            }
            EvalResult::Static(Value::Mem(mem)) => self.apply_mem(mem, args),
            EvalResult::Node(op_id) => {
                // dynamic operator: must be an SP instance value
                match self.trace.value(op_id).clone() {
                    Value::Sp(sp) => {
                        let arg_refs: Vec<ArgRef> = args.iter().map(|a| a.as_argref()).collect();
                        let vals = self.trace.arg_values(&arg_refs);
                        let value = self.draw(|ev| ev.trace.sp(sp).sample(ev.rng, &vals))?;
                        self.trace.sp_mut(sp).incorporate(&value);
                        let id = self.alloc(Node::new(
                            NodeKind::StochDyn { op: op_id },
                            value,
                            arg_refs,
                        ));
                        Ok(EvalResult::Node(id))
                    }
                    Value::Mem(mem) => self.apply_mem(mem, args),
                    v => Err(format!(
                        "dynamic application of a {} is not supported",
                        v.type_name()
                    )),
                }
            }
            EvalResult::Static(v) => Err(format!("cannot apply a {}", v.type_name())),
        }
    }

    fn apply_prim(&mut self, p: Prim, args: Vec<EvalResult>) -> Result<EvalResult, String> {
        let arg_refs: Vec<ArgRef> = args.iter().map(|a| a.as_argref()).collect();
        if arg_refs.iter().all(|a| matches!(a, ArgRef::Const(_))) {
            // constant folding
            let vals = self.trace.arg_values(&arg_refs);
            return Ok(EvalResult::Static(p.apply(&vals)?));
        }
        let vals = self.trace.arg_values(&arg_refs);
        let value = p.apply(&vals)?;
        let id = self.alloc(Node::new(NodeKind::Det(p), value, arg_refs));
        Ok(EvalResult::Node(id))
    }

    fn apply_closure(
        &mut self,
        c: &Rc<Closure>,
        args: Vec<EvalResult>,
    ) -> Result<EvalResult, String> {
        if c.params.len() != args.len() {
            return Err(format!(
                "closure expects {} args, got {}",
                c.params.len(),
                args.len()
            ));
        }
        let child = Env::child(&c.env);
        for (param, arg) in c.params.iter().zip(&args) {
            child.define(param.clone(), result_binding(arg));
        }
        self.eval(&c.body, &child)
    }

    /// Memoized application: route through the cache, creating the target
    /// on first use.  A `MemApp` node is materialized only when the key
    /// depends on random choices (e.g. `(w (z i))`).
    fn apply_mem(
        &mut self,
        mem: crate::ppl::value::MemId,
        args: Vec<EvalResult>,
    ) -> Result<EvalResult, String> {
        let arg_refs: Vec<ArgRef> = args.iter().map(|a| a.as_argref()).collect();
        let key = KeyVec(self.trace.arg_values(&arg_refs));
        let dynamic_key = arg_refs.iter().any(|a| matches!(a, ArgRef::Node(_)));
        let target = self.mem_lookup_or_eval(mem, &key)?;
        if dynamic_key {
            // refcount the route and materialize a MemApp node
            self.trace
                .mem_mut(mem)
                .cache
                .get_mut(&key)
                .expect("entry just ensured")
                .refcount += 1;
            self.ref_incs.push((mem, key.clone()));
            let value = self.trace.result_value(&target);
            let id = self.alloc(Node::new(
                NodeKind::MemApp {
                    mem,
                    key,
                    target,
                },
                value,
                arg_refs,
            ));
            Ok(EvalResult::Node(id))
        } else {
            // static key: the route can never change; share the target
            Ok(target)
        }
    }

    /// Ensure a mem cache entry exists for `key`, evaluating the body on
    /// a miss, and return its target.
    pub fn mem_lookup_or_eval(
        &mut self,
        mem: crate::ppl::value::MemId,
        key: &KeyVec,
    ) -> Result<EvalResult, String> {
        if let Some(e) = self.trace.mem(mem).cache.get(key) {
            return Ok(e.target.clone());
        }
        let closure = self.trace.mem(mem).closure.clone();
        if closure.params.len() != key.0.len() {
            return Err(format!(
                "mem proc expects {} args, got {}",
                closure.params.len(),
                key.0.len()
            ));
        }
        let child = Env::child(&closure.env);
        for (param, v) in closure.params.iter().zip(&key.0) {
            // bind params to the key VALUES so the cached subtrace does
            // not depend on whichever node supplied the key
            child.define(param.clone(), Binding::Static(v.clone()));
        }
        let mark = self.mark();
        let target = self.eval(&closure.body, &child)?;
        let owned = self.drain_since(mark);
        self.trace.mem_mut(mem).cache.insert(
            key.clone(),
            CacheEntry {
                target: target.clone(),
                refcount: 0,
                owned,
            },
        );
        self.inserted_cache.push((mem, key.clone()));
        Ok(target)
    }

    /// Draw a stochastic value: from the replay queue if present, else by
    /// sampling.
    fn draw(
        &mut self,
        sample: impl FnOnce(&mut Self) -> Result<Value, String>,
    ) -> Result<Value, String> {
        if let Some(q) = &mut self.replay {
            if let Some(v) = q.pop_front() {
                return Ok(v);
            }
        }
        sample(self)
    }
}

fn result_binding(r: &EvalResult) -> Binding {
    match r {
        EvalResult::Static(v) => Binding::Static(v.clone()),
        EvalResult::Node(id) => Binding::Node(*id),
    }
}

fn binding_result(b: Binding) -> EvalResult {
    match b {
        Binding::Static(v) => EvalResult::Static(v),
        Binding::Node(id) => EvalResult::Node(id),
    }
}

/// Resolve builtin names: primitives, SP families, makers.
fn builtin(name: &str) -> Option<Value> {
    if let Some(p) = Prim::from_name(name) {
        return Some(Value::Prim(p));
    }
    if let Some(f) = family_from_name(name) {
        return Some(Value::SpFam(f));
    }
    if let Some(m) = maker_from_name(name) {
        return Some(Value::MakerFam(m));
    }
    None
}

/// Execute a directive against a trace.
pub fn execute_directive(
    trace: &mut Trace,
    d: &Directive,
    rng: &mut Pcg64,
) -> Result<EvalResult, String> {
    let mut ev = Evaluator::new(trace, rng);
    let (result, owned) = match d {
        Directive::Assume(name, expr) => {
            let env = ev.trace.global_env.clone();
            let r = ev.eval(expr, &env)?;
            let owned = std::mem::take(&mut ev.created);
            ev.trace
                .global_env
                .define(name.clone(), result_binding(&r));
            (r, owned)
        }
        Directive::Observe(expr, value) => {
            let env = ev.trace.global_env.clone();
            let r = ev.eval(expr, &env)?;
            let owned = std::mem::take(&mut ev.created);
            ev.trace.constrain(&r, value.clone())?;
            (r, owned)
        }
        Directive::Predict(expr) => {
            let env = ev.trace.global_env.clone();
            let r = ev.eval(expr, &env)?;
            let owned = std::mem::take(&mut ev.created);
            (r, owned)
        }
    };
    trace.records.push(DirectiveRecord {
        directive: d.clone(),
        result: result.clone(),
        owned,
    });
    Ok(result)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(src: &str, seed: u64) -> (Trace, Pcg64) {
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(seed);
        t.run_program(src, &mut rng).unwrap();
        (t, rng)
    }

    #[test]
    fn constant_folding_makes_no_nodes() {
        let (t, _) = run("[assume a (+ 1 2 (* 3 4))]", 0);
        assert_eq!(t.num_live_nodes(), 0);
        let mut t = t;
        assert!(matches!(t.lookup_value("a"), Some(Value::Int(15))));
    }

    #[test]
    fn stochastic_nodes_materialize() {
        let (t, _) = run("[assume x (normal 0 1)] [assume y (+ x 1)]", 1);
        assert_eq!(t.num_live_nodes(), 2); // x node + det node
        let mut t = t;
        let x = t.lookup_value("x").unwrap().as_f64().unwrap();
        let y = t.lookup_value("y").unwrap().as_f64().unwrap();
        assert!((y - (x + 1.0)).abs() < 1e-12);
    }

    #[test]
    fn fig1_program_builds_expected_pet() {
        let (t, _) = run(
            r#"
            [assume b (bernoulli 0.5)]
            [assume mu (if b 1 (gamma 1 1))]
            [assume y (normal mu 0.1)]
            [observe y 10.0]
            "#,
            7,
        );
        let mut t = t;
        // y observed
        let y = t.lookup_node("y").unwrap();
        assert!(t.node(y).observed);
        assert!((t.value(y).as_f64().unwrap() - 10.0).abs() < 1e-12);
        // mu is an If node whose branch matches b
        let b = t.lookup_value("b").unwrap().as_bool().unwrap();
        let mu = t.lookup_node("mu").unwrap();
        match &t.node(mu).kind {
            NodeKind::If {
                take_conseq, owned, ..
            } => {
                assert_eq!(*take_conseq, b);
                if b {
                    assert!(owned.is_empty()); // constant branch
                } else {
                    assert_eq!(owned.len(), 1); // the gamma node
                }
            }
            k => panic!("mu should be If, got {k:?}"),
        }
    }

    #[test]
    fn closure_and_let() {
        let (t, _) = run(
            r#"
            [assume f (lambda (a b) (+ a (* 2 b)))]
            [assume r (let ((u 3)) (f u 4))]
            "#,
            2,
        );
        let mut t = t;
        assert!(matches!(t.lookup_value("r"), Some(Value::Int(11))));
    }

    #[test]
    fn observe_constrains_and_scores() {
        let (t, _) = run(
            "[assume m (normal 0 1)] [observe (normal m 0.5) 2.0]",
            3,
        );
        let mut t = t;
        let m = t.lookup_value("m").unwrap().as_f64().unwrap();
        let want = crate::dist::normal_logpdf(m, 0.0, 1.0)
            + crate::dist::normal_logpdf(2.0, m, 0.5);
        let got = t.log_joint();
        assert!((got - want).abs() < 1e-10, "{got} vs {want}");
    }

    #[test]
    fn mem_static_keys_share_nodes() {
        let (t, _) = run(
            r#"
            [assume h (mem (lambda (t) (normal t 1)))]
            [assume a (h 3)]
            [assume b (h 3)]
            [assume c (h 4)]
            "#,
            4,
        );
        let mut t = t;
        // (h 3) shared: a and b are the same node
        assert_eq!(t.lookup_node("a"), t.lookup_node("b"));
        assert_ne!(t.lookup_node("a"), t.lookup_node("c"));
        let a = t.lookup_value("a").unwrap().as_f64().unwrap();
        let b = t.lookup_value("b").unwrap().as_f64().unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn mem_recursion_builds_chain() {
        let (t, _) = run(
            r#"
            [assume h (mem (lambda (t) (if (<= t 0) 0 (normal (* 0.9 (h (- t 1))) 1))))]
            [assume h5 (h 5)]
            "#,
            5,
        );
        // 5 stochastic h nodes + 5 multiply nodes... (h 0) is static 0 so
        // (* 0.9 (h 0)) folds; h1's normal arg is Const. So 5 stoch + 4 det.
        assert_eq!(t.num_live_nodes(), 9);
    }

    #[test]
    fn crp_maker_and_applications() {
        let (t, _) = run(
            r#"
            [assume alpha (gamma 1 1)]
            [assume crp (make_crp alpha)]
            [assume z (mem (lambda (i) ((lambda () (crp)))))]
            [assume z0 (z 0)]
            [assume z1 (z 1)]
            [assume z2 (z 2)]
            "#,
            6,
        );
        let mut t = t;
        // all tables are small ints; counts incorporated
        let z0 = t.lookup_value("z0").unwrap().as_int().unwrap();
        let sp = match t.lookup_value("crp").unwrap() {
            Value::Sp(id) => id,
            v => panic!("{v}"),
        };
        let aux = t.sp(sp).crp_aux().unwrap();
        assert_eq!(aux.n(), 3);
        assert!(aux.count(z0) >= 1);
    }

    #[test]
    fn dynamic_mem_key_makes_memapp() {
        let (t, _) = run(
            r#"
            [assume z (bernoulli 0.5)]
            [assume w (mem (lambda (k) (normal 0 1)))]
            [assume wz (w z)]
            "#,
            8,
        );
        let mut t = t;
        let wz = t.lookup_node("wz").unwrap();
        assert!(matches!(t.node(wz).kind, NodeKind::MemApp { .. }));
        // value mirrors the routed target
        let v = t.fresh_value(wz).as_f64().unwrap();
        assert!(v.is_finite());
    }

    #[test]
    fn scope_registration() {
        let (t, _) = run(
            r#"
            [assume w (scope_include 'w 0 (normal 0 1))]
            [assume h (mem (lambda (i) (scope_include 'h i (normal 0 1))))]
            [assume a (h 1)]
            [assume b (h 2)]
            "#,
            9,
        );
        assert_eq!(t.scope_nodes("w").len(), 1);
        assert_eq!(t.scope_nodes("h").len(), 2);
        let s = t.scope("h").unwrap();
        assert_eq!(s.live_blocks().len(), 2);
    }

    #[test]
    fn unbound_symbol_errors() {
        let mut t = Trace::new();
        let mut rng = Pcg64::seeded(0);
        assert!(t.run_program("[assume x (nope 1)]", &mut rng).is_err());
        assert!(t.run_program("[assume x missing]", &mut rng).is_err());
    }

    #[test]
    fn logistic_regression_obs_has_two_nodes_each() {
        let src = r#"
            [assume w (scope_include 'w 0 (multivariate_normal (vector 0 0) 0.1))]
            [assume y (lambda (x) (bernoulli (linear_logistic w x)))]
            [observe (y (vector 1.0 2.0)) true]
            [observe (y (vector -1.0 0.5)) false]
        "#;
        let (t, _) = run(src, 10);
        // nodes: w + per-obs (linlog det + bernoulli)
        assert_eq!(t.num_live_nodes(), 1 + 2 * 2);
        let mut t = t;
        let w_node = t.lookup_node("w").unwrap();
        assert_eq!(t.node(w_node).children.len(), 2);
        let lj = t.log_joint();
        assert!(lj.is_finite());
    }
}
